//! Quickstart: synthesize a mixed offline workload, schedule it with
//! BlendServe, and compare against the strongest baseline (NanoFlow-DFS).
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```

use blendserve::baselines;
use blendserve::config::presets;
use blendserve::perfmodel::PerfModel;
use blendserve::scheduler::run_system;
use blendserve::trace::synth::{achieved, synthesize, SynthSpec};
use blendserve::trace::TraceKind;
use blendserve::util::Table;

fn main() {
    // 1. A Table-2-style workload: compute density 1.1, 25% prefix sharing,
    //    mixed from BurstGPT + OpenVid + MMLU.
    let pm = PerfModel::new(presets::llama3_8b(), presets::a100_80gb(), 1);
    let spec = SynthSpec::new(TraceKind::BurstGpt, 1.1, 0.25, 4000);
    let workload = synthesize(&spec, &pm);
    let (rho, s) = achieved(&workload, &pm);
    println!(
        "workload: {} requests, {:.1}M tokens, density {:.2}, sharing {:.2}\n",
        workload.len(),
        workload.total_tokens() as f64 / 1e6,
        rho,
        s
    );

    // 2. Run BlendServe and the baselines on the simulated A100 backend.
    let mut table = Table::new(
        "Offline throughput, Llama-3-8B on 1x A100 (simulated)",
        &["system", "tokens/s", "vs NanoFlow-DFS", "sharing", "% of optimal"],
    );
    let nano = run_system(&baselines::nanoflow_dfs(), &workload);
    for (name, cfg) in baselines::all_systems() {
        let out = run_system(&cfg, &workload);
        table.row(&[
            name.to_string(),
            format!("{:.0}", out.result.throughput),
            format!("{:.2}x", out.result.throughput / nano.result.throughput),
            format!("{:.3}", out.result.sharing_achieved),
            format!("{:.1}%", out.optimal_fraction * 100.0),
        ]);
    }
    println!("{}", table.to_text());
    println!("(optimal = practical upper bound T_o with interference; §6.2)");
}
