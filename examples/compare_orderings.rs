//! Fig. 3 in miniature: how request ordering shapes per-step resource
//! balance.  A workload with compute-intensive requests (BurstGPT) in
//! front and memory-intensive (OpenVid) behind is served with DFS order
//! (NanoFlow-DFS: sequential imbalance), random order (NanoFlow-Balance)
//! and BlendServe's dual scanner.
//!
//! ```bash
//! cargo run --release --example compare_orderings
//! ```

use blendserve::baselines;
use blendserve::config::presets;
use blendserve::perfmodel::PerfModel;
use blendserve::scheduler::run_system;
use blendserve::trace::generators::generate_kind;
use blendserve::trace::{TraceKind, Workload};
use blendserve::util::Table;

fn main() {
    let pm = PerfModel::new(presets::llama3_8b(), presets::a100_80gb(), 1);
    let burst = generate_kind(TraceKind::BurstGpt, 3000, 1);
    let vid = generate_kind(TraceKind::OpenVid, 40, 2);
    let workload = Workload::concat("burst-then-vid", &[&burst, &vid]);
    let _ = pm;

    println!(
        "workload: {} compute-intensive then {} memory-intensive requests\n",
        burst.len(),
        vid.len()
    );

    for (name, cfg) in [
        ("NanoFlow-DFS", baselines::nanoflow_dfs()),
        ("NanoFlow-Balance", baselines::nanoflow_balance()),
        ("BlendServe", baselines::blendserve()),
    ] {
        let out = run_system(&cfg, &workload);
        let mut table = Table::new(
            &format!(
                "{name}: per-step compute vs memory time (downsampled; total {:.0}s, {:.0} tok/s)",
                out.result.total_time, out.result.throughput
            ),
            &["step", "t_comp (ms)", "t_mem (ms)", "balance c/(c+m)"],
        );
        for s in out.result.downsampled(12) {
            let bal = if s.t_comp + s.t_mem > 0.0 {
                s.t_comp / (s.t_comp + s.t_mem)
            } else {
                0.0
            };
            table.row(&[
                s.step.to_string(),
                format!("{:.2}", s.t_comp * 1e3),
                format!("{:.2}", s.t_mem * 1e3),
                format!("{:.2}", bal),
            ]);
        }
        println!("{}", table.to_text());
    }
    println!(
        "Expected shape (paper Fig. 3): DFS runs compute-only then memory-only;\n\
         BlendServe holds balance ~constant across steps."
    );
}
