//! Data-parallel deployment (§5.5 / Table 3): decompose the resource-aware
//! prefix tree into balanced subtrees and serve them on DP replicas.
//!
//! ```bash
//! cargo run --release --example dp_serving
//! ```

use blendserve::baselines;
use blendserve::config::presets;
use blendserve::perfmodel::PerfModel;
use blendserve::server::serve_batch;
use blendserve::trace::synth::{synthesize, SynthSpec};
use blendserve::trace::TraceKind;
use blendserve::util::Table;

fn main() {
    let pm = PerfModel::new(presets::llama3_8b(), presets::a100_80gb(), 1);
    let workload = synthesize(&SynthSpec::new(TraceKind::BurstGpt, 1.1, 0.25, 6000), &pm);
    println!("workload: {} requests, {:.1}M tokens\n", workload.len(),
             workload.total_tokens() as f64 / 1e6);

    let mut table = Table::new(
        "BlendServe DP scalability, Llama-3-8B (simulated A100s)",
        &["DP", "throughput tok/s", "scaling", "makespan s", "replica imbalance"],
    );
    let mut base_tput = 0.0;
    for dp in [1usize, 2, 4] {
        let mut cfg = baselines::blendserve();
        cfg.scheduler.sample_prob = 0.05;
        cfg.dp_replicas = dp;
        let job = serve_batch(&cfg, &workload);
        if dp == 1 {
            base_tput = job.total_throughput;
        }
        let times: Vec<f64> = job.per_replica.iter().map(|o| o.result.total_time).collect();
        let mean = times.iter().sum::<f64>() / times.len() as f64;
        let imb = job.makespan / mean.max(1e-9);
        table.row(&[
            dp.to_string(),
            format!("{:.0}", job.total_throughput),
            format!("{:.2}x", job.total_throughput / base_tput),
            format!("{:.0}", job.makespan),
            format!("{:.2}", imb),
        ]);
    }
    println!("{}", table.to_text());
    println!("(paper Table 3: 1.85x-1.93x at DP=2, 3.78x-3.88x at DP=4)");
}
