//! End-to-end driver on the REAL model: load the AOT-compiled 3.4M-param
//! Llama-style model through PJRT and serve a synthesized multi-trace
//! workload with blended (prefill+decode) steps and real prefix-KV reuse.
//!
//! Proves all three layers compose: rust coordinator (L3) → jax model HLO
//! (L2) → pallas blended-attention kernel (L1), python never on the
//! request path.
//!
//! ```bash
//! make artifacts && cargo run --release --example serve_real_model
//! ```

use blendserve::config::presets;
use blendserve::perfmodel::PerfModel;
use blendserve::runtime::serve::zipper_order;
use blendserve::runtime::{artifacts_available, default_artifact_dir, RealServer};
use blendserve::trace::generators::{self, TraceSpec};
use blendserve::trace::Workload;
use blendserve::tree::PrefixTree;
use blendserve::util::Table;

fn scaled_workload(n_per_trace: usize) -> Workload {
    // Shrink the paper traces to the tiny model's 256-token context:
    // same structure (system prompts, MMLU subject stems, long-output
    // video requests), ~1/20 the lengths.
    let mk = |spec: TraceSpec, n: usize, seed: u64| {
        let mut s = spec.scaled(0.05);
        s.max_output = s.max_output.min(100);
        s.max_input = s.max_input.min(120);
        s.min_output = s.min_output.min(s.max_output);
        s.min_input = s.min_input.min(s.max_input);
        s.output_mean = s.output_mean.min(s.max_output as f64);
        s.input_mean = s.input_mean.min(s.max_input as f64);
        generators::generate(&s, n, seed)
    };
    let burst = mk(generators::burstgpt(), n_per_trace, 11);
    let mmlu = mk(generators::mmlu(), n_per_trace, 12);
    let vid = mk(generators::openvid(), n_per_trace / 4, 13);
    let all = Workload::concat("real-mix", &[&burst, &mmlu, &vid]);
    generators::remap_vocab(&all, 2048)
}

fn main() -> anyhow::Result<()> {
    let dir = default_artifact_dir();
    if !artifacts_available(&dir) {
        eprintln!("artifacts missing — run `make artifacts` first");
        std::process::exit(1);
    }

    let workload = scaled_workload(60);
    println!(
        "workload: {} requests, {} prompt tokens, {} output tokens",
        workload.len(),
        workload.total_input_tokens(),
        workload.total_output_tokens()
    );

    // BlendServe preprocessing on the real pool: tree, estimates, sort.
    let pm = PerfModel::new(presets::tiny_cpu(), presets::cpu_host(), 1);
    let mut tree = PrefixTree::build(&workload);
    tree.sample_outputs(0.05, 7);
    let stats = tree.transform(&pm, 0.99);
    println!(
        "tree: {} nodes, sharing {:.3} -> {:.3} after {} splits",
        tree.nodes.len(),
        stats.sharing_before,
        stats.sharing_after,
        stats.splits
    );

    let mut table = Table::new(
        "Real-model serving (CPU PJRT, 3.4M-param Llama-style, blended steps)",
        &["order", "tok/s", "steps", "blended", "hit ratio", "exec s", "wall s"],
    );
    for (name, order) in [
        ("BlendServe (zipper)", zipper_order(&tree)),
        ("DFS", tree.dfs_requests()),
        ("FCFS", (0..workload.len() as u32).collect::<Vec<u32>>()),
    ] {
        let mut server = RealServer::load(&dir)?;
        let rep = server.serve(&workload, &order)?;
        table.row(&[
            name.to_string(),
            format!("{:.0}", rep.throughput),
            rep.steps.to_string(),
            rep.blended_steps.to_string(),
            format!("{:.3}", rep.hit_ratio),
            format!("{:.1}", rep.exec_seconds),
            format!("{:.1}", rep.wall_seconds),
        ]);
    }
    println!("{}", table.to_text());
    table.save(std::path::Path::new("results"), "real_model_e2e")?;
    println!("saved to results/real_model_e2e.{{txt,csv}}");
    Ok(())
}
