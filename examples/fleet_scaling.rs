//! Work-stealing fleet vs static §5.5 fork-join across DP sizes, on an
//! adversarially skewed trace (sparse §5.1 sampling under-estimates a
//! third of the prompt groups by ~3x, so the est-balanced static partition
//! strands one replica with a multiple of its target).
//!
//! ```bash
//! cargo run --release --example fleet_scaling
//! ```

use blendserve::baselines;
use blendserve::server::serve_fleet;
use blendserve::trace::synth::adversarial_skew;
use blendserve::util::Table;

fn main() {
    let workload = adversarial_skew(32, 16, 10);
    println!(
        "workload: {} requests, {:.2}M tokens (1/3 of groups ~3x under-estimated)\n",
        workload.len(),
        workload.total_tokens() as f64 / 1e6
    );

    let mut table = Table::new(
        "Work-stealing fleet vs static fork-join, Llama-3-8B (simulated, KV-constrained)",
        &[
            "DP",
            "static makespan s",
            "stealing makespan s",
            "speedup",
            "steals",
            "mean idle",
            "sharing (steal/static)",
        ],
    );
    for dp in [1usize, 2, 4] {
        let mut cfg = baselines::blendserve();
        cfg.hardware.memory_bytes = 20.5e9; // KV-constrained regime
        cfg.scheduler.sample_prob = 0.02; // sparse sampling: noisy estimates
        cfg.dp_replicas = dp;
        let rep = serve_fleet(&cfg, &workload);
        table.row(&[
            dp.to_string(),
            format!("{:.1}", rep.static_makespan),
            format!("{:.1}", rep.makespan),
            format!("{:.2}x", rep.speedup_vs_static),
            rep.steals.to_string(),
            format!("{:.1}%", rep.mean_idle_frac * 100.0),
            format!("{:.3}/{:.3}", rep.sharing_achieved, rep.static_sharing),
        ]);
    }
    println!("{}", table.to_text());
    println!(
        "(dp=1 has no one to steal from: speedup 1.0 by construction; at \
         higher DP the static fork-join waits on whichever shard drew the \
         under-estimated groups, and stealing reclaims that idle capacity)"
    );
}
