//! Online/offline co-located serving (DESIGN.md §Co-located-Serving):
//! sweep the online arrival rate and watch the elastic admitter trade
//! offline goodput for online SLO attainment.
//!
//! At `online_rate = 0` the co-located path must reproduce pure-offline
//! BlendServe throughput within 1% (it is in fact bit-identical); as the
//! rate rises, offline goodput degrades gracefully while TTFT/TPOT SLOs
//! hold.
//!
//! ```bash
//! cargo run --release --example colocated_serving
//! ```

use blendserve::baselines;
use blendserve::config::presets;
use blendserve::perfmodel::PerfModel;
use blendserve::scheduler::run_system;
use blendserve::server::{online_stream, serve_colocated};
use blendserve::trace::synth::{synthesize, SynthSpec};
use blendserve::trace::TraceKind;
use blendserve::util::Table;

fn main() {
    let pm = PerfModel::new(presets::llama3_8b(), presets::a100_80gb(), 1);
    let offline = synthesize(&SynthSpec::new(TraceKind::BurstGpt, 1.1, 0.25, 4000), &pm);
    println!(
        "offline pool: {} requests, {:.1}M tokens",
        offline.len(),
        offline.total_tokens() as f64 / 1e6
    );

    // Reference: pure-offline BlendServe through the standard runner.
    let pure = run_system(&baselines::blendserve(), &offline);
    println!(
        "pure offline BlendServe: {:.0} tok/s over {:.1}s\n",
        pure.result.throughput, pure.result.total_time
    );

    let mut table = Table::new(
        "Elastic co-location: online load vs offline goodput (Llama-3-8B, 1x A100, simulated)",
        &[
            "online req/s",
            "n online",
            "SLO attain",
            "TTFT mean",
            "TTFT p99",
            "queueing",
            "offline tok/s",
            "vs pure offline",
            "retractions",
        ],
    );

    for rate in [0.0, 1.0, 2.0, 4.0, 8.0, 16.0] {
        let mut cfg = baselines::blendserve();
        cfg.colocate.online_rate = rate;
        // ~30 s of live chat traffic at each rate.
        let n_online = (rate * 30.0) as usize;
        let online = online_stream(&cfg, TraceKind::ShareGpt, n_online, 7);
        let rep = serve_colocated(&cfg, &offline, &online);
        let vs_pure = rep.offline_throughput / pure.result.throughput;
        table.row(&[
            format!("{rate:.0}"),
            rep.n_online.to_string(),
            format!("{:.1}%", rep.slo_attainment * 100.0),
            format!("{:.0}ms", rep.mean_ttft * 1e3),
            format!("{:.0}ms", rep.p99_ttft * 1e3),
            format!("{:.0}ms", rep.mean_queue_delay * 1e3),
            format!("{:.0}", rep.offline_throughput),
            format!("{:.1}%", vs_pure * 100.0),
            rep.result.retractions.to_string(),
        ]);
        if rate == 0.0 {
            assert!(
                (vs_pure - 1.0).abs() < 0.01,
                "rate-0 co-location drifted from pure offline: {vs_pure}"
            );
        }
    }
    println!("{}", table.to_text());
    println!(
        "(SLOs: HyGen-style, {}x the loaded-step baseline; policy: {}; \
         reserve {:.0}% of KV)",
        baselines::blendserve().colocate.slo_scale,
        baselines::blendserve().colocate.policy,
        baselines::blendserve().colocate.online_reserve * 100.0
    );
}
