//! Multi-modal serving (DESIGN.md §10): modality-aware vs modality-blind
//! BlendServe on the canonical mixed image-chat + video-gen + text
//! workload, plus an embedding-dedup demonstration.
//!
//! Three comparisons:
//! 1. blind vs aware ordering under memory pressure — the encoder term
//!    in scheduling densities buys simulated throughput;
//! 2. encoder overlap — how much of the vision-encoder time hides in the
//!    compute headroom of memory-bound decode steps;
//! 3. duplicate attachments — a popular-image trace shows the
//!    `EncoderCache` deduplicating encoder passes.
//!
//! ```bash
//! cargo run --release --example multimodal_serving
//! ```

use blendserve::baselines;
use blendserve::scheduler::run_system;
use blendserve::trace::generators::generate_vision_arena;
use blendserve::trace::synth::mixed_modal;
use blendserve::util::Table;

fn main() {
    // Reduced HBM: the regime where density mispricing costs retraction
    // churn (same trick as the kv example).
    let mut cfg = baselines::blendserve();
    cfg.hardware.memory_bytes = 40e9;

    let w = mixed_modal(680, 300, 300, 0.4, 7);
    println!(
        "mixed-modal pool: {} requests ({} with media, {:.1}M text tokens, {:.1}M encoder tokens)\n",
        w.len(),
        w.requests.iter().filter(|r| !r.modality.is_empty()).count(),
        w.total_tokens() as f64 / 1e6,
        w.total_encoder_tokens() as f64 / 1e6,
    );

    let mut table = Table::new(
        "Modality-aware vs blind BlendServe (Llama-3-8B + 2B vision tower, 40 GB A100, simulated)",
        &[
            "schedule",
            "makespan (s)",
            "tok/s",
            "retractions",
            "encode (s)",
            "overlap",
            "embed hits",
        ],
    );
    let mut blind_time = 0.0;
    let mut aware_time = 0.0;
    for aware in [false, true] {
        cfg.modality.enabled = aware;
        let out = run_system(&cfg, &w);
        let r = &out.result;
        if aware {
            aware_time = r.total_time;
        } else {
            blind_time = r.total_time;
        }
        table.row(&[
            if aware { "aware" } else { "blind" }.to_string(),
            format!("{:.1}", r.total_time),
            format!("{:.0}", r.throughput),
            format!("{}", r.retractions),
            format!("{:.1}", r.encode_time),
            format!("{:.2}", r.encode_overlap_frac),
            format!("{}", r.embed_cache_hit_tokens),
        ]);
    }
    println!("{}", table.to_text());
    println!("modality-aware speedup: {:.3}x\n", blind_time / aware_time);

    // Dedup in isolation: the same image-chat trace with every image
    // unique vs 60% popular-pool duplicates.
    println!("embedding dedup (image chat, 400 requests):");
    for (label, dup) in [("unique images", 0.0), ("60% popular", 0.6)] {
        let w = generate_vision_arena(400, 11, dup);
        cfg.modality.enabled = true;
        let out = run_system(&cfg, &w);
        let r = &out.result;
        println!(
            "  {label:<14} encode {:>6.2}s | embed hits {:>8} tokens | {:.0} tok/s",
            r.encode_time, r.embed_cache_hit_tokens, r.throughput
        );
    }
}
