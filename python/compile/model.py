"""L2: tiny Llama-style decoder served end-to-end by the rust coordinator.

The model exists to prove the full three-layer stack composes: the rust L3
scheduler forms *blended* token batches (chunked-prefill tokens + decode
tokens in one ragged step), and this module's `step` function — AOT-lowered
to HLO text by aot.py — executes them on the PJRT CPU client with the L1
pallas kernel doing attention.

Architecture (Llama-flavoured): RMSNorm, RoPE, GQA attention via
kernels.blend_attention, SwiGLU FFN, tied embedding/unembedding.

The single entry point is deliberately *ragged*:

    step(params, kv, tokens[T], seg_id[T], q_pos[T]) -> (kv', next_ids[T])

 - a prefill chunk for segment b is tokens with seg_id == b and consecutive
   q_pos; a decode token is a single row.  One executable therefore serves
   prefill, decode, and BlendServe's mixed batches alike.
 - padding rows use seg_id == BKV-1 (a scratch segment whose KV rows are
   never read by live segments) so their scatters are harmless.

KV cache layout: kv[L, 2, BKV, S, NKV, HD] float32; index 0 = keys,
1 = values.  The step scatters the new tokens' K/V *before* attention
(insert-then-attend), matching the kernel's inclusive causal window.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from compile.kernels.blend_attention import blend_attention


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """Architecture constants; must stay in sync with rust config presets."""

    vocab: int = 2048
    d_model: int = 256
    n_layers: int = 4
    n_q_heads: int = 8
    n_kv_heads: int = 2
    head_dim: int = 32
    d_ffn: int = 688
    max_seq: int = 256  # S: KV rows per segment
    n_segments: int = 8  # live segments; +1 scratch segment is appended
    rope_theta: float = 10000.0

    @property
    def bkv(self) -> int:
        """Total KV segments including the trailing scratch segment."""
        return self.n_segments + 1

    def param_count(self) -> int:
        c = self
        per_layer = (
            c.d_model * (c.n_q_heads * c.head_dim)  # wq
            + 2 * c.d_model * (c.n_kv_heads * c.head_dim)  # wk, wv
            + (c.n_q_heads * c.head_dim) * c.d_model  # wo
            + 3 * c.d_model * c.d_ffn  # gate, up, down
            + 2 * c.d_model  # ln1, ln2
        )
        return c.vocab * c.d_model + c.n_layers * per_layer + c.d_model


# Parameter order is the contract with aot.py / the rust weight loader.
PARAM_ORDER = (
    "embed",  # [V, D]
    "wq",  # [L, D, NQ*HD]
    "wk",  # [L, D, NKV*HD]
    "wv",  # [L, D, NKV*HD]
    "wo",  # [L, NQ*HD, D]
    "w_gate",  # [L, D, F]
    "w_up",  # [L, D, F]
    "w_down",  # [L, F, D]
    "ln1",  # [L, D]
    "ln2",  # [L, D]
    "ln_f",  # [D]
)


def param_shapes(cfg: ModelConfig) -> Dict[str, Tuple[int, ...]]:
    c = cfg
    qd, kd = c.n_q_heads * c.head_dim, c.n_kv_heads * c.head_dim
    return {
        "embed": (c.vocab, c.d_model),
        "wq": (c.n_layers, c.d_model, qd),
        "wk": (c.n_layers, c.d_model, kd),
        "wv": (c.n_layers, c.d_model, kd),
        "wo": (c.n_layers, qd, c.d_model),
        "w_gate": (c.n_layers, c.d_model, c.d_ffn),
        "w_up": (c.n_layers, c.d_model, c.d_ffn),
        "w_down": (c.n_layers, c.d_ffn, c.d_model),
        "ln1": (c.n_layers, c.d_model),
        "ln2": (c.n_layers, c.d_model),
        "ln_f": (c.d_model,),
    }


def init_params(cfg: ModelConfig, seed: int = 0) -> Dict[str, jax.Array]:
    """Deterministic init; the same bytes are written to weights.bin."""
    shapes = param_shapes(cfg)
    key = jax.random.PRNGKey(seed)
    params: Dict[str, jax.Array] = {}
    for name in PARAM_ORDER:
        key, sub = jax.random.split(key)
        shape = shapes[name]
        if name.startswith("ln"):
            params[name] = jnp.ones(shape, jnp.float32)
        else:
            fan_in = shape[-2] if len(shape) >= 2 else shape[-1]
            scale = 1.0 / jnp.sqrt(jnp.float32(fan_in))
            params[name] = (jax.random.normal(sub, shape, jnp.float32) * scale)
    return params


def kv_shape(cfg: ModelConfig) -> Tuple[int, ...]:
    return (
        cfg.n_layers,
        2,
        cfg.bkv,
        cfg.max_seq,
        cfg.n_kv_heads,
        cfg.head_dim,
    )


def init_kv(cfg: ModelConfig) -> jax.Array:
    return jnp.zeros(kv_shape(cfg), jnp.float32)


def _rmsnorm(x: jax.Array, w: jax.Array, eps: float = 1e-5) -> jax.Array:
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    return x * jax.lax.rsqrt(var + eps) * w


def _rope(x: jax.Array, pos: jax.Array, theta: float) -> jax.Array:
    """Rotary embedding.  x: [T, H, D]; pos: [T] int32."""
    t, h, d = x.shape
    half = d // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    angles = pos[:, None].astype(jnp.float32) * freqs[None, :]  # [T, half]
    cos = jnp.cos(angles)[:, None, :]  # [T, 1, half]
    sin = jnp.sin(angles)[:, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    return jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)


def _layer(
    cfg: ModelConfig,
    x: jax.Array,
    kv_l: jax.Array,
    w: Dict[str, jax.Array],
    seg_id: jax.Array,
    q_pos: jax.Array,
    interpret: bool,
) -> Tuple[jax.Array, jax.Array]:
    """One decoder layer over the ragged token batch.

    x: [T, D]; kv_l: [2, BKV, S, NKV, HD] for this layer.
    Returns (x', kv_l').
    """
    c = cfg
    t = x.shape[0]
    h = _rmsnorm(x, w["ln1"])
    q = (h @ w["wq"]).reshape(t, c.n_q_heads, c.head_dim)
    k = (h @ w["wk"]).reshape(t, c.n_kv_heads, c.head_dim)
    v = (h @ w["wv"]).reshape(t, c.n_kv_heads, c.head_dim)
    q = _rope(q, q_pos, c.rope_theta)
    k = _rope(k, q_pos, c.rope_theta)

    # Insert-then-attend: scatter the fresh K/V rows into the cache.
    k_cache = kv_l[0].at[seg_id, q_pos].set(k)  # [BKV, S, NKV, HD]
    v_cache = kv_l[1].at[seg_id, q_pos].set(v)
    kv_l_new = jnp.stack([k_cache, v_cache])

    flat = (c.bkv * c.max_seq, c.n_kv_heads, c.head_dim)
    attn = blend_attention(
        q,
        k_cache.reshape(flat),
        v_cache.reshape(flat),
        seg_id,
        q_pos,
        seq_len=c.max_seq,
        interpret=interpret,
    )
    x = x + attn.reshape(t, c.n_q_heads * c.head_dim) @ w["wo"]

    h = _rmsnorm(x, w["ln2"])
    x = x + (jax.nn.silu(h @ w["w_gate"]) * (h @ w["w_up"])) @ w["w_down"]
    return x, kv_l_new


def step(
    cfg: ModelConfig,
    params: Dict[str, jax.Array],
    kv: jax.Array,
    tokens: jax.Array,
    seg_id: jax.Array,
    q_pos: jax.Array,
    *,
    interpret: bool = True,
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Run one blended step over T ragged tokens.

    Returns (kv', next_ids[T], last_logits[T, V]).  next_ids is the greedy
    continuation for every row; the coordinator reads the rows it cares
    about (the last token of each prefill chunk, every decode row).
    """
    x = params["embed"][tokens]  # [T, D]

    layer_names = [n for n in PARAM_ORDER if n not in ("embed", "ln_f")]
    stacked = {n: params[n] for n in layer_names}

    def scan_body(x, layer_in):
        kv_l, w = layer_in
        x, kv_l_new = _layer(cfg, x, kv_l, w, seg_id, q_pos, interpret)
        return x, kv_l_new

    x, kv_new = jax.lax.scan(scan_body, x, (kv, stacked))
    x = _rmsnorm(x, params["ln_f"])
    logits = x @ params["embed"].T  # tied unembedding: [T, V]
    next_ids = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    return kv_new, next_ids, logits


def make_step_fn(cfg: ModelConfig, interpret: bool = True):
    """A positional-arg closure of `step` suitable for jit/lowering.

    Signature: f(kv, tokens, seg_id, q_pos, *param_arrays_in_PARAM_ORDER)
    -> (kv', next_ids).  Logits are dropped from the AOT artifact to keep
    host transfers small; tests use `step` directly when they need them.
    """

    def f(kv, tokens, seg_id, q_pos, *flat_params):
        params = dict(zip(PARAM_ORDER, flat_params))
        kv_new, next_ids, _ = step(
            cfg, params, kv, tokens, seg_id, q_pos, interpret=interpret
        )
        return kv_new, next_ids

    return f


@functools.lru_cache(maxsize=None)
def default_config() -> ModelConfig:
    return ModelConfig()
