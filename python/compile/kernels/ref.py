"""Pure-jnp oracle for the blended attention kernel.

Deliberately naive: materializes the full [T, BKV*S] score matrix and relies
only on jnp primitives, so it is trivially auditable.  pytest asserts the
pallas kernel matches this to tight tolerances across shape/dtype sweeps.
"""

from __future__ import annotations

import jax.numpy as jnp

NEG_INF = -1e30


def ref_blend_attention(q, k, v, seg_id, q_pos, *, seq_len):
    """Reference ragged causal GQA attention.

    Shapes match kernels.blend_attention.blend_attention:
      q [T, NQ, D], k/v [BKV*seq_len, NKV, D], seg_id/q_pos [T] int32.
    """
    t, nq, d = q.shape
    n_rows, nkv, _ = k.shape
    group = nq // nkv
    # Expand kv heads to query heads (GQA).
    k_full = jnp.repeat(k, group, axis=1)  # [rows, NQ, D]
    v_full = jnp.repeat(v, group, axis=1)

    scale = 1.0 / jnp.sqrt(jnp.float32(d))
    # scores[t, h, r] = q[t,h,:] . k[r,h,:]
    scores = jnp.einsum("thd,rhd->thr", q.astype(jnp.float32),
                        k_full.astype(jnp.float32)) * scale
    rows = jnp.arange(n_rows)[None, :]  # [1, rows]
    lo = (seg_id * seq_len)[:, None]
    hi = (seg_id * seq_len + q_pos)[:, None]
    valid = (rows >= lo) & (rows <= hi)  # [T, rows]
    scores = jnp.where(valid[:, None, :], scores, NEG_INF)
    probs = jnp.exp(scores - scores.max(axis=-1, keepdims=True))
    denom = probs.sum(axis=-1, keepdims=True)
    denom = jnp.where(denom == 0.0, 1.0, denom)
    probs = probs / denom
    out = jnp.einsum("thr,rhd->thd", probs, v_full.astype(jnp.float32))
    return out.astype(q.dtype)
