"""L1 Pallas kernel: blended-batch attention over a ragged prefill/decode mix.

This is BlendServe's compute hot-spot translated to TPU idiom (DESIGN.md
§Hardware-Adaptation).  A single kernel consumes a *blended* token batch —
prefill-chunk tokens (many query rows per segment, MXU-friendly, compute
bound) and decode tokens (one query row per segment, HBM-bandwidth bound) —
against a shared KV cache.  Interleaving both classes in one grid keeps the
MXU busy on the prefill tiles while the decode tiles stream KV pages, which
is the TPU analogue of NanoFlow's CUDA-stream operator overlap.

Layout
------
  q        [T, NQ, D]      T mixed query tokens, NQ query heads
  k, v     [BKV * S, NKV, D]  flattened per-segment KV cache (segment b owns
                              rows [b*S, (b+1)*S)); NKV kv heads (GQA)
  seg_id   [T] int32        owning segment of each query token
  q_pos    [T] int32        absolute position of the token in its segment;
                            the token attends kv rows [b*S, b*S + q_pos].
  out      [T, NQ, D]

The caller must have already scattered each token's own K/V into the cache
(insert-then-attend), so causal self-attention is the inclusive range above.
Padding tokens should point at a scratch segment (seg_id = BKV-1 by
convention in model.py) — their outputs are garbage and ignored.

The kernel is flash-attention style: the KV range is swept in TK-row tiles
with an online-softmax (m, l, acc) carry, so the score matrix never
materializes beyond [TQ, TK].  Grid = (T/TQ, NQ); GQA maps query head h to
kv head h // (NQ/NKV).

Pallas runs with interpret=True: the CPU PJRT plugin cannot execute Mosaic
custom-calls, so the kernel lowers to plain HLO.  Real-TPU efficiency is
estimated analytically (EXPERIMENTS.md §Perf).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Default tile sizes.  TQ is the query-tile height (one MXU pass per tile);
# TK is the KV-tile depth streamed per inner step.  Both are chosen so a
# [TQ, D] + 2*[TK, D] + [TQ, TK] working set fits comfortably in VMEM at
# D = 128 (see EXPERIMENTS.md §Perf for the footprint table).
DEFAULT_TQ = 16
DEFAULT_TK = 128

_NEG_INF = -1e30


def _attn_kernel(seg_ref, pos_ref, q_ref, k_ref, v_ref, o_ref, *, seq_len, tile_k):
    """One (query-tile, head) grid cell: online-softmax sweep over KV tiles."""
    q = q_ref[:, 0, :]  # [TQ, D]
    tq, d = q.shape
    n_rows = k_ref.shape[0]
    seg = seg_ref[:]  # [TQ]
    pos = pos_ref[:]  # [TQ]
    # kv window for each query token: rows [lo, lo + pos] inclusive.
    lo = seg * seq_len  # [TQ]
    hi = lo + pos  # inclusive upper bound

    scale = jax.lax.rsqrt(jnp.float32(d))
    num_tiles = n_rows // tile_k

    def body(j, carry):
        m, l, acc = carry
        k_tile = k_ref[pl.ds(j * tile_k, tile_k), 0, :]
        v_tile = v_ref[pl.ds(j * tile_k, tile_k), 0, :]
        s = jnp.dot(q, k_tile.T, preferred_element_type=jnp.float32) * scale
        rows = j * tile_k + jax.lax.broadcasted_iota(jnp.int32, (tq, tile_k), 1)
        valid = (rows >= lo[:, None]) & (rows <= hi[:, None])
        s = jnp.where(valid, s, _NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[:, None])
        # Tiles that are entirely masked contribute exp(-inf - m) ~ 0.
        corr = jnp.exp(m - m_new)
        l_new = l * corr + jnp.sum(p, axis=-1)
        acc_new = acc * corr[:, None] + jnp.dot(
            p, v_tile, preferred_element_type=jnp.float32
        )
        return m_new, l_new, acc_new

    m0 = jnp.full((tq,), _NEG_INF, dtype=jnp.float32)
    l0 = jnp.zeros((tq,), dtype=jnp.float32)
    acc0 = jnp.zeros((tq, d), dtype=jnp.float32)
    m, l, acc = jax.lax.fori_loop(0, num_tiles, body, (m0, l0, acc0))
    # Guard l == 0 (fully-masked padding tokens): emit zeros, not NaNs.
    l = jnp.where(l == 0.0, 1.0, l)
    o_ref[:, 0, :] = (acc / l[:, None]).astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("seq_len", "tile_q", "tile_k", "interpret")
)
def blend_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    seg_id: jax.Array,
    q_pos: jax.Array,
    *,
    seq_len: int,
    tile_q: int = DEFAULT_TQ,
    tile_k: int = DEFAULT_TK,
    interpret: bool = True,
) -> jax.Array:
    """Blended ragged-batch causal attention with GQA.

    Args:
      q:       [T, NQ, D] query tokens (mixed prefill chunks + decode rows).
      k, v:    [BKV * seq_len, NKV, D] flattened KV cache.
      seg_id:  [T] int32 owning segment per token.
      q_pos:   [T] int32 position of the token within its segment.
      seq_len: rows per segment in the flattened cache.
      tile_q, tile_k: pallas tile sizes; T % tile_q == 0 and
        (BKV*seq_len) % tile_k == 0 must hold.
      interpret: run the kernel in pallas interpret mode (required on CPU).

    Returns:
      [T, NQ, D] attention outputs (garbage rows for padding tokens).
    """
    t, nq, d = q.shape
    n_rows, nkv, dk = k.shape
    if dk != d or v.shape != k.shape:
        raise ValueError(f"bad kv shapes: k={k.shape} v={v.shape} q={q.shape}")
    # Clamp tiles to the problem size (tiny batches in tests / the real
    # CPU model), then require exact divisibility.
    tile_q = min(tile_q, t)
    tile_k = min(tile_k, n_rows)
    if t % tile_q != 0:
        raise ValueError(f"T={t} not a multiple of tile_q={tile_q}")
    if n_rows % tile_k != 0:
        raise ValueError(f"KV rows={n_rows} not a multiple of tile_k={tile_k}")
    if n_rows % seq_len != 0:
        raise ValueError(f"KV rows={n_rows} not a multiple of seq_len={seq_len}")
    if nq % nkv != 0:
        raise ValueError(f"NQ={nq} not a multiple of NKV={nkv}")
    group = nq // nkv

    grid = (t // tile_q, nq)
    kernel = functools.partial(_attn_kernel, seq_len=seq_len, tile_k=tile_k)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile_q,), lambda i, h: (i,)),  # seg_id
            pl.BlockSpec((tile_q,), lambda i, h: (i,)),  # q_pos
            pl.BlockSpec((tile_q, 1, d), lambda i, h: (i, h, 0)),  # q
            pl.BlockSpec((n_rows, 1, d), lambda i, h, g=group: (0, h // g, 0)),  # k
            pl.BlockSpec((n_rows, 1, d), lambda i, h, g=group: (0, h // g, 0)),  # v
        ],
        out_specs=pl.BlockSpec((tile_q, 1, d), lambda i, h: (i, h, 0)),
        out_shape=jax.ShapeDtypeStruct((t, nq, d), q.dtype),
        interpret=interpret,
    )(seg_id, q_pos, q, k, v)
