"""AOT compile path: lower the L2 model to HLO *text* artifacts for rust.

Run once by `make artifacts`; python never executes on the request path.

Interchange format is HLO text, NOT a serialized HloModuleProto: jax >= 0.5
emits protos with 64-bit instruction ids which the xla crate's bundled
xla_extension 0.5.1 rejects (`proto.id() <= INT_MAX`).  The HLO text parser
reassigns ids, so text round-trips cleanly (see /opt/xla-example/README.md).

Outputs under --out (default ../artifacts):
  step_t{T}.hlo.txt   one executable per blended-batch token budget T
  weights.bin         deterministic f32 little-endian params, PARAM_ORDER
  manifest.json       arch constants + tensor shapes/offsets + input order
"""

from __future__ import annotations

import argparse
import hashlib
import json
import pathlib

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from compile.model import (
    PARAM_ORDER,
    ModelConfig,
    init_params,
    kv_shape,
    make_step_fn,
    param_shapes,
)

# Token budgets the coordinator may request per blended step.  16 covers
# decode-dominated steps; 64 covers chunked-prefill-heavy steps.
STEP_VARIANTS = (16, 64)


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_step(cfg: ModelConfig, t: int) -> str:
    f = make_step_fn(cfg, interpret=True)
    shapes = param_shapes(cfg)
    args = [
        jax.ShapeDtypeStruct(kv_shape(cfg), jnp.float32),
        jax.ShapeDtypeStruct((t,), jnp.int32),  # tokens
        jax.ShapeDtypeStruct((t,), jnp.int32),  # seg_id
        jax.ShapeDtypeStruct((t,), jnp.int32),  # q_pos
    ] + [jax.ShapeDtypeStruct(shapes[n], jnp.float32) for n in PARAM_ORDER]
    lowered = jax.jit(f).lower(*args)
    return to_hlo_text(lowered)


def write_weights(cfg: ModelConfig, out_dir: pathlib.Path, seed: int) -> dict:
    params = init_params(cfg, seed=seed)
    tensors = []
    offset = 0
    blobs = []
    for name in PARAM_ORDER:
        arr = np.asarray(params[name], dtype="<f4")
        blobs.append(arr.tobytes())
        tensors.append(
            {
                "name": name,
                "shape": list(arr.shape),
                "offset_bytes": offset,
                "size_bytes": arr.nbytes,
            }
        )
        offset += arr.nbytes
    blob = b"".join(blobs)
    (out_dir / "weights.bin").write_bytes(blob)
    return {
        "tensors": tensors,
        "total_bytes": offset,
        "sha256": hashlib.sha256(blob).hexdigest(),
        "seed": seed,
    }


def make_golden(cfg: ModelConfig, seed: int) -> dict:
    """Golden outputs for the rust runtime's numerical cross-check.

    Runs the real (non-lowered) step function twice — a prefill of 8 tokens
    followed by one decode step — and records the greedy next ids.  The
    rust integration test replays the same inputs through the compiled HLO
    and must reproduce these ids exactly.
    """
    import jax
    import jax.numpy as jnp

    from compile.model import init_kv, step

    params = init_params(cfg, seed=seed)
    kv = init_kv(cfg)
    t = 16
    scratch = cfg.bkv - 1
    tokens = [3, 1, 4, 1, 5, 9, 2, 6] + [0] * 8
    seg = [0] * 8 + [scratch] * 8
    pos = list(range(8)) + list(range(8))
    kv, ids1, _ = step(
        cfg, params, kv,
        jnp.asarray(tokens, jnp.int32),
        jnp.asarray(seg, jnp.int32),
        jnp.asarray(pos, jnp.int32),
    )
    first_out = int(ids1[7])
    tokens2 = [first_out] + [0] * 15
    seg2 = [0] + [scratch] * 15
    pos2 = [8] + list(range(15))
    _, ids2, _ = step(
        cfg, params, kv,
        jnp.asarray(tokens2, jnp.int32),
        jnp.asarray(seg2, jnp.int32),
        jnp.asarray(pos2, jnp.int32),
    )
    return {
        "prefill": {
            "tokens": tokens,
            "seg_id": seg,
            "q_pos": pos,
            "next_ids": [int(x) for x in ids1],
        },
        "decode": {
            "tokens": tokens2,
            "seg_id": seg2,
            "q_pos": pos2,
            "next_ids": [int(x) for x in ids2],
        },
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts", help="artifact directory")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    out_dir = pathlib.Path(args.out)
    out_dir.mkdir(parents=True, exist_ok=True)

    cfg = ModelConfig()
    weights_meta = write_weights(cfg, out_dir, args.seed)
    print(f"weights.bin: {weights_meta['total_bytes']} bytes "
          f"({cfg.param_count()} params)")

    step_files = {}
    for t in STEP_VARIANTS:
        text = lower_step(cfg, t)
        name = f"step_t{t}.hlo.txt"
        (out_dir / name).write_text(text)
        step_files[str(t)] = name
        print(f"{name}: {len(text)} chars")

    golden = make_golden(cfg, args.seed)
    (out_dir / "golden.json").write_text(json.dumps(golden, indent=2))
    print("golden.json written (rust runtime cross-check)")

    manifest = {
        "model": {
            "vocab": cfg.vocab,
            "d_model": cfg.d_model,
            "n_layers": cfg.n_layers,
            "n_q_heads": cfg.n_q_heads,
            "n_kv_heads": cfg.n_kv_heads,
            "head_dim": cfg.head_dim,
            "d_ffn": cfg.d_ffn,
            "max_seq": cfg.max_seq,
            "n_segments": cfg.n_segments,
            "bkv": cfg.bkv,
            "rope_theta": cfg.rope_theta,
            "param_count": cfg.param_count(),
        },
        "kv_shape": list(kv_shape(cfg)),
        "step_variants": step_files,
        # Executable input order; outputs are a 2-tuple (kv', next_ids[T]).
        "input_order": ["kv", "tokens", "seg_id", "q_pos", *PARAM_ORDER],
        "weights": weights_meta,
    }
    (out_dir / "manifest.json").write_text(json.dumps(manifest, indent=2))
    print(f"manifest.json written to {out_dir}")


if __name__ == "__main__":
    main()
