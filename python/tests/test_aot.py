"""AOT path: HLO text generation + weights/manifest contract with rust."""

import json
import pathlib

import numpy as np
import pytest

from compile import aot
from compile.model import PARAM_ORDER, ModelConfig, init_params, param_shapes


@pytest.fixture(scope="module")
def artifacts(tmp_path_factory):
    out = tmp_path_factory.mktemp("artifacts")
    cfg = ModelConfig(
        vocab=128, d_model=32, n_layers=2, n_q_heads=4, n_kv_heads=2,
        head_dim=8, d_ffn=48, max_seq=32, n_segments=3,
    )
    meta = aot.write_weights(cfg, out, seed=0)
    return cfg, out, meta


class TestWeights:
    def test_offsets_contiguous(self, artifacts):
        _, _, meta = artifacts
        offset = 0
        for t in meta["tensors"]:
            assert t["offset_bytes"] == offset
            offset += t["size_bytes"]
        assert offset == meta["total_bytes"]

    def test_order_matches_param_order(self, artifacts):
        _, _, meta = artifacts
        assert [t["name"] for t in meta["tensors"]] == list(PARAM_ORDER)

    def test_roundtrip_bytes(self, artifacts):
        """Reading back a slice of weights.bin reproduces the jax array."""
        cfg, out, meta = artifacts
        params = init_params(cfg, seed=0)
        blob = (out / "weights.bin").read_bytes()
        assert len(blob) == meta["total_bytes"]
        for t in meta["tensors"]:
            arr = np.frombuffer(
                blob[t["offset_bytes"]: t["offset_bytes"] + t["size_bytes"]],
                dtype="<f4",
            ).reshape(t["shape"])
            np.testing.assert_array_equal(arr, np.asarray(params[t["name"]]))

    def test_shapes_match_config(self, artifacts):
        cfg, _, meta = artifacts
        shapes = param_shapes(cfg)
        for t in meta["tensors"]:
            assert tuple(t["shape"]) == shapes[t["name"]]

    def test_deterministic(self, artifacts, tmp_path):
        cfg, out, meta = artifacts
        meta2 = aot.write_weights(cfg, tmp_path, seed=0)
        assert meta2["sha256"] == meta["sha256"]


class TestLowering:
    def test_hlo_text_parses(self, artifacts):
        cfg, _, _ = artifacts
        text = aot.lower_step(cfg, 8)
        assert "HloModule" in text
        assert "ROOT" in text
        # Inputs: kv + 3 token arrays + 11 params = 15 parameters (ids 0-14)
        # in the entry computation; nested computations add more.
        n_entry_params = 4 + len(PARAM_ORDER)
        assert f"parameter({n_entry_params - 1})" in text
        assert f"parameter({n_entry_params})" not in text

    def test_full_main(self, tmp_path, monkeypatch):
        monkeypatch.setattr(aot, "STEP_VARIANTS", (16,))
        monkeypatch.setattr(
            "sys.argv", ["aot", "--out", str(tmp_path), "--seed", "0"])
        aot.main()
        manifest = json.loads((tmp_path / "manifest.json").read_text())
        assert (tmp_path / "weights.bin").exists()
        assert (tmp_path / manifest["step_variants"]["16"]).exists()
        assert manifest["input_order"][:4] == ["kv", "tokens", "seg_id",
                                               "q_pos"]
        assert manifest["model"]["param_count"] > 0
