"""L2 correctness: the ragged `step` function and its invariants."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as m

jax.config.update("jax_platform_name", "cpu")

CFG = m.ModelConfig(
    vocab=128, d_model=32, n_layers=2, n_q_heads=4, n_kv_heads=2,
    head_dim=8, d_ffn=48, max_seq=32, n_segments=3,
)


@pytest.fixture(scope="module")
def params():
    return m.init_params(CFG, seed=0)


def run_step(params, kv, tokens, seg_id, q_pos):
    return m.step(
        CFG, params, kv,
        jnp.asarray(tokens, jnp.int32),
        jnp.asarray(seg_id, jnp.int32),
        jnp.asarray(q_pos, jnp.int32),
    )


class TestShapes:
    def test_param_shapes_consistent(self, params):
        shapes = m.param_shapes(CFG)
        for name in m.PARAM_ORDER:
            assert tuple(params[name].shape) == shapes[name], name

    def test_param_count_matches(self, params):
        total = sum(int(np.prod(p.shape)) for p in params.values())
        assert total == CFG.param_count()

    def test_step_output_shapes(self, params):
        kv = m.init_kv(CFG)
        t = 16
        kv2, ids, logits = run_step(
            params, kv, [1] * t, [0] * t, list(range(t)))
        assert kv2.shape == m.kv_shape(CFG)
        assert ids.shape == (t,)
        assert ids.dtype == jnp.int32
        assert logits.shape == (t, CFG.vocab)
        assert bool(jnp.all((ids >= 0) & (ids < CFG.vocab)))


class TestSemantics:
    def test_causality(self, params):
        """Changing a future token must not change earlier logits."""
        kv = m.init_kv(CFG)
        t = 16
        toks_a = list(range(1, t + 1))
        toks_b = list(toks_a)
        toks_b[-1] = 99  # perturb only the last token
        _, _, la = run_step(params, kv, toks_a, [0] * t, list(range(t)))
        _, _, lb = run_step(params, kv, toks_b, [0] * t, list(range(t)))
        np.testing.assert_allclose(np.asarray(la[: t - 1]),
                                   np.asarray(lb[: t - 1]), atol=1e-6)
        assert not np.allclose(np.asarray(la[-1]), np.asarray(lb[-1]))

    def test_chunked_prefill_matches_single_shot(self, params):
        """Prefill in two chunks == prefill in one ragged step."""
        kv = m.init_kv(CFG)
        toks = list(range(10, 26))  # 16 tokens
        # One shot.
        kv_a, _, logits_a = run_step(params, kv, toks, [0] * 16,
                                     list(range(16)))
        # Two chunks of 8 (pads routed to the scratch segment).
        scratch = CFG.bkv - 1
        kv_b = kv
        kv_b, _, l1 = run_step(params, kv_b, toks[:8], [0] * 8,
                               list(range(8)))
        kv_b, _, l2 = run_step(params, kv_b, toks[8:], [0] * 8,
                               list(range(8, 16)))
        np.testing.assert_allclose(np.asarray(kv_a[:, :, 0]),
                                   np.asarray(kv_b[:, :, 0]), atol=1e-5)
        np.testing.assert_allclose(np.asarray(logits_a[8:]),
                                   np.asarray(l2), atol=1e-4, rtol=1e-4)

    def test_decode_matches_prefill_logits(self, params):
        """Decoding token-by-token == prefilling the same sequence."""
        kv = m.init_kv(CFG)
        toks = [5, 17, 42, 99, 3, 7, 64, 28]
        t = len(toks)
        _, _, logits_full = run_step(params, kv, toks, [0] * t,
                                     list(range(t)))
        kv_d = kv
        per_step = []
        for i, tok in enumerate(toks):
            # Pad the ragged step to 4 rows via the scratch segment.
            scratch = CFG.bkv - 1
            kv_d, _, lg = run_step(
                params, kv_d,
                [tok, 0, 0, 0],
                [0, scratch, scratch, scratch],
                [i, 0, 1, 2],
            )
            per_step.append(np.asarray(lg[0]))
        np.testing.assert_allclose(np.stack(per_step),
                                   np.asarray(logits_full),
                                   atol=1e-4, rtol=1e-4)

    def test_scratch_segment_isolated(self, params):
        """Garbage scattered into the scratch segment must not leak."""
        kv = m.init_kv(CFG)
        scratch = CFG.bkv - 1
        # Pollute scratch heavily.
        kv_p, _, _ = run_step(params, kv, [77] * 8, [scratch] * 8,
                              list(range(8)))
        toks = [1, 2, 3, 4, 5, 6, 7, 8]
        _, _, la = run_step(params, kv, toks, [0] * 8, list(range(8)))
        _, _, lb = run_step(params, kv_p, toks, [0] * 8, list(range(8)))
        np.testing.assert_allclose(np.asarray(la), np.asarray(lb), atol=1e-6)

    def test_segments_isolated(self, params):
        """Tokens in segment 1 must not affect segment 0's results."""
        kv = m.init_kv(CFG)
        _, _, la = run_step(params, kv, [1, 2, 3, 4], [0] * 4, [0, 1, 2, 3])
        _, _, lb = run_step(
            params, kv,
            [1, 2, 3, 4, 9, 9, 9, 9],
            [0, 0, 0, 0, 1, 1, 1, 1],
            [0, 1, 2, 3, 0, 1, 2, 3],
        )
        np.testing.assert_allclose(np.asarray(la), np.asarray(lb[:4]),
                                   atol=1e-6)

    def test_greedy_ids_are_argmax(self, params):
        kv = m.init_kv(CFG)
        _, ids, logits = run_step(params, kv, [3, 1, 4, 1], [0] * 4,
                                  [0, 1, 2, 3])
        np.testing.assert_array_equal(np.asarray(ids),
                                      np.argmax(np.asarray(logits), -1))

    def test_determinism(self, params):
        kv = m.init_kv(CFG)
        a = run_step(params, kv, [1, 2, 3, 4], [0] * 4, [0, 1, 2, 3])
        b = run_step(params, kv, [1, 2, 3, 4], [0] * 4, [0, 1, 2, 3])
        for xa, xb in zip(a, b):
            np.testing.assert_array_equal(np.asarray(xa), np.asarray(xb))


class TestStepFn:
    def test_make_step_fn_matches_step(self, params):
        kv = m.init_kv(CFG)
        f = m.make_step_fn(CFG)
        flat = [params[n] for n in m.PARAM_ORDER]
        toks = jnp.asarray([1, 2, 3, 4], jnp.int32)
        seg = jnp.zeros(4, jnp.int32)
        pos = jnp.arange(4, dtype=jnp.int32)
        kv_a, ids_a = f(kv, toks, seg, pos, *flat)
        kv_b, ids_b, _ = m.step(CFG, params, kv, toks, seg, pos)
        np.testing.assert_allclose(np.asarray(kv_a), np.asarray(kv_b))
        np.testing.assert_array_equal(np.asarray(ids_a), np.asarray(ids_b))
