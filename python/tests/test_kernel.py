"""L1 correctness: pallas blend_attention vs the pure-jnp oracle.

hypothesis sweeps shapes/dtypes and ragged prefill/decode mixes; fixed
cases pin the regimes the coordinator actually produces.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.blend_attention import blend_attention
from compile.kernels.ref import ref_blend_attention

jax.config.update("jax_platform_name", "cpu")


def make_inputs(rng, *, t, nq, nkv, d, bkv, seq_len, dtype=jnp.float32,
                mode="mixed"):
    """Build a ragged batch: prefill runs + decode singletons."""
    q = jnp.asarray(rng.standard_normal((t, nq, d)), dtype)
    k = jnp.asarray(rng.standard_normal((bkv * seq_len, nkv, d)), dtype)
    v = jnp.asarray(rng.standard_normal((bkv * seq_len, nkv, d)), dtype)

    seg, pos = [], []
    i = 0
    while i < t:
        if mode == "decode" or (mode == "mixed" and rng.random() < 0.5):
            run = 1
        else:
            run = int(rng.integers(1, min(t - i, seq_len) + 1))
        s = int(rng.integers(0, bkv))
        p0 = int(rng.integers(0, seq_len - run + 1))
        for j in range(run):
            seg.append(s)
            pos.append(p0 + j)
        i += run
    seg_id = jnp.asarray(seg[:t], jnp.int32)
    q_pos = jnp.asarray(pos[:t], jnp.int32)
    return q, k, v, seg_id, q_pos


def check(q, k, v, seg_id, q_pos, seq_len, **kw):
    got = blend_attention(q, k, v, seg_id, q_pos, seq_len=seq_len, **kw)
    want = ref_blend_attention(q, k, v, seg_id, q_pos, seq_len=seq_len)
    atol = 2e-5 if q.dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=atol, rtol=1e-3)


class TestFixedCases:
    def test_decode_only(self):
        rng = np.random.default_rng(0)
        args = make_inputs(rng, t=16, nq=8, nkv=2, d=32, bkv=9, seq_len=128,
                           mode="decode")
        check(*args, seq_len=128)

    def test_prefill_only_single_segment(self):
        rng = np.random.default_rng(1)
        q, k, v, _, _ = make_inputs(rng, t=32, nq=8, nkv=2, d=32, bkv=9,
                                    seq_len=128)
        seg_id = jnp.zeros((32,), jnp.int32)
        q_pos = jnp.arange(32, dtype=jnp.int32)
        check(q, k, v, seg_id, q_pos, 128)

    def test_blended_prefill_plus_decode(self):
        """The shape BlendServe actually produces: one chunk + decode rows."""
        rng = np.random.default_rng(2)
        t, seq_len = 32, 128
        q, k, v, _, _ = make_inputs(rng, t=t, nq=8, nkv=2, d=32, bkv=9,
                                    seq_len=seq_len)
        seg_id = jnp.asarray([0] * 24 + [1, 2, 3, 4, 5, 6, 7, 8], jnp.int32)
        q_pos = jnp.asarray(list(range(10, 34)) + [99, 5, 63, 127, 1, 42, 7, 0],
                            jnp.int32)
        check(q, k, v, seg_id, q_pos, seq_len)

    def test_mha_group_one(self):
        rng = np.random.default_rng(3)
        args = make_inputs(rng, t=16, nq=4, nkv=4, d=16, bkv=2, seq_len=64)
        check(*args, seq_len=64)

    def test_position_zero_token_attends_only_itself(self):
        rng = np.random.default_rng(4)
        q, k, v, _, _ = make_inputs(rng, t=16, nq=2, nkv=2, d=16, bkv=2,
                                    seq_len=64)
        seg_id = jnp.zeros((16,), jnp.int32)
        q_pos = jnp.zeros((16,), jnp.int32)
        got = blend_attention(q, k, v, seg_id, q_pos, seq_len=64)
        # softmax over a single row == that row's V
        want = jnp.broadcast_to(v[0][None], got.shape)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-5)

    def test_bf16(self):
        rng = np.random.default_rng(5)
        args = make_inputs(rng, t=16, nq=4, nkv=2, d=32, bkv=3, seq_len=128,
                           dtype=jnp.bfloat16)
        check(*args, seq_len=128)

    def test_tile_sizes(self):
        rng = np.random.default_rng(6)
        args = make_inputs(rng, t=32, nq=4, nkv=2, d=32, bkv=4, seq_len=64)
        check(*args, seq_len=64, tile_q=8, tile_k=32)

    def test_full_context_window(self):
        """q_pos = seq_len-1 must reach the segment's last KV row."""
        rng = np.random.default_rng(7)
        q, k, v, _, _ = make_inputs(rng, t=16, nq=2, nkv=2, d=16, bkv=2,
                                    seq_len=64)
        seg_id = jnp.asarray([0, 1] * 8, jnp.int32)
        q_pos = jnp.full((16,), 63, jnp.int32)
        check(q, k, v, seg_id, q_pos, 64)

    def test_rejects_bad_shapes(self):
        rng = np.random.default_rng(8)
        q, k, v, seg_id, q_pos = make_inputs(rng, t=16, nq=4, nkv=2, d=32,
                                             bkv=2, seq_len=64)
        with pytest.raises(ValueError):
            blend_attention(q, k, v, seg_id, q_pos, seq_len=64, tile_q=5)
        with pytest.raises(ValueError):
            blend_attention(q, k, v, seg_id, q_pos, seq_len=60)
        with pytest.raises(ValueError):
            blend_attention(q[:, :3], k, v, seg_id, q_pos, seq_len=64)


@settings(max_examples=25, deadline=None)
@given(
    t_tiles=st.integers(1, 3),
    nkv=st.sampled_from([1, 2]),
    group=st.sampled_from([1, 2, 4]),
    d=st.sampled_from([8, 16, 32]),
    bkv=st.integers(1, 4),
    seq_pow=st.integers(5, 7),  # seq_len in {32, 64, 128}
    seed=st.integers(0, 2**31 - 1),
    mode=st.sampled_from(["mixed", "decode", "prefill"]),
)
def test_kernel_matches_ref_property(t_tiles, nkv, group, d, bkv, seq_pow,
                                     seed, mode):
    seq_len = 2 ** seq_pow
    t = 16 * t_tiles
    rng = np.random.default_rng(seed)
    args = make_inputs(rng, t=t, nq=nkv * group, nkv=nkv, d=d, bkv=bkv,
                       seq_len=seq_len, mode=mode)
    check(*args, seq_len=seq_len, tile_k=32)
