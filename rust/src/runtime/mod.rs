//! The real-model PJRT runtime: load the AOT HLO artifacts produced by
//! `python/compile/aot.py` and serve actual tokens on the CPU PJRT client.
//!
//! Python never runs here — the interchange is HLO *text* (the bundled
//! xla_extension 0.5.1 rejects jax's 64-bit-id serialized protos; the text
//! parser reassigns ids, see /opt/xla-example/README.md) plus a flat
//! `weights.bin` + `manifest.json` contract.
//!
//! The L2 model exposes a single *ragged blended step*
//! `(kv, tokens[T], seg_id[T], q_pos[T], weights…) -> (kv', next_ids[T])`
//! — a prefill chunk, a decode batch, or BlendServe's prefill+decode blend
//! are all the same executable, which is exactly the paper's execution
//! model translated to the TPU-style kernel (DESIGN.md
//! §Hardware-Adaptation).

pub mod artifacts;
pub mod model;
pub mod serve;

pub use artifacts::{Manifest, TensorMeta};
pub use model::RealModel;
pub use serve::{RealServer, ServeReport};

use std::path::{Path, PathBuf};

/// Default artifact directory (relative to the repo root).
pub fn default_artifact_dir() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

/// True if the AOT artifacts exist (tests skip gracefully otherwise and
/// `make artifacts` produces them).
pub fn artifacts_available(dir: &Path) -> bool {
    dir.join("manifest.json").exists() && dir.join("weights.bin").exists()
}
