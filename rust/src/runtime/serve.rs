//! `RealServer`: a miniature BlendServe coordinator over the *real* model.
//!
//! This is the end-to-end proof that the three layers compose: the L3
//! scheduler forms ragged blended batches (chunked prefill + decode rows in
//! one step), the L2/L1 compiled HLO executes them, and prefix sharing is
//! *actual KV-row reuse* (segment-affinity hits plus cross-segment
//! `copy_prefix`), not an accounting fiction.
//!
//! Scale note: the CPU model has `n_segments` (8) concurrent slots and a
//! 256-token context, so workloads are generated with
//! `TraceSpec::scaled(..)` — same structure, smaller lengths.

use super::model::RealModel;
use crate::trace::Workload;
use crate::tree::PrefixTree;
use anyhow::Result;
use std::path::Path;
use std::time::Instant;

/// Outcome of serving one workload on the real model.
#[derive(Clone, Debug, Default)]
pub struct ServeReport {
    pub n_requests: usize,
    pub steps: u64,
    /// Steps that blended prefill and decode rows.
    pub blended_steps: u64,
    pub wall_seconds: f64,
    /// Time inside PJRT execute (the rest is coordinator overhead).
    pub exec_seconds: f64,
    /// Σ prompt + output tokens (the paper's throughput numerator).
    pub total_tokens: u64,
    pub prompt_tokens: u64,
    pub output_tokens: u64,
    /// Prompt tokens served by KV reuse instead of prefill compute.
    pub reused_tokens: u64,
    pub throughput: f64,
    /// reused / prompt.
    pub hit_ratio: f64,
}

struct ReqState {
    prompt: Vec<i32>,
    out_budget: usize,
    prefill_pos: usize,
    generated: usize,
    cur_len: usize,
    last_token: i32,
    decoding: bool,
}

struct Slot {
    /// Prompt tokens whose KV rows are valid in this segment.
    resident: Vec<u32>,
    req: Option<ReqState>,
}

fn lcp(a: &[u32], b: &[u32]) -> usize {
    a.iter().zip(b).take_while(|(x, y)| x == y).count()
}

/// A blended static order: interleave the density-sorted scheduling units
/// from both ends so concurrently-resident slots hold a compute/memory mix
/// (the dual scanner flattened for a fixed-slot backend).
pub fn zipper_order(tree: &PrefixTree) -> Vec<u32> {
    let units = tree.scheduling_units();
    let mut reqs: Vec<Vec<u32>> =
        units.iter().map(|&(id, _)| tree.nodes[id].requests.clone()).collect();
    let mut out = Vec::with_capacity(tree.n_requests());
    let (mut l, mut r) = (0usize, reqs.len());
    let mut from_left = true;
    while l < r {
        let side = if from_left {
            l += 1;
            &mut reqs[l - 1]
        } else {
            r -= 1;
            &mut reqs[r]
        };
        out.append(side);
        from_left = !from_left;
    }
    out
}

pub struct RealServer {
    pub model: RealModel,
}

impl RealServer {
    pub fn load(dir: &Path) -> Result<RealServer> {
        Ok(RealServer { model: RealModel::load(dir)? })
    }

    /// Serve `workload` in the given admission order.  Prompt token ids
    /// must be `< vocab`; prompts are truncated to fit the context window
    /// alongside their output budget.
    pub fn serve(&mut self, workload: &Workload, order: &[u32]) -> Result<ServeReport> {
        let m = &self.model.manifest;
        let n_slots = m.n_segments;
        let max_seq = m.max_seq;
        let budget = *self.model.variants().last().unwrap();
        let mut report = ServeReport {
            n_requests: workload.len(),
            ..Default::default()
        };
        // lint:allow(r2) -- reports real serving wall time; tokens are unaffected
        let start = Instant::now();
        let exec0 = self.model.exec_seconds;
        let steps0 = self.model.steps;

        let mut slots: Vec<Slot> = (0..n_slots)
            .map(|_| Slot { resident: Vec::new(), req: None })
            .collect();
        let mut queue: Vec<u32> = order.to_vec();
        queue.reverse(); // pop from back
        let mut remaining = workload.len();

        while remaining > 0 {
            // ---- admission: fill free slots, best prefix affinity first --
            loop {
                let Some(&next) = queue.last() else { break };
                let Some(free) = slots.iter().position(|s| s.req.is_none()) else {
                    break;
                };
                queue.pop();
                let r = &workload.requests[next as usize];
                let out_budget = (r.output_len as usize).clamp(1, max_seq / 2);
                let max_prompt = max_seq - out_budget - 1;
                let prompt_u32: Vec<u32> =
                    r.prompt.iter().take(max_prompt).copied().collect();
                // Best resident prefix across all slots.
                let (best_slot, best_lcp) = slots
                    .iter()
                    .enumerate()
                    .map(|(i, s)| (i, lcp(&s.resident, &prompt_u32)))
                    .max_by_key(|&(_, l)| l)
                    .unwrap();
                let mut reuse = lcp(&slots[free].resident, &prompt_u32);
                if best_lcp > reuse && best_slot != free {
                    self.model.copy_prefix(best_slot, free, best_lcp);
                    slots[free].resident =
                        slots[best_slot].resident[..best_lcp].to_vec();
                    reuse = best_lcp;
                }
                report.reused_tokens += reuse as u64;
                report.prompt_tokens += prompt_u32.len() as u64;
                let p = prompt_u32.len();
                slots[free].resident = prompt_u32.clone();
                slots[free].req = Some(ReqState {
                    prompt: prompt_u32.iter().map(|&t| t as i32).collect(),
                    out_budget,
                    prefill_pos: reuse.min(p.saturating_sub(1)),
                    generated: 0,
                    cur_len: p,
                    last_token: 0,
                    decoding: false,
                });
                // Note: even on a full-prompt hit we re-feed the last
                // prompt token (prefill_pos = p-1) to obtain the first
                // output token's logits.
            }

            // ---- assemble one blended step ----
            let mut tokens = Vec::with_capacity(budget);
            let mut seg = Vec::with_capacity(budget);
            let mut pos = Vec::with_capacity(budget);
            // (slot, kind): kind = how to interpret the row's next id.
            enum RowKind {
                PrefillLast,
                Prefill,
                Decode,
            }
            let mut rows: Vec<(usize, RowKind)> = Vec::new();
            let mut had_decode = false;
            let mut had_prefill = false;
            // Decode rows first (one per decoding slot).
            for (si, slot) in slots.iter_mut().enumerate() {
                let Some(req) = slot.req.as_mut() else { continue };
                if req.decoding {
                    tokens.push(req.last_token);
                    seg.push(si as i32);
                    pos.push(req.cur_len as i32);
                    rows.push((si, RowKind::Decode));
                    had_decode = true;
                }
            }
            // Prefill chunks fill the remaining budget.
            for (si, slot) in slots.iter_mut().enumerate() {
                if tokens.len() >= budget {
                    break;
                }
                let Some(req) = slot.req.as_mut() else { continue };
                if req.decoding {
                    continue;
                }
                let p = req.prompt.len();
                let room = budget - tokens.len();
                let take = (p - req.prefill_pos).min(room);
                for k in 0..take {
                    let at = req.prefill_pos + k;
                    tokens.push(req.prompt[at]);
                    seg.push(si as i32);
                    pos.push(at as i32);
                    let last = at + 1 == p;
                    rows.push((si, if last { RowKind::PrefillLast } else { RowKind::Prefill }));
                }
                req.prefill_pos += take;
                if take > 0 {
                    had_prefill = true;
                }
            }

            if tokens.is_empty() {
                anyhow::bail!("scheduler stalled with {remaining} requests left");
            }
            if had_decode && had_prefill {
                report.blended_steps += 1;
            }

            let ids = self.model.step(&tokens, &seg, &pos)?;

            // ---- apply results ----
            for (row, (si, kind)) in rows.iter().enumerate() {
                let slot = &mut slots[*si];
                let Some(req) = slot.req.as_mut() else { continue };
                match kind {
                    RowKind::Prefill => {}
                    RowKind::PrefillLast => {
                        req.decoding = true;
                        req.last_token = ids[row];
                        req.generated = 1;
                        report.output_tokens += 1;
                    }
                    RowKind::Decode => {
                        req.cur_len += 1;
                        req.generated += 1;
                        req.last_token = ids[row];
                        report.output_tokens += 1;
                    }
                }
                let done = req.decoding
                    && (req.generated >= req.out_budget
                        || req.cur_len + 1 >= max_seq);
                if done {
                    report.total_tokens +=
                        (req.prompt.len() + req.generated) as u64;
                    slot.req = None; // resident prompt stays for reuse
                    remaining -= 1;
                }
            }
        }

        report.steps = self.model.steps - steps0;
        report.exec_seconds = self.model.exec_seconds - exec0;
        report.wall_seconds = start.elapsed().as_secs_f64();
        report.throughput = report.total_tokens as f64 / report.wall_seconds.max(1e-9);
        report.hit_ratio = if report.prompt_tokens > 0 {
            report.reused_tokens as f64 / report.prompt_tokens as f64
        } else {
            0.0
        };
        Ok(report)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::{artifacts_available, default_artifact_dir};
    use crate::trace::{Request, TraceKind};

    fn server() -> Option<RealServer> {
        let dir = default_artifact_dir();
        if !artifacts_available(&dir) {
            eprintln!("skipping: run `make artifacts` first");
            return None;
        }
        Some(RealServer::load(&dir).expect("load"))
    }

    fn req(id: u32, prompt: Vec<u32>, out: u32) -> Request {
        Request::new(id, TraceKind::Custom, prompt, out)
    }

    #[test]
    #[ignore = "requires AOT artifacts + a real libxla_extension (PJRT); the build image ships the compile-only xla stub — see DESIGN.md §Test-Triage"]
    fn serves_small_workload_end_to_end() {
        let Some(mut s) = server() else { return };
        let w = Workload::new(
            "mini",
            (0..12u32)
                .map(|i| req(i, vec![i % 7 + 1, i % 5 + 1, i % 3 + 1, 42], 6))
                .collect(),
        );
        let order: Vec<u32> = (0..12).collect();
        let rep = s.serve(&w, &order).unwrap();
        assert_eq!(rep.n_requests, 12);
        assert_eq!(rep.output_tokens, 12 * 6);
        assert!(rep.steps > 0);
        assert!(rep.throughput > 0.0);
    }

    #[test]
    #[ignore = "requires AOT artifacts + a real libxla_extension (PJRT); the build image ships the compile-only xla stub — see DESIGN.md §Test-Triage"]
    fn shared_prefixes_are_reused() {
        let Some(mut s) = server() else { return };
        // 8 requests sharing a 20-token stem.
        let stem: Vec<u32> = (100..120).collect();
        let reqs: Vec<Request> = (0..8u32)
            .map(|i| {
                let mut p = stem.clone();
                p.push(200 + i);
                req(i, p, 4)
            })
            .collect();
        let w = Workload::new("shared", reqs);
        let order: Vec<u32> = (0..8).collect();
        let rep = s.serve(&w, &order).unwrap();
        // 7 of 8 should reuse the stem.
        assert!(
            rep.reused_tokens >= 7 * 20,
            "reused {} tokens",
            rep.reused_tokens
        );
        assert!(rep.hit_ratio > 0.5, "{}", rep.hit_ratio);
    }

    #[test]
    #[ignore = "requires AOT artifacts + a real libxla_extension (PJRT); the build image ships the compile-only xla stub — see DESIGN.md §Test-Triage"]
    fn blended_steps_occur_with_mixed_lengths() {
        let Some(mut s) = server() else { return };
        // Long-output (decode heavy) + long-prompt (prefill heavy) mix.
        let mut reqs = Vec::new();
        for i in 0..4u32 {
            reqs.push(req(i, vec![i + 1, i + 2], 40)); // decode heavy
        }
        for i in 4..8u32 {
            let p: Vec<u32> = (0..60).map(|k| 300 + i * 100 + k).collect();
            reqs.push(req(i, p, 2)); // prefill heavy
        }
        let w = Workload::new("mix", reqs);
        let order: Vec<u32> = (0..8).collect();
        let rep = s.serve(&w, &order).unwrap();
        assert!(rep.blended_steps > 0, "no blended steps");
    }

    #[test]
    #[ignore = "requires AOT artifacts + a real libxla_extension (PJRT); the build image ships the compile-only xla stub — see DESIGN.md §Test-Triage"]
    fn deterministic_generation() {
        let Some(mut s) = server() else { return };
        let w = Workload::new("det", vec![req(0, vec![5, 6, 7], 8)]);
        let r1 = s.serve(&w, &[0]).unwrap();
        // Re-serve on a fresh server: token counts identical.
        let Some(mut s2) = server() else { return };
        let r2 = s2.serve(&w, &[0]).unwrap();
        assert_eq!(r1.output_tokens, r2.output_tokens);
        assert_eq!(r1.total_tokens, r2.total_tokens);
    }
}
