//! Artifact manifest + weights loader (the contract with aot.py).

use crate::util::Json;
use anyhow::{anyhow, Context, Result};
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

/// One tensor's slice of weights.bin.
#[derive(Clone, Debug, PartialEq)]
pub struct TensorMeta {
    pub name: String,
    pub shape: Vec<usize>,
    pub offset_bytes: usize,
    pub size_bytes: usize,
}

/// Parsed manifest.json.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub dir: PathBuf,
    // Model constants (must match python ModelConfig).
    pub vocab: usize,
    pub d_model: usize,
    pub n_layers: usize,
    pub n_q_heads: usize,
    pub n_kv_heads: usize,
    pub head_dim: usize,
    pub max_seq: usize,
    pub n_segments: usize,
    pub bkv: usize,
    pub param_count: usize,
    pub kv_shape: Vec<usize>,
    /// Token budget T -> HLO file name.
    pub step_variants: BTreeMap<usize, String>,
    pub tensors: Vec<TensorMeta>,
    pub weights_total_bytes: usize,
}

impl Manifest {
    pub fn load(dir: &Path) -> Result<Manifest> {
        let text = std::fs::read_to_string(dir.join("manifest.json"))
            .with_context(|| format!("reading {}/manifest.json", dir.display()))?;
        let j = Json::parse(&text).map_err(|e| anyhow!("manifest: {e}"))?;
        let model = j.req("model").map_err(|e| anyhow!("{e}"))?;
        let get = |key: &str| -> Result<usize> {
            model
                .req(key)
                .map_err(|e| anyhow!("{e}"))?
                .as_usize()
                .ok_or_else(|| anyhow!("model.{key} not a number"))
        };
        let kv_shape: Vec<usize> = j
            .req("kv_shape")
            .map_err(|e| anyhow!("{e}"))?
            .as_arr()
            .ok_or_else(|| anyhow!("kv_shape not an array"))?
            .iter()
            .map(|x| x.as_usize().unwrap_or(0))
            .collect();
        let mut step_variants = BTreeMap::new();
        for (k, v) in j
            .req("step_variants")
            .map_err(|e| anyhow!("{e}"))?
            .as_obj()
            .ok_or_else(|| anyhow!("step_variants not an object"))?
        {
            step_variants.insert(
                k.parse::<usize>().with_context(|| format!("variant {k}"))?,
                v.as_str().ok_or_else(|| anyhow!("variant path"))?.to_string(),
            );
        }
        let weights = j.req("weights").map_err(|e| anyhow!("{e}"))?;
        let tensors = weights
            .req("tensors")
            .map_err(|e| anyhow!("{e}"))?
            .as_arr()
            .ok_or_else(|| anyhow!("tensors not an array"))?
            .iter()
            .map(|t| -> Result<TensorMeta> {
                Ok(TensorMeta {
                    name: t
                        .req("name")
                        .map_err(|e| anyhow!("{e}"))?
                        .as_str()
                        .ok_or_else(|| anyhow!("tensor name"))?
                        .to_string(),
                    shape: t
                        .req("shape")
                        .map_err(|e| anyhow!("{e}"))?
                        .as_arr()
                        .ok_or_else(|| anyhow!("tensor shape"))?
                        .iter()
                        .map(|x| x.as_usize().unwrap_or(0))
                        .collect(),
                    offset_bytes: t
                        .req("offset_bytes")
                        .map_err(|e| anyhow!("{e}"))?
                        .as_usize()
                        .ok_or_else(|| anyhow!("offset"))?,
                    size_bytes: t
                        .req("size_bytes")
                        .map_err(|e| anyhow!("{e}"))?
                        .as_usize()
                        .ok_or_else(|| anyhow!("size"))?,
                })
            })
            .collect::<Result<Vec<_>>>()?;
        Ok(Manifest {
            dir: dir.to_path_buf(),
            vocab: get("vocab")?,
            d_model: get("d_model")?,
            n_layers: get("n_layers")?,
            n_q_heads: get("n_q_heads")?,
            n_kv_heads: get("n_kv_heads")?,
            head_dim: get("head_dim")?,
            max_seq: get("max_seq")?,
            n_segments: get("n_segments")?,
            bkv: get("bkv")?,
            param_count: get("param_count")?,
            kv_shape,
            step_variants,
            tensors,
            weights_total_bytes: weights
                .req("total_bytes")
                .map_err(|e| anyhow!("{e}"))?
                .as_usize()
                .ok_or_else(|| anyhow!("total_bytes"))?,
        })
    }

    /// Load weights.bin as per-tensor f32 vectors (little-endian contract).
    pub fn load_weights(&self) -> Result<Vec<(TensorMeta, Vec<f32>)>> {
        let blob = std::fs::read(self.dir.join("weights.bin"))
            .with_context(|| "reading weights.bin")?;
        anyhow::ensure!(
            blob.len() == self.weights_total_bytes,
            "weights.bin size {} != manifest {}",
            blob.len(),
            self.weights_total_bytes
        );
        let mut out = Vec::with_capacity(self.tensors.len());
        for t in &self.tensors {
            let bytes = &blob[t.offset_bytes..t.offset_bytes + t.size_bytes];
            let mut v = Vec::with_capacity(bytes.len() / 4);
            for c in bytes.chunks_exact(4) {
                v.push(f32::from_le_bytes([c[0], c[1], c[2], c[3]]));
            }
            let expect: usize = t.shape.iter().product();
            anyhow::ensure!(v.len() == expect, "tensor {} wrong length", t.name);
            out.push((t.clone(), v));
        }
        Ok(out)
    }

    pub fn kv_len(&self) -> usize {
        self.kv_shape.iter().product()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::{artifacts_available, default_artifact_dir};

    #[test]
    #[ignore = "requires AOT artifacts + a real libxla_extension (PJRT); the build image ships the compile-only xla stub — see DESIGN.md §Test-Triage"]
    fn manifest_loads_when_artifacts_present() {
        let dir = default_artifact_dir();
        if !artifacts_available(&dir) {
            eprintln!("skipping: run `make artifacts` first");
            return;
        }
        let m = Manifest::load(&dir).unwrap();
        assert_eq!(m.vocab, 2048);
        assert_eq!(m.kv_shape.len(), 6);
        assert_eq!(m.bkv, m.n_segments + 1);
        assert!(m.step_variants.contains_key(&16));
        assert_eq!(m.tensors.len(), 11);
        assert_eq!(m.tensors[0].name, "embed");
        // Offsets contiguous.
        let mut off = 0;
        for t in &m.tensors {
            assert_eq!(t.offset_bytes, off);
            off += t.size_bytes;
        }
        assert_eq!(off, m.weights_total_bytes);
    }

    #[test]
    #[ignore = "requires AOT artifacts + a real libxla_extension (PJRT); the build image ships the compile-only xla stub — see DESIGN.md §Test-Triage"]
    fn weights_load_and_param_count_matches() {
        let dir = default_artifact_dir();
        if !artifacts_available(&dir) {
            eprintln!("skipping: run `make artifacts` first");
            return;
        }
        let m = Manifest::load(&dir).unwrap();
        let ws = m.load_weights().unwrap();
        let total: usize = ws.iter().map(|(_, v)| v.len()).sum();
        assert_eq!(total, m.param_count);
        // Norm weights initialize to ones.
        let ln_f = ws.iter().find(|(t, _)| t.name == "ln_f").unwrap();
        assert!(ln_f.1.iter().all(|&x| x == 1.0));
    }
}
