//! `RealModel`: the compiled blended-step executables + host-side KV state.
//!
//! The KV cache lives on the host (`Vec<f32>`) between steps.  That buys
//! two things on the CPU platform: (a) prefix-KV reuse is a memcpy of
//! rows between segments, giving *real* prefix sharing; (b) segment resets
//! are free.  The per-step host↔device copy (~5 MB each way) is the price;
//! §Perf measures it and the CPU device makes it a memcpy.

use super::artifacts::Manifest;
use anyhow::{anyhow, Context, Result};
use std::collections::BTreeMap;
use std::path::Path;

pub struct RealModel {
    pub manifest: Manifest,
    client: xla::PjRtClient,
    /// Token budget T -> compiled executable.
    exes: BTreeMap<usize, xla::PjRtLoadedExecutable>,
    /// Weight literals in aot input order (after kv/tokens/seg/pos).
    weight_lits: Vec<xla::Literal>,
    /// Host KV cache [L, 2, BKV, S, NKV, HD] flattened row-major.
    pub kv: Vec<f32>,
    /// Steps executed (stats).
    pub steps: u64,
    /// Wall time inside PJRT execute (stats).
    pub exec_seconds: f64,
}

impl RealModel {
    /// Load artifacts, compile every step variant on the CPU PJRT client.
    pub fn load(dir: &Path) -> Result<RealModel> {
        let manifest = Manifest::load(dir)?;
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("pjrt: {e}"))?;
        let mut exes = BTreeMap::new();
        for (&t, file) in &manifest.step_variants {
            let proto = xla::HloModuleProto::from_text_file(
                dir.join(file).to_str().context("path utf8")?,
            )
            .map_err(|e| anyhow!("parse {file}: {e}"))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = client.compile(&comp).map_err(|e| anyhow!("compile {file}: {e}"))?;
            exes.insert(t, exe);
        }
        let weights = manifest.load_weights()?;
        let weight_lits = weights
            .into_iter()
            .map(|(meta, data)| {
                let dims: Vec<i64> = meta.shape.iter().map(|&d| d as i64).collect();
                xla::Literal::vec1(&data)
                    .reshape(&dims)
                    .map_err(|e| anyhow!("reshape {}: {e}", meta.name))
            })
            .collect::<Result<Vec<_>>>()?;
        let kv = vec![0f32; manifest.kv_len()];
        Ok(RealModel {
            manifest,
            client,
            exes,
            weight_lits,
            kv,
            steps: 0,
            exec_seconds: 0.0,
        })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Available token budgets, ascending.
    pub fn variants(&self) -> Vec<usize> {
        self.exes.keys().copied().collect()
    }

    /// Smallest variant that fits `n` tokens (largest variant if none).
    pub fn pick_variant(&self, n: usize) -> usize {
        for &t in self.exes.keys() {
            if n <= t {
                return t;
            }
        }
        *self.exes.keys().last().expect("at least one variant")
    }

    /// Execute one blended step.  Inputs may be shorter than the chosen
    /// variant; they are padded onto the scratch segment.  Returns the
    /// greedy next ids for the *real* rows.
    pub fn step(&mut self, tokens: &[i32], seg_id: &[i32], q_pos: &[i32]) -> Result<Vec<i32>> {
        let n = tokens.len();
        anyhow::ensure!(n > 0, "empty step");
        anyhow::ensure!(
            seg_id.len() == n && q_pos.len() == n,
            "ragged step arrays disagree"
        );
        let scratch = (self.manifest.bkv - 1) as i32;
        for (&s, &p) in seg_id.iter().zip(q_pos) {
            anyhow::ensure!(
                (s as usize) < self.manifest.bkv,
                "segment {s} out of range"
            );
            anyhow::ensure!(
                (p as usize) < self.manifest.max_seq,
                "position {p} out of range"
            );
        }
        let t = self.pick_variant(n);
        anyhow::ensure!(n <= t, "step of {n} tokens exceeds largest variant {t}");

        let mut tok = tokens.to_vec();
        let mut seg = seg_id.to_vec();
        let mut pos = q_pos.to_vec();
        // Pad onto the scratch segment at distinct positions.
        let mut pad_pos = 0i32;
        while tok.len() < t {
            tok.push(0);
            seg.push(scratch);
            pos.push(pad_pos % self.manifest.max_seq as i32);
            pad_pos += 1;
        }

        let kv_dims: Vec<i64> = self.manifest.kv_shape.iter().map(|&d| d as i64).collect();
        let kv_lit = xla::Literal::vec1(&self.kv)
            .reshape(&kv_dims)
            .map_err(|e| anyhow!("kv reshape: {e}"))?;
        let tok_lit = xla::Literal::vec1(&tok);
        let seg_lit = xla::Literal::vec1(&seg);
        let pos_lit = xla::Literal::vec1(&pos);

        let mut inputs: Vec<&xla::Literal> = vec![&kv_lit, &tok_lit, &seg_lit, &pos_lit];
        for w in &self.weight_lits {
            inputs.push(w);
        }

        // lint:allow(r2) -- reports real PJRT execute latency; tokens are unaffected
        let start = std::time::Instant::now();
        let exe = self.exes.get(&t).expect("variant exists");
        let result = exe
            .execute::<&xla::Literal>(&inputs)
            .map_err(|e| anyhow!("execute: {e}"))?;
        let out = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetch: {e}"))?;
        self.exec_seconds += start.elapsed().as_secs_f64();
        self.steps += 1;

        let mut parts = out.to_tuple().map_err(|e| anyhow!("tuple: {e}"))?;
        anyhow::ensure!(parts.len() == 2, "expected (kv, ids), got {}", parts.len());
        let ids = parts.pop().unwrap();
        let kv_new = parts.pop().unwrap();
        kv_new
            .copy_raw_to::<f32>(&mut self.kv)
            .map_err(|e| anyhow!("kv copy: {e}"))?;
        let ids: Vec<i32> = ids.to_vec::<i32>().map_err(|e| anyhow!("ids: {e}"))?;
        Ok(ids[..n].to_vec())
    }

    // ---- host-side KV manipulation (prefix reuse) ----

    /// Row stride in floats (one token's K or V in one layer).
    fn row(&self) -> usize {
        self.manifest.n_kv_heads * self.manifest.head_dim
    }

    /// Copy KV rows `[0, rows)` from segment `from` to segment `to` in all
    /// layers — the real prefix-sharing primitive.
    pub fn copy_prefix(&mut self, from: usize, to: usize, rows: usize) {
        assert!(from < self.manifest.bkv && to < self.manifest.bkv);
        assert!(rows <= self.manifest.max_seq);
        if from == to || rows == 0 {
            return;
        }
        let (l, s, row) = (self.manifest.n_layers, self.manifest.max_seq, self.row());
        let seg_stride = s * row; // one segment within (layer, k/v)
        let kvhalf_stride = self.manifest.bkv * seg_stride;
        for layer in 0..l {
            for half in 0..2 {
                let base = (layer * 2 + half) * kvhalf_stride;
                let src = base + from * seg_stride;
                let dst = base + to * seg_stride;
                // Non-overlapping (from != to): safe to split_at_mut via
                // copy_within.
                self.kv.copy_within(src..src + rows * row, dst);
            }
        }
    }

    /// Zero a segment's KV (slot recycling hygiene; attention masks make
    /// this semantically unnecessary, but it keeps state auditable).
    pub fn clear_segment(&mut self, seg: usize) {
        assert!(seg < self.manifest.bkv);
        let (l, s, row) = (self.manifest.n_layers, self.manifest.max_seq, self.row());
        let seg_stride = s * row;
        let kvhalf_stride = self.manifest.bkv * seg_stride;
        for layer in 0..l {
            for half in 0..2 {
                let base = (layer * 2 + half) * kvhalf_stride + seg * seg_stride;
                self.kv[base..base + seg_stride].fill(0.0);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::{artifacts_available, default_artifact_dir};
    use crate::util::Json;

    fn model() -> Option<RealModel> {
        let dir = default_artifact_dir();
        if !artifacts_available(&dir) {
            eprintln!("skipping: run `make artifacts` first");
            return None;
        }
        Some(RealModel::load(&dir).expect("load artifacts"))
    }

    #[test]
    #[ignore = "requires AOT artifacts + a real libxla_extension (PJRT); the build image ships the compile-only xla stub — see DESIGN.md §Test-Triage"]
    fn loads_and_compiles() {
        let Some(m) = model() else { return };
        assert_eq!(m.platform().to_lowercase(), "cpu");
        assert_eq!(m.variants(), vec![16, 64]);
        assert_eq!(m.pick_variant(10), 16);
        assert_eq!(m.pick_variant(17), 64);
        assert_eq!(m.pick_variant(999), 64);
    }

    #[test]
    #[ignore = "requires AOT artifacts + a real libxla_extension (PJRT); the build image ships the compile-only xla stub — see DESIGN.md §Test-Triage"]
    fn golden_cross_check_prefill_and_decode() {
        // The decisive L3<->L2<->L1 integration test: the compiled HLO must
        // reproduce the python step() greedy ids bit-exactly.
        let Some(mut m) = model() else { return };
        let dir = default_artifact_dir();
        let golden = Json::parse(&std::fs::read_to_string(dir.join("golden.json")).unwrap())
            .unwrap();
        let arr = |j: &Json, k: &str| -> Vec<i32> {
            j.get(k)
                .unwrap()
                .as_arr()
                .unwrap()
                .iter()
                .map(|x| x.as_f64().unwrap() as i32)
                .collect()
        };
        for phase in ["prefill", "decode"] {
            let g = golden.get(phase).unwrap();
            let tokens = arr(g, "tokens");
            let seg = arr(g, "seg_id");
            let pos = arr(g, "q_pos");
            let want = arr(g, "next_ids");
            let got = m.step(&tokens, &seg, &pos).unwrap();
            assert_eq!(got, want, "{phase} ids mismatch");
        }
    }

    #[test]
    #[ignore = "requires AOT artifacts + a real libxla_extension (PJRT); the build image ships the compile-only xla stub — see DESIGN.md §Test-Triage"]
    fn prefix_copy_reproduces_decode() {
        // Prefill segment 0 with a prompt; copy its prefix KV to segment 1
        // and decode there: the next id must equal decoding on segment 0.
        let Some(mut m) = model() else { return };
        let prompt: Vec<i32> = vec![7, 11, 13, 17, 19, 23, 29, 31];
        let n = prompt.len();
        let seg0 = vec![0i32; n];
        let pos: Vec<i32> = (0..n as i32).collect();
        let ids = m.step(&prompt, &seg0, &pos).unwrap();
        let next_tok = ids[n - 1];
        // Decode on segment 0 (reference).
        let mut m_ref_kv = m.kv.clone();
        let ref_id = m.step(&[next_tok], &[0], &[n as i32]).unwrap()[0];
        // Restore, copy prefix to segment 1, decode there.
        std::mem::swap(&mut m.kv, &mut m_ref_kv);
        m.copy_prefix(0, 1, n);
        let got = m.step(&[next_tok], &[1], &[n as i32]).unwrap()[0];
        assert_eq!(got, ref_id, "prefix-copied decode diverged");
    }

    #[test]
    #[ignore = "requires AOT artifacts + a real libxla_extension (PJRT); the build image ships the compile-only xla stub — see DESIGN.md §Test-Triage"]
    fn step_validates_inputs() {
        let Some(mut m) = model() else { return };
        assert!(m.step(&[], &[], &[]).is_err());
        assert!(m.step(&[1], &[99], &[0]).is_err()); // bad segment
        assert!(m.step(&[1], &[0], &[4096]).is_err()); // bad position
        assert!(m.step(&[1, 2], &[0], &[0]).is_err()); // ragged
    }

    #[test]
    #[ignore = "requires AOT artifacts + a real libxla_extension (PJRT); the build image ships the compile-only xla stub — see DESIGN.md §Test-Triage"]
    fn clear_segment_zeroes_only_that_segment() {
        let Some(mut m) = model() else { return };
        let prompt: Vec<i32> = (1..9).collect();
        let pos: Vec<i32> = (0..8).collect();
        m.step(&prompt, &vec![0; 8], &pos).unwrap();
        m.step(&prompt, &vec![1; 8], &pos).unwrap();
        let kv_before = m.kv.clone();
        m.clear_segment(0);
        // Segment 1 rows unchanged: decode on seg 1 gives same id as before.
        assert_ne!(m.kv, kv_before);
        let a = {
            let mut m2_kv = kv_before.clone();
            std::mem::swap(&mut m.kv, &mut m2_kv);
            let id = m.step(&[5], &[1], &[8]).unwrap()[0];
            std::mem::swap(&mut m.kv, &mut m2_kv);
            id
        };
        m.clear_segment(0);
        let b = m.step(&[5], &[1], &[8]).unwrap()[0];
        assert_eq!(a, b);
    }
}
