//! §4 performance model: request-level compute/memory time, compute
//! density, batch-level equivalence, and the §3.3 optimal-throughput bound.
//!
//! All quantities are in SI units (seconds, bytes, FLOPs).  The model is
//! the paper's:
//!
//! ```text
//! Comp(r) ≈ (2 (p+d) P_model + 4 p² H L) / compute
//! Mem(r)  ≈ (p d + d²/2) · H_kv · L · 4 / bandwidth
//! ρ(r)    = Comp(r) / Mem(r)
//! ρ(R)    = (1-s) · ΣComp / ΣMem          (sharing-discounted, §5.1)
//! T_o     = max((1-s_o) · T_comp, T_mem)  (§3.3)
//! ```
//!
//! The paper derives then omits the quadratic prefill-attention term; we
//! keep it behind a flag (default on) because it matters for the long-input
//! Azure/BurstGPT traces.

pub mod roofline;

use crate::config::{HardwareSpec, ModalityConfig, ModelSpec};

/// Per-request resource demand (compute seconds, memory seconds, encoder
/// seconds).
///
/// `enc` is the multi-modal vision-encoder term (DESIGN.md §10): pure
/// compute with no KV bytes, so it raises density without touching `mem`.
/// It is populated only by [`PerfModel::demand_mm`] on a modality-aware
/// model; every pre-modality path keeps it at exactly 0.0.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Demand {
    pub comp: f64,
    pub mem: f64,
    pub enc: f64,
}

impl Demand {
    pub const ZERO: Demand = Demand { comp: 0.0, mem: 0.0, enc: 0.0 };

    /// Total compute-side seconds (LM GEMMs + encoder passes).
    pub fn comp_total(&self) -> f64 {
        self.comp + self.enc
    }

    pub fn density(&self) -> f64 {
        if self.mem <= 0.0 {
            f64::INFINITY
        } else {
            self.comp_total() / self.mem
        }
    }

    pub fn add(&mut self, other: Demand) {
        self.comp += other.comp;
        self.mem += other.mem;
        self.enc += other.enc;
    }

    pub fn sub(&mut self, other: Demand) {
        self.comp -= other.comp;
        self.mem -= other.mem;
        self.enc -= other.enc;
    }
}

/// The §4 analytical performance model for one model replica.
///
/// Tensor parallelism scales both `compute` and `bandwidth` by the replica's
/// GPU count (§5.5: TP communication is overlappable, §7: SP/CP likewise
/// scale both resources).
#[derive(Clone, Debug)]
pub struct PerfModel {
    pub model: ModelSpec,
    pub hw: HardwareSpec,
    pub n_gpus: usize,
    /// Include the 4 p² H L prefill-attention FLOPs term.
    pub prefill_attn_flops: bool,
    /// Vision-encoder FLOPs per encoder token (2 · P_encoder; linear-term
    /// roofline, like `comp_tokens`).  Set from `[modality]
    /// encoder_params`; the default matches
    /// [`ModalityConfig::default`].
    pub enc_flops_per_token: f64,
    /// Include the encoder term in [`Self::demand_mm`] (and therefore in
    /// tree/scanner densities).  Mirrors `[modality] enabled`; the
    /// engine's *physics* (`encode_time`) is not gated by this — only
    /// what the scheduler gets to see.
    pub modality_aware: bool,
}

impl PerfModel {
    pub fn new(model: ModelSpec, hw: HardwareSpec, n_gpus: usize) -> Self {
        assert!(n_gpus >= 1);
        PerfModel {
            model,
            hw,
            n_gpus,
            prefill_attn_flops: true,
            enc_flops_per_token: 2.0 * ModalityConfig::DEFAULT_ENCODER_PARAMS,
            modality_aware: false,
        }
    }

    /// Apply the `[modality]` section: encoder sizing always (it is the
    /// physics constant), density awareness per `enabled`.
    pub fn set_modality(&mut self, m: &ModalityConfig) {
        self.enc_flops_per_token = 2.0 * m.encoder_params;
        self.modality_aware = m.enabled;
    }

    /// Effective FLOP/s of the replica.
    pub fn compute(&self) -> f64 {
        self.hw.compute_flops * self.n_gpus as f64
    }

    /// Effective bytes/s of the replica.
    pub fn bandwidth(&self) -> f64 {
        self.hw.bandwidth * self.n_gpus as f64
    }

    /// KV capacity of the replica, tokens.
    pub fn kv_capacity_tokens(&self) -> f64 {
        self.hw.kv_capacity_tokens(&self.model, self.n_gpus)
    }

    // ---- request level (§4.1) ----

    /// Total compute-bound operator time of a request with input length `p`
    /// and output length `d`.
    pub fn comp_request(&self, p: usize, d: usize) -> f64 {
        let (p, d) = (p as f64, d as f64);
        let mut flops = 2.0 * (p + d) * self.model.params;
        if self.prefill_attn_flops {
            flops += 4.0 * p * p * self.model.hidden as f64 * self.model.layers as f64;
        }
        flops / self.compute()
    }

    /// Total memory-bound operator time: d decode steps each loading the
    /// running KV context: Σ_{i=1..d} (p+i) tokens = p·d + d²/2 (+d/2 ≈).
    pub fn mem_request(&self, p: usize, d: usize) -> f64 {
        let (p, d) = (p as f64, d as f64);
        let tokens_loaded = p * d + 0.5 * d * d;
        tokens_loaded * self.model.kv_bytes_per_token / self.bandwidth()
    }

    pub fn demand(&self, p: usize, d: usize) -> Demand {
        Demand { comp: self.comp_request(p, d), mem: self.mem_request(p, d), enc: 0.0 }
    }

    /// Multi-modal demand: text demand plus the encoder-compute term for
    /// `enc_tokens` of attached media — included only when this model is
    /// `modality_aware` (so a modality-blind scheduler prices the same
    /// request as pure text).
    pub fn demand_mm(&self, p: usize, d: usize, enc_tokens: u64) -> Demand {
        let mut dem = self.demand(p, d);
        if self.modality_aware && enc_tokens > 0 {
            dem.enc = self.encode_time(enc_tokens as f64);
        }
        dem
    }

    /// Request-level compute density ρ(r).
    pub fn density(&self, p: usize, d: usize) -> f64 {
        self.demand(p, d).density()
    }

    // ---- encoder level (modality module, DESIGN.md §10) ----

    /// Vision-encoder pass time for `enc_tokens` patch/frame tokens.
    /// Compute-only (no KV bytes): the engine overlaps it into the
    /// compute headroom of memory-bound steps.  NOT gated by
    /// `modality_aware` — this is physics, not scheduler knowledge.
    pub fn encode_time(&self, enc_tokens: f64) -> f64 {
        enc_tokens * self.enc_flops_per_token / self.compute()
    }

    // ---- incremental step-level quantities used by the engine ----

    /// GEMM compute time for processing `n_tokens` tokens in one step
    /// (QKV/FFN/O projections dominate: 2 FLOPs per token per parameter).
    pub fn comp_tokens(&self, n_tokens: usize) -> f64 {
        2.0 * n_tokens as f64 * self.model.params / self.compute()
    }

    /// Prefill self-attention compute for a chunk of `chunk` tokens whose
    /// context (including the chunk) ends at `ctx_end`: 2 GEMMs of
    /// `chunk x ctx x H` per layer ≈ 4·chunk·ctx·H·L FLOPs.
    pub fn comp_prefill_attn(&self, chunk: usize, ctx_end: usize) -> f64 {
        if !self.prefill_attn_flops {
            return 0.0;
        }
        4.0 * chunk as f64
            * ctx_end as f64
            * self.model.hidden as f64
            * self.model.layers as f64
            / self.compute()
    }

    /// Memory time to stream `ctx_tokens` of KV cache (one decode step of a
    /// request with that context, or summed over a batch).
    pub fn mem_kv_load(&self, ctx_tokens: f64) -> f64 {
        ctx_tokens * self.model.kv_bytes_per_token / self.bandwidth()
    }

    // ---- host-link level (kv module, DESIGN.md §9) ----

    /// Host-link (PCIe) bandwidth of the replica, bytes/s.  Each GPU owns
    /// its own link, so like `compute`/`bandwidth` it scales with the
    /// replica's GPU count.
    pub fn link_bandwidth(&self) -> f64 {
        self.hw.pcie_gbps * 1e9 * self.n_gpus as f64
    }

    /// Time to move `tokens` of KV one way across the host link
    /// (infinite when the hardware has no link — offload then never
    /// pays off).
    pub fn link_kv_time(&self, tokens: f64) -> f64 {
        let bw = self.link_bandwidth();
        if bw <= 0.0 {
            f64::INFINITY
        } else {
            tokens * self.model.kv_bytes_per_token / bw
        }
    }

    /// Round-trip (swap-out now + swap-in later) link time for `tokens`.
    pub fn link_kv_roundtrip(&self, tokens: f64) -> f64 {
        2.0 * self.link_kv_time(tokens)
    }

    // ---- set level (§5.1) ----

    /// Sharing-discounted density of a request set:
    /// ((1-s)·ΣComp + ΣEnc) / ΣMem.  The encoder term is not discounted —
    /// prefix sharing eliminates shared *prefill*, not encoder passes
    /// (media dedup is the EncoderCache's job, priced separately).
    pub fn set_density(&self, demands: &Demand, sharing: f64) -> f64 {
        assert!((0.0..=1.0).contains(&sharing), "s={sharing}");
        if demands.mem <= 0.0 {
            return f64::INFINITY;
        }
        ((1.0 - sharing) * demands.comp + demands.enc) / demands.mem
    }

    // ---- workload level (§3.3) ----

    /// Idealized optimal execution time
    /// T_o = max((1-s)·T_comp + T_enc, T_mem).
    pub fn optimal_time(&self, total: Demand, sharing: f64) -> f64 {
        ((1.0 - sharing) * total.comp + total.enc).max(total.mem)
    }

    /// Practical optimal: idealized T_o inflated by the profiled spatial-
    /// sharing interference (§6.2 "practical upperbound").
    pub fn practical_optimal_time(&self, total: Demand, sharing: f64) -> f64 {
        self.optimal_time(total, sharing) * (1.0 + self.hw.interference)
    }
}

/// Solve the §5.3 memory-partition equations:
///
/// ```text
/// M_L + M_R = M
/// M_L·ρ(R_L) + M_R·ρ(R_R) = M·ρ(rt)
/// ```
///
/// Returns `(M_L, M_R)` clamped to `[0, M]` (when the target density is not
/// between the two node densities, the partition saturates at one side —
/// the scanner then simply drains that side).
pub fn partition_memory(m: f64, rho_root: f64, rho_l: f64, rho_r: f64) -> (f64, f64) {
    assert!(m >= 0.0);
    let denom = rho_l - rho_r;
    if denom.abs() < 1e-12 {
        // Both sides equally dense: split evenly.
        return (m / 2.0, m / 2.0);
    }
    let ml = (m * (rho_root - rho_r) / denom).clamp(0.0, m);
    (ml, m - ml)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::presets;

    fn pm() -> PerfModel {
        PerfModel::new(presets::llama3_8b(), presets::a100_80gb(), 1)
    }

    #[test]
    fn density_decreases_with_output_length() {
        let pm = pm();
        // Fig. 4: longer outputs -> memory intensive.
        let d_short = pm.density(512, 32);
        let d_long = pm.density(512, 4096);
        assert!(d_short > 1.0, "short-output should be compute bound: {d_short}");
        assert!(d_long < 1.0, "long-output should be memory bound: {d_long}");
        assert!(d_short > d_long * 10.0);
    }

    #[test]
    fn density_vs_input_length_is_u_shaped() {
        // At fixed d, growing p first *lowers* density (each decode step
        // must stream a longer KV context) and eventually raises it again
        // (quadratic prefill attention dominates) — the Fig. 4 heatmap.
        let pm = pm();
        let short = pm.density(128, 256);
        let mid = pm.density(4096, 256);
        let long = pm.density(65536, 256);
        assert!(short > mid, "short={short} mid={mid}");
        assert!(long > mid, "long={long} mid={mid}");
    }

    #[test]
    fn mem_request_matches_closed_form() {
        let pm = pm();
        let (p, d) = (100usize, 10usize);
        // Σ_{i=1..d}(p+i) = p·d + d(d+1)/2 ≈ p·d + d²/2 (paper's form).
        let approx = pm.mem_request(p, d);
        let exact_tokens: f64 = (1..=d).map(|i| (p + i) as f64).sum();
        let exact = exact_tokens * pm.model.kv_bytes_per_token / pm.bandwidth();
        assert!((approx - exact).abs() / exact < 0.01);
    }

    #[test]
    fn comp_scales_with_params() {
        let small = pm();
        let big = PerfModel::new(presets::llama3_70b(), presets::a100_80gb(), 8);
        // Same request, bigger model on 8 gpus: 70/8 ≈ 8.8x params on 8x
        // compute -> slightly more time per request.
        let a = small.comp_request(1000, 100);
        let b = big.comp_request(1000, 100);
        assert!(b > a * 0.9 && b < a * 1.6, "a={a} b={b}");
    }

    #[test]
    fn tp_scales_both_resources() {
        let one = pm();
        let eight = PerfModel::new(presets::llama3_8b(), presets::a100_80gb(), 8);
        // Density is invariant under TP (both resources scale together).
        let d1 = one.density(777, 123);
        let d8 = eight.density(777, 123);
        assert!((d1 - d8).abs() < 1e-9);
        assert!((one.comp_request(777, 123) / eight.comp_request(777, 123) - 8.0).abs() < 1e-9);
    }

    #[test]
    fn sharing_discount_reduces_density() {
        let pm = pm();
        let d = pm.demand(1000, 100);
        let rho_0 = pm.set_density(&d, 0.0);
        let rho_half = pm.set_density(&d, 0.5);
        assert!((rho_half - rho_0 * 0.5).abs() < 1e-9);
    }

    #[test]
    fn optimal_time_is_max() {
        let pm = pm();
        let total = Demand { comp: 10.0, mem: 4.0, enc: 0.0 };
        assert_eq!(pm.optimal_time(total, 0.0), 10.0);
        assert_eq!(pm.optimal_time(total, 0.7), 4.0); // 3.0 comp < 4.0 mem
        let practical = pm.practical_optimal_time(total, 0.0);
        assert!((practical - 11.5).abs() < 1e-9); // x1.15 interference
    }

    #[test]
    fn partition_memory_satisfies_equations() {
        let (ml, mr) = partition_memory(60e9, 1.27, 3.73, 0.096);
        assert!((ml + mr - 60e9).abs() < 1.0);
        // The paper's Figure 6 example: 19.3 GB / 40.7 GB.
        assert!((ml / 1e9 - 19.4).abs() < 0.5, "ml={}", ml / 1e9);
        assert!((mr / 1e9 - 40.6).abs() < 0.5, "mr={}", mr / 1e9);
        let achieved = (ml * 3.73 + mr * 0.096) / 60e9;
        assert!((achieved - 1.27).abs() < 1e-9);
    }

    #[test]
    fn partition_memory_clamps() {
        // Target density above both sides: all memory goes left.
        let (ml, mr) = partition_memory(10.0, 5.0, 2.0, 1.0);
        assert_eq!(ml, 10.0);
        assert_eq!(mr, 0.0);
        // Degenerate equal densities: even split.
        let (ml, mr) = partition_memory(10.0, 1.0, 2.0, 2.0);
        assert_eq!(ml, 5.0);
        assert_eq!(mr, 5.0);
    }

    #[test]
    fn prefill_attn_term_togglable() {
        let mut pm = pm();
        let with = pm.comp_request(4096, 1);
        pm.prefill_attn_flops = false;
        let without = pm.comp_request(4096, 1);
        assert!(with > without);
        // At p=4096 the quadratic term is noticeable but not dominant.
        assert!(with / without < 2.0);
    }

    #[test]
    fn link_time_scales_with_tokens_and_gpus() {
        let one = pm();
        // A100 x1: 32 GB/s; 1000 tokens x 131072 B = 131 MB -> ~4.1 ms.
        let t = one.link_kv_time(1000.0);
        assert!((t - 1000.0 * 131072.0 / 32e9).abs() < 1e-12);
        assert_eq!(one.link_kv_roundtrip(1000.0), 2.0 * t);
        // Each GPU owns a link: 8 GPUs move the same tokens 8x faster.
        let eight = PerfModel::new(presets::llama3_8b(), presets::a100_80gb(), 8);
        assert!((eight.link_kv_time(1000.0) - t / 8.0).abs() < 1e-15);
        // The host link is far slower than HBM: streaming the same
        // tokens over PCIe costs ~64x the HBM pass on the A100.
        assert!(one.link_kv_time(1000.0) > one.mem_kv_load(1000.0) * 10.0);
    }

    #[test]
    fn linkless_hardware_has_infinite_link_time() {
        let pm = PerfModel::new(presets::tiny_cpu(), presets::cpu_host(), 1);
        assert_eq!(pm.link_bandwidth(), 0.0);
        assert!(pm.link_kv_time(1.0).is_infinite());
        assert!(pm.link_kv_roundtrip(1.0).is_infinite());
    }

    #[test]
    fn encoder_term_raises_density_only_when_aware() {
        let mut pm = pm();
        // Memory-bound text request; heavy conditioning attachment.
        let blind = pm.demand_mm(120, 2048, 8192);
        assert_eq!(blind.enc, 0.0, "blind model must not price the encoder");
        assert_eq!(blind, pm.demand(120, 2048));
        pm.modality_aware = true;
        let aware = pm.demand_mm(120, 2048, 8192);
        assert!((aware.enc - pm.encode_time(8192.0)).abs() < 1e-18);
        assert_eq!(aware.comp, blind.comp);
        assert_eq!(aware.mem, blind.mem);
        assert!(
            aware.density() > blind.density() * 1.5,
            "aware {} vs blind {}",
            aware.density(),
            blind.density()
        );
        // No attachments -> identical even when aware.
        assert_eq!(pm.demand_mm(120, 2048, 0), blind);
    }

    #[test]
    fn encode_time_is_linear_and_tp_scaled() {
        let mut pm = pm();
        pm.enc_flops_per_token = 4e9; // 2B-param encoder
        let t1 = pm.encode_time(1000.0);
        assert!((t1 - 1000.0 * 4e9 / pm.compute()).abs() < 1e-18);
        assert_eq!(pm.encode_time(2000.0), 2.0 * t1);
        let mut eight = PerfModel::new(presets::llama3_8b(), presets::a100_80gb(), 8);
        eight.enc_flops_per_token = 4e9;
        assert!((eight.encode_time(1000.0) - t1 / 8.0).abs() < 1e-18);
    }

    #[test]
    fn encoder_term_in_set_density_and_bounds_undiscounted() {
        let pm = pm();
        let d = Demand { comp: 6.0, mem: 4.0, enc: 2.0 };
        // Sharing discounts comp only: ((1-0.5)·6 + 2) / 4 = 1.25.
        assert!((pm.set_density(&d, 0.5) - 1.25).abs() < 1e-12);
        // T_o = max((1-s)·comp + enc, mem).
        assert_eq!(pm.optimal_time(d, 0.5), 5.0);
        assert_eq!(pm.optimal_time(d, 0.0), 8.0);
        let mut acc = Demand::ZERO;
        acc.add(d);
        acc.add(d);
        assert_eq!(acc.enc, 4.0);
        acc.sub(d);
        assert_eq!(acc, d);
        assert!((d.density() - 2.0).abs() < 1e-12);
    }

    #[test]
    fn zero_output_request_is_pure_compute() {
        let pm = pm();
        let d = pm.demand(100, 0);
        assert!(d.comp > 0.0);
        assert_eq!(d.mem, 0.0);
        assert!(d.density().is_infinite());
    }
}
