//! Roofline utilities (§Perf): arithmetic intensity, MXU/SM utilization
//! estimates, and the estimated-vs-measured operator timing used by the
//! Table 1 harness.

use super::PerfModel;

/// Arithmetic intensity (FLOPs / byte) at which the device flips from
/// memory- to compute-bound.
pub fn ridge_point(pm: &PerfModel) -> f64 {
    pm.compute() / pm.bandwidth()
}

/// Attainable FLOP/s at a given arithmetic intensity (classic roofline).
pub fn attainable_flops(pm: &PerfModel, intensity: f64) -> f64 {
    (intensity * pm.bandwidth()).min(pm.compute())
}

/// Estimated GEMM execution time for the Table 1 micro benchmark:
/// `[batch, hidden] x [hidden, hidden]`-class projections over one
/// transformer layer's GEMMs, approximated (as in §4.1) by
/// `2 * batch * params_per_layer / compute`.
pub fn gemm_time_est(pm: &PerfModel, batch_tokens: usize) -> f64 {
    let per_layer = pm.model.params / pm.model.layers as f64;
    2.0 * batch_tokens as f64 * per_layer / pm.compute()
}

/// Estimated decode-attention time for a batch of `batch` requests each
/// with `seq` cached tokens, one layer: pure KV streaming.
pub fn attention_time_est(pm: &PerfModel, batch: usize, seq: usize) -> f64 {
    let bytes_per_layer = pm.model.kv_bytes_per_token / pm.model.layers as f64;
    batch as f64 * seq as f64 * bytes_per_layer / pm.bandwidth()
}

/// Estimated MXU (or tensor-core) utilization of a blended step that
/// processes `prefill_tokens` GEMM-heavy tokens while streaming
/// `kv_tokens` of KV context: utilization of the compute unit during the
/// step under perfect overlap.
pub fn blended_utilization(
    pm: &PerfModel,
    prefill_tokens: usize,
    decode_tokens: usize,
    kv_tokens: f64,
) -> (f64, f64) {
    let comp = pm.comp_tokens(prefill_tokens + decode_tokens);
    let mem = pm.mem_kv_load(kv_tokens);
    let step = comp.max(mem);
    if step <= 0.0 {
        return (0.0, 0.0);
    }
    (comp / step, mem / step)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::presets;

    fn pm() -> PerfModel {
        PerfModel::new(presets::llama3_8b(), presets::a100_80gb(), 1)
    }

    #[test]
    fn ridge_point_a100() {
        // 312 TFLOPs / 2039 GB/s ≈ 153 FLOPs/byte.
        let r = ridge_point(&pm());
        assert!((r - 153.0).abs() < 2.0, "{r}");
    }

    #[test]
    fn attainable_is_capped() {
        let pm = pm();
        assert_eq!(attainable_flops(&pm, 1e9), pm.compute());
        let low = attainable_flops(&pm, 1.0);
        assert!((low - pm.bandwidth()).abs() < 1.0);
    }

    #[test]
    fn table1_magnitudes() {
        // Paper Table 1 (A100, seq 1024): GEMM ≈ 1.0-2.0 ms for batch
        // 512-1024 tokens; attention ≈ 1.2-2.5 ms. Our estimates should be
        // in the same millisecond regime.
        let pm = pm();
        let gemm = gemm_time_est(&pm, 512) * 1e3;
        let attn = attention_time_est(&pm, 512, 1024) * 1e3;
        assert!(gemm > 0.4 && gemm < 2.0, "gemm={gemm}ms");
        assert!(attn > 0.5 && attn < 3.0, "attn={attn}ms");
    }

    #[test]
    fn blended_utilization_balances() {
        let pm = pm();
        // A compute-heavy step: compute util = 1, memory util < 1.
        let (c, m) = blended_utilization(&pm, 2048, 0, 1000.0);
        assert!((c - 1.0).abs() < 1e-9);
        assert!(m < 1.0);
        // A memory-heavy step.
        let (c2, m2) = blended_utilization(&pm, 64, 256, 3e6);
        assert!((m2 - 1.0).abs() < 1e-9);
        assert!(c2 < 1.0);
    }
}
