//! Online (latency-sensitive) arrival streams for co-located serving.
//!
//! BlendServe (§1, §5) schedules a *closed* offline pool with relaxed
//! latency.  The co-location subsystem (DESIGN.md §Co-located-Serving)
//! adds an *open* stream of online requests in the style of HyGen and the
//! hybrid offline/online schedulers: requests drawn from the same §A.3
//! trace marginals as the offline pool ([`super::generators`]), but tagged
//! with an arrival timestamp and per-request TTFT/TPOT SLOs.
//!
//! Two arrival processes are provided, both byte-for-byte deterministic
//! from the spec's seed:
//!
//! - [`ArrivalProcess::Poisson`]: exponential inter-arrival gaps at a
//!   constant rate — the steady-traffic regime.
//! - [`ArrivalProcess::Bursty`]: a two-phase Markov-modulated Poisson
//!   process alternating calm and burst phases (BurstGPT-style diurnal
//!   bursts compressed to batch scale) — the regime that actually stresses
//!   SLO-aware admission, because bursts demand headroom and the ebbs are
//!   where offline backfill wins its throughput back.
//!
//! SLOs follow the HyGen convention: a baseline per-request latency is
//! derived from the perf model ([`baseline_latency`]: the prompt's own
//! prefill compute plus fully-loaded engine steps) and multiplied by a
//! `slo_scale` knob — scale 1.0 means "no worse than a fully-loaded
//! blended step per token", larger scales relax the deadline.

use super::generators::{spec_for, TraceSpec};
use super::{Request, TraceKind, Workload};
use crate::perfmodel::PerfModel;
use crate::util::DetRng;

/// How online arrivals are spaced in time.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum ArrivalProcess {
    /// Constant-rate Poisson arrivals (`rate` requests/s).
    Poisson { rate: f64 },
    /// Markov-modulated Poisson: calm phases at `rate`, burst phases at
    /// `rate * burst_factor`, with exponentially-distributed phase
    /// lengths of mean `phase_secs`.
    Bursty { rate: f64, burst_factor: f64, phase_secs: f64 },
}

impl ArrivalProcess {
    /// Long-run average arrival rate (requests/s).
    pub fn mean_rate(&self) -> f64 {
        match *self {
            ArrivalProcess::Poisson { rate } => rate,
            // Phases alternate calm/burst with equal mean lengths.
            ArrivalProcess::Bursty { rate, burst_factor, .. } => {
                rate * (1.0 + burst_factor) / 2.0
            }
        }
    }

    /// A bursty process whose *long-run mean* rate is `mean_rate` — the
    /// inverse of [`Self::mean_rate`], kept next to it so the phase
    /// algebra lives in one place.
    pub fn bursty_with_mean(mean_rate: f64, burst_factor: f64, phase_secs: f64) -> Self {
        ArrivalProcess::Bursty {
            rate: 2.0 * mean_rate / (1.0 + burst_factor),
            burst_factor,
            phase_secs,
        }
    }
}

/// Description of one online request stream.
#[derive(Clone, Debug)]
pub struct OnlineSpec {
    /// Which trace's length marginals the requests are drawn from
    /// (chat-style ShareGPT is the natural default for live traffic).
    pub trace: TraceKind,
    pub arrivals: ArrivalProcess,
    /// Number of online requests to generate.
    pub n_requests: usize,
    /// SLO slack multiplier over the idle-replica baseline latency
    /// (HyGen-style; 1.0 = tightest, larger = more relaxed).
    pub slo_scale: f64,
    pub seed: u64,
}

impl OnlineSpec {
    pub fn new(trace: TraceKind, rate: f64, n_requests: usize) -> Self {
        OnlineSpec {
            trace,
            arrivals: ArrivalProcess::Poisson { rate },
            n_requests,
            slo_scale: 5.0,
            seed: 0,
        }
    }

    pub fn with_arrivals(mut self, arrivals: ArrivalProcess) -> Self {
        self.arrivals = arrivals;
        self
    }

    pub fn with_slo_scale(mut self, slo_scale: f64) -> Self {
        assert!(slo_scale > 0.0, "slo_scale must be positive");
        self.slo_scale = slo_scale;
        self
    }

    pub fn with_seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }
}

/// One online request: payload plus arrival time and SLOs (seconds).
#[derive(Clone, Debug)]
pub struct OnlineRequest {
    pub request: Request,
    pub arrival: f64,
    pub ttft_slo: f64,
    pub tpot_slo: f64,
}

/// A generated online stream, arrivals non-decreasing.
#[derive(Clone, Debug, Default)]
pub struct OnlineWorkload {
    pub name: String,
    pub requests: Vec<OnlineRequest>,
}

impl OnlineWorkload {
    pub fn len(&self) -> usize {
        self.requests.len()
    }

    pub fn is_empty(&self) -> bool {
        self.requests.is_empty()
    }

    /// Timestamp of the last arrival (0 for an empty stream).
    pub fn horizon(&self) -> f64 {
        self.requests.last().map(|r| r.arrival).unwrap_or(0.0)
    }

    /// Σ input+output tokens over the stream.
    pub fn total_tokens(&self) -> u64 {
        self.requests
            .iter()
            .map(|r| r.request.input_len() as u64 + r.request.output_len as u64)
            .sum()
    }

    /// The payloads as a plain [`Workload`] (arrival/SLO metadata dropped);
    /// used for tree statistics and tests.
    pub fn as_workload(&self) -> Workload {
        Workload::new(
            &self.name,
            self.requests.iter().map(|r| r.request.clone()).collect(),
        )
    }
}

/// A representative fully-loaded engine step: a default-sized (2048-token)
/// prefill chunk overlapped with a full-KV decode sweep.  Under continuous
/// batching every output token shares its step with the whole batch, so
/// this — not the request's isolated decode time — is the honest latency
/// floor for co-located serving.
fn loaded_step_time(pm: &PerfModel) -> f64 {
    let t_comp = pm.comp_tokens(2048);
    let t_mem = pm.mem_kv_load(pm.kv_capacity_tokens());
    t_comp.max(t_mem) * (1.0 + pm.hw.interference)
}

/// Baseline latencies `(ttft, tpot)` for a request of shape `(p, d)`:
/// TTFT = the prompt's own prefill compute plus two loaded steps (one of
/// admission alignment, one to surface the first token); TPOT = one
/// loaded step per token.  `slo_scale = 1` therefore means "no worse than
/// a fully-loaded blended step", and larger scales relax from there.
pub fn baseline_latency(pm: &PerfModel, p: usize, _d: usize) -> (f64, f64) {
    let step = loaded_step_time(pm);
    let ttft = pm.comp_tokens(p) + pm.comp_prefill_attn(p, p) + 2.0 * step;
    (ttft, step)
}

/// Generate an online stream from the spec.  Deterministic for a given
/// `(spec.trace, spec.seed)`: arrivals, lengths, prompts and SLOs replay
/// exactly.  Token pools are shared with the *offline* generator for the
/// same trace, so online requests participate in prefix sharing (system
/// prompts, MMLU stems) exactly like their offline siblings.
pub fn generate_online(spec: &OnlineSpec, pm: &PerfModel) -> OnlineWorkload {
    let tspec: TraceSpec = spec_for(spec.trace);
    let payloads = super::generators::generate(&tspec, spec.n_requests, spec.seed ^ 0x0a11e);

    let mut rng = DetRng::new(spec.seed).child("online-arrivals");
    let mut clock = 0.0f64;
    // Bursty-phase state: start calm, flip on exponential phase ends.
    let (mut in_burst, mut phase_end) = (false, f64::INFINITY);
    if let ArrivalProcess::Bursty { phase_secs, .. } = spec.arrivals {
        phase_end = exp_draw(&mut rng, 1.0 / phase_secs.max(1e-9));
    }

    let mut requests = Vec::with_capacity(payloads.len());
    for r in payloads.requests.into_iter() {
        match spec.arrivals {
            ArrivalProcess::Poisson { rate } => clock += exp_draw(&mut rng, rate),
            ArrivalProcess::Bursty { rate, burst_factor, phase_secs } => {
                // Phase-aware gap: a draw that crosses a phase boundary is
                // restarted from the boundary at the new phase's rate
                // (valid by exponential memorylessness).  Drawing the whole
                // gap at the start-of-gap rate would let long calm gaps
                // swallow entire bursts and undershoot the long-run mean.
                if rate <= 0.0 {
                    clock = f64::INFINITY; // degenerate spec: no arrivals
                }
                while clock.is_finite() {
                    let rate_now = if in_burst { rate * burst_factor } else { rate };
                    let gap = exp_draw(&mut rng, rate_now);
                    if clock + gap <= phase_end {
                        clock += gap;
                        break;
                    }
                    clock = phase_end;
                    in_burst = !in_burst;
                    phase_end += exp_draw(&mut rng, 1.0 / phase_secs.max(1e-9));
                }
            }
        };
        let (ttft_base, tpot_base) =
            baseline_latency(pm, r.input_len(), r.output_len as usize);
        requests.push(OnlineRequest {
            arrival: clock,
            ttft_slo: ttft_base * spec.slo_scale,
            tpot_slo: tpot_base * spec.slo_scale,
            request: r,
        });
    }
    OnlineWorkload {
        name: format!("online-{}-{}", spec.trace.name(), spec.n_requests),
        requests,
    }
}

/// Exponential inter-arrival draw with the given rate (1/mean).
fn exp_draw(rng: &mut DetRng, rate: f64) -> f64 {
    if rate <= 0.0 {
        return f64::INFINITY;
    }
    -(1.0 - rng.f64()).ln() / rate
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::presets;

    fn pm() -> PerfModel {
        PerfModel::new(presets::llama3_8b(), presets::a100_80gb(), 1)
    }

    #[test]
    fn deterministic_given_seed() {
        let spec = OnlineSpec::new(TraceKind::ShareGpt, 2.0, 200).with_seed(9);
        let a = generate_online(&spec, &pm());
        let b = generate_online(&spec, &pm());
        assert_eq!(a.len(), b.len());
        for (x, y) in a.requests.iter().zip(&b.requests) {
            assert_eq!(x.request.prompt, y.request.prompt);
            assert_eq!(x.arrival, y.arrival);
            assert_eq!(x.ttft_slo, y.ttft_slo);
        }
        let c = generate_online(&spec.clone().with_seed(10), &pm());
        assert_ne!(a.requests[0].arrival, c.requests[0].arrival);
    }

    #[test]
    fn arrivals_sorted_and_rate_matches() {
        let rate = 4.0;
        let spec = OnlineSpec::new(TraceKind::BurstGpt, rate, 2000).with_seed(3);
        let w = generate_online(&spec, &pm());
        for pair in w.requests.windows(2) {
            assert!(pair[0].arrival <= pair[1].arrival);
        }
        // Mean inter-arrival ≈ 1/rate over 2000 draws (±15%).
        let achieved = w.len() as f64 / w.horizon();
        assert!(
            (achieved - rate).abs() / rate < 0.15,
            "achieved rate {achieved} vs target {rate}"
        );
    }

    #[test]
    fn bursty_process_has_heavier_tail_than_poisson() {
        let n = 3000;
        let poisson = generate_online(
            &OnlineSpec::new(TraceKind::ShareGpt, 2.0, n).with_seed(5),
            &pm(),
        );
        let bursty = generate_online(
            &OnlineSpec::new(TraceKind::ShareGpt, 2.0, n)
                .with_arrivals(ArrivalProcess::Bursty {
                    rate: 2.0,
                    burst_factor: 8.0,
                    phase_secs: 20.0,
                })
                .with_seed(5),
            &pm(),
        );
        // Compare coefficient of variation of arrivals-per-window counts:
        // the MMPP must be overdispersed relative to Poisson.
        let cv = |w: &OnlineWorkload| {
            let win = w.horizon() / 50.0;
            let mut counts = vec![0.0f64; 51];
            for r in &w.requests {
                counts[(r.arrival / win) as usize] += 1.0;
            }
            crate::util::stats::stddev(&counts) / crate::util::stats::mean(&counts)
        };
        assert!(
            cv(&bursty) > cv(&poisson) * 1.5,
            "bursty cv {} vs poisson cv {}",
            cv(&bursty),
            cv(&poisson)
        );
    }

    #[test]
    fn slo_scale_scales_deadlines() {
        let tight = generate_online(
            &OnlineSpec::new(TraceKind::ShareGpt, 1.0, 50).with_slo_scale(1.0),
            &pm(),
        );
        let loose = generate_online(
            &OnlineSpec::new(TraceKind::ShareGpt, 1.0, 50).with_slo_scale(10.0),
            &pm(),
        );
        for (a, b) in tight.requests.iter().zip(&loose.requests) {
            assert!((b.ttft_slo / a.ttft_slo - 10.0).abs() < 1e-9);
            assert!((b.tpot_slo / a.tpot_slo - 10.0).abs() < 1e-9);
            assert!(a.ttft_slo > 0.0 && a.tpot_slo > 0.0);
        }
    }

    #[test]
    fn online_prompts_share_pools_with_offline_trace() {
        // The online WildChat stream must share the dataset-wide system
        // prompt with the offline WildChat trace so prefix sharing spans
        // the online/offline boundary.
        let online = generate_online(&OnlineSpec::new(TraceKind::WildChat, 1.0, 20), &pm());
        let offline = super::super::generators::generate_kind(TraceKind::WildChat, 20, 3);
        let sys_len = super::super::generators::wildchat().sys_prompt_len;
        assert_eq!(
            &online.requests[0].request.prompt[..sys_len],
            &offline.requests[0].prompt[..sys_len]
        );
    }

    #[test]
    fn mean_rate_of_processes() {
        assert_eq!(ArrivalProcess::Poisson { rate: 3.0 }.mean_rate(), 3.0);
        let b = ArrivalProcess::Bursty { rate: 2.0, burst_factor: 5.0, phase_secs: 10.0 };
        assert_eq!(b.mean_rate(), 6.0);
    }

    #[test]
    fn as_workload_preserves_payloads() {
        let w = generate_online(&OnlineSpec::new(TraceKind::ShareGpt, 2.0, 30), &pm());
        let plain = w.as_workload();
        assert_eq!(plain.len(), 30);
        assert_eq!(plain.total_tokens(), w.total_tokens());
    }
}
