//! Synthetic trace generators matching the published marginals of the six
//! paper traces (Fig. 2 length distributions, Table 4 density/sharing).
//!
//! Each dataset is described by a [`TraceSpec`]: log-normal input/output
//! length distributions plus a *prefix structure* — a dataset-wide system
//! prompt and per-group shared stems (MMLU subjects share long question
//! stems; chat traces share only their system prompt).  Token ids are drawn
//! deterministically from per-(dataset, group) pools so shared prefixes are
//! literal shared id sequences, exactly what a prefix tree sees.
//!
//! Calibration targets (Llama-3-8B on A100, §4 model): see
//! `expected_density_class` tests and `trace::stats`.

use super::{Request, TraceKind, Workload};
use crate::modality::Attachment;
use crate::util::DetRng;

/// Distribution + prefix-structure description of one dataset.
#[derive(Clone, Debug)]
pub struct TraceSpec {
    pub kind: TraceKind,
    /// Mean input length (tokens) and log-space sigma.
    pub input_mean: f64,
    pub input_sigma: f64,
    /// Mean output length and log-space sigma.
    pub output_mean: f64,
    pub output_sigma: f64,
    /// Length of the dataset-wide shared system prompt.
    pub sys_prompt_len: usize,
    /// Number of groups with an additional shared stem (0 = none).
    pub n_groups: usize,
    /// Length of each group's shared stem.
    pub group_prefix_len: usize,
    /// Clamp bounds for sampled lengths.
    pub min_input: usize,
    pub max_input: usize,
    pub min_output: usize,
    pub max_output: usize,
    /// §5.4: outputs predefined by generation parameters (video/image
    /// generation traces).  Set explicitly per spec so the generator —
    /// not the dataset tag — decides what the scheduler may read.
    pub known_output: bool,
}

impl TraceSpec {
    /// Scale all lengths by `f` (used by the tiny real-model E2E example,
    /// which runs with max_seq=256).
    pub fn scaled(mut self, f: f64) -> Self {
        let s = |x: f64| (x * f).max(1.0);
        self.input_mean = s(self.input_mean);
        self.output_mean = s(self.output_mean);
        self.sys_prompt_len = ((self.sys_prompt_len as f64 * f) as usize).max(1);
        self.group_prefix_len = (self.group_prefix_len as f64 * f) as usize;
        self.min_input = ((self.min_input as f64 * f) as usize).max(1);
        self.max_input = ((self.max_input as f64 * f) as usize).max(2);
        self.min_output = ((self.min_output as f64 * f) as usize).max(1);
        self.max_output = ((self.max_output as f64 * f) as usize).max(2);
        self
    }
}

/// ShareGPT: chat, mild density (~3), negligible sharing (Table 4: 0.02).
pub fn sharegpt() -> TraceSpec {
    TraceSpec {
        kind: TraceKind::ShareGpt,
        input_mean: 250.0,
        input_sigma: 0.9,
        output_mean: 380.0,
        output_sigma: 0.9,
        sys_prompt_len: 5,
        n_groups: 0,
        group_prefix_len: 0,
        min_input: 8,
        max_input: 4096,
        min_output: 4,
        max_output: 4096,
        known_output: false,
    }
}

/// WildChat: chat with a common system prompt (Table 4: sharing 0.19);
/// output normalized for a mildly compute-intensive mix (§A.3).
pub fn wildchat() -> TraceSpec {
    TraceSpec {
        kind: TraceKind::WildChat,
        input_mean: 350.0,
        input_sigma: 0.8,
        output_mean: 480.0,
        output_sigma: 1.0,
        sys_prompt_len: 66,
        n_groups: 0,
        group_prefix_len: 0,
        min_input: 70,
        max_input: 4096,
        min_output: 4,
        max_output: 8192,
        known_output: false,
    }
}

/// Azure-Trace: API service; very long inputs, short outputs (ρ ≈ 33).
pub fn azure_trace() -> TraceSpec {
    TraceSpec {
        kind: TraceKind::AzureTrace,
        input_mean: 2000.0,
        input_sigma: 0.6,
        output_mean: 26.0,
        output_sigma: 0.4,
        sys_prompt_len: 20,
        n_groups: 0,
        group_prefix_len: 0,
        min_input: 128,
        max_input: 8192,
        min_output: 2,
        max_output: 256,
        known_output: false,
    }
}

/// BurstGPT: API service; compute-intensive (ρ ≈ 18), low variance.
pub fn burstgpt() -> TraceSpec {
    TraceSpec {
        kind: TraceKind::BurstGpt,
        input_mean: 650.0,
        input_sigma: 0.5,
        output_mean: 46.0,
        output_sigma: 0.35,
        sys_prompt_len: 13,
        n_groups: 0,
        group_prefix_len: 0,
        min_input: 32,
        max_input: 4096,
        min_output: 2,
        max_output: 512,
        known_output: false,
    }
}

/// OpenVid: video generation; short text prompt, ~16K-token autoregressive
/// output (§A.3 normalizes 45K→16K).  Output length is *predefined* by the
/// frame count, hence the tiny sigma.  Strongly memory-intensive.
pub fn openvid() -> TraceSpec {
    TraceSpec {
        kind: TraceKind::OpenVid,
        input_mean: 120.0,
        input_sigma: 0.5,
        output_mean: 16384.0,
        output_sigma: 0.2,
        sys_prompt_len: 0,
        n_groups: 0,
        group_prefix_len: 0,
        min_input: 8,
        max_input: 1024,
        min_output: 2048,
        max_output: 45056,
        known_output: true,
    }
}

/// MMLU: benchmark; 57 subjects share long few-shot stems (Table 4:
/// sharing 0.86), outputs of a few tokens (ρ ≈ 55).
pub fn mmlu() -> TraceSpec {
    TraceSpec {
        kind: TraceKind::Mmlu,
        input_mean: 400.0,
        input_sigma: 0.25,
        output_mean: 15.0,
        output_sigma: 0.4,
        sys_prompt_len: 12,
        n_groups: 57,
        group_prefix_len: 330,
        min_input: 350,
        max_input: 1024,
        min_output: 2,
        max_output: 64,
        known_output: false,
    }
}

/// LIMO: hard math reasoning; long chain-of-thought outputs
/// (memory-intensive; Fig. 2).
pub fn limo() -> TraceSpec {
    TraceSpec {
        kind: TraceKind::Limo,
        input_mean: 200.0,
        input_sigma: 0.5,
        output_mean: 4000.0,
        output_sigma: 0.6,
        sys_prompt_len: 10,
        n_groups: 0,
        group_prefix_len: 0,
        min_input: 16,
        max_input: 2048,
        min_output: 256,
        max_output: 16384,
        known_output: false,
    }
}

/// VisionArena: multi-modal chat (text marginals; attachments are added
/// by [`generate_vision_arena`]).  Length marginals follow the public
/// VisionArena-Chat summary: short-to-moderate text prompts, chat-length
/// outputs, a shared VLM system prompt.
pub fn vision_arena() -> TraceSpec {
    TraceSpec {
        kind: TraceKind::VisionArena,
        input_mean: 60.0,
        input_sigma: 0.9,
        output_mean: 320.0,
        output_sigma: 0.8,
        sys_prompt_len: 24,
        n_groups: 0,
        group_prefix_len: 0,
        min_input: 25,
        max_input: 2048,
        min_output: 4,
        max_output: 4096,
        known_output: false,
    }
}

pub fn spec_for(kind: TraceKind) -> TraceSpec {
    match kind {
        TraceKind::ShareGpt => sharegpt(),
        TraceKind::WildChat => wildchat(),
        TraceKind::AzureTrace => azure_trace(),
        TraceKind::BurstGpt => burstgpt(),
        TraceKind::OpenVid => openvid(),
        TraceKind::Mmlu => mmlu(),
        TraceKind::Limo => limo(),
        TraceKind::VisionArena => vision_arena(),
        TraceKind::Custom => panic!("no spec for Custom"),
    }
}

/// Token-id space layout: ids are partitioned per dataset/group so distinct
/// pools never collide, keeping accidental prefix sharing at zero.
const DATASET_STRIDE: u32 = 1 << 24;
const GROUP_STRIDE: u32 = 1 << 14;

fn dataset_base(kind: TraceKind) -> u32 {
    let idx = match kind {
        TraceKind::ShareGpt => 1,
        TraceKind::WildChat => 2,
        TraceKind::AzureTrace => 3,
        TraceKind::BurstGpt => 4,
        TraceKind::OpenVid => 5,
        TraceKind::Mmlu => 6,
        TraceKind::Limo => 7,
        TraceKind::Custom => 8,
        TraceKind::VisionArena => 9,
    };
    idx * DATASET_STRIDE
}

/// Generate `n` requests from a spec.  Deterministic for a given
/// (spec.kind, seed): prompts, lengths and group assignment replay exactly.
pub fn generate(spec: &TraceSpec, n: usize, seed: u64) -> Workload {
    let mut rng = DetRng::new(seed ^ (dataset_base(spec.kind) as u64));
    let base = dataset_base(spec.kind);

    // Dataset-wide system prompt (shared by every request).
    let sys_prompt: Vec<u32> =
        (0..spec.sys_prompt_len).map(|i| base + i as u32).collect();

    // Group stems (e.g. MMLU subjects).
    let group_prefixes: Vec<Vec<u32>> = (0..spec.n_groups)
        .map(|g| {
            let gbase = base + GROUP_STRIDE * (g as u32 + 1);
            (0..spec.group_prefix_len).map(|i| gbase + i as u32).collect()
        })
        .collect();

    let mut requests = Vec::with_capacity(n);
    for i in 0..n {
        let p = (rng.lognormal_mean(spec.input_mean, spec.input_sigma) as usize)
            .clamp(spec.min_input, spec.max_input);
        let d = (rng.lognormal_mean(spec.output_mean, spec.output_sigma) as usize)
            .clamp(spec.min_output, spec.max_output) as u32;

        let mut prompt = Vec::with_capacity(p);
        prompt.extend_from_slice(&sys_prompt);
        if !group_prefixes.is_empty() {
            let g = rng.range(0, group_prefixes.len() as u64 - 1) as usize;
            prompt.extend_from_slice(&group_prefixes[g]);
        }
        // Unique tail: ids from the request's private range.
        while prompt.len() < p {
            // Large random ids (top half of u32 space) — never collide with
            // pool ids, and essentially never with other tails.
            prompt.push((1 << 31) | (rng.u64() as u32 & 0x7fff_ffff));
        }
        prompt.truncate(p.max(spec.sys_prompt_len + 1));
        // known_output comes from the spec, not the dataset tag: a
        // generator of predefined-output requests says so explicitly.
        requests.push(Request::with_known_output(
            i as u32,
            spec.kind,
            prompt,
            d,
            spec.known_output,
        ));
    }
    Workload::new(&format!("{}-{}", spec.kind.name(), n), requests)
}

/// Convenience: generate a paper trace by kind.
pub fn generate_kind(kind: TraceKind, n: usize, seed: u64) -> Workload {
    generate(&spec_for(kind), n, seed)
}

/// Encoder tokens of one 336×336 image under a /14 patcher (24² = 576) —
/// the ViT-L/14 class constant the image-chat generator uses.
pub const IMAGE_ENC_TOKENS: u32 = 576;

/// Encoder tokens per video frame (spatially pooled 12² patches).
pub const FRAME_ENC_TOKENS: u32 = 144;

/// VisionArena-style image chat: text marginals from [`vision_arena`],
/// plus 1–2 image attachments per request.  With probability `dup_frac`
/// an attachment references one of a small pool of *popular* images
/// (shared content hashes — the embedding dedup cache's hit source);
/// otherwise it is unique.  Deterministic for a given (n, seed,
/// dup_frac).
pub fn generate_vision_arena(n: usize, seed: u64, dup_frac: f64) -> Workload {
    assert!((0.0..=1.0).contains(&dup_frac), "dup_frac={dup_frac}");
    let mut w = generate(&vision_arena(), n, seed);
    let mut rng = DetRng::new(seed ^ 0x5157_0a11);
    // Popular-image pool: hashes disjoint from the unique range.
    const POPULAR: u64 = 8;
    for (i, r) in w.requests.iter_mut().enumerate() {
        let n_images = 1 + usize::from(rng.chance(0.3));
        let atts = (0..n_images)
            .map(|k| {
                let hash = if rng.chance(dup_frac) {
                    1_000 + rng.range(0, POPULAR - 1)
                } else {
                    // Unique per (request, slot); < 2^32 for JSONL.
                    1_000_000 + (i as u64) * 4 + k as u64
                };
                Attachment::new(hash, IMAGE_ENC_TOKENS)
            })
            .collect();
        r.modality = crate::modality::ModalityProfile::new(atts);
    }
    w
}

/// Conditioned video generation: short text prompt + a conditioning clip
/// (reference frames through the vision encoder), with the output length
/// *predefined* by the requested frame count — `known_output = true` on a
/// `Custom`-tagged trace, the case the hardcoded
/// `known_output = dataset == OpenVid` rule mislabeled.
///
/// The conditioning-clip length (`frames_in`, encoder side) and the
/// generated-clip length (`frames_out`, decode side) vary
/// *independently*: an edit/extend job re-renders a short continuation
/// of a long input clip (encoder-heavy, modest decode), a text-to-video
/// job conditions on a few reference frames and decodes a long latent
/// stream (memory-heavy).  The two axes span the §6 demand spread inside
/// one trace — a request's true density can sit on either side of ρ = 1,
/// and only a modality-aware scheduler can tell which.
pub fn generate_video_gen(n: usize, seed: u64) -> Workload {
    let mut rng = DetRng::new(seed ^ 0x71de_0_6e4);
    let base = 10_000_000u64;
    let requests = (0..n)
        .map(|i| {
            let p = rng.range(24, 160) as usize;
            // Prompt ids from a private pool (no cross-trace collisions).
            let prompt: Vec<u32> =
                (0..p).map(|k| 0x3000_0000 + (i * 4096 + k) as u32).collect();
            let frames_in = rng.range(16, 256) as u32;
            let frames_out = rng.range(16, 96) as u32;
            let out = frames_out * 64; // 64 latent tokens per generated frame
            Request::with_known_output(i as u32, TraceKind::Custom, prompt, out, true)
                .with_attachments(vec![Attachment::new(
                    base + i as u64,
                    frames_in * FRAME_ENC_TOKENS,
                )])
        })
        .collect();
    Workload::new(&format!("video-gen-{n}"), requests)
}

/// Remap token ids into a small vocabulary while *preserving the prefix
/// structure* (injective per pool in practice for small pools).  Used by
/// the real-model E2E example (vocab 2048).
pub fn remap_vocab(w: &Workload, vocab: u32) -> Workload {
    let requests = w
        .requests
        .iter()
        .map(|r| {
            let prompt: Vec<u32> = r
                .prompt
                .iter()
                .map(|&t| {
                    // Splittable hash, stable across runs.
                    let mut h = t as u64;
                    h ^= h >> 33;
                    h = h.wrapping_mul(0xff51_afd7_ed55_8ccd);
                    h ^= h >> 33;
                    (h as u32) % vocab
                })
                .collect();
            // Preserve the explicit known_output flag and any media
            // attachments — remapping touches token ids only.
            let mut m = Request::with_known_output(
                r.id,
                r.dataset,
                prompt,
                r.output_len,
                r.known_output,
            );
            m.modality = r.modality.clone();
            m
        })
        .collect();
    Workload::new(&format!("{}-v{}", w.name, vocab), requests)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::stats::mean;

    #[test]
    fn deterministic() {
        let a = generate_kind(TraceKind::BurstGpt, 50, 7);
        let b = generate_kind(TraceKind::BurstGpt, 50, 7);
        for (x, y) in a.requests.iter().zip(&b.requests) {
            assert_eq!(x.prompt, y.prompt);
            assert_eq!(x.output_len, y.output_len);
        }
        let c = generate_kind(TraceKind::BurstGpt, 50, 8);
        assert_ne!(a.requests[0].prompt, c.requests[0].prompt);
    }

    #[test]
    fn mean_lengths_near_spec() {
        for kind in TraceKind::ALL_PAPER {
            let spec = spec_for(kind);
            let w = generate(&spec, 4000, 1);
            let p_mean = mean(
                &w.requests.iter().map(|r| r.input_len() as f64).collect::<Vec<_>>(),
            );
            let d_mean = mean(
                &w.requests.iter().map(|r| r.output_len as f64).collect::<Vec<_>>(),
            );
            // Clamping biases means slightly; accept 25%.
            assert!(
                (p_mean - spec.input_mean).abs() / spec.input_mean < 0.25,
                "{kind}: p_mean={p_mean} spec={}",
                spec.input_mean
            );
            assert!(
                (d_mean - spec.output_mean).abs() / spec.output_mean < 0.25,
                "{kind}: d_mean={d_mean} spec={}",
                spec.output_mean
            );
        }
    }

    #[test]
    fn sys_prompt_shared_across_requests() {
        let w = generate_kind(TraceKind::WildChat, 20, 3);
        let sys_len = wildchat().sys_prompt_len;
        let first = &w.requests[0].prompt[..sys_len];
        for r in &w.requests {
            assert_eq!(&r.prompt[..sys_len], first);
        }
    }

    #[test]
    fn mmlu_groups_share_stems() {
        let w = generate_kind(TraceKind::Mmlu, 500, 3);
        let spec = mmlu();
        let stem_end = spec.sys_prompt_len + spec.group_prefix_len;
        // Count distinct stems: should be ≤ n_groups and > 1.
        let stems: std::collections::HashSet<Vec<u32>> = w
            .requests
            .iter()
            .map(|r| r.prompt[..stem_end.min(r.prompt.len())].to_vec())
            .collect();
        assert!(stems.len() > 1 && stems.len() <= spec.n_groups, "{}", stems.len());
    }

    #[test]
    fn tails_unique_across_datasets() {
        let a = generate_kind(TraceKind::ShareGpt, 10, 1);
        let b = generate_kind(TraceKind::BurstGpt, 10, 1);
        // No shared first token between datasets (different pools).
        assert_ne!(a.requests[0].prompt[0], b.requests[0].prompt[0]);
    }

    #[test]
    fn scaled_spec_shrinks_lengths() {
        let s = burstgpt().scaled(0.1);
        let w = generate(&s, 200, 5);
        let p_mean = mean(
            &w.requests.iter().map(|r| r.input_len() as f64).collect::<Vec<_>>(),
        );
        assert!(p_mean < 100.0, "{p_mean}");
    }

    #[test]
    fn vision_arena_attaches_images_with_duplicates() {
        let w = generate_vision_arena(300, 5, 0.4);
        assert_eq!(w.len(), 300);
        assert!(w.has_attachments());
        let mut counts: std::collections::HashMap<u64, usize> =
            std::collections::HashMap::new();
        for r in &w.requests {
            let n_att = r.modality.attachments.len();
            assert!((1..=2).contains(&n_att), "{n_att} attachments");
            for a in &r.modality.attachments {
                assert_eq!(a.enc_tokens, IMAGE_ENC_TOKENS);
                assert!(a.content_hash < (1 << 32), "hash too wide for JSONL");
                *counts.entry(a.content_hash).or_default() += 1;
            }
            assert!(!r.known_output, "image chat outputs are not predefined");
        }
        // Popular images repeat; unique ones do not.
        let dup_refs: usize = counts.values().filter(|&&c| c > 1).copied().sum();
        assert!(dup_refs > 50, "dup_frac=0.4 produced only {dup_refs} dup refs");
        assert!(counts.values().any(|&c| c == 1), "no unique images at all");
        // Deterministic; dup_frac=0 means every hash is unique.
        let a = generate_vision_arena(50, 9, 0.4);
        let b = generate_vision_arena(50, 9, 0.4);
        for (x, y) in a.requests.iter().zip(&b.requests) {
            assert_eq!(x.modality, y.modality);
            assert_eq!(x.prompt, y.prompt);
        }
        let u = generate_vision_arena(100, 3, 0.0);
        let hashes: std::collections::HashSet<u64> = u
            .requests
            .iter()
            .flat_map(|r| r.modality.attachments.iter().map(|a| a.content_hash))
            .collect();
        let total: usize =
            u.requests.iter().map(|r| r.modality.attachments.len()).sum();
        assert_eq!(hashes.len(), total, "dup_frac=0 must not share content");
    }

    #[test]
    fn video_gen_is_known_output_custom_with_conditioning_clip() {
        let w = generate_video_gen(120, 7);
        assert_eq!(w.len(), 120);
        for r in &w.requests {
            // The satellite-fix case: Custom-tagged yet predefined output.
            assert_eq!(r.dataset, TraceKind::Custom);
            assert!(r.known_output, "video-gen outputs are predefined");
            assert_eq!(r.modality.attachments.len(), 1);
            let a = &r.modality.attachments[0];
            // Conditioning clip and generated clip vary independently:
            // enc = frames_in · FRAME_ENC_TOKENS, out = frames_out · 64.
            let frames_in = a.enc_tokens / FRAME_ENC_TOKENS;
            assert!((16..=256).contains(&frames_in), "frames_in={frames_in}");
            assert_eq!(a.enc_tokens % FRAME_ENC_TOKENS, 0);
            let frames_out = r.output_len / 64;
            assert!((16..=96).contains(&frames_out), "frames_out={frames_out}");
            assert_eq!(r.output_len % 64, 0);
        }
        // The two axes are genuinely independent (both tails occur).
        let enc_heavy = w
            .requests
            .iter()
            .filter(|r| {
                r.modality.attachments[0].enc_tokens > 128 * FRAME_ENC_TOKENS
                    && r.output_len < 48 * 64
            })
            .count();
        let dec_heavy = w
            .requests
            .iter()
            .filter(|r| {
                r.modality.attachments[0].enc_tokens < 64 * FRAME_ENC_TOKENS
                    && r.output_len > 64 * 64
            })
            .count();
        assert!(enc_heavy > 0, "no encoder-heavy edit/extend jobs generated");
        assert!(dec_heavy > 0, "no decode-heavy t2v jobs generated");
        // Conditioning clips are per-request unique.
        let hashes: std::collections::HashSet<u64> = w
            .requests
            .iter()
            .map(|r| r.modality.attachments[0].content_hash)
            .collect();
        assert_eq!(hashes.len(), w.len());
    }

    #[test]
    fn remap_vocab_preserves_sharing_structure() {
        let w = generate_kind(TraceKind::Mmlu, 50, 2);
        let m = remap_vocab(&w, 2048);
        for r in &m.requests {
            assert!(r.prompt.iter().all(|&t| t < 2048));
        }
        // Same-group requests still share their stem after remap.
        let spec = mmlu();
        let stem_end = spec.sys_prompt_len + spec.group_prefix_len;
        for (a, b) in w.requests.iter().zip(&m.requests) {
            assert_eq!(a.prompt.len(), b.prompt.len());
            let _ = stem_end;
        }
        // Two originally-equal prefixes must remain equal.
        let (r0, r1) = (&m.requests[0], &m.requests[1]);
        let common = w.requests[0]
            .prompt
            .iter()
            .zip(w.requests[1].prompt.iter())
            .take_while(|(a, b)| a == b)
            .count();
        assert_eq!(&r0.prompt[..common], &r1.prompt[..common]);
    }
}
