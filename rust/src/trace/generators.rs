//! Synthetic trace generators matching the published marginals of the six
//! paper traces (Fig. 2 length distributions, Table 4 density/sharing).
//!
//! Each dataset is described by a [`TraceSpec`]: log-normal input/output
//! length distributions plus a *prefix structure* — a dataset-wide system
//! prompt and per-group shared stems (MMLU subjects share long question
//! stems; chat traces share only their system prompt).  Token ids are drawn
//! deterministically from per-(dataset, group) pools so shared prefixes are
//! literal shared id sequences, exactly what a prefix tree sees.
//!
//! Calibration targets (Llama-3-8B on A100, §4 model): see
//! `expected_density_class` tests and `trace::stats`.

use super::{Request, TraceKind, Workload};
use crate::util::DetRng;

/// Distribution + prefix-structure description of one dataset.
#[derive(Clone, Debug)]
pub struct TraceSpec {
    pub kind: TraceKind,
    /// Mean input length (tokens) and log-space sigma.
    pub input_mean: f64,
    pub input_sigma: f64,
    /// Mean output length and log-space sigma.
    pub output_mean: f64,
    pub output_sigma: f64,
    /// Length of the dataset-wide shared system prompt.
    pub sys_prompt_len: usize,
    /// Number of groups with an additional shared stem (0 = none).
    pub n_groups: usize,
    /// Length of each group's shared stem.
    pub group_prefix_len: usize,
    /// Clamp bounds for sampled lengths.
    pub min_input: usize,
    pub max_input: usize,
    pub min_output: usize,
    pub max_output: usize,
}

impl TraceSpec {
    /// Scale all lengths by `f` (used by the tiny real-model E2E example,
    /// which runs with max_seq=256).
    pub fn scaled(mut self, f: f64) -> Self {
        let s = |x: f64| (x * f).max(1.0);
        self.input_mean = s(self.input_mean);
        self.output_mean = s(self.output_mean);
        self.sys_prompt_len = ((self.sys_prompt_len as f64 * f) as usize).max(1);
        self.group_prefix_len = (self.group_prefix_len as f64 * f) as usize;
        self.min_input = ((self.min_input as f64 * f) as usize).max(1);
        self.max_input = ((self.max_input as f64 * f) as usize).max(2);
        self.min_output = ((self.min_output as f64 * f) as usize).max(1);
        self.max_output = ((self.max_output as f64 * f) as usize).max(2);
        self
    }
}

/// ShareGPT: chat, mild density (~3), negligible sharing (Table 4: 0.02).
pub fn sharegpt() -> TraceSpec {
    TraceSpec {
        kind: TraceKind::ShareGpt,
        input_mean: 250.0,
        input_sigma: 0.9,
        output_mean: 380.0,
        output_sigma: 0.9,
        sys_prompt_len: 5,
        n_groups: 0,
        group_prefix_len: 0,
        min_input: 8,
        max_input: 4096,
        min_output: 4,
        max_output: 4096,
    }
}

/// WildChat: chat with a common system prompt (Table 4: sharing 0.19);
/// output normalized for a mildly compute-intensive mix (§A.3).
pub fn wildchat() -> TraceSpec {
    TraceSpec {
        kind: TraceKind::WildChat,
        input_mean: 350.0,
        input_sigma: 0.8,
        output_mean: 480.0,
        output_sigma: 1.0,
        sys_prompt_len: 66,
        n_groups: 0,
        group_prefix_len: 0,
        min_input: 70,
        max_input: 4096,
        min_output: 4,
        max_output: 8192,
    }
}

/// Azure-Trace: API service; very long inputs, short outputs (ρ ≈ 33).
pub fn azure_trace() -> TraceSpec {
    TraceSpec {
        kind: TraceKind::AzureTrace,
        input_mean: 2000.0,
        input_sigma: 0.6,
        output_mean: 26.0,
        output_sigma: 0.4,
        sys_prompt_len: 20,
        n_groups: 0,
        group_prefix_len: 0,
        min_input: 128,
        max_input: 8192,
        min_output: 2,
        max_output: 256,
    }
}

/// BurstGPT: API service; compute-intensive (ρ ≈ 18), low variance.
pub fn burstgpt() -> TraceSpec {
    TraceSpec {
        kind: TraceKind::BurstGpt,
        input_mean: 650.0,
        input_sigma: 0.5,
        output_mean: 46.0,
        output_sigma: 0.35,
        sys_prompt_len: 13,
        n_groups: 0,
        group_prefix_len: 0,
        min_input: 32,
        max_input: 4096,
        min_output: 2,
        max_output: 512,
    }
}

/// OpenVid: video generation; short text prompt, ~16K-token autoregressive
/// output (§A.3 normalizes 45K→16K).  Output length is *predefined* by the
/// frame count, hence the tiny sigma.  Strongly memory-intensive.
pub fn openvid() -> TraceSpec {
    TraceSpec {
        kind: TraceKind::OpenVid,
        input_mean: 120.0,
        input_sigma: 0.5,
        output_mean: 16384.0,
        output_sigma: 0.2,
        sys_prompt_len: 0,
        n_groups: 0,
        group_prefix_len: 0,
        min_input: 8,
        max_input: 1024,
        min_output: 2048,
        max_output: 45056,
    }
}

/// MMLU: benchmark; 57 subjects share long few-shot stems (Table 4:
/// sharing 0.86), outputs of a few tokens (ρ ≈ 55).
pub fn mmlu() -> TraceSpec {
    TraceSpec {
        kind: TraceKind::Mmlu,
        input_mean: 400.0,
        input_sigma: 0.25,
        output_mean: 15.0,
        output_sigma: 0.4,
        sys_prompt_len: 12,
        n_groups: 57,
        group_prefix_len: 330,
        min_input: 350,
        max_input: 1024,
        min_output: 2,
        max_output: 64,
    }
}

/// LIMO: hard math reasoning; long chain-of-thought outputs
/// (memory-intensive; Fig. 2).
pub fn limo() -> TraceSpec {
    TraceSpec {
        kind: TraceKind::Limo,
        input_mean: 200.0,
        input_sigma: 0.5,
        output_mean: 4000.0,
        output_sigma: 0.6,
        sys_prompt_len: 10,
        n_groups: 0,
        group_prefix_len: 0,
        min_input: 16,
        max_input: 2048,
        min_output: 256,
        max_output: 16384,
    }
}

pub fn spec_for(kind: TraceKind) -> TraceSpec {
    match kind {
        TraceKind::ShareGpt => sharegpt(),
        TraceKind::WildChat => wildchat(),
        TraceKind::AzureTrace => azure_trace(),
        TraceKind::BurstGpt => burstgpt(),
        TraceKind::OpenVid => openvid(),
        TraceKind::Mmlu => mmlu(),
        TraceKind::Limo => limo(),
        TraceKind::Custom => panic!("no spec for Custom"),
    }
}

/// Token-id space layout: ids are partitioned per dataset/group so distinct
/// pools never collide, keeping accidental prefix sharing at zero.
const DATASET_STRIDE: u32 = 1 << 24;
const GROUP_STRIDE: u32 = 1 << 14;

fn dataset_base(kind: TraceKind) -> u32 {
    let idx = match kind {
        TraceKind::ShareGpt => 1,
        TraceKind::WildChat => 2,
        TraceKind::AzureTrace => 3,
        TraceKind::BurstGpt => 4,
        TraceKind::OpenVid => 5,
        TraceKind::Mmlu => 6,
        TraceKind::Limo => 7,
        TraceKind::Custom => 8,
    };
    idx * DATASET_STRIDE
}

/// Generate `n` requests from a spec.  Deterministic for a given
/// (spec.kind, seed): prompts, lengths and group assignment replay exactly.
pub fn generate(spec: &TraceSpec, n: usize, seed: u64) -> Workload {
    let mut rng = DetRng::new(seed ^ (dataset_base(spec.kind) as u64));
    let base = dataset_base(spec.kind);

    // Dataset-wide system prompt (shared by every request).
    let sys_prompt: Vec<u32> =
        (0..spec.sys_prompt_len).map(|i| base + i as u32).collect();

    // Group stems (e.g. MMLU subjects).
    let group_prefixes: Vec<Vec<u32>> = (0..spec.n_groups)
        .map(|g| {
            let gbase = base + GROUP_STRIDE * (g as u32 + 1);
            (0..spec.group_prefix_len).map(|i| gbase + i as u32).collect()
        })
        .collect();

    let mut requests = Vec::with_capacity(n);
    for i in 0..n {
        let p = (rng.lognormal_mean(spec.input_mean, spec.input_sigma) as usize)
            .clamp(spec.min_input, spec.max_input);
        let d = (rng.lognormal_mean(spec.output_mean, spec.output_sigma) as usize)
            .clamp(spec.min_output, spec.max_output) as u32;

        let mut prompt = Vec::with_capacity(p);
        prompt.extend_from_slice(&sys_prompt);
        if !group_prefixes.is_empty() {
            let g = rng.range(0, group_prefixes.len() as u64 - 1) as usize;
            prompt.extend_from_slice(&group_prefixes[g]);
        }
        // Unique tail: ids from the request's private range.
        while prompt.len() < p {
            // Large random ids (top half of u32 space) — never collide with
            // pool ids, and essentially never with other tails.
            prompt.push((1 << 31) | (rng.u64() as u32 & 0x7fff_ffff));
        }
        prompt.truncate(p.max(spec.sys_prompt_len + 1));
        requests.push(Request::new(i as u32, spec.kind, prompt, d));
    }
    Workload::new(&format!("{}-{}", spec.kind.name(), n), requests)
}

/// Convenience: generate a paper trace by kind.
pub fn generate_kind(kind: TraceKind, n: usize, seed: u64) -> Workload {
    generate(&spec_for(kind), n, seed)
}

/// Remap token ids into a small vocabulary while *preserving the prefix
/// structure* (injective per pool in practice for small pools).  Used by
/// the real-model E2E example (vocab 2048).
pub fn remap_vocab(w: &Workload, vocab: u32) -> Workload {
    let requests = w
        .requests
        .iter()
        .map(|r| {
            let prompt: Vec<u32> = r
                .prompt
                .iter()
                .map(|&t| {
                    // Splittable hash, stable across runs.
                    let mut h = t as u64;
                    h ^= h >> 33;
                    h = h.wrapping_mul(0xff51_afd7_ed55_8ccd);
                    h ^= h >> 33;
                    (h as u32) % vocab
                })
                .collect();
            Request::new(r.id, r.dataset, prompt, r.output_len)
        })
        .collect();
    Workload::new(&format!("{}-v{}", w.name, vocab), requests)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::stats::mean;

    #[test]
    fn deterministic() {
        let a = generate_kind(TraceKind::BurstGpt, 50, 7);
        let b = generate_kind(TraceKind::BurstGpt, 50, 7);
        for (x, y) in a.requests.iter().zip(&b.requests) {
            assert_eq!(x.prompt, y.prompt);
            assert_eq!(x.output_len, y.output_len);
        }
        let c = generate_kind(TraceKind::BurstGpt, 50, 8);
        assert_ne!(a.requests[0].prompt, c.requests[0].prompt);
    }

    #[test]
    fn mean_lengths_near_spec() {
        for kind in TraceKind::ALL_PAPER {
            let spec = spec_for(kind);
            let w = generate(&spec, 4000, 1);
            let p_mean = mean(
                &w.requests.iter().map(|r| r.input_len() as f64).collect::<Vec<_>>(),
            );
            let d_mean = mean(
                &w.requests.iter().map(|r| r.output_len as f64).collect::<Vec<_>>(),
            );
            // Clamping biases means slightly; accept 25%.
            assert!(
                (p_mean - spec.input_mean).abs() / spec.input_mean < 0.25,
                "{kind}: p_mean={p_mean} spec={}",
                spec.input_mean
            );
            assert!(
                (d_mean - spec.output_mean).abs() / spec.output_mean < 0.25,
                "{kind}: d_mean={d_mean} spec={}",
                spec.output_mean
            );
        }
    }

    #[test]
    fn sys_prompt_shared_across_requests() {
        let w = generate_kind(TraceKind::WildChat, 20, 3);
        let sys_len = wildchat().sys_prompt_len;
        let first = &w.requests[0].prompt[..sys_len];
        for r in &w.requests {
            assert_eq!(&r.prompt[..sys_len], first);
        }
    }

    #[test]
    fn mmlu_groups_share_stems() {
        let w = generate_kind(TraceKind::Mmlu, 500, 3);
        let spec = mmlu();
        let stem_end = spec.sys_prompt_len + spec.group_prefix_len;
        // Count distinct stems: should be ≤ n_groups and > 1.
        let stems: std::collections::HashSet<Vec<u32>> = w
            .requests
            .iter()
            .map(|r| r.prompt[..stem_end.min(r.prompt.len())].to_vec())
            .collect();
        assert!(stems.len() > 1 && stems.len() <= spec.n_groups, "{}", stems.len());
    }

    #[test]
    fn tails_unique_across_datasets() {
        let a = generate_kind(TraceKind::ShareGpt, 10, 1);
        let b = generate_kind(TraceKind::BurstGpt, 10, 1);
        // No shared first token between datasets (different pools).
        assert_ne!(a.requests[0].prompt[0], b.requests[0].prompt[0]);
    }

    #[test]
    fn scaled_spec_shrinks_lengths() {
        let s = burstgpt().scaled(0.1);
        let w = generate(&s, 200, 5);
        let p_mean = mean(
            &w.requests.iter().map(|r| r.input_len() as f64).collect::<Vec<_>>(),
        );
        assert!(p_mean < 100.0, "{p_mean}");
    }

    #[test]
    fn remap_vocab_preserves_sharing_structure() {
        let w = generate_kind(TraceKind::Mmlu, 50, 2);
        let m = remap_vocab(&w, 2048);
        for r in &m.requests {
            assert!(r.prompt.iter().all(|&t| t < 2048));
        }
        // Same-group requests still share their stem after remap.
        let spec = mmlu();
        let stem_end = spec.sys_prompt_len + spec.group_prefix_len;
        for (a, b) in w.requests.iter().zip(&m.requests) {
            assert_eq!(a.prompt.len(), b.prompt.len());
            let _ = stem_end;
        }
        // Two originally-equal prefixes must remain equal.
        let (r0, r1) = (&m.requests[0], &m.requests[1]);
        let common = w.requests[0]
            .prompt
            .iter()
            .zip(w.requests[1].prompt.iter())
            .take_while(|(a, b)| a == b)
            .count();
        assert_eq!(&r0.prompt[..common], &r1.prompt[..common]);
    }
}
