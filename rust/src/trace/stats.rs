//! Workload characterization: optimal prefix-sharing ratio, compute
//! density, and Fig. 2 / Table 4-style summaries.
//!
//! The optimal sharing ratio s_o is a pure property of the prompts
//! (§3.3): with perfect caching every distinct trie token is computed
//! exactly once, so `s_o = 1 - unique_trie_tokens / total_prompt_tokens`.
//! We count unique trie tokens with a hash-chained trie (O(total tokens),
//! no tree construction needed).

use super::Workload;
use crate::perfmodel::{Demand, PerfModel};
use crate::util::stats::Summary;
use std::collections::HashSet;

/// Count the number of *unique* prompt tokens under maximal prefix sharing
/// (the node-token count of the trie over all prompts).
pub fn unique_prefix_tokens(w: &Workload) -> u64 {
    // Chain-hash each (prefix, token) pair; set size = trie tokens.
    let mut seen: HashSet<u64> = HashSet::new();
    let mut unique = 0u64;
    for r in &w.requests {
        let mut h: u64 = 0xcbf2_9ce4_8422_2325;
        for &t in r.prompt.iter() {
            h ^= t as u64;
            h = h.wrapping_mul(0x1000_0000_01b3);
            h ^= h >> 29;
            if seen.insert(h) {
                unique += 1;
            }
        }
    }
    unique
}

/// Optimal prefix-sharing ratio s_o ∈ [0,1): fraction of prompt tokens
/// whose computation a perfect cache eliminates.
pub fn optimal_sharing_ratio(w: &Workload) -> f64 {
    let total = w.total_input_tokens();
    if total == 0 {
        return 0.0;
    }
    1.0 - unique_prefix_tokens(w) as f64 / total as f64
}

/// Aggregate §4 demand of a workload (no sharing discount).  On a
/// modality-aware perf model attached media contributes its encoder
/// compute (`Demand::enc`); on the default blind model `demand_mm`
/// degrades to the text-only demand exactly.
pub fn total_demand(w: &Workload, pm: &PerfModel) -> Demand {
    let mut total = Demand::ZERO;
    for r in &w.requests {
        total.add(pm.demand_mm(r.input_len(), r.output_len as usize, r.encoder_tokens()));
    }
    total
}

/// Sharing-discounted compute density of the whole workload — the tree
/// root's ρ(rt) in §5.1.
pub fn workload_density(w: &Workload, pm: &PerfModel) -> f64 {
    let s = optimal_sharing_ratio(w);
    pm.set_density(&total_demand(w, pm), s)
}

/// Raw (undiscounted) density — what Table 4 reports per trace.
pub fn raw_density(w: &Workload, pm: &PerfModel) -> f64 {
    total_demand(w, pm).density()
}

/// Per-trace characterization row (Fig. 2 / Table 4).
#[derive(Clone, Debug)]
pub struct TraceProfile {
    pub name: String,
    pub n: usize,
    pub input: Summary,
    pub output: Summary,
    pub density: f64,
    pub sharing: f64,
}

pub fn profile(w: &Workload, pm: &PerfModel) -> TraceProfile {
    let inputs: Vec<f64> = w.requests.iter().map(|r| r.input_len() as f64).collect();
    let outputs: Vec<f64> = w.requests.iter().map(|r| r.output_len as f64).collect();
    TraceProfile {
        name: w.name.clone(),
        n: w.len(),
        input: Summary::of(&inputs),
        output: Summary::of(&outputs),
        density: raw_density(w, pm),
        sharing: optimal_sharing_ratio(w),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::presets;
    use crate::trace::generators::{generate_kind, spec_for};
    use crate::trace::{Request, TraceKind, Workload};

    fn pm() -> PerfModel {
        PerfModel::new(presets::llama3_8b(), presets::a100_80gb(), 1)
    }

    fn req(prompt: Vec<u32>, out: u32) -> Request {
        Request::new(0, TraceKind::Custom, prompt, out)
    }

    #[test]
    fn unique_tokens_identical_prompts() {
        let w = Workload::new(
            "w",
            vec![req(vec![1, 2, 3], 1); 10],
        );
        assert_eq!(unique_prefix_tokens(&w), 3);
        assert!((optimal_sharing_ratio(&w) - 0.9).abs() < 1e-9);
    }

    #[test]
    fn unique_tokens_disjoint_prompts() {
        let w = Workload::new(
            "w",
            vec![req(vec![1, 2], 1), req(vec![3, 4], 1)],
        );
        assert_eq!(unique_prefix_tokens(&w), 4);
        assert_eq!(optimal_sharing_ratio(&w), 0.0);
    }

    #[test]
    fn shared_prefix_counted_once() {
        // [1,2,3] and [1,2,4]: trie has 4 tokens, total 6 -> s = 1/3.
        let w = Workload::new(
            "w",
            vec![req(vec![1, 2, 3], 1), req(vec![1, 2, 4], 1)],
        );
        assert_eq!(unique_prefix_tokens(&w), 4);
        assert!((optimal_sharing_ratio(&w) - 1.0 / 3.0).abs() < 1e-9);
    }

    #[test]
    fn same_token_different_position_not_shared() {
        // [9] and [8,9] share nothing ([9] at depth 0 vs depth 1).
        let w = Workload::new("w", vec![req(vec![9], 1), req(vec![8, 9], 1)]);
        assert_eq!(unique_prefix_tokens(&w), 3);
    }

    // ---- Table 4 calibration: density classes and sharing ratios ----

    #[test]
    fn table4_sharing_ratios() {
        let pm = pm();
        let cases = [
            (TraceKind::ShareGpt, 0.02, 0.02),
            (TraceKind::WildChat, 0.19, 0.05),
            (TraceKind::AzureTrace, 0.01, 0.02),
            (TraceKind::OpenVid, 0.00, 0.02),
            (TraceKind::BurstGpt, 0.02, 0.02),
            (TraceKind::Mmlu, 0.86, 0.06),
        ];
        for (kind, want, tol) in cases {
            let w = generate_kind(kind, 4000, 11);
            let p = profile(&w, &pm);
            assert!(
                (p.sharing - want).abs() < tol,
                "{kind}: sharing={:.3} want~{want}",
                p.sharing
            );
        }
    }

    #[test]
    fn table4_density_classes() {
        // Exact Table-4 values are not reproducible without the authors'
        // constants; classes and orderings are (DESIGN.md §Substitutions).
        let pm = pm();
        let density = |k| raw_density(&generate_kind(k, 3000, 13), &pm);
        let sharegpt = density(TraceKind::ShareGpt);
        let wildchat = density(TraceKind::WildChat);
        let azure = density(TraceKind::AzureTrace);
        let openvid = density(TraceKind::OpenVid);
        let burst = density(TraceKind::BurstGpt);
        let mmlu = density(TraceKind::Mmlu);
        // Memory- vs compute-intensive classes.
        assert!(openvid < 0.3, "openvid={openvid}");
        for (name, d) in [
            ("sharegpt", sharegpt),
            ("wildchat", wildchat),
            ("azure", azure),
            ("burst", burst),
            ("mmlu", mmlu),
        ] {
            assert!(d > 1.0, "{name}={d} should be compute-intensive");
        }
        // Orderings from Table 4: MMLU > Azure > BurstGPT > ShareGPT/WildChat.
        assert!(mmlu > azure && azure > burst && burst > sharegpt);
        assert!(burst > wildchat);
        // Magnitudes within 2x of Table 4.
        assert!((10.0..40.0).contains(&burst), "burst={burst}");
        assert!((15.0..70.0).contains(&azure), "azure={azure}");
        assert!((25.0..110.0).contains(&mmlu), "mmlu={mmlu}");
        assert!((1.5..6.5).contains(&sharegpt), "sharegpt={sharegpt}");
        assert!((1.2..4.5).contains(&wildchat), "wildchat={wildchat}");
    }

    #[test]
    fn limo_is_memory_intensive() {
        let pm = pm();
        let d = raw_density(&generate_kind(TraceKind::Limo, 2000, 17), &pm);
        assert!(d < 1.0, "limo={d}");
    }

    #[test]
    fn profile_summaries_sane() {
        let pm = pm();
        let w = generate_kind(TraceKind::BurstGpt, 1000, 5);
        let p = profile(&w, &pm);
        assert_eq!(p.n, 1000);
        assert!(p.input.p50 > 0.0 && p.input.max >= p.input.p99);
        let spec = spec_for(TraceKind::BurstGpt);
        assert!(p.output.mean < spec.output_mean * 1.3);
    }
}
