//! §A.3 workload synthesizer: mix traces to hit a target compute density
//! and prefix-sharing ratio.
//!
//! Recipe (as in the paper): pick one compute-intensive trace (BurstGPT /
//! Azure-Trace / ShareGPT / WildChat), blend in the memory-intensive
//! OpenVid until the *sharing-discounted* density reaches the target `t`,
//! then mix in MMLU requests until the sharing ratio reaches `s`.  Because
//! MMLU also shifts density, we alternate the two adjustments until both
//! targets converge (a damped fixed point; ~10 rounds suffice).

use super::generators::{generate, mmlu, spec_for, TraceSpec};
use super::stats;
use super::{TraceKind, Workload};
use crate::perfmodel::PerfModel;

/// Target description of one synthesized workload.
#[derive(Clone, Debug)]
pub struct SynthSpec {
    /// The compute-intensive constituent.
    pub compute_trace: TraceKind,
    /// Target sharing-discounted compute density ρ.
    pub density: f64,
    /// Target optimal prefix-sharing ratio s_o.
    pub sharing: f64,
    /// Total request count.
    pub n_requests: usize,
    pub seed: u64,
}

impl SynthSpec {
    pub fn new(compute_trace: TraceKind, density: f64, sharing: f64, n: usize) -> Self {
        SynthSpec { compute_trace, density, sharing, n_requests: n, seed: 0 }
    }

    pub fn with_seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }

    pub fn name(&self) -> String {
        format!(
            "synth-{}-rho{:.2}-s{:.2}",
            self.compute_trace.name(),
            self.density,
            self.sharing
        )
    }
}

/// The four representative workloads of Table 2.
pub fn table2_traces(n_requests: usize) -> Vec<(String, SynthSpec)> {
    vec![
        ("Trace#1".into(), SynthSpec::new(TraceKind::BurstGpt, 1.4, 0.35, n_requests)),
        ("Trace#2".into(), SynthSpec::new(TraceKind::BurstGpt, 0.9, 0.35, n_requests)),
        ("Trace#3".into(), SynthSpec::new(TraceKind::BurstGpt, 1.4, 0.05, n_requests)),
        ("Trace#4".into(), SynthSpec::new(TraceKind::BurstGpt, 0.9, 0.05, n_requests)),
    ]
}

/// Synthesize a workload matching `spec` under the given perf model.
///
/// Returns the interleaved workload (deterministic shuffle so no constituent
/// arrives "first"; the *scheduler* decides the processing order).
pub fn synthesize(spec: &SynthSpec, pm: &PerfModel) -> Workload {
    let n = spec.n_requests.max(10);
    let comp_spec = spec_for(spec.compute_trace);
    let mem_spec = spec_for(TraceKind::OpenVid);
    let mmlu_spec = mmlu();

    // Per-request average demands of each constituent (measured on a probe).
    let probe = |s: &TraceSpec, seed| -> (f64, f64, f64, f64) {
        let w = generate(s, 600, seed);
        let d = stats::total_demand(&w, pm);
        let per = 1.0 / w.len() as f64;
        (
            d.comp * per,
            d.mem * per,
            w.total_input_tokens() as f64 * per,
            stats::optimal_sharing_ratio(&w),
        )
    };
    let (c_c, m_c, p_c, s_c) = probe(&comp_spec, spec.seed ^ 1);
    let (c_m, m_m, p_m, _s_m) = probe(&mem_spec, spec.seed ^ 2);
    let (c_u, m_u, p_u, s_u) = probe(&mmlu_spec, spec.seed ^ 3);

    // Fractions of the three constituents (compute, openvid, mmlu).
    let mut f_mem: f64 = 0.05;
    let mut f_mmlu: f64 = 0.10;
    for _ in 0..60 {
        let f_comp = (1.0 - f_mem - f_mmlu).max(0.0);
        // Aggregate density with sharing discount.
        let comp = f_comp * c_c + f_mem * c_m + f_mmlu * c_u;
        let mem = f_comp * m_c + f_mem * m_m + f_mmlu * m_u;
        let saved = f_comp * p_c * s_c + f_mmlu * p_u * s_u;
        let total_p = f_comp * p_c + f_mem * p_m + f_mmlu * p_u;
        let s_now = saved / total_p.max(1e-9);
        let rho_now = (1.0 - s_now) * comp / mem.max(1e-12);

        // Damped multiplicative updates.
        let rho_err = rho_now / spec.density;
        // More memory-trace lowers density: adjust f_mem by the error.
        f_mem = (f_mem * rho_err.powf(0.5)).clamp(1e-4, 0.9);
        let s_err = (spec.sharing / s_now.max(1e-6)).clamp(0.25, 4.0);
        f_mmlu = (f_mmlu * s_err.powf(0.5)).clamp(1e-4, 0.9);
    }
    let n_mem = ((n as f64) * f_mem).round().max(1.0) as usize;
    let n_mmlu = ((n as f64) * f_mmlu).round() as usize;
    let n_comp = n.saturating_sub(n_mem + n_mmlu).max(1);

    let wc = generate(&comp_spec, n_comp, spec.seed ^ 0x11);
    let wm = generate(&mem_spec, n_mem, spec.seed ^ 0x22);
    let wu = generate(&mmlu_spec, n_mmlu, spec.seed ^ 0x33);

    // Sequential combination, as in the paper's §A.3 / Fig. 3: the
    // constituent traces are concatenated, NOT interleaved — arrival order
    // groups compute-intensive requests before memory-intensive ones,
    // which is precisely the regime where reordering matters.
    Workload::concat(&spec.name(), &[&wc, &wu, &wm])
}

/// Achieved (density, sharing) of a synthesized workload — used by tests
/// and by the figure harnesses to annotate results.
pub fn achieved(w: &Workload, pm: &PerfModel) -> (f64, f64) {
    (stats::workload_density(w, pm), stats::optimal_sharing_ratio(w))
}

/// The HyGen-style adversary for the work-stealing fleet (DESIGN.md
/// §Fleet): `honest_groups` + `liar_groups` shared-stem prompt groups of
/// `per` requests each (480-token stem, 32-token unique tails).  Honest
/// groups decode 32 tokens; liar groups decode 800 — lengths that sparse
/// §5.1 sampling under-estimates ~3x for every liar group without a
/// sampled member, so `partition_dp`'s est-balanced shards are
/// adversarially imbalanced in true time.  Shared by the fleet tests,
/// `benches/fleet.rs` and `examples/fleet_scaling.rs`, so the acceptance
/// bar ("stealing strictly beats static on the adversarial trace") is
/// asserted against one and the same trace shape everywhere.
pub fn adversarial_skew(honest_groups: usize, liar_groups: usize, per: usize) -> Workload {
    use crate::trace::Request;
    let mut reqs = Vec::new();
    let mut mk_group = |stem_base: u32, out: u32| {
        let stem: Vec<u32> = (0..480u32).map(|k| stem_base + k).collect();
        for i in 0..per as u32 {
            let mut p = stem.clone();
            p.extend((0..32u32).map(|k| stem_base + 1000 + i * 32 + k));
            reqs.push(Request::new(0, TraceKind::Custom, p, out));
        }
    };
    for g in 0..honest_groups as u32 {
        mk_group(1_000_000 + g * 10_000, 32);
    }
    for g in 0..liar_groups as u32 {
        mk_group(100_000_000 + g * 10_000, 800);
    }
    Workload::new("adversarial-skew", reqs)
}

/// The mixed multi-modal workload of DESIGN.md §10: text (compute-heavy
/// BurstGPT plus a long-decode LIMO slice, so the memory end holds both
/// attachment-free and attachment-bearing work) + VisionArena image chat
/// (duplicate-bearing attachments) + conditioned video generation
/// (independent encoder/decode axes, predefined outputs).  This is the
/// §6-style modality-diverse regime the acceptance bar is asserted
/// against — shared by the modality tests, `benches/modality.rs`,
/// `examples/multimodal_serving.rs` and the `blendserve modality` CLI,
/// so they all measure one and the same trace shape.
pub fn mixed_modal(
    n_text: usize,
    n_image: usize,
    n_video: usize,
    dup_frac: f64,
    seed: u64,
) -> Workload {
    use crate::trace::generators::{generate_kind, generate_video_gen, generate_vision_arena};
    // ~1/8 of the text slice is long-decode reasoning: the memory end of
    // the density order then contains *text* work a blind scheduler must
    // rank against encoder-bearing video requests — the ranking the
    // encoder term exists to fix.
    let n_limo = n_text / 8;
    let text = generate_kind(TraceKind::BurstGpt, n_text - n_limo, seed);
    let limo = generate_kind(TraceKind::Limo, n_limo, seed ^ 0xc33);
    let image = generate_vision_arena(n_image, seed ^ 0xa11, dup_frac);
    let video = generate_video_gen(n_video, seed ^ 0xb22);
    Workload::concat("mixed-modal", &[&text, &limo, &image, &video])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::presets;

    fn pm() -> PerfModel {
        PerfModel::new(presets::llama3_8b(), presets::a100_80gb(), 1)
    }

    #[test]
    fn hits_density_and_sharing_targets() {
        let pm = pm();
        for (rho, s) in [(1.4, 0.35), (0.9, 0.35), (1.4, 0.05), (0.9, 0.05)] {
            let spec = SynthSpec::new(TraceKind::BurstGpt, rho, s, 4000);
            let w = synthesize(&spec, &pm);
            let (got_rho, got_s) = achieved(&w, &pm);
            assert!(
                (got_rho - rho).abs() / rho < 0.25,
                "rho: want {rho}, got {got_rho}"
            );
            assert!((got_s - s).abs() < 0.08, "s: want {s}, got {got_s}");
        }
    }

    #[test]
    fn grid_targets_feasible() {
        // Fig. 11's extremes.
        let pm = pm();
        for (rho, s) in [(0.8, 0.45), (1.4, 0.05), (1.3, 0.25)] {
            let spec = SynthSpec::new(TraceKind::BurstGpt, rho, s, 3000);
            let (got_rho, got_s) = achieved(&synthesize(&spec, &pm), &pm);
            assert!((got_rho - rho).abs() / rho < 0.3, "want {rho} got {got_rho}");
            assert!((got_s - s).abs() < 0.1, "want {s} got {got_s}");
        }
    }

    #[test]
    fn other_compute_traces_work() {
        // §A.4: Azure-Trace, ShareGPT, WildChat mixes.
        let pm = pm();
        for kind in [TraceKind::AzureTrace, TraceKind::ShareGpt, TraceKind::WildChat] {
            let spec = SynthSpec::new(kind, 1.1, 0.15, 2500);
            let w = synthesize(&spec, &pm);
            let (got_rho, got_s) = achieved(&w, &pm);
            assert!((got_rho - 1.1).abs() < 0.4, "{kind}: rho={got_rho}");
            assert!((got_s - 0.15).abs() < 0.1, "{kind}: s={got_s}");
        }
    }

    #[test]
    fn deterministic_given_seed() {
        let pm = pm();
        let spec = SynthSpec::new(TraceKind::BurstGpt, 1.2, 0.2, 500);
        let a = synthesize(&spec, &pm);
        let b = synthesize(&spec, &pm);
        assert_eq!(a.len(), b.len());
        for (x, y) in a.requests.iter().zip(&b.requests) {
            assert_eq!(x.prompt, y.prompt);
        }
    }

    #[test]
    fn contains_all_three_constituents() {
        let pm = pm();
        let spec = SynthSpec::new(TraceKind::BurstGpt, 1.0, 0.25, 3000);
        let w = synthesize(&spec, &pm);
        let has = |k: TraceKind| w.requests.iter().any(|r| r.dataset == k);
        assert!(has(TraceKind::BurstGpt));
        assert!(has(TraceKind::OpenVid));
        assert!(has(TraceKind::Mmlu));
        assert_eq!(w.len(), 3000);
    }

    #[test]
    fn table2_has_four_traces() {
        let traces = table2_traces(1000);
        assert_eq!(traces.len(), 4);
        assert_eq!(traces[0].0, "Trace#1");
        assert_eq!(traces[3].1.sharing, 0.05);
    }

    #[test]
    fn mixed_modal_shape() {
        let w = mixed_modal(100, 40, 20, 0.5, 3);
        assert_eq!(w.len(), 160);
        let with_att = w.requests.iter().filter(|r| !r.modality.is_empty()).count();
        assert_eq!(with_att, 60, "every image/video request carries media");
        let known = w.requests.iter().filter(|r| r.known_output).count();
        assert_eq!(known, 20, "exactly the video-gen requests are predefined");
        assert!(w.total_encoder_tokens() > 0);
        // The modality-aware density spread must be wider than the blind
        // one: encoder compute lifts the video-gen units.
        let mut pm = PerfModel::new(presets::llama3_8b(), presets::a100_80gb(), 1);
        let blind = crate::trace::stats::total_demand(&w, &pm);
        assert_eq!(blind.enc, 0.0);
        pm.modality_aware = true;
        let aware = crate::trace::stats::total_demand(&w, &pm);
        assert!(aware.enc > 0.0);
        assert!(aware.density() > blind.density());
    }

    #[test]
    fn adversarial_skew_shape() {
        let w = adversarial_skew(4, 2, 3);
        assert_eq!(w.len(), 18);
        // Every prompt: 480-token stem + 32-token tail, group-unique ids.
        for r in &w.requests {
            assert_eq!(r.input_len(), 512);
        }
        let honest = w.requests.iter().filter(|r| r.output_len == 32).count();
        let liars = w.requests.iter().filter(|r| r.output_len == 800).count();
        assert_eq!((honest, liars), (12, 6));
        // Stems shared within a group, disjoint across groups.
        assert_eq!(w.requests[0].prompt[..480], w.requests[1].prompt[..480]);
        assert_ne!(w.requests[0].prompt[0], w.requests[3].prompt[0]);
    }
}
