//! Workload substrate: requests, datasets, trace generators and the §A.3
//! workload synthesizer.
//!
//! The paper evaluates on six public traces (WildChat, ShareGPT,
//! Azure-Trace, BurstGPT, OpenVid, MMLU; §6.2 Fig. 2 / Table 4) plus LIMO
//! (Fig. 2).  Those traces are Hugging Face downloads we do not have, so
//! [`generators`] re-synthesizes each one from its *published marginals*:
//! input/output length distributions, compute density and prefix-sharing
//! ratio.  BlendServe consumes nothing else about a request, so the
//! substitution preserves every behaviour the scheduler can observe
//! (DESIGN.md §Substitutions).

pub mod generators;
pub mod online;
pub mod stats;
pub mod synth;

use std::sync::Arc;

/// Which (synthesized) public trace a request came from.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum TraceKind {
    ShareGpt,
    WildChat,
    AzureTrace,
    BurstGpt,
    OpenVid,
    Mmlu,
    Limo,
    /// Hand-built requests (tests, the real-model E2E example).
    Custom,
}

impl TraceKind {
    pub fn name(&self) -> &'static str {
        match self {
            TraceKind::ShareGpt => "ShareGPT",
            TraceKind::WildChat => "WildChat",
            TraceKind::AzureTrace => "Azure-Trace",
            TraceKind::BurstGpt => "BurstGPT",
            TraceKind::OpenVid => "OpenVid",
            TraceKind::Mmlu => "MMLU",
            TraceKind::Limo => "LIMO",
            TraceKind::Custom => "Custom",
        }
    }

    pub const ALL_PAPER: [TraceKind; 6] = [
        TraceKind::ShareGpt,
        TraceKind::WildChat,
        TraceKind::AzureTrace,
        TraceKind::OpenVid,
        TraceKind::BurstGpt,
        TraceKind::Mmlu,
    ];
}

impl std::fmt::Display for TraceKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}", self.name())
    }
}

/// One offline inference request.
///
/// `output_len` is the *true* generation length — known to the engine (it
/// decides when the request finishes) but hidden from the scheduler, which
/// sees only `est_output_len` filled in by §5.1 output-length sampling.
#[derive(Clone, Debug)]
pub struct Request {
    pub id: u32,
    pub dataset: TraceKind,
    /// Prompt token ids.  Shared prefixes are literal shared id sequences.
    pub prompt: Arc<Vec<u32>>,
    /// True output length (tokens), realized only at execution time.
    pub output_len: u32,
    /// §5.4: image/video generation outputs are *predefined* by frame
    /// count/quality parameters — the scheduler may read them directly.
    pub known_output: bool,
}

impl Request {
    pub fn new(id: u32, dataset: TraceKind, prompt: Vec<u32>, output_len: u32) -> Self {
        let known_output = dataset == TraceKind::OpenVid;
        Request { id, dataset, prompt: Arc::new(prompt), output_len, known_output }
    }

    pub fn input_len(&self) -> usize {
        self.prompt.len()
    }
}

/// A named set of requests (one experiment's workload).
#[derive(Clone, Debug, Default)]
pub struct Workload {
    pub name: String,
    pub requests: Vec<Request>,
}

impl Workload {
    pub fn new(name: &str, mut requests: Vec<Request>) -> Self {
        // Re-number so ids are dense and unique regardless of provenance.
        for (i, r) in requests.iter_mut().enumerate() {
            r.id = i as u32;
        }
        Workload { name: name.to_string(), requests }
    }

    pub fn len(&self) -> usize {
        self.requests.len()
    }

    pub fn is_empty(&self) -> bool {
        self.requests.is_empty()
    }

    /// Total prompt tokens.
    pub fn total_input_tokens(&self) -> u64 {
        self.requests.iter().map(|r| r.input_len() as u64).sum()
    }

    /// Total output tokens.
    pub fn total_output_tokens(&self) -> u64 {
        self.requests.iter().map(|r| r.output_len as u64).sum()
    }

    /// Total processed tokens (the paper's end-to-end throughput counts
    /// input + output tokens; §6.3).
    pub fn total_tokens(&self) -> u64 {
        self.total_input_tokens() + self.total_output_tokens()
    }

    /// Concatenate workloads (e.g. Fig. 3's BurstGPT-then-OpenVid).
    pub fn concat(name: &str, parts: &[&Workload]) -> Workload {
        let mut requests = Vec::new();
        for p in parts {
            requests.extend(p.requests.iter().cloned());
        }
        Workload::new(name, requests)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(id: u32, prompt: Vec<u32>, out: u32) -> Request {
        Request::new(id, TraceKind::Custom, prompt, out)
    }

    #[test]
    fn workload_renumbers_ids() {
        let w = Workload::new(
            "w",
            vec![req(7, vec![1, 2], 3), req(7, vec![3], 4)],
        );
        assert_eq!(w.requests[0].id, 0);
        assert_eq!(w.requests[1].id, 1);
    }

    #[test]
    fn token_accounting() {
        let w = Workload::new(
            "w",
            vec![req(0, vec![1, 2, 3], 10), req(1, vec![4], 5)],
        );
        assert_eq!(w.total_input_tokens(), 4);
        assert_eq!(w.total_output_tokens(), 15);
        assert_eq!(w.total_tokens(), 19);
    }

    #[test]
    fn concat_preserves_order_and_renumbers() {
        let a = Workload::new("a", vec![req(0, vec![1], 1)]);
        let b = Workload::new("b", vec![req(0, vec![2], 2)]);
        let c = Workload::concat("c", &[&a, &b]);
        assert_eq!(c.len(), 2);
        assert_eq!(*c.requests[0].prompt, vec![1]);
        assert_eq!(*c.requests[1].prompt, vec![2]);
        assert_eq!(c.requests[1].id, 1);
    }

    #[test]
    fn trace_kind_names_unique() {
        let names: std::collections::HashSet<_> =
            TraceKind::ALL_PAPER.iter().map(|k| k.name()).collect();
        assert_eq!(names.len(), TraceKind::ALL_PAPER.len());
    }
}
