//! Workload substrate: requests, datasets, trace generators and the §A.3
//! workload synthesizer.
//!
//! The paper evaluates on six public traces (WildChat, ShareGPT,
//! Azure-Trace, BurstGPT, OpenVid, MMLU; §6.2 Fig. 2 / Table 4) plus LIMO
//! (Fig. 2).  Those traces are Hugging Face downloads we do not have, so
//! [`generators`] re-synthesizes each one from its *published marginals*:
//! input/output length distributions, compute density and prefix-sharing
//! ratio.  BlendServe consumes nothing else about a request, so the
//! substitution preserves every behaviour the scheduler can observe
//! (DESIGN.md §Substitutions).

pub mod generators;
pub mod online;
pub mod stats;
pub mod synth;

use crate::modality::{Attachment, ModalityProfile};
use std::sync::Arc;

/// Which (synthesized) public trace a request came from.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum TraceKind {
    ShareGpt,
    WildChat,
    AzureTrace,
    BurstGpt,
    OpenVid,
    Mmlu,
    Limo,
    /// VisionArena-style multi-modal chat: text prompts carrying image
    /// attachments (DESIGN.md §10 / §Substitutions).
    VisionArena,
    /// Hand-built requests (tests, the real-model E2E example).
    Custom,
}

impl TraceKind {
    pub fn name(&self) -> &'static str {
        match self {
            TraceKind::ShareGpt => "ShareGPT",
            TraceKind::WildChat => "WildChat",
            TraceKind::AzureTrace => "Azure-Trace",
            TraceKind::BurstGpt => "BurstGPT",
            TraceKind::OpenVid => "OpenVid",
            TraceKind::Mmlu => "MMLU",
            TraceKind::Limo => "LIMO",
            TraceKind::VisionArena => "VisionArena",
            TraceKind::Custom => "Custom",
        }
    }

    /// Historical `known_output` derivation: only OpenVid outputs are
    /// predefined by frame-count parameters.  Generators now set the flag
    /// explicitly ([`Request::with_known_output`]); this remains the
    /// fallback for the compat constructor and attribute-less JSONL.
    pub fn default_known_output(&self) -> bool {
        matches!(self, TraceKind::OpenVid)
    }

    pub const ALL_PAPER: [TraceKind; 6] = [
        TraceKind::ShareGpt,
        TraceKind::WildChat,
        TraceKind::AzureTrace,
        TraceKind::OpenVid,
        TraceKind::BurstGpt,
        TraceKind::Mmlu,
    ];
}

impl std::fmt::Display for TraceKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}", self.name())
    }
}

/// One offline inference request.
///
/// `output_len` is the *true* generation length — known to the engine (it
/// decides when the request finishes) but hidden from the scheduler, which
/// sees only `est_output_len` filled in by §5.1 output-length sampling.
#[derive(Clone, Debug)]
pub struct Request {
    pub id: u32,
    pub dataset: TraceKind,
    /// Prompt token ids.  Shared prefixes are literal shared id sequences.
    pub prompt: Arc<Vec<u32>>,
    /// True output length (tokens), realized only at execution time.
    pub output_len: u32,
    /// §5.4: image/video generation outputs are *predefined* by frame
    /// count/quality parameters — the scheduler may read them directly.
    /// Set explicitly by generators (a custom video-gen trace is
    /// `Custom` + `known_output = true`); not derivable from `dataset`.
    pub known_output: bool,
    /// Multi-modal profile: image/video attachments (DESIGN.md §10).
    /// Empty for text-only requests.
    pub modality: ModalityProfile,
}

impl Request {
    /// Compat constructor: derives `known_output` from the dataset tag
    /// (the historical `dataset == OpenVid` rule).  Generators of
    /// predefined-output workloads on other kinds must use
    /// [`Self::with_known_output`] instead, or the scheduler will treat
    /// their exact lengths as unsampled estimates.
    pub fn new(id: u32, dataset: TraceKind, prompt: Vec<u32>, output_len: u32) -> Self {
        let known = dataset.default_known_output();
        Self::with_known_output(id, dataset, prompt, output_len, known)
    }

    /// Full constructor with an explicit `known_output`.
    pub fn with_known_output(
        id: u32,
        dataset: TraceKind,
        prompt: Vec<u32>,
        output_len: u32,
        known_output: bool,
    ) -> Self {
        Request {
            id,
            dataset,
            prompt: Arc::new(prompt),
            output_len,
            known_output,
            modality: ModalityProfile::EMPTY,
        }
    }

    /// Attach image/video media to this request (builder style).
    pub fn with_attachments(mut self, attachments: Vec<Attachment>) -> Self {
        self.modality = ModalityProfile::new(attachments);
        self
    }

    pub fn input_len(&self) -> usize {
        self.prompt.len()
    }

    /// Encoder tokens this request's attachments expand to (0 for text).
    pub fn encoder_tokens(&self) -> u64 {
        self.modality.encoder_tokens()
    }
}

/// A named set of requests (one experiment's workload).
#[derive(Clone, Debug, Default)]
pub struct Workload {
    pub name: String,
    pub requests: Vec<Request>,
}

impl Workload {
    pub fn new(name: &str, mut requests: Vec<Request>) -> Self {
        // Re-number so ids are dense and unique regardless of provenance.
        for (i, r) in requests.iter_mut().enumerate() {
            r.id = i as u32;
        }
        Workload { name: name.to_string(), requests }
    }

    pub fn len(&self) -> usize {
        self.requests.len()
    }

    pub fn is_empty(&self) -> bool {
        self.requests.is_empty()
    }

    /// Total prompt tokens.
    pub fn total_input_tokens(&self) -> u64 {
        self.requests.iter().map(|r| r.input_len() as u64).sum()
    }

    /// Total output tokens.
    pub fn total_output_tokens(&self) -> u64 {
        self.requests.iter().map(|r| r.output_len as u64).sum()
    }

    /// Total processed tokens (the paper's end-to-end throughput counts
    /// input + output tokens; §6.3).
    pub fn total_tokens(&self) -> u64 {
        self.total_input_tokens() + self.total_output_tokens()
    }

    /// Total encoder tokens over all attachments (pre-dedup).
    pub fn total_encoder_tokens(&self) -> u64 {
        self.requests.iter().map(|r| r.encoder_tokens()).sum()
    }

    /// Any request carrying media attachments?
    pub fn has_attachments(&self) -> bool {
        self.requests.iter().any(|r| !r.modality.is_empty())
    }

    /// Concatenate workloads (e.g. Fig. 3's BurstGPT-then-OpenVid).
    pub fn concat(name: &str, parts: &[&Workload]) -> Workload {
        let mut requests = Vec::new();
        for p in parts {
            requests.extend(p.requests.iter().cloned());
        }
        Workload::new(name, requests)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(id: u32, prompt: Vec<u32>, out: u32) -> Request {
        Request::new(id, TraceKind::Custom, prompt, out)
    }

    #[test]
    fn workload_renumbers_ids() {
        let w = Workload::new(
            "w",
            vec![req(7, vec![1, 2], 3), req(7, vec![3], 4)],
        );
        assert_eq!(w.requests[0].id, 0);
        assert_eq!(w.requests[1].id, 1);
    }

    #[test]
    fn token_accounting() {
        let w = Workload::new(
            "w",
            vec![req(0, vec![1, 2, 3], 10), req(1, vec![4], 5)],
        );
        assert_eq!(w.total_input_tokens(), 4);
        assert_eq!(w.total_output_tokens(), 15);
        assert_eq!(w.total_tokens(), 19);
    }

    #[test]
    fn concat_preserves_order_and_renumbers() {
        let a = Workload::new("a", vec![req(0, vec![1], 1)]);
        let b = Workload::new("b", vec![req(0, vec![2], 2)]);
        let c = Workload::concat("c", &[&a, &b]);
        assert_eq!(c.len(), 2);
        assert_eq!(*c.requests[0].prompt, vec![1]);
        assert_eq!(*c.requests[1].prompt, vec![2]);
        assert_eq!(c.requests[1].id, 1);
    }

    #[test]
    fn trace_kind_names_unique() {
        let names: std::collections::HashSet<_> =
            TraceKind::ALL_PAPER.iter().map(|k| k.name()).collect();
        assert_eq!(names.len(), TraceKind::ALL_PAPER.len());
    }

    #[test]
    fn known_output_is_explicit_not_dataset_derived() {
        // Regression: `Request::new` used to hardcode
        // `known_output = dataset == OpenVid`, so a custom video-gen
        // trace (predefined frame counts, Custom kind) was mislabeled as
        // sampled.  The explicit constructor must win over the tag.
        let custom_video =
            Request::with_known_output(0, TraceKind::Custom, vec![1, 2], 2048, true);
        assert!(custom_video.known_output, "custom video-gen mislabeled");
        let openvid_est =
            Request::with_known_output(0, TraceKind::OpenVid, vec![1, 2], 2048, false);
        assert!(!openvid_est.known_output, "explicit false overridden by tag");
        // The compat constructor keeps the historical derivation.
        assert!(Request::new(0, TraceKind::OpenVid, vec![1], 4).known_output);
        assert!(!Request::new(0, TraceKind::Custom, vec![1], 4).known_output);
        assert!(TraceKind::OpenVid.default_known_output());
        assert!(!TraceKind::VisionArena.default_known_output());
    }

    #[test]
    fn attachments_builder_and_accounting() {
        use crate::modality::Attachment;
        let r = Request::new(0, TraceKind::VisionArena, vec![1, 2, 3], 8)
            .with_attachments(vec![Attachment::new(42, 576), Attachment::new(7, 288)]);
        assert_eq!(r.encoder_tokens(), 864);
        let plain = Request::new(1, TraceKind::Custom, vec![4], 8);
        assert_eq!(plain.encoder_tokens(), 0);
        let w = Workload::new("w", vec![r, plain]);
        assert!(w.has_attachments());
        assert_eq!(w.total_encoder_tokens(), 864);
        let text = Workload::new("t", vec![Request::new(0, TraceKind::Custom, vec![1], 1)]);
        assert!(!text.has_attachments());
        assert_eq!(text.total_encoder_tokens(), 0);
    }
}
