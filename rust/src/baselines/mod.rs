//! Baseline system configurations (§6.2), all expressed as `SystemConfig`s
//! over the same engine so comparisons isolate the scheduling policy:
//!
//! | system            | order  | overlap     | prefix cache |
//! |-------------------|--------|-------------|--------------|
//! | vLLM-DFS          | DFS    | sequential  | block-16     |
//! | SGLang-DFS        | DFS    | sequential  | token radix  |
//! | NanoFlow-DFS      | DFS    | overlapped  | token radix  |
//! | NanoFlow-Balance  | random | overlapped  | token radix  |
//! | Prefix-Aligned    | aligned DFS | overlapped | token radix |
//! | BlendServe        | dual scanner | overlapped | token radix |
//!
//! DistServe (xPyD P/D disaggregation) lives in `engine::distserve`.

use crate::config::{presets, OrderPolicy, OverlapMode, SystemConfig};

fn base() -> SystemConfig {
    SystemConfig::new(presets::llama3_8b(), presets::a100_80gb())
}

/// vLLM with prefix caching enabled and the trace pre-sorted into DFS
/// order (§6.2).  Sequential compute/memory execution (no operator-level
/// overlap).
pub fn vllm_dfs() -> SystemConfig {
    let mut c = base();
    c.scheduler.order = OrderPolicy::Dfs;
    c.engine.overlap = OverlapMode::Sequential;
    c
}

/// SGLang with RadixAttention, DFS order.  Sequential execution.
pub fn sglang_dfs() -> SystemConfig {
    let mut c = base();
    c.scheduler.order = OrderPolicy::Dfs;
    c.engine.overlap = OverlapMode::Sequential;
    c
}

/// NanoFlow (operator-level overlap) + prefix caching, DFS order — the
/// strongest baseline in the paper.
pub fn nanoflow_dfs() -> SystemConfig {
    let mut c = base();
    c.scheduler.order = OrderPolicy::Dfs;
    c.engine.overlap = OverlapMode::Overlapped;
    c
}

/// NanoFlow with random request order ("NanoFlow-Balance"): resource
/// balance through shuffling, at the cost of prefix locality.
pub fn nanoflow_balance() -> SystemConfig {
    let mut c = base();
    c.scheduler.order = OrderPolicy::Random;
    c.engine.overlap = OverlapMode::Overlapped;
    c
}

/// AlignedServe-style prefix-aligned static order + overlap: the strong
/// heuristic baseline of the optimality-gap bench (DESIGN.md §11) —
/// everything NanoFlow-DFS has, plus sharing-savings-aligned traversal.
pub fn prefix_aligned() -> SystemConfig {
    let mut c = base();
    c.scheduler.order = OrderPolicy::PrefixAligned;
    c.engine.overlap = OverlapMode::Overlapped;
    c
}

/// BlendServe: resource-aware prefix tree + dual scanner + overlap.
pub fn blendserve() -> SystemConfig {
    let mut c = base();
    c.scheduler.order = OrderPolicy::BlendServe;
    c.engine.overlap = OverlapMode::Overlapped;
    c.scheduler.balanced_chunk = true;
    c
}

/// All five systems of Fig. 7, in the paper's plotting order.
pub fn all_systems() -> Vec<(&'static str, SystemConfig)> {
    vec![
        ("vLLM-DFS", vllm_dfs()),
        ("SGLang-DFS", sglang_dfs()),
        ("NanoFlow-Balance", nanoflow_balance()),
        ("NanoFlow-DFS", nanoflow_dfs()),
        ("BlendServe", blendserve()),
    ]
}

/// Swap the model/hardware of a system config (for Fig. 7b, Fig. 12).
pub fn with_model(mut cfg: SystemConfig, model: crate::config::ModelSpec) -> SystemConfig {
    cfg.gpus_per_replica = model.tp_degree;
    cfg.model = model;
    cfg
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn five_systems() {
        let all = all_systems();
        assert_eq!(all.len(), 5);
        assert_eq!(all[4].0, "BlendServe");
        assert_eq!(all[4].1.scheduler.order, OrderPolicy::BlendServe);
        assert_eq!(all[0].1.engine.overlap, OverlapMode::Sequential);
    }

    #[test]
    fn with_model_updates_gpus() {
        let cfg = with_model(blendserve(), presets::llama3_70b().with_tp(8));
        assert_eq!(cfg.gpus_per_replica, 8);
        assert_eq!(cfg.model.name, "llama-3-70b");
    }
}
