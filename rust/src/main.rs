//! `blendserve` CLI — the leader entrypoint.
//!
//! ```text
//! blendserve synth    --trace burstgpt --density 1.1 --sharing 0.25 --n 20000 --out pool.jsonl
//! blendserve simulate --pool pool.jsonl [--system blendserve|nanoflow-dfs|...] [--dp N]
//! blendserve fleet    --pool pool.jsonl [--dp N] [--no-steal] [--gpus 1,1,2] [--hardware a,b]
//! blendserve colocate --pool pool.jsonl [--online-rate 4] [--slo-scale 5] [--policy elastic]
//! blendserve kv       --pool pool.jsonl [--memory-gb 22] [--margins 0.5,1,2] [--out kv.json]
//! blendserve modality [--n 1200] [--dup 0.4] [--encoder-params 2e9] [--out mm.json]
//! blendserve plan     --pool pool.jsonl [--systems blendserve,prefix-aligned] [--out plan.json]
//! blendserve stream   --pool pool.jsonl [--window-requests N] [--window-tokens N] [--out stream.json]
//! blendserve serve    --pool pool.jsonl --artifacts artifacts [--order blend|dfs|fcfs]
//! blendserve config   [--preset llama-3-8b] > system.toml
//! ```
//!
//! `simulate` runs the profile-guided A100 simulator; `fleet` runs the
//! work-stealing multi-replica cluster engine (DESIGN.md §Fleet);
//! `colocate` blends a latency-sensitive online stream into the offline
//! schedule (DESIGN.md §Co-located-Serving); `kv` sweeps the tiered KV
//! manager's swap policy against the discard baseline (DESIGN.md §9);
//! `serve` runs the REAL tiny model through PJRT (python never on the
//! request path); `plan` reports each scheduler's optimality gap against
//! the planner's makespan lower bound (DESIGN.md §11).

use blendserve::baselines;
use blendserve::config::{presets, ColocationPolicy, SystemConfig};
use blendserve::perfmodel::PerfModel;
use blendserve::runtime::serve::zipper_order;
use blendserve::runtime::RealServer;
use blendserve::server::pool::{load_jsonl, save_jsonl, save_results};
use blendserve::server::{
    online_stream, serve_batch, serve_colocated, serve_fleet_opts, FleetFtOptions,
};
use blendserve::trace::generators::remap_vocab;
use blendserve::trace::synth::{synthesize, SynthSpec};
use blendserve::trace::TraceKind;
use blendserve::tree::PrefixTree;
use std::collections::HashMap;
use std::path::{Path, PathBuf};

fn usage() -> ! {
    eprintln!(
        "blendserve — offline LLM batch inference with resource-aware batching

USAGE:
  blendserve synth    --trace <sharegpt|wildchat|azure|burstgpt> --density F --sharing F --n N --out FILE
  blendserve simulate --pool FILE [--system NAME] [--dp N] [--model NAME] [--out FILE] [--trace FILE]
  blendserve fleet    --pool FILE [--dp N] [--no-steal] [--steal-ratio F] [--gpus N,N,..]
                      [--hardware NAME,NAME,..] [--model NAME] [--out FILE] [--trace FILE]
                      [--faults] [--mtbf F] [--fault-seed N] [--strategy recover|restart]
                      [--journal FILE] [--resume FILE]
  blendserve colocate --pool FILE [--online-rate F] [--slo-scale F] [--policy elastic|best-effort]
                      [--n-online N] [--online-trace NAME] [--reserve F] [--burst F] [--model NAME]
                      [--trace FILE]
  blendserve kv       --pool FILE [--memory-gb F] [--margins F,F,..] [--host-gb F] [--no-prefetch]
                      [--model NAME] [--out FILE]
  blendserve modality [--pool FILE] [--n N] [--dup F] [--encoder-params F] [--cache-frac F]
                      [--model NAME] [--out FILE]
  blendserve plan     --pool FILE [--systems NAME,NAME,..] [--model NAME] [--out FILE]
  blendserve stream   --pool FILE [--window-requests N] [--window-tokens N] [--model NAME] [--out FILE]
                      [--trace FILE]
  blendserve serve    --pool FILE [--artifacts DIR] [--order blend|dfs|fcfs]
  blendserve trace    --in FILE [--top N]   (summarize a --trace Perfetto export)
  blendserve lint     [--root DIR]   (default rust/src; exits 1 on violations)
  blendserve config   [--preset MODEL]

SYSTEMS:   vllm-dfs sglang-dfs nanoflow-dfs nanoflow-balance prefix-aligned blendserve
MODELS:    llama-3-8b llama-3-70b llama-2-7b qwen-2.5-7b qwen-2.5-72b deepseek-67b
HARDWARE:  a100-80gb-sxm h100-80gb-sxm (per-replica fleet overrides)"
    );
    std::process::exit(2);
}

fn parse_flags(args: &[String]) -> HashMap<String, String> {
    let mut m = HashMap::new();
    let mut i = 0;
    while i < args.len() {
        if let Some(key) = args[i].strip_prefix("--") {
            if i + 1 < args.len() && !args[i + 1].starts_with("--") {
                m.insert(key.to_string(), args[i + 1].clone());
                i += 2;
            } else {
                m.insert(key.to_string(), "true".to_string());
                i += 1;
            }
        } else {
            eprintln!("unexpected argument '{}'", args[i]);
            usage();
        }
    }
    m
}

/// Export recorded trace streams as one Perfetto-loadable JSON file
/// (DESIGN.md §15).  Shared by every `--trace FILE` flag.
fn write_trace(
    path: &str,
    streams: &[&blendserve::obs::TraceData],
    label: &str,
) -> anyhow::Result<()> {
    let doc = blendserve::obs::perfetto::export(streams, label);
    std::fs::write(path, format!("{doc}\n"))?;
    println!("trace -> {path} ({} streams; load in ui.perfetto.dev)", streams.len());
    Ok(())
}

fn system_by_name(name: &str) -> Option<SystemConfig> {
    match name {
        "vllm-dfs" => Some(baselines::vllm_dfs()),
        "sglang-dfs" => Some(baselines::sglang_dfs()),
        "nanoflow-dfs" => Some(baselines::nanoflow_dfs()),
        "nanoflow-balance" => Some(baselines::nanoflow_balance()),
        "prefix-aligned" => Some(baselines::prefix_aligned()),
        "blendserve" => Some(baselines::blendserve()),
        _ => None,
    }
}

fn cmd_synth(flags: HashMap<String, String>) -> anyhow::Result<()> {
    let trace = match flags.get("trace").map(|s| s.as_str()).unwrap_or("burstgpt") {
        "sharegpt" => TraceKind::ShareGpt,
        "wildchat" => TraceKind::WildChat,
        "azure" => TraceKind::AzureTrace,
        "burstgpt" => TraceKind::BurstGpt,
        other => anyhow::bail!("unknown compute trace '{other}'"),
    };
    let density: f64 = flags.get("density").map(|s| s.parse()).transpose()?.unwrap_or(1.1);
    let sharing: f64 = flags.get("sharing").map(|s| s.parse()).transpose()?.unwrap_or(0.2);
    let n: usize = flags.get("n").map(|s| s.parse()).transpose()?.unwrap_or(20_000);
    let out = PathBuf::from(flags.get("out").cloned().unwrap_or("pool.jsonl".into()));
    let pm = PerfModel::new(presets::llama3_8b(), presets::a100_80gb(), 1);
    let w = synthesize(&SynthSpec::new(trace, density, sharing, n), &pm);
    save_jsonl(&w, &out)?;
    let (rho, s) = blendserve::trace::synth::achieved(&w, &pm);
    println!(
        "wrote {} requests ({:.1}M tokens, ρ={rho:.2}, s={s:.2}) to {}",
        w.len(),
        w.total_tokens() as f64 / 1e6,
        out.display()
    );
    Ok(())
}

fn cmd_simulate(flags: HashMap<String, String>) -> anyhow::Result<()> {
    let pool = flags.get("pool").map(PathBuf::from).unwrap_or_else(|| usage());
    let w = load_jsonl(&pool)?;
    anyhow::ensure!(!w.is_empty(), "pool {} contains no requests", pool.display());
    let sys_name = flags.get("system").cloned().unwrap_or("blendserve".into());
    let mut cfg =
        system_by_name(&sys_name).ok_or_else(|| anyhow::anyhow!("unknown system {sys_name}"))?;
    if let Some(model_name) = flags.get("model") {
        let model = presets::model_by_name(model_name)
            .ok_or_else(|| anyhow::anyhow!("unknown model {model_name}"))?;
        cfg = baselines::with_model(cfg, model);
    }
    if let Some(dp) = flags.get("dp") {
        cfg.dp_replicas = dp.parse()?;
    }
    if flags.contains_key("trace") {
        cfg.engine.trace = true;
    }
    println!(
        "simulating {} requests on {} ({} x{} + DP={})",
        w.len(),
        sys_name,
        cfg.model.name,
        cfg.gpus_per_replica,
        cfg.dp_replicas
    );
    let job = serve_batch(&cfg, &w);
    println!(
        "makespan {:.1}s | {:.0} tok/s total | sharing {:.3} | optimal fraction {:.1}%",
        job.makespan,
        job.total_throughput,
        job.per_replica[0].result.sharing_achieved,
        job.per_replica[0].optimal_fraction * 100.0
    );
    if let Some(out) = flags.get("out") {
        save_results(&job.per_replica, Path::new(out))?;
        println!("results -> {out}");
    }
    if let Some(tp) = flags.get("trace") {
        let streams: Vec<&blendserve::obs::TraceData> = job
            .per_replica
            .iter()
            .filter_map(|o| o.result.trace.as_deref())
            .collect();
        write_trace(tp, &streams, "simulate")?;
    }
    Ok(())
}

fn cmd_fleet(flags: HashMap<String, String>) -> anyhow::Result<()> {
    let pool = flags.get("pool").map(PathBuf::from).unwrap_or_else(|| usage());
    // A resume implies a prior crash, which may also have torn the pool
    // file's final line mid-append: load tolerantly and say what was
    // dropped.  Fresh runs keep the strict parser (a malformed pool is a
    // bug to surface, not a tail to forgive).
    let w = if flags.contains_key("resume") {
        let (w, truncated) = blendserve::server::load_jsonl_tolerant(&pool)?;
        if truncated > 0 {
            println!(
                "pool {}: dropped {truncated} torn trailing record (tolerant resume load)",
                pool.display()
            );
        }
        w
    } else {
        load_jsonl(&pool)?
    };
    anyhow::ensure!(!w.is_empty(), "pool {} contains no requests", pool.display());
    let mut cfg = baselines::blendserve();
    if let Some(model_name) = flags.get("model") {
        let model = presets::model_by_name(model_name)
            .ok_or_else(|| anyhow::anyhow!("unknown model {model_name}"))?;
        cfg = baselines::with_model(cfg, model);
    }
    if let Some(dp) = flags.get("dp") {
        cfg.dp_replicas = dp.parse()?;
    } else {
        cfg.dp_replicas = 4;
    }
    if flags.contains_key("no-steal") {
        cfg.fleet.steal = false;
    }
    if let Some(r) = flags.get("steal-ratio") {
        cfg.fleet.steal_ratio = r.parse()?;
    }
    if let Some(g) = flags.get("gpus") {
        cfg.fleet.gpus = g
            .split(',')
            .filter(|s| !s.trim().is_empty())
            .map(|s| s.trim().parse::<usize>())
            .collect::<Result<_, _>>()?;
    }
    if let Some(h) = flags.get("hardware") {
        cfg.fleet.hardware = h
            .split(',')
            .map(str::trim)
            .filter(|s| !s.is_empty())
            .map(str::to_string)
            .collect();
    }
    // Fault injection + checkpoint/resume (DESIGN.md §12).
    if flags.contains_key("faults") {
        cfg.faults.enabled = true;
    }
    if let Some(m) = flags.get("mtbf") {
        cfg.faults.mtbf_s = m.parse()?;
    }
    if let Some(s) = flags.get("fault-seed") {
        cfg.faults.seed = s.parse()?;
    }
    if let Some(name) = flags.get("strategy") {
        cfg.faults.strategy = blendserve::config::RecoveryStrategy::from_name(name)
            .ok_or_else(|| anyhow::anyhow!("unknown recovery strategy '{name}'"))?;
    }
    if flags.contains_key("trace") {
        cfg.engine.trace = true;
    }
    let opts = FleetFtOptions {
        journal_path: flags.get("journal").map(PathBuf::from),
        resume_path: flags.get("resume").map(PathBuf::from),
        halt_after_steps: None,
    };
    anyhow::ensure!(cfg.dp_replicas >= 1, "--dp must be >= 1");
    // Same semantic checks as the [fleet] TOML section (one source of
    // truth in FleetConfig::validate).
    cfg.fleet
        .validate(cfg.dp_replicas)
        .map_err(|e| anyhow::anyhow!("fleet config: {e}"))?;
    println!(
        "fleet: {} requests on {} x DP={} ({})",
        w.len(),
        cfg.model.name,
        cfg.dp_replicas,
        if cfg.fleet.steal { "work stealing" } else { "static fork-join" },
    );
    if cfg.faults.enabled {
        println!(
            "faults: seed {} mtbf {:.1}s strategy {} (max {} deaths, rejoin {:+.1}s)",
            cfg.faults.seed,
            cfg.faults.mtbf_s,
            cfg.faults.strategy,
            cfg.faults.max_deaths,
            cfg.faults.rejoin_delay_s,
        );
    }
    let rep = serve_fleet_opts(&cfg, &w, opts)?;
    if rep.faults.deaths + rep.faults.host_shrinks + rep.faults.link_degrades > 0
        || rep.faults.resumed_finishes > 0
    {
        let f = &rep.faults;
        println!(
            "recovery: {} deaths ({} suppressed, {} rejoins) | {} requests reclaimed | \
             {} KV extents rescued ({} tok) | {} tok in-flight lost | resumed {} finishes",
            f.deaths,
            f.suppressed_deaths,
            f.rejoins,
            f.reclaimed_requests,
            f.rescued_extents,
            f.rescued_tokens,
            f.lost_progress_tokens,
            f.resumed_finishes,
        );
    }
    for (desc, idle) in rep.replica_desc.iter().zip(&rep.idle_fracs) {
        println!("  replica {desc}: idle {:.1}%", idle * 100.0);
    }
    println!(
        "makespan {:.1}s (static {:.1}s, speedup {:.2}x) | {:.0} tok/s | \
         {} steals ({} units, {} requests) | sharing {:.3} (static {:.3}, lost {:.4})",
        rep.makespan,
        rep.static_makespan,
        rep.speedup_vs_static,
        rep.total_throughput,
        rep.steals,
        rep.stolen_units,
        rep.stolen_requests,
        rep.sharing_achieved,
        rep.static_sharing,
        rep.sharing_lost_to_steals,
    );
    if let Some(out) = flags.get("out") {
        std::fs::write(out, format!("{}\n", rep.to_json()))?;
        println!("report -> {out}");
    }
    if let Some(tp) = flags.get("trace") {
        let mut streams: Vec<&blendserve::obs::TraceData> = rep
            .per_replica
            .iter()
            .filter_map(|r| r.trace.as_deref())
            .collect();
        streams.extend(rep.coord_trace.as_deref());
        write_trace(tp, &streams, "fleet")?;
    }
    Ok(())
}

fn cmd_colocate(flags: HashMap<String, String>) -> anyhow::Result<()> {
    let pool = flags.get("pool").map(PathBuf::from).unwrap_or_else(|| usage());
    let w = load_jsonl(&pool)?;
    let mut cfg = baselines::blendserve();
    if let Some(model_name) = flags.get("model") {
        let model = presets::model_by_name(model_name)
            .ok_or_else(|| anyhow::anyhow!("unknown model {model_name}"))?;
        cfg = baselines::with_model(cfg, model);
    }
    cfg.colocate.online_rate =
        flags.get("online-rate").map(|s| s.parse()).transpose()?.unwrap_or(4.0);
    cfg.colocate.slo_scale =
        flags.get("slo-scale").map(|s| s.parse()).transpose()?.unwrap_or(5.0);
    if let Some(name) = flags.get("policy") {
        cfg.colocate.policy = ColocationPolicy::from_name(name)
            .ok_or_else(|| anyhow::anyhow!("unknown colocation policy '{name}'"))?;
    }
    if let Some(r) = flags.get("reserve") {
        cfg.colocate.online_reserve = r.parse()?;
    }
    if let Some(b) = flags.get("burst") {
        cfg.colocate.burst_factor = b.parse()?;
    }
    // Validate user knobs here so bad input is a CLI error, not a panic
    // from the admitter/generator asserts.
    anyhow::ensure!(
        cfg.colocate.online_rate >= 0.0,
        "--online-rate must be >= 0, got {}",
        cfg.colocate.online_rate
    );
    anyhow::ensure!(
        cfg.colocate.slo_scale > 0.0,
        "--slo-scale must be > 0, got {}",
        cfg.colocate.slo_scale
    );
    anyhow::ensure!(
        (0.0..1.0).contains(&cfg.colocate.online_reserve),
        "--reserve must be in [0, 1), got {}",
        cfg.colocate.online_reserve
    );
    anyhow::ensure!(
        cfg.colocate.burst_factor >= 1.0,
        "--burst must be >= 1 (1 = Poisson), got {}",
        cfg.colocate.burst_factor
    );
    let n_online: usize =
        flags.get("n-online").map(|s| s.parse()).transpose()?.unwrap_or(200);
    let trace = match flags.get("online-trace").map(|s| s.as_str()).unwrap_or("sharegpt") {
        "sharegpt" => TraceKind::ShareGpt,
        "wildchat" => TraceKind::WildChat,
        "azure" => TraceKind::AzureTrace,
        "burstgpt" => TraceKind::BurstGpt,
        other => anyhow::bail!("unknown online trace '{other}'"),
    };
    if flags.contains_key("trace") {
        cfg.engine.trace = true;
    }
    let online = online_stream(&cfg, trace, n_online, 7);
    println!(
        "colocating {} offline + {} online requests ({} policy, {:.1} req/s, SLO x{:.1}) on {}",
        w.len(),
        online.len(),
        cfg.colocate.policy,
        cfg.colocate.online_rate,
        cfg.colocate.slo_scale,
        cfg.model.name,
    );
    let rep = serve_colocated(&cfg, &w, &online);
    println!(
        "makespan {:.1}s | offline {:.0} tok/s | SLO attainment {:.1}% | \
         TTFT mean {:.0}ms p99 {:.0}ms | queueing {:.0}ms | retractions {}",
        rep.result.total_time,
        rep.offline_throughput,
        rep.slo_attainment * 100.0,
        rep.mean_ttft * 1e3,
        rep.p99_ttft * 1e3,
        rep.mean_queue_delay * 1e3,
        rep.result.retractions,
    );
    if let Some(tp) = flags.get("trace") {
        let streams: Vec<&blendserve::obs::TraceData> =
            rep.result.trace.as_deref().into_iter().collect();
        write_trace(tp, &streams, "colocate")?;
    }
    Ok(())
}

/// `blendserve modality`: modality-aware vs modality-blind BlendServe on
/// a mixed image-chat + video-gen + text workload (DESIGN.md §10).  With
/// `--pool` the comparison runs on an existing (attachment-carrying)
/// pool; without it the canonical `mixed_modal` trace is generated.
fn cmd_modality(flags: HashMap<String, String>) -> anyhow::Result<()> {
    use blendserve::scheduler::run_system;
    use blendserve::trace::synth::mixed_modal;
    use blendserve::util::Json;

    let mut cfg = baselines::blendserve();
    if let Some(model_name) = flags.get("model") {
        let model = presets::model_by_name(model_name)
            .ok_or_else(|| anyhow::anyhow!("unknown model {model_name}"))?;
        cfg = baselines::with_model(cfg, model);
    }
    if let Some(p) = flags.get("encoder-params") {
        cfg.modality.encoder_params = p.parse()?;
    }
    if let Some(f) = flags.get("cache-frac") {
        cfg.modality.embed_cache_frac = f.parse()?;
    }
    cfg.modality
        .validate()
        .map_err(|e| anyhow::anyhow!("modality config: {e}"))?;
    let n: usize = flags.get("n").map(|s| s.parse()).transpose()?.unwrap_or(1200);
    let dup: f64 = flags.get("dup").map(|s| s.parse()).transpose()?.unwrap_or(0.4);
    anyhow::ensure!((0.0..=1.0).contains(&dup), "--dup must be in [0, 1], got {dup}");
    // (source, workload): --n/--dup shape only the generated trace; a
    // --pool run must not report them as if they described the pool.
    let (source, w) = match flags.get("pool") {
        Some(p) => {
            let w = load_jsonl(Path::new(p))?;
            anyhow::ensure!(!w.is_empty(), "pool {p} contains no requests");
            anyhow::ensure!(
                !flags.contains_key("n") && !flags.contains_key("dup"),
                "--n/--dup shape the generated trace and conflict with --pool"
            );
            (p.clone(), w)
        }
        // Canonical §10 mix: 60% text / 25% image chat / 15% video gen.
        None => (
            "generated".to_string(),
            mixed_modal(n * 60 / 100, n * 25 / 100, n * 15 / 100, dup, 7),
        ),
    };
    println!(
        "modality sweep: {} requests ({} with media, {:.1}M encoder tokens) on {}",
        w.len(),
        w.requests.iter().filter(|r| !r.modality.is_empty()).count(),
        w.total_encoder_tokens() as f64 / 1e6,
        cfg.model.name,
    );
    cfg.modality.enabled = false;
    let blind = run_system(&cfg, &w);
    cfg.modality.enabled = true;
    let aware = run_system(&cfg, &w);
    let speedup =
        aware.result.throughput / blind.result.throughput.max(1e-12);
    for (name, out) in [("blind", &blind), ("aware", &aware)] {
        let r = &out.result;
        println!(
            "{name:<6} makespan {:>8.2}s | {:>8.0} tok/s | encode {:>7.2}s \
             (overlap {:>5.1}%) | embed hits {:>8} tok | sharing {:.3}",
            r.total_time,
            r.throughput,
            r.encode_time,
            r.encode_overlap_frac * 100.0,
            r.embed_cache_hit_tokens,
            r.sharing_achieved,
        );
    }
    println!("modality-aware speedup {speedup:.3}x over blind ordering");
    if let Some(out) = flags.get("out") {
        let row = |o: &blendserve::scheduler::RunOutput| {
            let r = &o.result;
            Json::obj(vec![
                ("makespan_s", Json::Num(r.total_time)),
                ("throughput_tok_s", Json::Num(r.throughput)),
                ("encode_time_s", Json::Num(r.encode_time)),
                ("encode_overlap_frac", Json::Num(r.encode_overlap_frac)),
                (
                    "embed_cache_hit_tokens",
                    Json::from(r.embed_cache_hit_tokens as usize),
                ),
                ("sharing_achieved", Json::Num(r.sharing_achieved)),
            ])
        };
        let mut fields = vec![
            ("source", Json::from(source.as_str())),
            ("n_requests", Json::from(w.len())),
            ("encoder_params", Json::Num(cfg.modality.encoder_params)),
        ];
        if source == "generated" {
            fields.push(("dup_frac", Json::Num(dup)));
        }
        fields.extend([
            ("blind", row(&blind)),
            ("aware", row(&aware)),
            ("aware_speedup", Json::Num(speedup)),
        ]);
        let doc = Json::obj(fields);
        std::fs::write(out, format!("{doc}\n"))?;
        println!("report -> {out}");
    }
    Ok(())
}

/// `blendserve kv`: sweep the tiered KV manager's swap margin against the
/// discard baseline on one pool (DESIGN.md §9).  `--memory-gb` shrinks
/// device memory to provoke retractions; the baseline row is always the
/// kv-disabled engine on the same config.
fn cmd_kv(flags: HashMap<String, String>) -> anyhow::Result<()> {
    use blendserve::scheduler::run_system;
    use blendserve::util::Json;

    let pool = flags.get("pool").map(PathBuf::from).unwrap_or_else(|| usage());
    let w = load_jsonl(&pool)?;
    anyhow::ensure!(!w.is_empty(), "pool {} contains no requests", pool.display());
    let mut cfg = baselines::blendserve();
    if let Some(model_name) = flags.get("model") {
        let model = presets::model_by_name(model_name)
            .ok_or_else(|| anyhow::anyhow!("unknown model {model_name}"))?;
        cfg = baselines::with_model(cfg, model);
    }
    if let Some(gb) = flags.get("memory-gb") {
        cfg.hardware.memory_bytes = gb.parse::<f64>()? * 1e9;
    }
    if let Some(gb) = flags.get("host-gb") {
        cfg.hardware.host_mem_bytes = gb.parse::<f64>()? * 1e9;
    }
    if flags.contains_key("no-prefetch") {
        cfg.kv.prefetch = false;
    }
    let margins: Vec<f64> = match flags.get("margins") {
        None => vec![1.0],
        Some(s) => s
            .split(',')
            .map(str::trim)
            .filter(|m| !m.is_empty())
            .map(|m| m.parse::<f64>())
            .collect::<Result<_, _>>()?,
    };
    for &m in &margins {
        cfg.kv.swap_margin = m;
        cfg.kv
            .validate()
            .map_err(|e| anyhow::anyhow!("kv config: {e}"))?;
    }

    println!(
        "kv sweep: {} requests on {} ({:.0} GB HBM, {:.0} GB host @ {:.0} GB/s link)",
        w.len(),
        cfg.model.name,
        cfg.hardware.memory_bytes / 1e9,
        cfg.hardware.host_mem_bytes / 1e9,
        cfg.hardware.pcie_gbps,
    );
    cfg.kv.enabled = false;
    let base = run_system(&cfg, &w);
    println!(
        "{:<14} makespan {:>8.2}s | {} retractions | {:>9} recomputed tok",
        "discard", base.result.total_time, base.result.retractions,
        base.result.recomputed_tokens,
    );
    let mut rows: Vec<(String, Json)> = vec![(
        "discard".to_string(),
        Json::obj(vec![
            ("makespan_s", Json::Num(base.result.total_time)),
            ("retractions", Json::from(base.result.retractions as usize)),
            (
                "recomputed_tokens",
                Json::from(base.result.recomputed_tokens as usize),
            ),
        ]),
    )];
    cfg.kv.enabled = true;
    for &m in &margins {
        cfg.kv.swap_margin = m;
        let out = run_system(&cfg, &w);
        let r = &out.result;
        let speedup = base.result.total_time / r.total_time.max(1e-12);
        println!(
            "{:<14} makespan {:>8.2}s ({speedup:.3}x) | {} retractions | \
             {:>9} recomputed | {:>9} swapped | {:>9} saved | link {:>5.1}% \
             (stall {:.2}s)",
            format!("swap x{m}"),
            r.total_time,
            r.retractions,
            r.recomputed_tokens,
            r.swapped_out_tokens,
            r.recompute_saved_tokens,
            r.link_busy_frac * 100.0,
            r.link_stall_time,
        );
        rows.push((
            format!("margin_{m}"),
            Json::obj(vec![
                ("makespan_s", Json::Num(r.total_time)),
                ("speedup_vs_discard", Json::Num(speedup)),
                ("retractions", Json::from(r.retractions as usize)),
                ("recomputed_tokens", Json::from(r.recomputed_tokens as usize)),
                ("swapped_out_tokens", Json::from(r.swapped_out_tokens as usize)),
                ("swapped_in_tokens", Json::from(r.swapped_in_tokens as usize)),
                (
                    "recompute_saved_tokens",
                    Json::from(r.recompute_saved_tokens as usize),
                ),
                ("link_busy_frac", Json::Num(r.link_busy_frac)),
                ("link_stall_s", Json::Num(r.link_stall_time)),
            ]),
        ));
    }
    if let Some(out) = flags.get("out") {
        let doc = Json::obj(vec![
            ("pool", Json::from(pool.display().to_string().as_str())),
            ("n_requests", Json::from(w.len())),
            ("model", Json::from(cfg.model.name.as_str())),
            ("memory_bytes", Json::Num(cfg.hardware.memory_bytes)),
            ("pcie_gbps", Json::Num(cfg.hardware.pcie_gbps)),
            ("sweep", Json::Obj(rows.into_iter().collect())),
        ]);
        std::fs::write(out, format!("{doc}\n"))?;
        println!("report -> {out}");
    }
    Ok(())
}

/// `blendserve plan`: the optimality-gap report (DESIGN.md §11).  Prints
/// the planner's resource-area makespan lower bound for the pool, the
/// exact wave-DP optimum when the trace is small enough, and each
/// requested system's achieved makespan as a multiple of the bound.
fn cmd_plan(flags: HashMap<String, String>) -> anyhow::Result<()> {
    use blendserve::planner::{plan_units, workload_lower_bound, EXACT_MAX_UNITS};
    use blendserve::scheduler::{prepare_blendserve, run_system};
    use blendserve::util::Json;

    let pool = flags.get("pool").map(PathBuf::from).unwrap_or_else(|| usage());
    let w = load_jsonl(&pool)?;
    anyhow::ensure!(!w.is_empty(), "pool {} contains no requests", pool.display());
    let model = flags
        .get("model")
        .map(|name| {
            presets::model_by_name(name)
                .ok_or_else(|| anyhow::anyhow!("unknown model {name}"))
        })
        .transpose()?;
    let mut base = baselines::blendserve();
    if let Some(m) = &model {
        base = baselines::with_model(base, m.clone());
    }
    let (pm, tree, _, _) = prepare_blendserve(&base, &w);
    let units = plan_units(&tree, &w, &pm);
    let lb = workload_lower_bound(&w, &pm);
    println!(
        "plan: {} requests in {} scheduling units on {} | lower bound {lb:.2}s",
        w.len(),
        units.len(),
        base.model.name,
    );
    let exact = if units.len() <= EXACT_MAX_UNITS { units.exact() } else { None };
    match &exact {
        Some(e) => println!(
            "exact wave optimum {:.2}s in {} waves ({:.3}x over the bound)",
            e.makespan,
            e.waves.len(),
            e.makespan / lb.max(1e-12),
        ),
        None => println!(
            "exact planner skipped ({} units > {EXACT_MAX_UNITS}); the bound stays valid",
            units.len()
        ),
    }
    let names: Vec<String> = match flags.get("systems") {
        Some(s) => s
            .split(',')
            .map(str::trim)
            .filter(|n| !n.is_empty())
            .map(str::to_string)
            .collect(),
        None => ["blendserve", "prefix-aligned", "nanoflow-dfs"]
            .map(str::to_string)
            .to_vec(),
    };
    let mut rows: Vec<(String, Json)> = Vec::new();
    for name in &names {
        let mut cfg = system_by_name(name)
            .ok_or_else(|| anyhow::anyhow!("unknown system {name}"))?;
        if let Some(m) = &model {
            cfg = baselines::with_model(cfg, m.clone());
        }
        let out = run_system(&cfg, &w);
        println!(
            "{name:<18} makespan {:>9.2}s | gap {:.3}x over bound",
            out.result.total_time, out.optimality_gap,
        );
        rows.push((
            name.clone(),
            Json::obj(vec![
                ("makespan_s", Json::Num(out.result.total_time)),
                ("optimality_gap", Json::Num(out.optimality_gap)),
                ("sharing_achieved", Json::Num(out.result.sharing_achieved)),
            ]),
        ));
    }
    if let Some(out) = flags.get("out") {
        let mut fields = vec![
            ("pool", Json::from(pool.display().to_string().as_str())),
            ("n_requests", Json::from(w.len())),
            ("n_units", Json::from(units.len())),
            ("model", Json::from(base.model.name.as_str())),
            ("lower_bound_s", Json::Num(lb)),
        ];
        if let Some(e) = &exact {
            fields.push(("exact_makespan_s", Json::Num(e.makespan)));
            fields.push(("exact_waves", Json::from(e.waves.len())));
        }
        fields.push(("systems", Json::Obj(rows.into_iter().collect())));
        let doc = Json::obj(fields);
        std::fs::write(out, format!("{doc}\n"))?;
        println!("report -> {out}");
    }
    Ok(())
}

/// `blendserve stream`: windowed bounded-memory scheduling of a JSONL
/// pool (DESIGN.md §14).  The pool is never materialized: windows of
/// `[stream]`-sized request batches flow through one persistent engine,
/// each window's tree built while the previous one executes.  Writes its
/// own report document — the monolithic `save_results` planner bounds
/// need the whole pool in memory, which is exactly what streaming
/// avoids.
fn cmd_stream(flags: HashMap<String, String>) -> anyhow::Result<()> {
    use blendserve::stream::run_stream_file;
    use blendserve::util::Json;

    let pool = flags.get("pool").map(PathBuf::from).unwrap_or_else(|| usage());
    let mut cfg = baselines::blendserve();
    if let Some(model_name) = flags.get("model") {
        let model = presets::model_by_name(model_name)
            .ok_or_else(|| anyhow::anyhow!("unknown model {model_name}"))?;
        cfg = baselines::with_model(cfg, model);
    }
    if let Some(n) = flags.get("window-requests") {
        cfg.stream.window_requests = n.parse()?;
    }
    if let Some(n) = flags.get("window-tokens") {
        cfg.stream.window_tokens = n.parse()?;
    }
    cfg.stream
        .validate()
        .map_err(|e| anyhow::anyhow!("stream config: {e}"))?;
    if flags.contains_key("trace") {
        cfg.engine.trace = true;
    }
    println!(
        "streaming {} on {} (window: {} requests / {} tokens; 0 = unbounded)",
        pool.display(),
        cfg.model.name,
        cfg.stream.window_requests,
        cfg.stream.window_tokens,
    );
    let rep = run_stream_file(&cfg, &pool)?;
    let r = &rep.result;
    println!(
        "{} requests in {} windows | makespan {:.1}s | {:.0} tok/s | \
         peak resident {} requests | sharing {:.3} ({} tok cross-window)",
        rep.n_requests,
        r.windows,
        r.total_time,
        r.throughput,
        r.peak_resident_requests,
        r.sharing_achieved,
        r.cross_window_hit_tokens,
    );
    if let Some(out) = flags.get("out") {
        let doc = Json::obj(vec![
            ("pool", Json::from(pool.display().to_string().as_str())),
            ("model", Json::from(cfg.model.name.as_str())),
            ("window_requests", Json::from(cfg.stream.window_requests)),
            ("window_tokens", Json::from(cfg.stream.window_tokens as usize)),
            ("n_requests", Json::from(rep.n_requests)),
            ("windows", Json::from(r.windows as usize)),
            ("total_time_s", Json::Num(r.total_time)),
            ("throughput_tok_s", Json::Num(r.throughput)),
            ("steps", Json::from(r.steps as usize)),
            ("total_tokens", Json::from(r.total_tokens as usize)),
            ("sharing_achieved", Json::Num(r.sharing_achieved)),
            ("hit_tokens", Json::from(r.hit_tokens as usize)),
            (
                "cross_window_hit_tokens",
                Json::from(r.cross_window_hit_tokens as usize),
            ),
            (
                "peak_resident_requests",
                Json::from(r.peak_resident_requests),
            ),
        ]);
        std::fs::write(out, format!("{doc}\n"))?;
        println!("report -> {out}");
    }
    if let Some(tp) = flags.get("trace") {
        let streams: Vec<&blendserve::obs::TraceData> =
            rep.result.trace.as_deref().into_iter().collect();
        write_trace(tp, &streams, "stream")?;
    }
    Ok(())
}

/// `blendserve trace`: parse a `--trace FILE` Perfetto export and print
/// the triage summary — event counts plus the top-k requests by
/// recompute waste, queue delay, and swap traffic (DESIGN.md §15).
fn cmd_trace(flags: HashMap<String, String>) -> anyhow::Result<()> {
    use blendserve::obs::perfetto::summarize;
    use blendserve::util::Json;

    let path = flags.get("in").map(PathBuf::from).unwrap_or_else(|| usage());
    let k: usize = flags.get("top").map(|s| s.parse()).transpose()?.unwrap_or(10);
    anyhow::ensure!(k > 0, "--top must be >= 1");
    let text = std::fs::read_to_string(&path)?;
    let doc = Json::parse(&text)
        .map_err(|e| anyhow::anyhow!("{}: {e}", path.display()))?;
    let s = summarize(&doc, k)?;
    let total: u64 = s.counts.iter().map(|(_, c)| c).sum();
    println!("{}: {total} lifecycle events", path.display());
    if s.dropped > 0 {
        println!("  WARNING: {} records dropped at the recorder cap", s.dropped);
    }
    for (name, count) in &s.counts {
        println!("  {name:<14} {count:>10}");
    }
    if !s.top_recompute.is_empty() {
        println!("top {} by recompute waste (discarded tokens):", s.top_recompute.len());
        for (req, tok) in &s.top_recompute {
            println!("  req {req:<8} {tok:>10} tok");
        }
    }
    if !s.top_wait.is_empty() {
        println!("top {} by queue delay:", s.top_wait.len());
        for (req, w) in &s.top_wait {
            println!("  req {req:<8} {:>9.3} s", w);
        }
    }
    if !s.top_swap.is_empty() {
        println!("top {} by swap traffic:", s.top_swap.len());
        for (req, tok) in &s.top_swap {
            println!("  req {req:<8} {tok:>10} tok");
        }
    }
    Ok(())
}

fn cmd_serve(flags: HashMap<String, String>) -> anyhow::Result<()> {
    let pool = flags.get("pool").map(PathBuf::from).unwrap_or_else(|| usage());
    let dir = flags
        .get("artifacts")
        .map(PathBuf::from)
        .unwrap_or_else(blendserve::runtime::default_artifact_dir);
    let w = remap_vocab(&load_jsonl(&pool)?, 2048);
    let order_name = flags.get("order").cloned().unwrap_or("blend".into());
    let mut server = RealServer::load(&dir)?;
    let order: Vec<u32> = match order_name.as_str() {
        "fcfs" => (0..w.len() as u32).collect(),
        "dfs" | "blend" => {
            let pm = PerfModel::new(presets::tiny_cpu(), presets::cpu_host(), 1);
            let mut tree = PrefixTree::build(&w);
            tree.sample_outputs(0.05, 7);
            if order_name == "blend" {
                tree.transform(&pm, 0.99);
                zipper_order(&tree)
            } else {
                tree.recompute_aggregates(&pm);
                tree.dfs_requests()
            }
        }
        other => anyhow::bail!("unknown order '{other}'"),
    };
    let rep = server.serve(&w, &order)?;
    println!(
        "served {} requests | {:.0} tok/s | {} steps ({} blended) | hit {:.3} | wall {:.1}s (exec {:.1}s)",
        rep.n_requests,
        rep.throughput,
        rep.steps,
        rep.blended_steps,
        rep.hit_ratio,
        rep.wall_seconds,
        rep.exec_seconds
    );
    Ok(())
}

fn cmd_lint(flags: HashMap<String, String>) -> anyhow::Result<()> {
    let root = flags.get("root").map(PathBuf::from).unwrap_or_else(|| PathBuf::from("rust/src"));
    if !root.is_dir() {
        anyhow::bail!("lint root {} is not a directory (use --root DIR)", root.display());
    }
    let diags = blendserve::lint::lint_dir(&root)?;
    print!("{}", blendserve::lint::render(&diags));
    if !diags.is_empty() {
        std::process::exit(1);
    }
    Ok(())
}

fn cmd_config(flags: HashMap<String, String>) -> anyhow::Result<()> {
    let name = flags.get("preset").cloned().unwrap_or("llama-3-8b".into());
    let model = presets::model_by_name(&name)
        .ok_or_else(|| anyhow::anyhow!("unknown model {name}"))?;
    let cfg = SystemConfig::new(model, presets::a100_80gb());
    print!("{}", cfg.to_toml());
    Ok(())
}

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let Some(cmd) = args.first() else { usage() };
    let flags = parse_flags(&args[1..]);
    match cmd.as_str() {
        "synth" => cmd_synth(flags),
        "simulate" => cmd_simulate(flags),
        "fleet" => cmd_fleet(flags),
        "colocate" => cmd_colocate(flags),
        "kv" => cmd_kv(flags),
        "modality" => cmd_modality(flags),
        "plan" => cmd_plan(flags),
        "stream" => cmd_stream(flags),
        "serve" => cmd_serve(flags),
        "trace" => cmd_trace(flags),
        "lint" => cmd_lint(flags),
        "config" => cmd_config(flags),
        _ => usage(),
    }
}
