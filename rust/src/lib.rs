//! # BlendServe — offline LLM batch inference with resource-aware batching
//!
//! Reproduction of *"BlendServe: Optimizing Offline Inference with
//! Resource-Aware Batching"* (Zhao et al., ASPLOS '26) as a three-layer
//! Rust + JAX + Pallas system:
//!
//! - **L3 (this crate)** — the paper's contribution: a resource-aware prefix
//!   tree ([`tree`]), the dual-scanner request scheduler ([`scheduler`]), a
//!   NanoFlow-style overlapping execution engine ([`engine`]) with a tiered
//!   HBM ↔ host KV manager ([`kv`], DESIGN.md §9) and a multi-modal
//!   request subsystem — vision-encoder demand, embedding dedup cache and
//!   encode/decode overlap ([`modality`], DESIGN.md §10) — a fault-tolerance
//!   layer: seeded failure injection, exactly-once recovery and a
//!   crash-consistent journal with deterministic resume ([`recovery`],
//!   DESIGN.md §12) — a streaming ingest engine that windows
//!   million-request pools through the scheduler in bounded memory with
//!   cross-window cache carryover ([`stream`], DESIGN.md §14) — workload
//!   synthesis ([`trace`]), the §4 performance model ([`perfmodel`]), data /
//!   tensor parallel deployment ([`parallel`]) and the serving frontends
//!   ([`server`]) — the offline batch API plus online/offline co-located
//!   serving with SLO-aware elastic admission (DESIGN.md
//!   §Co-located-Serving).
//! - **L2** — a small Llama-style JAX model (`python/compile/model.py`),
//!   AOT-lowered once to HLO text.
//! - **L1** — a Pallas *blended attention* kernel executing ragged
//!   prefill/decode mixes (`python/compile/kernels/`).
//!
//! Python never runs on the request path: [`runtime`] loads the AOT HLO
//! artifacts through the PJRT C API (`xla` crate) and serves real tokens.
//!
//! See `DESIGN.md` for the system inventory and the experiment index that
//! maps every table/figure of the paper to a harness in this crate.

pub mod baselines;
pub mod config;
pub mod engine;
pub mod kv;
pub mod lint;
pub mod modality;
pub mod obs;
pub mod parallel;
pub mod perfmodel;
pub mod planner;
pub mod recovery;
pub mod scheduler;
pub mod server;
pub mod stream;
pub mod trace;
pub mod tree;
pub mod util;

// The PJRT runtime links against libxla_extension; keep it an always-on
// module (the build image bundles the library).
pub mod runtime;

pub use config::{
    ColocateConfig, ColocationPolicy, FaultsConfig, FleetConfig, HardwareSpec,
    KvConfig, ModalityConfig, ModelSpec, RecoveryStrategy, SchedulerConfig,
    SystemConfig,
};
pub use perfmodel::PerfModel;
pub use trace::{Request, Workload};
