//! §5.2 tree transformation: layer-wise sorting (Alg. 1), conditional node
//! splitting (Alg. 2) and the §5.4 convergence loop
//! ("layer-wise sort → conditional node split → (re)sort" until C1 or C2).
//!
//! After `transform`, a DFS of the tree enumerates requests in (nearly)
//! non-increasing compute-density order while preserving ≥
//! `split_sharing_floor` of the optimal prefix-sharing ratio — the input
//! the dual scanner needs.

use super::{NodeId, PrefixTree, ROOT};
use crate::perfmodel::PerfModel;

/// Outcome of a `transform` run (§5.4 stopping conditions).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum StopReason {
    /// C1: the DFS leaf density sequence became non-increasing.
    Monotone,
    /// C2: every remaining violation costs more than the split budget.
    BudgetExhausted,
    /// Defensive cap (never expected; N_leaf splits bound the loop).
    IterationCap,
}

/// Summary of a transform run.
#[derive(Clone, Copy, Debug)]
pub struct TransformStats {
    pub rounds: usize,
    pub splits: usize,
    /// Unique tokens added by splits (prefix recomputation cost).
    pub recompute_tokens: u64,
    pub stop: StopReason,
    /// Sharing ratio before/after.
    pub sharing_before: f64,
    pub sharing_after: f64,
}

impl PrefixTree {
    /// Alg. 1: layer-wise sort — order every node's children by subtree
    /// density, descending.  Requests attached to internal nodes are
    /// unaffected (they precede all children in DFS, matching the paper's
    /// "shared prefix computed first").
    pub fn layer_sort(&mut self) {
        for id in 0..self.nodes.len() {
            if self.nodes[id].children.len() > 1 {
                let mut kids = std::mem::take(&mut self.nodes[id].children);
                kids.sort_by(|&a, &b| {
                    self.nodes[b]
                        .density
                        .partial_cmp(&self.nodes[a].density)
                        .unwrap_or(std::cmp::Ordering::Equal)
                });
                self.nodes[id].children = kids;
            }
        }
    }

    /// DFS sequence of *scheduling units*: nodes that carry requests, with
    /// their subtree-discounted density.  (The paper calls these leaves;
    /// requests can also sit on internal nodes when one prompt prefixes
    /// another.)
    pub fn scheduling_units(&self) -> Vec<(NodeId, f64)> {
        let mut units = Vec::new();
        for id in self.pre_order() {
            if !self.nodes[id].requests.is_empty() {
                // Unit density: density over the node's own requests only
                // (its subtree may contain denser/looser descendants that
                // form their own units).
                units.push((id, self.unit_density(id)));
            }
        }
        units
    }

    /// Density of the requests attached directly to `id` (no descendants),
    /// discounted by this unit's *effective* sharing: in DFS order every
    /// ancestor segment is computed once for its whole subtree, so the unit
    /// is charged its own segment plus an amortized share of each ancestor
    /// segment (`seg_len(a) / n_requests(a)`).  This keeps unit densities
    /// consistent with the subtree densities that layer_sort uses.
    fn unit_density(&self, id: NodeId) -> f64 {
        let node = &self.nodes[id];
        let n_own = node.requests.len().max(1) as f64;
        let mut comp = 0.0;
        let mut mem = 0.0;
        let mut enc = 0.0;
        let mut prefill = 0u64;
        for &r in &node.requests {
            let p = self.input_len(r);
            let d = self.est_output[r as usize].max(1) as usize;
            comp += self.unit_pm_comp(p, d);
            mem += self.unit_pm_mem(p, d);
            enc += self.unit_pm_enc(r);
            prefill += p as u64;
        }
        if mem <= 0.0 {
            return f64::INFINITY;
        }
        // Effective unique tokens: own segment (computed once even when
        // several identical prompts stack here) + amortized ancestors.
        let mut unique_eff = node.seg_len as f64;
        let mut cur = node.parent;
        while cur != ROOT {
            let a = &self.nodes[cur];
            unique_eff += a.seg_len as f64 / a.n_requests.max(1) as f64 * n_own;
            cur = a.parent;
        }
        let s = if prefill == 0 {
            0.0
        } else {
            (1.0 - unique_eff / prefill as f64).clamp(0.0, 1.0)
        };
        // Encoder compute rides undiscounted, matching the subtree
        // densities of `recompute_aggregates` (DESIGN.md §10).
        ((1.0 - s) * comp + enc) / mem
    }

    // Transform-time perf model access: stored per-transform (set by
    // `transform`), so `unit_density` stays allocation-free.
    fn unit_pm_comp(&self, p: usize, d: usize) -> f64 {
        let pm = self.pm_cache.as_ref().expect("transform sets pm_cache");
        pm.comp_request(p, d)
    }
    fn unit_pm_mem(&self, p: usize, d: usize) -> f64 {
        let pm = self.pm_cache.as_ref().expect("transform sets pm_cache");
        pm.mem_request(p, d)
    }
    /// Encoder seconds of one request's attachments — 0 on a
    /// modality-blind perf model, so blind unit densities are
    /// bit-identical to the pre-modality scheduler.
    fn unit_pm_enc(&self, r: u32) -> f64 {
        let pm = self.pm_cache.as_ref().expect("transform sets pm_cache");
        let enc_tokens = self.enc_tokens[r as usize];
        if pm.modality_aware && enc_tokens > 0 {
            pm.encode_time(enc_tokens as f64)
        } else {
            0.0
        }
    }

    /// Find local density outliers: children (below root level) whose
    /// subtree density deviates by ≥ `OUTLIER_FACTOR` from *every* sibling.
    /// Returns `(split cost, node)` pairs.
    fn local_outliers(&self) -> Vec<(u64, NodeId)> {
        const OUTLIER_FACTOR: f64 = 4.0;
        let mut out = Vec::new();
        for id in self.pre_order() {
            if id == ROOT {
                continue;
            }
            let kids = &self.nodes[id].children;
            if kids.len() < 2 {
                continue;
            }
            // Children are density-sorted (layer_sort ran first): check
            // both edges against their neighbours.
            let first = kids[0];
            let second = kids[1];
            let last = kids[kids.len() - 1];
            let second_last = kids[kids.len() - 2];
            let d = |n: NodeId| self.nodes[n].density.max(1e-12);
            if d(first).is_finite() && d(first) > d(second) * OUTLIER_FACTOR {
                out.push((self.nodes[first].prefix_len as u64, first));
            }
            if kids.len() >= 2 && d(last) * OUTLIER_FACTOR < d(second_last) {
                out.push((self.nodes[last].prefix_len as u64, last));
            }
        }
        out
    }

    /// Detach the subtree rooted at `id` and re-attach it directly under
    /// the root with its full prefix materialized (the §5.2 "node split").
    /// Returns the number of recompute tokens this costs (= prefix_len).
    ///
    /// When a perf model is cached (`recompute_aggregates` ran), the
    /// affected aggregates — the moved node plus the old-parent → root
    /// path — are re-summed incrementally in O(depth), bit-identical to a
    /// full O(nodes) recompute (see `recompute_node`); only these nodes'
    /// aggregates can change, because a split leaves every other node's
    /// segment, request set, child list and descendant aggregates intact
    /// (descendant `prefix_len`s are preserved too: the moved node's
    /// `prefix_len + seg_len` is invariant).  Without a cached model,
    /// aggregates are stale afterwards and the caller recomputes.
    pub fn split_to_root(&mut self, id: NodeId) -> u64 {
        assert_ne!(id, ROOT, "cannot split the root");
        let parent = self.nodes[id].parent;
        assert_ne!(parent, ROOT, "node already at root level");
        let cost = self.nodes[id].prefix_len as u64;

        // Remove from old parent.
        let slot = self.nodes[parent]
            .children
            .iter()
            .position(|&c| c == id)
            .expect("child listed under parent");
        self.nodes[parent].children.remove(slot);

        // Materialize the full prefix: the segment becomes
        // prompt[0 .. prefix_len + seg_len] of any request in the subtree
        // (all subtree requests share that exact prefix).
        let rep = self.any_request_in_subtree(id).expect("non-empty subtree");
        let new_len = self.nodes[id].prefix_len + self.nodes[id].seg_len;
        let node = &mut self.nodes[id];
        node.seg_req = rep;
        node.seg_start = 0;
        node.seg_len = new_len;
        node.parent = ROOT;
        node.split_off = true;
        node.prefix_len = 0; // now a direct root child
        self.nodes[ROOT].children.push(id);

        // Incremental aggregate maintenance: re-sum the moved node first
        // (its own segment grew by the materialized prefix, so
        // `subtree_unique` and density change; its children are untouched),
        // then every node on the old-parent → root path bottom-up (each
        // lost the subtree from its sums; root gained it back).  `take`
        // instead of borrowing keeps the borrow checker happy without
        // cloning the perf model per split.
        if let Some(pm) = self.pm_cache.take() {
            self.recompute_node(id, &pm);
            let mut cur = parent;
            loop {
                self.recompute_node(cur, &pm);
                if cur == ROOT {
                    break;
                }
                cur = self.nodes[cur].parent;
            }
            self.pm_cache = Some(pm);
        }

        // If the old parent became a pass-through (no requests, one child),
        // the tree stays valid but slightly fragmented; the dual scanner is
        // insensitive to that, and `merge_chains` can clean it up.
        cost
    }

    fn any_request_in_subtree(&self, id: NodeId) -> Option<u32> {
        let mut stack = vec![id];
        while let Some(n) = stack.pop() {
            if let Some(&r) = self.nodes[n].requests.first() {
                return Some(r);
            }
            stack.extend_from_slice(&self.nodes[n].children);
        }
        None
    }

    /// §A.2 "offline prefix tree" merging: collapse pass-through chains
    /// (internal nodes with no requests and exactly one child) to reduce
    /// fragmentation.  Does not change sharing.
    pub fn merge_chains(&mut self) {
        for id in self.post_order() {
            if id == ROOT {
                continue;
            }
            // Merge child into `id` while the single child is contiguous
            // with this node's segment view.
            while self.nodes[id].requests.is_empty()
                && self.nodes[id].children.len() == 1
            {
                let c = self.nodes[id].children[0];
                // Only merge when the child's segment directly follows this
                // node's segment in the same prompt (always true right
                // after build; may be false after splits).
                let (req_ok, contiguous) = {
                    let a = &self.nodes[id];
                    let b = &self.nodes[c];
                    (
                        a.seg_req == b.seg_req,
                        a.seg_start + a.seg_len == b.seg_start,
                    )
                };
                if !(req_ok && contiguous) {
                    break;
                }
                let b_len = self.nodes[c].seg_len;
                let b_children = std::mem::take(&mut self.nodes[c].children);
                let b_requests = std::mem::take(&mut self.nodes[c].requests);
                self.nodes[id].seg_len += b_len;
                self.nodes[id].requests = b_requests;
                for &g in &b_children {
                    self.nodes[g].parent = id;
                }
                self.nodes[id].children = b_children;
                // `c` is now orphaned (kept in the arena, unreachable).
            }
        }
    }

    /// The §5.4 convergence loop.  `pm` prices demands; the split budget is
    /// `(1 - split_sharing_floor) × total shared tokens` (§5.2: preserve
    /// e.g. 99% of the prefix-sharing ratio).
    pub fn transform(&mut self, pm: &PerfModel, split_sharing_floor: f64) -> TransformStats {
        self.pm_cache = Some(pm.clone());
        self.recompute_aggregates(pm);
        let sharing_before = self.sharing_ratio();
        let total_shared =
            (self.nodes[ROOT].subtree_prefill - self.nodes[ROOT].subtree_unique) as f64;
        let mut budget = ((1.0 - split_sharing_floor.clamp(0.0, 1.0)) * total_shared) as i64;

        let mut stats = TransformStats {
            rounds: 0,
            splits: 0,
            recompute_tokens: 0,
            stop: StopReason::IterationCap,
            sharing_before,
            sharing_after: sharing_before,
        };

        // Each split moves one node to the root and never repeats (a
        // root-level node cannot be split again), so N_node bounds rounds
        // (§5.4 termination argument).
        let cap = self.nodes.len() + 2;
        for round in 0..cap {
            stats.rounds = round + 1;
            self.layer_sort();

            // C1: non-increasing unit densities (with 1% slack)?
            let units = self.scheduling_units();
            let mut violators: Vec<NodeId> = Vec::new();
            let mut run_max = f64::INFINITY;
            for &(id, rho) in units.iter() {
                if rho > run_max * 1.01 {
                    violators.push(id);
                } else {
                    run_max = rho;
                }
            }
            if violators.is_empty() {
                stats.stop = StopReason::Monotone;
                break;
            }

            // Phase 1 — local outliers (the Fig. 5 "request #2" pattern): a
            // child whose density deviates ≥ 4x from every sibling drags
            // its parent's aggregate and mis-sorts the whole subtree.
            // Split all affordable ones this round, cheapest first.
            let mut outliers = self.local_outliers();
            outliers.sort_by_key(|&(cost, _)| cost);
            let mut split_this_round = 0usize;
            for (cost, id) in outliers {
                if (cost as i64) <= budget {
                    self.split_to_root(id);
                    budget -= cost as i64;
                    stats.splits += 1;
                    stats.recompute_tokens += cost;
                    split_this_round += 1;
                }
            }

            // Phase 2 — fallback for residual violations: split the
            // cheapest affordable violator itself (guaranteed progress:
            // it lands at root level and can never be split again).
            if split_this_round == 0 {
                let mut best: Option<(u64, NodeId)> = None;
                for &id in &violators {
                    if self.nodes[id].parent == ROOT {
                        continue;
                    }
                    let cost = self.nodes[id].prefix_len as u64;
                    if (cost as i64) <= budget
                        && best.map(|(c, _)| cost < c).unwrap_or(true)
                    {
                        best = Some((cost, id));
                    }
                }
                match best {
                    None => {
                        stats.stop = StopReason::BudgetExhausted;
                        break;
                    }
                    Some((cost, id)) => {
                        self.split_to_root(id);
                        budget -= cost as i64;
                        stats.splits += 1;
                        stats.recompute_tokens += cost;
                    }
                }
            }
            // No per-round O(nodes) recompute: every `split_to_root` above
            // maintained the affected aggregates incrementally (bit-identical
            // to a full sweep — see its doc), so the next round's layer_sort
            // and violation scan read exact densities already.
        }
        self.recompute_aggregates(pm);
        stats.sharing_after = self.sharing_ratio();
        stats
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::presets;
    use crate::trace::generators::generate_kind;
    use crate::trace::synth::{synthesize, SynthSpec};
    use crate::trace::{Request, TraceKind, Workload};
    use crate::util::check::forall;
    use crate::util::DetRng;

    fn pm() -> PerfModel {
        PerfModel::new(presets::llama3_8b(), presets::a100_80gb(), 1)
    }

    /// The Fig. 5 pattern: a shared-prefix subtree A of compute-intensive
    /// requests containing ONE memory hog (request id 10 below), plus a
    /// disjoint mid-density group B.  The hog drags A's aggregate density
    /// below B's, so plain layer-sorting orders B before A's dense leaves —
    /// a violation only a node split can fix.
    fn outlier_workload() -> Workload {
        let mut reqs = Vec::new();
        // A: 10 dense leaves + 1 outlier under prefix [7,7,7,7].
        for i in 0..10u32 {
            let mut p = vec![7, 7, 7, 7];
            p.extend([100 + i, 200 + i, 300 + i]);
            reqs.push(Request::new(0, TraceKind::Custom, p, 8));
        }
        let mut hog = vec![7, 7, 7, 7];
        hog.extend([999, 998, 997]);
        reqs.push(Request::new(0, TraceKind::Custom, hog, 20000)); // id 10
        // B: mid-density group under prefix [55,54].
        for i in 0..2u32 {
            reqs.push(Request::new(
                0,
                TraceKind::Custom,
                vec![55, 54, 60 + i],
                100,
            ));
        }
        Workload::new("outlier", reqs)
    }

    fn prepared(w: &Workload) -> (PrefixTree, PerfModel) {
        let mut t = PrefixTree::build(w);
        let pm = pm();
        for (i, r) in w.requests.iter().enumerate() {
            t.est_output[i] = r.output_len; // perfect estimates for tests
        }
        t.recompute_aggregates(&pm);
        (t, pm)
    }

    fn unit_densities(t: &PrefixTree) -> Vec<f64> {
        t.scheduling_units().iter().map(|&(_, d)| d).collect()
    }

    fn is_non_increasing(xs: &[f64]) -> bool {
        xs.windows(2).all(|w| w[1] <= w[0] * 1.01 + 1e-12)
    }

    #[test]
    fn layer_sort_orders_children_by_density() {
        let w = outlier_workload();
        let (mut t, _) = prepared(&w);
        t.layer_sort();
        t.verify();
        let root_kids = &t.nodes[ROOT].children;
        // Compute-heavy [7,7,7,7] subtree must precede the [99,98] one.
        assert!(t.nodes[root_kids[0]].density >= t.nodes[root_kids[1]].density);
    }

    #[test]
    fn layer_sort_preserves_structure() {
        let w = generate_kind(TraceKind::Mmlu, 300, 5);
        let (mut t, _) = prepared(&w);
        let unique_before = t.unique_tokens();
        t.layer_sort();
        t.verify();
        assert_eq!(t.unique_tokens(), unique_before);
        let mut dfs = t.dfs_requests();
        dfs.sort_unstable();
        assert_eq!(dfs, (0..300).collect::<Vec<u32>>());
    }

    #[test]
    fn transform_fixes_outlier_and_converges() {
        let w = outlier_workload();
        let (mut t, pm) = prepared(&w);
        // Before: the memory-hog under the shared prefix breaks order.
        t.layer_sort();
        assert!(!is_non_increasing(&unit_densities(&t)));
        let stats = t.transform(&pm, 0.0); // unlimited budget (floor 0)
        t.verify();
        assert_eq!(stats.stop, StopReason::Monotone);
        assert!(stats.splits >= 1);
        assert!(is_non_increasing(&unit_densities(&t)));
    }

    #[test]
    fn transform_zero_budget_never_splits() {
        let w = outlier_workload();
        let (mut t, pm) = prepared(&w);
        let stats = t.transform(&pm, 1.0); // preserve 100% sharing
        t.verify();
        assert_eq!(stats.splits, 0);
        assert_eq!(stats.sharing_after, stats.sharing_before);
        assert_eq!(stats.stop, StopReason::BudgetExhausted);
    }

    #[test]
    fn transform_respects_sharing_floor() {
        let pm = pm();
        let w = synthesize(&SynthSpec::new(TraceKind::BurstGpt, 1.1, 0.3, 1500), &pm);
        let mut t = PrefixTree::build(&w);
        t.sample_outputs(1.0, 3); // perfect estimates
        let stats = t.transform(&pm, 0.99);
        t.verify();
        // ≥99% of sharing preserved.
        assert!(
            stats.sharing_after >= stats.sharing_before * 0.99 - 1e-9,
            "before={} after={}",
            stats.sharing_before,
            stats.sharing_after
        );
    }

    #[test]
    fn transform_orders_synthesized_workload() {
        let pm = pm();
        let w = synthesize(&SynthSpec::new(TraceKind::BurstGpt, 1.0, 0.2, 2000), &pm);
        let mut t = PrefixTree::build(&w);
        t.sample_outputs(1.0, 3);
        t.transform(&pm, 0.99);
        t.verify();
        let densities = unit_densities(&t);
        // Global trend: first-quartile mean density > last-quartile mean
        // (the workload is ~94% BurstGPT, so quartile contrast is modest),
        // and the memory-intensive OpenVid units all sit at the right end.
        let q = densities.len() / 4;
        let head: f64 = densities[..q].iter().sum::<f64>() / q as f64;
        let tail: f64 = densities[densities.len() - q..].iter().sum::<f64>() / q as f64;
        assert!(
            head > tail * 1.5,
            "head={head} tail={tail} (tree not density-ordered)"
        );
        let n_memory = densities.iter().filter(|&&d| d < 1.0).count();
        assert!(n_memory > 0, "synth workload should contain OpenVid units");
        assert!(
            densities[densities.len() - n_memory..].iter().all(|&d| d < 1.0),
            "memory-intensive units not at the right end"
        );
    }

    #[test]
    fn split_to_root_preserves_request_paths() {
        let w = outlier_workload();
        let (mut t, pm) = prepared(&w);
        // Find the outlier's node (request 10, the memory hog).
        let id = t
            .pre_order()
            .into_iter()
            .find(|&n| t.nodes[n].requests.contains(&10))
            .unwrap();
        assert_ne!(t.nodes[id].parent, ROOT);
        let cost = t.split_to_root(id);
        assert_eq!(cost, 4); // the shared [7,7,7,7] prefix
        t.recompute_aggregates(&pm);
        t.verify(); // paths still spell the full prompts
        assert!(t.nodes[id].split_off);
        assert_eq!(t.nodes[id].parent, ROOT);
    }

    #[test]
    fn split_reduces_sharing_by_cost() {
        let w = outlier_workload();
        let (mut t, pm) = prepared(&w);
        let unique_before = t.unique_tokens();
        let id = t
            .pre_order()
            .into_iter()
            .find(|&n| t.nodes[n].requests.contains(&10))
            .unwrap();
        let cost = t.split_to_root(id);
        t.recompute_aggregates(&pm);
        assert_eq!(t.unique_tokens(), unique_before + cost);
    }

    #[test]
    fn merge_chains_removes_passthrough() {
        // After splitting a middle child away, its former parent may become
        // a pass-through node; merge_chains collapses it.
        let w = Workload::new(
            "m",
            vec![
                Request::new(0, TraceKind::Custom, vec![1, 2, 3, 4], 8),
                Request::new(0, TraceKind::Custom, vec![1, 2, 3, 5], 8),
            ],
        );
        let (mut t, pm) = prepared(&w);
        let reachable_before = t.pre_order().len();
        // Split one leaf away: parent [1,2,3] now has a single child.
        let id = t
            .pre_order()
            .into_iter()
            .find(|&n| t.nodes[n].requests.contains(&1))
            .unwrap();
        t.split_to_root(id);
        t.recompute_aggregates(&pm);
        t.merge_chains();
        t.recompute_aggregates(&pm);
        t.verify();
        assert!(t.pre_order().len() <= reachable_before);
    }

    #[test]
    fn property_transform_preserves_requests_and_floor() {
        forall("transform invariants", 15, 77, |rng: &mut DetRng| {
            let n = rng.range(5, 80) as usize;
            let mut reqs = Vec::new();
            for _ in 0..n {
                let len = rng.range(2, 30) as usize;
                let p: Vec<u32> = (0..len).map(|_| rng.range(0, 4) as u32).collect();
                let out = if rng.chance(0.3) {
                    rng.range(4000, 30000) as u32
                } else {
                    rng.range(2, 200) as u32
                };
                reqs.push(Request::new(0, TraceKind::Custom, p, out));
            }
            let w = Workload::new("prop", reqs);
            let mut t = PrefixTree::build(&w);
            let pm = pm();
            t.sample_outputs(1.0, rng.u64());
            let floor = 0.5 + rng.f64() * 0.5;
            let stats = t.transform(&pm, floor);
            t.verify();
            if stats.sharing_after < stats.sharing_before * floor - 1e-9 {
                return Err(format!(
                    "sharing floor violated: {} < {} * {floor}",
                    stats.sharing_after, stats.sharing_before
                ));
            }
            let mut dfs = t.dfs_requests();
            dfs.sort_unstable();
            if dfs != (0..n as u32).collect::<Vec<_>>() {
                return Err("requests lost by transform".into());
            }
            if stats.stop == StopReason::IterationCap {
                return Err("hit iteration cap".into());
            }
            Ok(())
        });
    }

    /// Pins the incremental aggregate maintenance in `split_to_root`:
    /// after every split (no intervening full recompute), every node's
    /// aggregates must match a from-scratch `recompute_aggregates` on a
    /// clone bit-for-bit — the summation-order argument made executable.
    #[test]
    fn property_incremental_split_matches_full_recompute() {
        forall("incremental split aggregates", 20, 91, |rng: &mut DetRng| {
            let n = rng.range(5, 80) as usize;
            let mut reqs = Vec::new();
            for _ in 0..n {
                let len = rng.range(2, 30) as usize;
                let p: Vec<u32> = (0..len).map(|_| rng.range(0, 3) as u32).collect();
                reqs.push(Request::new(
                    0,
                    TraceKind::Custom,
                    p,
                    rng.range(2, 500) as u32,
                ));
            }
            let w = Workload::new("diff", reqs);
            let mut t = PrefixTree::build(&w);
            let pm = pm();
            t.sample_outputs(1.0, rng.u64());
            t.recompute_aggregates(&pm);
            // A handful of random splits, differentially checked each time.
            for round in 0..5 {
                let cands: Vec<NodeId> = t
                    .pre_order()
                    .into_iter()
                    .filter(|&id| id != ROOT && t.nodes[id].parent != ROOT)
                    .collect();
                if cands.is_empty() {
                    break;
                }
                let id = cands[rng.range(0, cands.len() as u64 - 1) as usize];
                t.split_to_root(id); // incremental path (pm is cached)
                let mut full = t.clone();
                full.recompute_aggregates(&pm);
                for node in t.pre_order() {
                    let a = &t.nodes[node];
                    let b = &full.nodes[node];
                    let ok = a.demand.comp.to_bits() == b.demand.comp.to_bits()
                        && a.demand.mem.to_bits() == b.demand.mem.to_bits()
                        && a.demand.enc.to_bits() == b.demand.enc.to_bits()
                        && a.subtree_prefill == b.subtree_prefill
                        && a.subtree_unique == b.subtree_unique
                        && a.n_requests == b.n_requests
                        && a.est_output.to_bits() == b.est_output.to_bits()
                        && a.density.to_bits() == b.density.to_bits()
                        && a.prefix_len == b.prefix_len;
                    if !ok {
                        return Err(format!(
                            "round {round}: node {node} diverged after \
                             splitting {id}: incremental ρ={} vs full ρ={}",
                            a.density, b.density
                        ));
                    }
                }
            }
            Ok(())
        });
    }
}
