//! §5.1 output-length sampling.
//!
//! Output lengths are unknown before decoding, so BlendServe selects a
//! subset of requests with probability `p` (1% in the paper) to run first
//! ("warm-up"); their realized output lengths seed the estimates.  Each
//! subtree then estimates the remaining requests with the average sampled
//! output length of the subtree; a subtree with no samples borrows its
//! *sibling* subtree's average (they share the longest common prefix, so
//! their output-length distributions correlate — §5.1), implemented as the
//! nearest sampled ancestor average.

use super::{NodeId, PrefixTree, ROOT};
use crate::util::DetRng;

/// Fallback when the whole workload has zero samples.
pub const DEFAULT_EST: u32 = 256;

impl PrefixTree {
    /// Choose the warm-up sample set and fill `est_output` for every
    /// request.  Sampled requests get their *true* output length (they are
    /// really executed during warm-up and returned to the user — zero extra
    /// cost); others get the subtree/sibling estimate.
    ///
    /// Returns the number of sampled requests.
    pub fn sample_outputs(&mut self, prob: f64, seed: u64) -> usize {
        let mut rng = DetRng::new(seed ^ 0x5a3c_17e9);
        let n = self.n_requests();
        let mut n_sampled = 0;
        for r in 0..n {
            // Predefined outputs (video generation) are free knowledge;
            // they do not consume warm-up budget.
            let hit = self.known_output[r] || rng.chance(prob);
            self.sampled[r] = hit;
            if hit && !self.known_output[r] {
                n_sampled += 1;
            }
        }
        // Guarantee at least one sample for non-empty workloads so the
        // estimator has an anchor (the paper's warm-up always runs).
        if n_sampled == 0 && n > 0 && prob > 0.0 {
            let r = rng.range(0, n as u64 - 1) as usize;
            self.sampled[r] = true;
            n_sampled = 1;
        }
        self.propagate_estimates();
        n_sampled
    }

    /// Fill `est_output` from the current `sampled` flags (bottom-up
    /// subtree averages + top-down sibling fallback).
    pub fn propagate_estimates(&mut self) {
        let order = self.post_order();
        // Bottom-up: (sum of sampled true outputs, count) per node.
        let mut sum = vec![0f64; self.nodes.len()];
        let mut cnt = vec![0u32; self.nodes.len()];
        for &id in &order {
            let mut s = 0f64;
            let mut c = 0u32;
            for &r in &self.nodes[id].requests {
                if self.sampled[r as usize] {
                    s += self.true_output_len(r) as f64;
                    c += 1;
                }
            }
            for &ch in &self.nodes[id].children {
                s += sum[ch];
                c += cnt[ch];
            }
            sum[id] = s;
            cnt[id] = c;
        }
        let global = if cnt[ROOT] > 0 {
            sum[ROOT] / cnt[ROOT] as f64
        } else {
            DEFAULT_EST as f64
        };
        // Top-down: effective estimate per node = own sampled average, else
        // nearest ancestor with samples (≈ sibling average), else global.
        let mut est = vec![0f64; self.nodes.len()];
        for &id in order.iter().rev() {
            // pre-order (parents first)
            est[id] = if cnt[id] > 0 {
                sum[id] / cnt[id] as f64
            } else if id == ROOT {
                global
            } else {
                est[self.nodes[id].parent]
            };
        }
        for id in 0..self.nodes.len() {
            for i in 0..self.nodes[id].requests.len() {
                let r = self.nodes[id].requests[i] as usize;
                self.est_output[r] = if self.sampled[r] {
                    self.true_output_len(r as u32).max(1)
                } else {
                    (est[id].round() as u32).max(1)
                };
            }
        }
    }

    /// Mean absolute relative estimation error over unsampled requests —
    /// used by the robustness experiments (§5.4).
    pub fn estimation_error(&self) -> f64 {
        let mut err = 0.0;
        let mut n = 0usize;
        for r in 0..self.n_requests() {
            if self.sampled[r] {
                continue;
            }
            let truth = self.true_output_len(r as u32).max(1) as f64;
            err += (self.est_output[r] as f64 - truth).abs() / truth;
            n += 1;
        }
        if n == 0 {
            0.0
        } else {
            err / n as f64
        }
    }

    /// The subtree rooted at `id` uses this estimate for its unsampled
    /// requests (test helper).
    pub fn node_estimate(&self, id: NodeId) -> f64 {
        self.nodes[id].est_output
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::presets;
    use crate::perfmodel::PerfModel;
    use crate::trace::generators::generate_kind;
    use crate::trace::{Request, TraceKind, Workload};

    fn pm() -> PerfModel {
        PerfModel::new(presets::llama3_8b(), presets::a100_80gb(), 1)
    }

    fn wl(items: Vec<(Vec<u32>, u32)>) -> Workload {
        let reqs = items
            .into_iter()
            .map(|(p, d)| Request::new(0, TraceKind::Custom, p, d))
            .collect();
        Workload::new("t", reqs)
    }

    #[test]
    fn sampled_requests_get_true_length() {
        let w = wl(vec![(vec![1, 2], 100), (vec![1, 3], 900)]);
        let mut t = PrefixTree::build(&w);
        t.sampled = vec![true, true];
        t.propagate_estimates();
        assert_eq!(t.est_output, vec![100, 900]);
    }

    #[test]
    fn unsampled_borrow_sibling_average() {
        // Two subtrees under the shared [1] prefix: requests 0,1 sampled in
        // the left subtree; request 2 (right subtree, unsampled) must
        // borrow the ancestor average (150), not the global default.
        let w = wl(vec![
            (vec![1, 2, 5], 100),
            (vec![1, 2, 6], 200),
            (vec![1, 9, 9], 7777),
        ]);
        let mut t = PrefixTree::build(&w);
        t.sampled = vec![true, true, false];
        t.propagate_estimates();
        assert_eq!(t.est_output[0], 100);
        assert_eq!(t.est_output[1], 200);
        assert_eq!(t.est_output[2], 150);
    }

    #[test]
    fn subtree_average_preferred_over_global() {
        // Group A sampled at 100; group B sampled at 1000.  Unsampled
        // requests in each group take their own group's average.
        let w = wl(vec![
            (vec![1, 2, 3], 100),
            (vec![1, 2, 4], 555), // unsampled; should estimate 100
            (vec![9, 8, 7], 1000),
            (vec![9, 8, 6], 555), // unsampled; should estimate 1000
        ]);
        let mut t = PrefixTree::build(&w);
        t.sampled = vec![true, false, true, false];
        t.propagate_estimates();
        assert_eq!(t.est_output[1], 100);
        assert_eq!(t.est_output[3], 1000);
    }

    #[test]
    fn no_samples_uses_default() {
        let w = wl(vec![(vec![1], 42), (vec![2], 43)]);
        let mut t = PrefixTree::build(&w);
        t.sampled = vec![false, false];
        t.propagate_estimates();
        assert_eq!(t.est_output, vec![DEFAULT_EST, DEFAULT_EST]);
    }

    #[test]
    fn sample_outputs_rate_and_determinism() {
        let w = generate_kind(TraceKind::BurstGpt, 3000, 9);
        let mut t = PrefixTree::build(&w);
        let n1 = t.sample_outputs(0.01, 7);
        // ~1% ± slack.
        assert!(n1 >= 10 && n1 <= 70, "{n1}");
        let est1 = t.est_output.clone();
        let mut t2 = PrefixTree::build(&w);
        t2.sample_outputs(0.01, 7);
        assert_eq!(est1, t2.est_output);
    }

    #[test]
    fn at_least_one_sample_forced() {
        let w = wl(vec![(vec![1], 42); 5]);
        let mut t = PrefixTree::build(&w);
        let n = t.sample_outputs(1e-9, 3);
        assert_eq!(n, 1);
    }

    #[test]
    fn low_sample_rate_separates_request_classes() {
        // The §5.4 claim: 1% sampling suffices to tell benchmark-type
        // (short output) from video-type (long output) requests.
        let mmlu = generate_kind(TraceKind::Mmlu, 2000, 21);
        let vid = generate_kind(TraceKind::OpenVid, 500, 22);
        let w = Workload::concat("mix", &[&mmlu, &vid]);
        let mut t = PrefixTree::build(&w);
        t.sample_outputs(0.01, 5);
        let pm = pm();
        t.recompute_aggregates(&pm);
        // Average estimates per dataset must differ by >10x.
        let (mut e_mmlu, mut n_mmlu, mut e_vid, mut n_vid) = (0f64, 0, 0f64, 0);
        for (i, r) in w.requests.iter().enumerate() {
            match r.dataset {
                TraceKind::Mmlu => {
                    e_mmlu += t.est_output[i] as f64;
                    n_mmlu += 1;
                }
                TraceKind::OpenVid => {
                    e_vid += t.est_output[i] as f64;
                    n_vid += 1;
                }
                _ => {}
            }
        }
        e_mmlu /= n_mmlu as f64;
        e_vid /= n_vid as f64;
        assert!(e_vid > e_mmlu * 10.0, "mmlu={e_mmlu} vid={e_vid}");
    }

    #[test]
    fn estimation_error_reasonable_on_low_variance_trace() {
        let w = generate_kind(TraceKind::BurstGpt, 4000, 31);
        let mut t = PrefixTree::build(&w);
        t.sample_outputs(0.01, 11);
        let err = t.estimation_error();
        // BurstGPT sigma=0.35 -> mean abs rel error well under 1.
        assert!(err < 0.6, "err={err}");
    }
}
