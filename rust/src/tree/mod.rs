//! The resource-aware prefix tree (§5.1) — BlendServe's key data structure.
//!
//! A radix (path-compressed) trie over prompt token ids.  Each node owns a
//! token *segment* (represented as a `(request, start, len)` slice into an
//! immutable prompt, so the tree never copies token data); a request is
//! attached to the node where its prompt ends.  Every node carries subtree
//! aggregates: §4 demand (using *estimated* output lengths), unique/total
//! prefill tokens (→ subtree sharing ratio `s`) and the sharing-discounted
//! compute density `ρ(R) = (1-s)·ΣComp / ΣMem`.
//!
//! Submodules: [`sampling`] (§5.1 output-length sampling), [`transform`]
//! (§5.2 layer-wise sort + conditional node split + §5.4 convergence loop).

pub mod sampling;
pub mod transform;

use crate::perfmodel::{Demand, PerfModel};
use crate::trace::Workload;
use std::collections::HashMap;
use std::sync::Arc;

/// Index of a node in the tree arena.
pub type NodeId = usize;

pub const ROOT: NodeId = 0;

/// One radix-tree node.
#[derive(Clone, Debug)]
pub struct Node {
    pub parent: NodeId,
    /// Token segment: `prompts[seg_req][seg_start .. seg_start + seg_len]`.
    /// The root has an empty segment.
    pub seg_req: u32,
    pub seg_start: u32,
    pub seg_len: u32,
    /// Children in *scheduling order* (layer-sorted by density after
    /// `transform`).
    pub children: Vec<NodeId>,
    /// Requests whose prompt ends exactly at this node.
    pub requests: Vec<u32>,
    /// True if detached from its original position and re-rooted (its
    /// segment then materializes the full prefix, which must be recomputed
    /// — the §5.2 split cost).
    pub split_off: bool,

    // ---- subtree aggregates (valid after `recompute_aggregates`) ----
    /// Σ §4 demand over subtree requests (estimated output lengths).
    pub demand: Demand,
    /// Total prompt tokens over subtree requests.
    pub subtree_prefill: u64,
    /// Unique trie tokens in the subtree (Σ seg_len).
    pub subtree_unique: u64,
    /// Number of requests in the subtree.
    pub n_requests: u32,
    /// Sharing-discounted compute density ρ(R) of the subtree.
    pub density: f64,
    /// Tokens on the path from root up to (excluding) this node's segment.
    pub prefix_len: u32,
    /// Average estimated output length of subtree requests.
    pub est_output: f64,
}

impl Node {
    fn new(parent: NodeId, seg_req: u32, seg_start: u32, seg_len: u32) -> Self {
        Node {
            parent,
            seg_req,
            seg_start,
            seg_len,
            children: Vec::new(),
            requests: Vec::new(),
            split_off: false,
            demand: Demand::ZERO,
            subtree_prefill: 0,
            subtree_unique: 0,
            n_requests: 0,
            density: 0.0,
            prefix_len: 0,
            est_output: 0.0,
        }
    }

    /// Subtree sharing ratio s = 1 - unique/total.
    pub fn sharing(&self) -> f64 {
        if self.subtree_prefill == 0 {
            0.0
        } else {
            1.0 - self.subtree_unique as f64 / self.subtree_prefill as f64
        }
    }
}

/// The resource-aware prefix tree over one workload.
#[derive(Clone, Debug)]
pub struct PrefixTree {
    pub nodes: Vec<Node>,
    /// Prompt storage, indexed by request id (ids are dense per Workload).
    prompts: Vec<Arc<Vec<u32>>>,
    /// True output lengths (engine-side knowledge).
    true_output: Vec<u32>,
    /// Estimated output lengths (scheduler-side; filled by `sampling`).
    pub est_output: Vec<u32>,
    /// Which requests were chosen for warm-up sampling (their estimate is
    /// exact).
    pub sampled: Vec<bool>,
    /// Requests with predefined output lengths (§5.4: video generation);
    /// always treated as sampled.
    pub known_output: Vec<bool>,
    /// Encoder tokens of each request's attachments (0 for text-only).
    /// Priced into densities only by a modality-aware perf model
    /// (`PerfModel::demand_mm`), so the blind scheduler is unchanged.
    pub enc_tokens: Vec<u64>,
    /// Perf model snapshot, set by `recompute_aggregates`; used by the
    /// transform pass to price scheduling units without re-threading it.
    pub(crate) pm_cache: Option<PerfModel>,
}

impl PrefixTree {
    /// Build the radix trie over all prompts.  O(total prompt tokens).
    pub fn build(workload: &Workload) -> Self {
        let n = workload.len();
        let mut tree = PrefixTree {
            nodes: vec![Node::new(ROOT, 0, 0, 0)],
            prompts: workload.requests.iter().map(|r| r.prompt.clone()).collect(),
            true_output: workload.requests.iter().map(|r| r.output_len).collect(),
            est_output: vec![0; n],
            sampled: vec![false; n],
            known_output: workload.requests.iter().map(|r| r.known_output).collect(),
            enc_tokens: workload.requests.iter().map(|r| r.encoder_tokens()).collect(),
            pm_cache: None,
        };
        // Build-phase child index: (node, first token) -> child.
        let mut index: HashMap<(NodeId, u32), NodeId> = HashMap::new();
        for req in 0..n as u32 {
            tree.insert(req, &mut index);
        }
        tree
    }

    pub(crate) fn seg(&self, id: NodeId) -> &[u32] {
        let nd = &self.nodes[id];
        let p = &self.prompts[nd.seg_req as usize];
        &p[nd.seg_start as usize..(nd.seg_start + nd.seg_len) as usize]
    }

    /// Full prompt of a request.
    pub fn prompt(&self, req: u32) -> &[u32] {
        &self.prompts[req as usize]
    }

    pub fn true_output_len(&self, req: u32) -> u32 {
        self.true_output[req as usize]
    }

    pub fn input_len(&self, req: u32) -> usize {
        self.prompts[req as usize].len()
    }

    fn insert(&mut self, req: u32, index: &mut HashMap<(NodeId, u32), NodeId>) {
        let prompt = self.prompts[req as usize].clone();
        let mut cur = ROOT;
        let mut pos = 0usize;
        loop {
            if pos == prompt.len() {
                self.nodes[cur].requests.push(req);
                return;
            }
            let first = prompt[pos];
            match index.get(&(cur, first)).copied() {
                None => {
                    let id = self.nodes.len();
                    self.nodes.push(Node::new(
                        cur,
                        req,
                        pos as u32,
                        (prompt.len() - pos) as u32,
                    ));
                    self.nodes[id].requests.push(req);
                    self.nodes[cur].children.push(id);
                    index.insert((cur, first), id);
                    return;
                }
                Some(child) => {
                    // Longest common prefix of the remaining prompt and the
                    // child's segment.
                    let m = {
                        let seg = self.seg(child);
                        let rest = &prompt[pos..];
                        let mut m = 0;
                        let lim = seg.len().min(rest.len());
                        while m < lim && seg[m] == rest[m] {
                            m += 1;
                        }
                        m
                    };
                    debug_assert!(m >= 1);
                    if m == self.nodes[child].seg_len as usize {
                        // Full segment match: descend.
                        cur = child;
                        pos += m;
                        continue;
                    }
                    // Partial match: split `child` at offset m.
                    let mid = self.nodes.len();
                    let (c_req, c_start) =
                        (self.nodes[child].seg_req, self.nodes[child].seg_start);
                    self.nodes.push(Node::new(cur, c_req, c_start, m as u32));
                    // child becomes a child of mid with a shortened segment.
                    self.nodes[child].parent = mid;
                    self.nodes[child].seg_start += m as u32;
                    self.nodes[child].seg_len -= m as u32;
                    self.nodes[mid].children.push(child);
                    // Replace child with mid under cur.
                    let slot = self.nodes[cur]
                        .children
                        .iter()
                        .position(|&c| c == child)
                        .expect("child listed under parent");
                    self.nodes[cur].children[slot] = mid;
                    index.insert((cur, first), mid);
                    let child_first = self.seg(child)[0];
                    index.insert((mid, child_first), child);

                    if pos + m == prompt.len() {
                        self.nodes[mid].requests.push(req);
                    } else {
                        let leaf = self.nodes.len();
                        self.nodes.push(Node::new(
                            mid,
                            req,
                            (pos + m) as u32,
                            (prompt.len() - pos - m) as u32,
                        ));
                        self.nodes[leaf].requests.push(req);
                        self.nodes[mid].children.push(leaf);
                        let leaf_first = prompt[pos + m];
                        index.insert((mid, leaf_first), leaf);
                    }
                    return;
                }
            }
        }
    }

    /// Number of requests in the tree.
    pub fn n_requests(&self) -> usize {
        self.prompts.len()
    }

    /// Unique trie tokens of the whole tree (root aggregate).
    pub fn unique_tokens(&self) -> u64 {
        self.nodes[ROOT].subtree_unique
    }

    /// Optimal sharing ratio of the whole workload per the tree.
    pub fn sharing_ratio(&self) -> f64 {
        self.nodes[ROOT].sharing()
    }

    /// Root density ρ(rt) (valid after `recompute_aggregates`).
    pub fn root_density(&self) -> f64 {
        self.nodes[ROOT].density
    }

    /// Recompute one node's aggregates from its own requests and its
    /// children's (already-correct) aggregates.  The per-node summation
    /// order — own requests in attachment order, then children in child
    /// order — is the *only* float summation this tree ever does, so any
    /// caller that respects bottom-up ordering (full post-order sweep or
    /// an ancestor-path walk after a local edit) produces bit-identical
    /// aggregates.
    pub(crate) fn recompute_node(&mut self, id: NodeId, pm: &PerfModel) {
        let mut demand = Demand::ZERO;
        let mut prefill = 0u64;
        let mut unique = self.nodes[id].seg_len as u64;
        let mut n_req = 0u32;
        let mut est_sum = 0f64;
        for i in 0..self.nodes[id].requests.len() {
            let req = self.nodes[id].requests[i];
            let p = self.input_len(req);
            let d = self.est_output[req as usize].max(1) as usize;
            demand.add(pm.demand_mm(p, d, self.enc_tokens[req as usize]));
            prefill += p as u64;
            n_req += 1;
            est_sum += d as f64;
        }
        for i in 0..self.nodes[id].children.len() {
            let c = self.nodes[id].children[i];
            let cn = &self.nodes[c];
            demand.add(cn.demand);
            prefill += cn.subtree_prefill;
            unique += cn.subtree_unique;
            n_req += cn.n_requests;
            est_sum += cn.est_output * cn.n_requests as f64;
        }
        let node = &mut self.nodes[id];
        node.demand = demand;
        node.subtree_prefill = prefill;
        node.subtree_unique = unique;
        node.n_requests = n_req;
        node.est_output = if n_req > 0 { est_sum / n_req as f64 } else { 0.0 };
        // Encoder compute is undiscounted: prefix sharing eliminates
        // shared prefill, not encoder passes (DESIGN.md §10).
        let s = node.sharing();
        node.density = if demand.mem > 0.0 {
            ((1.0 - s) * demand.comp + demand.enc) / demand.mem
        } else {
            f64::INFINITY
        };
    }

    /// Recompute all subtree aggregates bottom-up using the current
    /// estimated output lengths.  O(nodes + requests).
    pub fn recompute_aggregates(&mut self, pm: &PerfModel) {
        self.pm_cache = Some(pm.clone());
        // Post-order via an explicit stack (prompt chains can be deep).
        let order = self.post_order();
        for &id in &order {
            self.recompute_node(id, pm);
        }
        // prefix_len top-down (pre_order guarantees parents first).
        for id in self.pre_order() {
            let parent = self.nodes[id].parent;
            self.nodes[id].prefix_len = if id == ROOT {
                0
            } else {
                self.nodes[parent].prefix_len + self.nodes[parent].seg_len
            };
        }
    }

    /// Post-order traversal (children before parents).
    pub fn post_order(&self) -> Vec<NodeId> {
        let mut order = Vec::with_capacity(self.nodes.len());
        let mut stack = vec![(ROOT, false)];
        while let Some((id, expanded)) = stack.pop() {
            if expanded {
                order.push(id);
            } else {
                stack.push((id, true));
                for &c in &self.nodes[id].children {
                    stack.push((c, false));
                }
            }
        }
        order
    }

    /// Pre-order (DFS) traversal respecting current child order.
    pub fn pre_order(&self) -> Vec<NodeId> {
        let mut order = Vec::with_capacity(self.nodes.len());
        let mut stack = vec![ROOT];
        while let Some(id) = stack.pop() {
            order.push(id);
            for &c in self.nodes[id].children.iter().rev() {
                stack.push(c);
            }
        }
        order
    }

    /// Requests in DFS order — the prefix-sharing-optimal schedule
    /// (§2.2, [73]).  With layer-sorted children this is also the
    /// density-descending order the dual scanner consumes.
    pub fn dfs_requests(&self) -> Vec<u32> {
        let mut out = Vec::with_capacity(self.n_requests());
        for id in self.pre_order() {
            out.extend_from_slice(&self.nodes[id].requests);
        }
        out
    }

    /// Consistency check used by tests: every request reachable exactly
    /// once, path segments concatenate to its prompt, sibling first-tokens
    /// unique, parent links intact.  Panics on violation.
    pub fn verify(&self) {
        let mut seen = vec![0u32; self.n_requests()];
        for id in self.pre_order() {
            let mut firsts = std::collections::HashSet::new();
            for &c in &self.nodes[id].children {
                assert!(self.nodes[c].seg_len > 0, "empty child segment");
                assert_eq!(self.nodes[c].parent, id, "parent link broken");
                // Split-off nodes intentionally duplicate a prefix at root
                // level (their prefix is recomputed); the radix uniqueness
                // invariant applies only to organically-built siblings.
                if !self.nodes[c].split_off {
                    assert!(
                        firsts.insert(self.seg(c)[0]),
                        "duplicate sibling first token under node {id}"
                    );
                }
            }
            for &r in &self.nodes[id].requests {
                seen[r as usize] += 1;
                // Path from root must spell the request's prompt — except
                // for split-off nodes, whose segment materializes the full
                // prefix (checked the same way: concatenation still spells
                // the prompt because the segment starts at offset 0).
                let mut segs: Vec<&[u32]> = Vec::new();
                let mut cur = id;
                while cur != ROOT {
                    segs.push(self.seg(cur));
                    cur = self.nodes[cur].parent;
                }
                let path: Vec<u32> =
                    segs.iter().rev().flat_map(|s| s.iter().copied()).collect();
                assert_eq!(
                    &path[..],
                    &self.prompts[r as usize][..],
                    "request {r} path mismatch"
                );
            }
        }
        for (r, &count) in seen.iter().enumerate() {
            assert_eq!(count, 1, "request {r} appears {count} times");
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::presets;
    use crate::trace::generators::generate_kind;
    use crate::trace::{stats, Request, TraceKind};
    use crate::util::check::forall;
    use crate::util::DetRng;

    fn pm() -> PerfModel {
        PerfModel::new(presets::llama3_8b(), presets::a100_80gb(), 1)
    }

    fn wl(prompts: Vec<Vec<u32>>) -> Workload {
        let reqs = prompts
            .into_iter()
            .map(|p| Request::new(0, TraceKind::Custom, p, 8))
            .collect();
        Workload::new("t", reqs)
    }

    fn built(prompts: Vec<Vec<u32>>) -> (PrefixTree, PerfModel) {
        let w = wl(prompts);
        let mut t = PrefixTree::build(&w);
        let pm = pm();
        for e in t.est_output.iter_mut() {
            *e = 8;
        }
        t.recompute_aggregates(&pm);
        (t, pm)
    }

    #[test]
    fn single_request() {
        let (t, _) = built(vec![vec![1, 2, 3]]);
        t.verify();
        assert_eq!(t.nodes.len(), 2); // root + one leaf
        assert_eq!(t.unique_tokens(), 3);
        assert_eq!(t.dfs_requests(), vec![0]);
    }

    #[test]
    fn shared_prefix_splits_node() {
        let (t, _) = built(vec![vec![1, 2, 3, 4], vec![1, 2, 9, 9]]);
        t.verify();
        assert_eq!(t.unique_tokens(), 6);
        assert!((t.sharing_ratio() - 0.25).abs() < 1e-9); // 2 of 8 saved
    }

    #[test]
    fn prompt_prefix_of_other_prompt() {
        let (t, _) = built(vec![vec![1, 2, 3, 4], vec![1, 2]]);
        t.verify();
        assert_eq!(t.unique_tokens(), 4);
        // Request 1 ends at the internal [1,2] node and is visited first.
        let dfs = t.dfs_requests();
        assert_eq!(dfs, vec![1, 0]);
    }

    #[test]
    fn identical_prompts_stack_on_one_node() {
        let (t, _) = built(vec![vec![5, 6]; 4]);
        t.verify();
        assert_eq!(t.unique_tokens(), 2);
        assert_eq!(t.nodes.len(), 2);
        assert_eq!(t.dfs_requests().len(), 4);
    }

    #[test]
    fn unique_tokens_matches_hash_trie() {
        // Cross-validate against trace::stats' independent implementation.
        let w = generate_kind(TraceKind::Mmlu, 400, 3);
        let mut t = PrefixTree::build(&w);
        for e in t.est_output.iter_mut() {
            *e = 8;
        }
        t.recompute_aggregates(&pm());
        t.verify();
        assert_eq!(t.unique_tokens(), stats::unique_prefix_tokens(&w));
    }

    #[test]
    fn aggregates_consistent() {
        let w = generate_kind(TraceKind::BurstGpt, 300, 5);
        let mut t = PrefixTree::build(&w);
        for (i, r) in w.requests.iter().enumerate() {
            t.est_output[i] = r.output_len;
        }
        let pm = pm();
        t.recompute_aggregates(&pm);
        let root = &t.nodes[ROOT];
        assert_eq!(root.n_requests as usize, w.len());
        assert_eq!(root.subtree_prefill, w.total_input_tokens());
        // Demand equals the flat sum over requests.
        let flat = stats::total_demand(&w, &pm);
        assert!((root.demand.comp - flat.comp).abs() / flat.comp < 1e-9);
        assert!((root.demand.mem - flat.mem).abs() / flat.mem < 1e-9);
        // Density = (1-s) comp/mem.
        let want = (1.0 - t.sharing_ratio()) * flat.comp / flat.mem;
        assert!((t.root_density() - want).abs() < 1e-9);
    }

    #[test]
    fn prefix_len_accumulates() {
        let (t, _) = built(vec![vec![1, 2, 3, 4], vec![1, 2, 9, 9]]);
        // Both leaves hang off the [1,2] node: prefix_len == 2.
        for id in t.pre_order() {
            if !t.nodes[id].requests.is_empty() {
                assert_eq!(t.nodes[id].prefix_len, 2, "node {id}");
            }
        }
    }

    #[test]
    fn dfs_groups_shared_prefixes() {
        // Three MMLU-ish groups; DFS must emit each group contiguously.
        let mut prompts = Vec::new();
        for g in 0..3u32 {
            for i in 0..5u32 {
                prompts.push(vec![100 + g, 101 + g, 200 + g * 10 + i]);
            }
        }
        let (t, _) = built(prompts);
        t.verify();
        let dfs = t.dfs_requests();
        let groups: Vec<u32> = dfs.iter().map(|r| r / 5).collect();
        let mut seen = std::collections::HashSet::new();
        let mut prev = u32::MAX;
        for g in groups {
            if g != prev {
                assert!(seen.insert(g), "group {g} not contiguous in DFS");
                prev = g;
            }
        }
    }

    #[test]
    fn modality_aware_density_prices_encoder_blind_does_not() {
        use crate::modality::Attachment;
        // A memory-bound request carrying a heavy conditioning clip.
        let video = Request::with_known_output(
            0,
            TraceKind::Custom,
            (0..120).collect(),
            2048,
            true,
        )
        .with_attachments(vec![Attachment::new(1, 6912)]);
        let text = Request::new(1, TraceKind::Custom, (1000..1400).collect(), 16);
        let w = Workload::new("mm", vec![video, text]);

        let mut blind = PrefixTree::build(&w);
        for (i, r) in w.requests.iter().enumerate() {
            blind.est_output[i] = r.output_len;
        }
        let pm_blind = pm();
        blind.recompute_aggregates(&pm_blind);

        let mut aware = PrefixTree::build(&w);
        for (i, r) in w.requests.iter().enumerate() {
            aware.est_output[i] = r.output_len;
        }
        let mut pm_aware = pm();
        pm_aware.modality_aware = true;
        aware.recompute_aggregates(&pm_aware);

        let node_of = |t: &PrefixTree, req: u32| {
            t.pre_order()
                .into_iter()
                .find(|&n| t.nodes[n].requests.contains(&req))
                .unwrap()
        };
        // Blind: the attachment is priced at zero — same density as the
        // bare text demand, and ρ(video) is memory-bound.
        let b = blind.nodes[node_of(&blind, 0)].density;
        let want_blind = pm_blind.demand(120, 2048).density();
        assert!((b - want_blind).abs() / want_blind < 1e-9, "{b} vs {want_blind}");
        assert!(b < 1.0, "blind video density should be memory-bound: {b}");
        // Aware: the encoder term lifts it, widening the ρ spread.
        let a = aware.nodes[node_of(&aware, 0)].density;
        assert!(a > b * 1.5, "aware {a} vs blind {b}");
        // The text-only request is priced identically either way.
        let bt = blind.nodes[node_of(&blind, 1)].density;
        let at = aware.nodes[node_of(&aware, 1)].density;
        assert_eq!(bt, at);
        // Root aggregates carry the enc term only when aware.
        assert_eq!(blind.nodes[ROOT].demand.enc, 0.0);
        assert!(aware.nodes[ROOT].demand.enc > 0.0);
        assert!(aware.root_density() > blind.root_density());
    }

    #[test]
    fn property_build_invariants_on_random_workloads() {
        forall("tree build invariants", 30, 42, |rng: &mut DetRng| {
            let n = rng.range(1, 60) as usize;
            let mut prompts = Vec::new();
            for _ in 0..n {
                let len = rng.range(1, 40) as usize;
                // Small alphabet to force heavy sharing and splits.
                let p: Vec<u32> = (0..len).map(|_| rng.range(0, 3) as u32).collect();
                prompts.push(p);
            }
            let w = wl(prompts);
            let mut t = PrefixTree::build(&w);
            for e in t.est_output.iter_mut() {
                *e = 4;
            }
            t.recompute_aggregates(&pm());
            t.verify();
            if t.unique_tokens() != stats::unique_prefix_tokens(&w) {
                return Err(format!(
                    "unique mismatch: {} vs {}",
                    t.unique_tokens(),
                    stats::unique_prefix_tokens(&w)
                ));
            }
            let mut dfs = t.dfs_requests();
            dfs.sort_unstable();
            let want: Vec<u32> = (0..w.len() as u32).collect();
            if dfs != want {
                return Err("dfs not a permutation".into());
            }
            Ok(())
        });
    }
}
