//! A hand-rolled, dependency-free Rust lexer — just enough fidelity for
//! the repo lints (DESIGN.md §13).
//!
//! The crate vendors offline dependencies only, so `syn` is off the
//! table; token-level analysis is also exactly the right altitude for
//! the rules we enforce — every one of them is a pattern over
//! identifiers, punctuation and literal kinds, none needs a full AST.
//! The lexer understands the constructs that would otherwise produce
//! false positives: strings (plain, raw, byte), char literals vs
//! lifetimes, nested block comments, and float vs integer literals
//! (including `1.` / `1..2` / `1.0f64` / `1e-9` disambiguation).
//!
//! Line comments are captured separately because the suppression syntax
//! (`// lint:allow(<rule>) -- <reason>`) lives in them.

/// What kind of lexeme a [`Token`] is.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TokKind {
    /// Identifier or keyword (`for`, `in`, `let`, `self`, type names…).
    Ident,
    /// Integer literal (any base, any suffix except `f32`/`f64`).
    Int,
    /// Float literal (`1.0`, `1.`, `1e-9`, `2f64`, `0.5e3`).
    Float,
    /// String literal (plain, raw or byte) — contents opaque.
    Str,
    /// Char literal (`'x'`, `'\n'`).
    Char,
    /// Lifetime or loop label (`'a`, `'outer`).
    Lifetime,
    /// Punctuation, longest-match (`==`, `::`, `->`, `{`, …).
    Punct,
}

/// One token with its 1-indexed source line.
#[derive(Clone, Debug)]
pub struct Token {
    pub kind: TokKind,
    pub text: String,
    pub line: u32,
}

/// One `//` line comment: its line and the text after the `//`.
#[derive(Clone, Debug)]
pub struct Comment {
    pub line: u32,
    pub text: String,
    /// Whether any token precedes the comment on the same line (a
    /// trailing comment suppresses its own line; a full-line comment
    /// suppresses the next line that carries code).
    pub trailing: bool,
}

/// Lexed form of one source file.
#[derive(Debug, Default)]
pub struct Lexed {
    pub tokens: Vec<Token>,
    pub comments: Vec<Comment>,
}

/// Multi-char punctuation, longest first so greedy matching is correct.
const PUNCTS: [&str; 24] = [
    "<<=", ">>=", "..=", "...", "==", "!=", "<=", ">=", "&&", "||", "::", "..", "->", "=>", "+=",
    "-=", "*=", "/=", "%=", "^=", "&=", "|=", "<<", ">>",
];

fn is_ident_start(c: char) -> bool {
    c.is_ascii_alphabetic() || c == '_'
}

fn is_ident_continue(c: char) -> bool {
    c.is_ascii_alphanumeric() || c == '_'
}

/// Tokenize `src`.  Unterminated strings/comments end the file quietly —
/// the linter reports on what it saw, it is not a compiler front-end.
pub fn lex(src: &str) -> Lexed {
    let b: Vec<char> = src.chars().collect();
    let mut out = Lexed::default();
    let mut i = 0usize;
    let mut line: u32 = 1;
    while i < b.len() {
        let c = b[i];
        if c == '\n' {
            line += 1;
            i += 1;
            continue;
        }
        if c.is_whitespace() {
            i += 1;
            continue;
        }
        // Line comment (also covers `///` and `//!` doc comments).
        if c == '/' && b.get(i + 1) == Some(&'/') {
            let start = i + 2;
            let mut j = start;
            while j < b.len() && b[j] != '\n' {
                j += 1;
            }
            let trailing = out.tokens.last().is_some_and(|t| t.line == line);
            out.comments.push(Comment {
                line,
                text: b[start..j].iter().collect(),
                trailing,
            });
            i = j;
            continue;
        }
        // Block comment, nested per Rust rules.
        if c == '/' && b.get(i + 1) == Some(&'*') {
            let mut depth = 1usize;
            let mut j = i + 2;
            while j < b.len() && depth > 0 {
                if b[j] == '\n' {
                    line += 1;
                    j += 1;
                } else if b[j] == '/' && b.get(j + 1) == Some(&'*') {
                    depth += 1;
                    j += 2;
                } else if b[j] == '*' && b.get(j + 1) == Some(&'/') {
                    depth -= 1;
                    j += 2;
                } else {
                    j += 1;
                }
            }
            i = j;
            continue;
        }
        // Raw / byte string prefixes: r"…", r#"…"#, b"…", br#"…"#.
        if (c == 'r' || c == 'b') && raw_or_byte_string(&b, i).is_some() {
            let (j, lines) = raw_or_byte_string(&b, i).expect("checked above");
            out.tokens.push(Token { kind: TokKind::Str, text: String::new(), line });
            line += lines;
            i = j;
            continue;
        }
        if c == '"' {
            let (j, lines) = skip_string(&b, i);
            out.tokens.push(Token { kind: TokKind::Str, text: String::new(), line });
            line += lines;
            i = j;
            continue;
        }
        // Char literal vs lifetime: a lifetime is `'ident` NOT followed
        // by a closing quote.
        if c == '\'' {
            let next = b.get(i + 1).copied().unwrap_or('\0');
            if is_ident_start(next) && b.get(i + 2) != Some(&'\'') {
                let mut j = i + 1;
                while j < b.len() && is_ident_continue(b[j]) {
                    j += 1;
                }
                out.tokens.push(Token {
                    kind: TokKind::Lifetime,
                    text: b[i + 1..j].iter().collect(),
                    line,
                });
                i = j;
                continue;
            }
            let (j, lines) = skip_char(&b, i);
            out.tokens.push(Token { kind: TokKind::Char, text: String::new(), line });
            line += lines;
            i = j;
            continue;
        }
        if is_ident_start(c) {
            let mut j = i + 1;
            while j < b.len() && is_ident_continue(b[j]) {
                j += 1;
            }
            out.tokens.push(Token {
                kind: TokKind::Ident,
                text: b[i..j].iter().collect(),
                line,
            });
            i = j;
            continue;
        }
        if c.is_ascii_digit() {
            let (j, kind) = lex_number(&b, i);
            out.tokens.push(Token { kind, text: b[i..j].iter().collect(), line });
            i = j;
            continue;
        }
        // Punctuation, longest match first.
        let mut matched = false;
        for p in PUNCTS {
            let pc: Vec<char> = p.chars().collect();
            if b.len() - i >= pc.len() && b[i..i + pc.len()] == pc[..] {
                out.tokens.push(Token { kind: TokKind::Punct, text: p.to_string(), line });
                i += pc.len();
                matched = true;
                break;
            }
        }
        if !matched {
            out.tokens.push(Token { kind: TokKind::Punct, text: c.to_string(), line });
            i += 1;
        }
    }
    out
}

/// Skip a plain string starting at the opening quote; returns (index
/// past the closing quote, newlines crossed).
fn skip_string(b: &[char], start: usize) -> (usize, u32) {
    let mut j = start + 1;
    let mut lines = 0;
    while j < b.len() {
        match b[j] {
            '\\' => j += 2,
            '\n' => {
                lines += 1;
                j += 1;
            }
            '"' => return (j + 1, lines),
            _ => j += 1,
        }
    }
    (j, lines)
}

/// Skip a char literal starting at the opening quote.
fn skip_char(b: &[char], start: usize) -> (usize, u32) {
    let mut j = start + 1;
    let mut lines = 0;
    while j < b.len() {
        match b[j] {
            '\\' => j += 2,
            '\n' => {
                lines += 1;
                j += 1;
            }
            '\'' => return (j + 1, lines),
            _ => j += 1,
        }
    }
    (j, lines)
}

/// Recognize `r"…"`, `r#"…"#`, `b"…"`, `br##"…"##` at `start`; returns
/// (index past the close, newlines crossed) or None if not one.
fn raw_or_byte_string(b: &[char], start: usize) -> Option<(usize, u32)> {
    let mut j = start;
    if b[j] == 'b' {
        j += 1;
    }
    let raw = b.get(j) == Some(&'r');
    if raw {
        j += 1;
    }
    let mut hashes = 0usize;
    while b.get(j) == Some(&'#') {
        hashes += 1;
        j += 1;
    }
    if b.get(j) != Some(&'"') || (!raw && hashes > 0) {
        return None;
    }
    if !raw {
        // Plain byte string: backslash escapes apply.
        let (end, lines) = skip_string(b, j);
        return Some((end, lines));
    }
    // Raw string: ends at `"` followed by `hashes` hashes, no escapes.
    j += 1;
    let mut lines = 0;
    while j < b.len() {
        if b[j] == '\n' {
            lines += 1;
            j += 1;
            continue;
        }
        if b[j] == '"' {
            let mut k = 0;
            while k < hashes && b.get(j + 1 + k) == Some(&'#') {
                k += 1;
            }
            if k == hashes {
                return Some((j + 1 + hashes, lines));
            }
        }
        j += 1;
    }
    Some((j, lines))
}

/// Lex a numeric literal; classifies float vs int per Rust's rules
/// (`1.` float, `1..2` int + range, `1.max(2)` int + method call,
/// `1e-9` float, `1f64` float-by-suffix, `0x1f` int).
fn lex_number(b: &[char], start: usize) -> (usize, TokKind) {
    let mut j = start;
    let mut float = false;
    if b[j] == '0' && matches!(b.get(j + 1), Some('x' | 'o' | 'b')) {
        j += 2;
        while j < b.len() && (b[j].is_ascii_alphanumeric() || b[j] == '_') {
            j += 1;
        }
        return (j, TokKind::Int);
    }
    while j < b.len() && (b[j].is_ascii_digit() || b[j] == '_') {
        j += 1;
    }
    if b.get(j) == Some(&'.') {
        let after = b.get(j + 1).copied().unwrap_or('\0');
        // `1..2` is int + range; `1.max()` is int + method call.
        if after.is_ascii_digit() || !(after == '.' || is_ident_start(after)) {
            float = true;
            j += 1;
            while j < b.len() && (b[j].is_ascii_digit() || b[j] == '_') {
                j += 1;
            }
        }
    }
    if matches!(b.get(j), Some('e' | 'E')) {
        let mut k = j + 1;
        if matches!(b.get(k), Some('+' | '-')) {
            k += 1;
        }
        if b.get(k).is_some_and(|c| c.is_ascii_digit()) {
            float = true;
            j = k;
            while j < b.len() && (b[j].is_ascii_digit() || b[j] == '_') {
                j += 1;
            }
        }
    }
    // Suffix (`u32`, `f64`, …): a float suffix makes the literal float.
    if b.get(j).copied().is_some_and(is_ident_start) {
        let s = j;
        while j < b.len() && is_ident_continue(b[j]) {
            j += 1;
        }
        let suffix: String = b[s..j].iter().collect();
        if suffix == "f32" || suffix == "f64" {
            float = true;
        }
    }
    (j, if float { TokKind::Float } else { TokKind::Int })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn kinds(src: &str) -> Vec<(TokKind, String)> {
        lex(src).tokens.into_iter().map(|t| (t.kind, t.text)).collect()
    }

    #[test]
    fn float_vs_int_disambiguation() {
        let ks = kinds("1.0 1. 1..2 1.max(2) 1e-9 2f64 3u32 0x1f 1_000.5");
        let got: Vec<TokKind> = ks.iter().map(|(k, _)| *k).collect();
        use TokKind::*;
        assert_eq!(
            got,
            vec![
                Float, // 1.0
                Float, // 1.
                Int, Punct, Int, // 1..2
                Int, Punct, Ident, Punct, Int, Punct, // 1.max(2)
                Float, // 1e-9
                Float, // 2f64
                Int,   // 3u32
                Int,   // 0x1f
                Float, // 1_000.5
            ]
        );
    }

    #[test]
    fn strings_chars_lifetimes_and_comments() {
        let src = "let s = \"a == b\"; // trailing\n// lint:allow(r2) -- x\nlet c = 'x'; let l: &'a str = r#\"raw \"x\" \"#;";
        let lx = lex(src);
        // The `==` inside the string must NOT surface as a token.
        assert!(!lx.tokens.iter().any(|t| t.text == "=="));
        assert_eq!(lx.comments.len(), 2);
        assert!(lx.comments[0].trailing);
        assert!(!lx.comments[1].trailing);
        assert_eq!(lx.comments[1].text.trim(), "lint:allow(r2) -- x");
        assert!(lx.tokens.iter().any(|t| t.kind == TokKind::Lifetime && t.text == "a"));
        assert!(lx.tokens.iter().any(|t| t.kind == TokKind::Char));
        assert_eq!(lx.tokens.iter().filter(|t| t.kind == TokKind::Str).count(), 2);
    }

    #[test]
    fn lines_are_tracked_through_multiline_constructs() {
        let src = "a\n/* b\nc */ d\n\"e\nf\" g";
        let lx = lex(src);
        let find = |name: &str| lx.tokens.iter().find(|t| t.text == name).unwrap().line;
        assert_eq!(find("a"), 1);
        assert_eq!(find("d"), 3);
        assert_eq!(find("g"), 5);
    }

    #[test]
    fn nested_block_comments_and_punct_greed() {
        let ks = kinds("/* a /* b */ c */ x ..= <<= == != ->");
        let texts: Vec<&str> = ks.iter().map(|(_, t)| t.as_str()).collect();
        assert_eq!(texts, vec!["x", "..=", "<<=", "==", "!=", "->"]);
    }
}
