//! Determinism & accounting lint pass (DESIGN.md §13).
//!
//! A dependency-free, token-level static analyzer over `rust/src/` that
//! guards the invariants the runtime [`EngineAuditor`](crate::engine)
//! and the golden-trace pins can only check *after* the fact.  No `syn`
//! (the crate vendors offline deps only): [`lexer`] hand-rolls a Rust
//! lexer good enough to distinguish strings, chars, lifetimes, nested
//! block comments, and float-vs-int literals, so rule patterns stored
//! inside string literals — including this linter's own source — never
//! flag.  [`rules`] holds the catalog (r1–r6) and suppression handling.
//!
//! Entry points: `blendserve lint [--root DIR]` (exits non-zero on any
//! diagnostic) and the `lint_gate` integration test that runs the same
//! sweep under `cargo test -q`.

pub mod lexer;
pub mod rules;

pub use rules::{lint_source, Diagnostic};

use std::path::{Path, PathBuf};

/// Files pooled for the cross-file r6 emission check: every
/// `TraceEvent` variant must be constructed in at least one of these.
const R6_EMISSION_SCOPE: [&str; 5] = [
    "engine/sim.rs",
    "server/fleet.rs",
    "server/colocate.rs",
    "stream/mod.rs",
    "kv/mod.rs",
];

/// Lint a set of in-memory files: per-file rules r1–r4 on each, plus the
/// cross-file r5 when both `engine/sim.rs` and `engine/audit.rs` are
/// present and the cross-file r6 when `obs/mod.rs` is present.  Paths
/// are relative to the source root with forward slashes.
pub fn lint_files(files: &[(String, String)]) -> Vec<Diagnostic> {
    let mut diags = Vec::new();
    let mut sorted: Vec<&(String, String)> = files.iter().collect();
    sorted.sort_by(|a, b| a.0.cmp(&b.0));
    for (relpath, src) in &sorted {
        diags.extend(rules::lint_source(relpath, src));
    }
    let find = |p: &str| sorted.iter().find(|(rp, _)| rp == p);
    if let (Some((sim_path, sim_src)), Some((audit_path, audit_src))) =
        (find("engine/sim.rs"), find("engine/audit.rs"))
    {
        let sim = lexer::lex(sim_src);
        let audit = lexer::lex(audit_src);
        let r5 = rules::rule_r5(sim_path, &sim, audit_path, &audit);
        let (allow, _) = rules::allows(sim_path, &sim);
        diags.extend(rules::apply_allows(r5, &allow));
    }
    if let Some((obs_path, obs_src)) = find("obs/mod.rs") {
        let obs = lexer::lex(obs_src);
        let lexed: Vec<(&str, lexer::Lexed)> = R6_EMISSION_SCOPE
            .iter()
            .filter_map(|p| find(p))
            .map(|(rp, src)| (rp.as_str(), lexer::lex(src)))
            .collect();
        let emitters: Vec<(&str, &lexer::Lexed)> =
            lexed.iter().map(|(p, l)| (*p, l)).collect();
        let r6 = rules::rule_r6(obs_path, &obs, &emitters);
        let (allow, _) = rules::allows(obs_path, &obs);
        diags.extend(rules::apply_allows(r6, &allow));
    }
    diags.sort();
    diags
}

/// Recursively collect `.rs` files under `root` (sorted walk — `read_dir`
/// order is itself platform-nondeterministic) and lint them.
pub fn lint_dir(root: &Path) -> std::io::Result<Vec<Diagnostic>> {
    let mut files = Vec::new();
    let mut stack = vec![root.to_path_buf()];
    while let Some(dir) = stack.pop() {
        let mut entries: Vec<PathBuf> =
            std::fs::read_dir(&dir)?.map(|e| e.map(|e| e.path())).collect::<Result<_, _>>()?;
        entries.sort();
        for path in entries {
            if path.is_dir() {
                stack.push(path);
            } else if path.extension().is_some_and(|e| e == "rs") {
                let rel = path
                    .strip_prefix(root)
                    .unwrap_or(&path)
                    .components()
                    .map(|c| c.as_os_str().to_string_lossy())
                    .collect::<Vec<_>>()
                    .join("/");
                files.push((rel, std::fs::read_to_string(&path)?));
            }
        }
    }
    Ok(lint_files(&files))
}

/// Render diagnostics as the canonical `file:line: [rule] msg` report.
pub fn render(diags: &[Diagnostic]) -> String {
    let mut out = String::new();
    for d in diags {
        out.push_str(&d.to_string());
        out.push('\n');
    }
    if diags.is_empty() {
        out.push_str("lint: clean\n");
    } else {
        out.push_str(&format!(
            "lint: {} violation{}\n",
            diags.len(),
            if diags.len() == 1 { "" } else { "s" }
        ));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn diag_ids(diags: &[Diagnostic]) -> Vec<(String, u32)> {
        diags.iter().map(|d| (d.rule.clone(), d.line)).collect()
    }

    #[test]
    fn r1_flags_map_iteration_only_in_sensitive_modules() {
        let src = "pub struct C { entries: HashMap<u64, u32> }\n\
                   impl C {\n\
                   fn total(&self) -> u32 { self.entries.values().sum() }\n\
                   }\n";
        let hits = lint_source("modality/cache.rs", src);
        assert_eq!(diag_ids(&hits), vec![("r1".into(), 3)]);
        assert!(lint_source("util/json.rs", src).is_empty());
    }

    #[test]
    fn r1_flags_for_loops_and_respects_allow() {
        let src = "fn f(m: &HashSet<u32>) -> u32 {\n\
                   let mut s = 0;\n\
                   // lint:allow(r1) -- commutative integer sum\n\
                   for x in m { s += x; }\n\
                   s\n\
                   }\n\
                   fn g(m: &HashSet<u32>) { for x in m { drop(x); } }\n";
        let hits = lint_source("kv/ledger.rs", src);
        assert_eq!(diag_ids(&hits), vec![("r1".into(), 7)]);
    }

    #[test]
    fn r2_flags_wall_clock_anywhere() {
        let src = "fn f() { let t = std::time::Instant::now(); drop(t); }\n";
        let hits = lint_source("util/misc.rs", src);
        assert_eq!(diag_ids(&hits), vec![("r2".into(), 1)]);
        // Pattern inside a string literal must not flag.
        let clean = "const P: &str = \"Instant::now\";\n";
        assert!(lint_source("util/misc.rs", clean).is_empty());
    }

    #[test]
    fn r3_flags_float_eq_but_not_to_bits_or_tests() {
        let src = "fn f(x: f64) -> bool { x == 0.5 }\n\
                   fn g(x: f64, y: f64) -> bool { x.to_bits() == y.to_bits() }\n\
                   #[cfg(test)]\n\
                   mod t { fn h(x: f64) -> bool { x == 0.5 } }\n";
        let hits = lint_source("engine/sim.rs", src);
        assert_eq!(diag_ids(&hits), vec![("r3".into(), 1)]);
    }

    #[test]
    fn r4_scoped_to_pool_and_recovery() {
        let src = "fn f(p: &std::path::Path) { let _ = std::fs::File::create(p); }\n";
        assert_eq!(diag_ids(&lint_source("server/pool.rs", src)), vec![("r4".into(), 1)]);
        assert_eq!(diag_ids(&lint_source("recovery/mod.rs", src)), vec![("r4".into(), 1)]);
        assert!(lint_source("util/bench.rs", src).is_empty());
    }

    #[test]
    fn empty_reason_allow_is_itself_a_violation() {
        let src = "fn f(x: f64) -> bool {\n\
                   // lint:allow(r3) --\n\
                   x == 0.5\n\
                   }\n";
        let hits = lint_source("engine/sim.rs", src);
        // The r3 hit is suppressed structurally? No: a reasonless allow
        // grants nothing, so both the allow error and the r3 hit remain.
        assert_eq!(diag_ids(&hits), vec![("allow".into(), 2), ("r3".into(), 3)]);
    }

    #[test]
    fn r6_cross_file_checks_trace_event_emission() {
        let obs = "pub enum TraceEvent {\n\
                   Admit { req: u32 },\n\
                   Ghost { req: u32 },\n\
                   }\n";
        // sim.rs emits Admit in production code and Ghost only in a test
        // module — Ghost must flag.
        let sim = "fn step(tr: &mut TraceData) {\n\
                   tr.emit(0.0, 0, TraceEvent::Admit { req: 1 });\n\
                   }\n\
                   #[cfg(test)]\n\
                   mod t {\n\
                   fn g(tr: &mut TraceData) {\n\
                   tr.emit(0.0, 0, TraceEvent::Ghost { req: 1 });\n\
                   }\n\
                   }\n";
        let files = vec![
            ("obs/mod.rs".to_string(), obs.to_string()),
            ("engine/sim.rs".to_string(), sim.to_string()),
        ];
        let hits = lint_files(&files);
        assert_eq!(hits.len(), 1, "{hits:?}");
        assert_eq!(hits[0].rule, "r6");
        assert_eq!(hits[0].line, 3);
        assert!(hits[0].msg.contains("Ghost"));
        // Emitting Ghost from another scope file clears the diagnostic.
        let kv = "fn swap(tr: &mut TraceData) {\n\
                  tr.emit(0.0, 0, TraceEvent::Ghost { req: 2 });\n\
                  }\n";
        let mut files = files;
        files.push(("kv/mod.rs".to_string(), kv.to_string()));
        assert!(lint_files(&files).is_empty(), "{:?}", lint_files(&files));
    }

    #[test]
    fn r5_cross_file_checks_simresult_fields() {
        let sim = "pub struct SimResult { pub steps: u64, pub novel: f64 }\n";
        let audit = "fn check(r: &SimResult) { assert!(r.steps > 0); }\n";
        let files = vec![
            ("engine/sim.rs".to_string(), sim.to_string()),
            ("engine/audit.rs".to_string(), audit.to_string()),
        ];
        let hits = lint_files(&files);
        assert_eq!(hits.len(), 1);
        assert_eq!(hits[0].rule, "r5");
        assert_eq!(hits[0].line, 1);
        assert!(hits[0].msg.contains("novel"));
    }
}
