//! The repo-specific rule catalog (DESIGN.md §13).  Every rule guards a
//! determinism or accounting invariant that the runtime `EngineAuditor`
//! and the golden-trace pins can only catch *after* a seed-dependent
//! flake has already happened; each descends from a real historical bug:
//!
//! - **r1** — no iteration over `HashMap`/`HashSet` in ordering-sensitive
//!   modules (the PR 6 `EncoderCache` eviction-order bug class).
//! - **r2** — no ambient nondeterminism or wall-clock (`Instant::now`,
//!   `SystemTime`, `thread_rng`, `RandomState`) anywhere in `rust/src`.
//! - **r3** — no direct `==`/`!=` between float expressions outside
//!   `to_bits` comparisons and test code (the PR 5 running-sum drift
//!   class that deadlocked the prefill gate).
//! - **r4** — file writes in `server/pool.rs` and `recovery/` must route
//!   through `write_atomic`/`JournalWriter` (PR 7 crash consistency).
//! - **r5** — every field of `SimResult` must be referenced in
//!   `engine/audit.rs`, so new accounting can never silently escape the
//!   auditor (cross-file, see [`super::lint_files`]).
//! - **r6** — every `TraceEvent` variant declared in `obs/mod.rs` must
//!   be constructed (`TraceEvent::X`) outside test code somewhere in the
//!   emission scope (`engine/sim.rs`, `server/fleet.rs`,
//!   `server/colocate.rs`, `stream/mod.rs`, `kv/mod.rs`) — dead schema
//!   the Perfetto tooling advertises but never delivers is a lint error
//!   (cross-file, see [`super::lint_files`]).
//!
//! Suppression: `// lint:allow(<rule>[, <rule>]) -- <reason>` on the
//! violating line (trailing) or alone on the line above; the reason is
//! mandatory and an empty one is itself a violation (`allow`).

use super::lexer::{lex, Lexed, TokKind, Token};
use std::collections::{BTreeMap, BTreeSet};

/// One lint finding: `file:line: [rule] msg`.
#[derive(Clone, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub struct Diagnostic {
    pub file: String,
    pub line: u32,
    pub rule: String,
    pub msg: String,
}

impl std::fmt::Display for Diagnostic {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}:{}: [{}] {}", self.file, self.line, self.rule, self.msg)
    }
}

/// Modules where map iteration order can reach scheduling decisions,
/// golden traces, or the resume replay (rule r1's scope).
const ORDER_SENSITIVE: [&str; 8] = [
    "engine/",
    "scheduler/",
    "modality/",
    "kv/",
    "server/",
    "recovery/",
    "stream/",
    "obs/",
];

/// Map methods whose visit order is the `RandomState` iteration order.
const ITER_METHODS: [&str; 10] = [
    "iter",
    "iter_mut",
    "keys",
    "values",
    "values_mut",
    "drain",
    "into_iter",
    "into_keys",
    "into_values",
    "retain",
];

const VALID_RULES: [&str; 6] = ["r1", "r2", "r3", "r4", "r5", "r6"];

fn is_order_sensitive(relpath: &str) -> bool {
    ORDER_SENSITIVE.iter().any(|m| relpath.starts_with(m))
}

fn is_crash_consistent_scope(relpath: &str) -> bool {
    relpath == "server/pool.rs" || relpath.starts_with("recovery/")
}

/// Everything the per-file rules need, computed in one pre-pass.
pub struct FileCtx<'a> {
    pub relpath: &'a str,
    pub lexed: &'a Lexed,
    /// Per-token: inside a `#[cfg(test)]` module or `#[test]` fn body.
    pub in_test: Vec<bool>,
    /// Identifiers declared (or initialized) as `HashMap`/`HashSet`.
    pub map_names: BTreeSet<String>,
    /// Identifiers declared `f32`/`f64` or initialized from a float
    /// literal, in this file.
    pub float_names: BTreeSet<String>,
}

impl<'a> FileCtx<'a> {
    pub fn new(relpath: &'a str, lexed: &'a Lexed) -> Self {
        FileCtx {
            relpath,
            lexed,
            in_test: test_regions(&lexed.tokens),
            map_names: collect_map_names(&lexed.tokens),
            float_names: collect_float_names(&lexed.tokens),
        }
    }
}

/// Mark tokens inside `#[cfg(test)] mod … { }` / `#[test] fn … { }`.
fn test_regions(toks: &[Token]) -> Vec<bool> {
    let mut out = vec![false; toks.len()];
    let mut depth: i32 = 0;
    let mut pending = false;
    // While `Some(d)`, we are in a test region that ends when a `}`
    // returns the depth to `d`.
    let mut test_end: Option<i32> = None;
    let mut i = 0;
    while i < toks.len() {
        let t = &toks[i];
        // Attribute: scan `#[ … ]` as a unit so its contents never
        // confuse the brace depth, and classify it.
        if t.text == "#" && toks.get(i + 1).is_some_and(|n| n.text == "[") {
            let mut j = i + 2;
            let mut brackets = 1;
            let mut idents: Vec<&str> = Vec::new();
            while j < toks.len() && brackets > 0 {
                match toks[j].text.as_str() {
                    "[" => brackets += 1,
                    "]" => brackets -= 1,
                    _ => {
                        if toks[j].kind == TokKind::Ident {
                            idents.push(&toks[j].text);
                        }
                    }
                }
                j += 1;
            }
            let is_test_attr = idents.as_slice() == ["test"]
                || (idents.first() == Some(&"cfg")
                    && idents.contains(&"test")
                    && !idents.contains(&"not"));
            if is_test_attr && test_end.is_none() {
                pending = true;
            }
            for slot in out.iter_mut().take(j).skip(i) {
                *slot = test_end.is_some() || pending;
            }
            i = j;
            continue;
        }
        match t.text.as_str() {
            "{" => {
                if pending && test_end.is_none() {
                    test_end = Some(depth);
                    pending = false;
                }
                depth += 1;
                out[i] = test_end.is_some();
            }
            "}" => {
                depth -= 1;
                // The closing brace itself still belongs to the region.
                out[i] = test_end.is_some();
                if test_end == Some(depth) {
                    test_end = None;
                }
            }
            ";" => {
                // `#[cfg(test)] use …;` — attribute spent without a body.
                out[i] = test_end.is_some() || pending;
                if test_end.is_none() {
                    pending = false;
                }
            }
            _ => out[i] = test_end.is_some() || pending,
        }
        i += 1;
    }
    out
}

/// Names bound to a `HashMap`/`HashSet`: `name: [&][mut] [path::]HashMap`
/// (fields, params, lets, struct-literal inits) and
/// `name = HashMap::new()/with_capacity/from/default()`.
fn collect_map_names(toks: &[Token]) -> BTreeSet<String> {
    let mut names = BTreeSet::new();
    for i in 0..toks.len() {
        let t = &toks[i];
        if t.kind != TokKind::Ident || (t.text != "HashMap" && t.text != "HashSet") {
            continue;
        }
        // Forward form: `= HashMap::new()` etc.
        if toks.get(i + 1).is_some_and(|n| n.text == "::")
            && toks.get(i + 2).is_some_and(|n| {
                matches!(n.text.as_str(), "new" | "with_capacity" | "from" | "default")
            })
            && i >= 2
            && toks[i - 1].text == "="
            && toks[i - 2].kind == TokKind::Ident
        {
            names.insert(toks[i - 2].text.clone());
        }
        // Backward form: `name : [&][mut] [std::collections::] HashMap`.
        let mut j = i;
        while j >= 2 && toks[j - 1].text == "::" && toks[j - 2].kind == TokKind::Ident {
            j -= 2; // hop over one `path::` segment
        }
        while j >= 1 && (toks[j - 1].text == "mut" || toks[j - 1].text == "&") {
            j -= 1;
        }
        if j >= 2 && toks[j - 1].text == ":" && toks[j - 2].kind == TokKind::Ident {
            names.insert(toks[j - 2].text.clone());
        }
    }
    names
}

/// Names declared `f32`/`f64` (fields, params, lets, consts) or
/// `let`-bound directly to a float literal.
fn collect_float_names(toks: &[Token]) -> BTreeSet<String> {
    let mut names = BTreeSet::new();
    for i in 0..toks.len() {
        let t = &toks[i];
        if t.kind == TokKind::Ident && (t.text == "f64" || t.text == "f32") {
            let mut j = i;
            while j >= 1 && (toks[j - 1].text == "mut" || toks[j - 1].text == "&") {
                j -= 1;
            }
            if j >= 2 && toks[j - 1].text == ":" && toks[j - 2].kind == TokKind::Ident {
                names.insert(toks[j - 2].text.clone());
            }
        }
        if t.text == "let" && t.kind == TokKind::Ident {
            let mut j = i + 1;
            if toks.get(j).is_some_and(|n| n.text == "mut") {
                j += 1;
            }
            if toks.get(j).is_some_and(|n| n.kind == TokKind::Ident)
                && toks.get(j + 1).is_some_and(|n| n.text == "=")
                && toks.get(j + 2).is_some_and(|n| n.kind == TokKind::Float)
            {
                names.insert(toks[j].text.clone());
            }
        }
    }
    names
}

/// r1 — iteration over `HashMap`/`HashSet` in ordering-sensitive modules.
pub fn rule_r1(ctx: &FileCtx) -> Vec<Diagnostic> {
    let mut out = Vec::new();
    if !is_order_sensitive(ctx.relpath) || ctx.map_names.is_empty() {
        return out;
    }
    let toks = &ctx.lexed.tokens;
    for i in 0..toks.len() {
        // `name.iter()` / `name.keys()` / …
        if toks[i].text == "."
            && toks.get(i + 1).is_some_and(|m| {
                m.kind == TokKind::Ident && ITER_METHODS.contains(&m.text.as_str())
            })
            && toks.get(i + 2).is_some_and(|p| p.text == "(")
            && i >= 1
            && toks[i - 1].kind == TokKind::Ident
            && ctx.map_names.contains(&toks[i - 1].text)
        {
            out.push(Diagnostic {
                file: ctx.relpath.to_string(),
                line: toks[i + 1].line,
                rule: "r1".into(),
                msg: format!(
                    "iteration over hash-ordered `{}` via `.{}()` in an \
                     ordering-sensitive module — use a sorted key list, a Vec, \
                     or a BTreeMap",
                    toks[i - 1].text,
                    toks[i + 1].text
                ),
            });
        }
        // `for … in [&][mut] [self.]name {`
        if toks[i].kind == TokKind::Ident && toks[i].text == "for" {
            let mut j = i + 1;
            // Skip the pattern up to `in` (bounded so a stray `for` in a
            // generic bound cannot run away).
            let mut hops = 0;
            while j < toks.len() && toks[j].text != "in" && hops < 24 {
                j += 1;
                hops += 1;
            }
            if j >= toks.len() || toks[j].text != "in" {
                continue;
            }
            j += 1;
            while j < toks.len() && (toks[j].text == "&" || toks[j].text == "mut") {
                j += 1;
            }
            if toks.get(j).is_some_and(|t| t.text == "self")
                && toks.get(j + 1).is_some_and(|t| t.text == ".")
            {
                j += 2;
            }
            if toks.get(j).is_some_and(|t| {
                t.kind == TokKind::Ident && ctx.map_names.contains(&t.text)
            }) && toks.get(j + 1).is_some_and(|t| t.text == "{")
            {
                out.push(Diagnostic {
                    file: ctx.relpath.to_string(),
                    line: toks[j].line,
                    rule: "r1".into(),
                    msg: format!(
                        "`for … in` over hash-ordered `{}` in an \
                         ordering-sensitive module — collect and sort the keys \
                         first",
                        toks[j].text
                    ),
                });
            }
        }
    }
    out
}

/// r2 — ambient nondeterminism / wall-clock sources.
pub fn rule_r2(ctx: &FileCtx) -> Vec<Diagnostic> {
    let toks = &ctx.lexed.tokens;
    let mut out = Vec::new();
    for i in 0..toks.len() {
        if toks[i].kind != TokKind::Ident {
            continue;
        }
        let hit = match toks[i].text.as_str() {
            "Instant"
                if toks.get(i + 1).is_some_and(|t| t.text == "::")
                    && toks.get(i + 2).is_some_and(|t| t.text == "now") =>
            {
                Some("`Instant::now` reads the wall clock")
            }
            "SystemTime" => Some("`SystemTime` reads the wall clock"),
            "thread_rng" => Some("`thread_rng` is OS-seeded — use `util::DetRng`"),
            "RandomState" => Some("`RandomState` randomizes hash iteration order"),
            _ => None,
        };
        if let Some(why) = hit {
            out.push(Diagnostic {
                file: ctx.relpath.to_string(),
                line: toks[i].line,
                rule: "r2".into(),
                msg: format!("{why}; simulations must be bit-deterministic"),
            });
        }
    }
    out
}

/// r3 — direct float `==`/`!=` outside `to_bits` and test code.
pub fn rule_r3(ctx: &FileCtx) -> Vec<Diagnostic> {
    let toks = &ctx.lexed.tokens;
    let mut out = Vec::new();
    for i in 0..toks.len() {
        if toks[i].kind != TokKind::Punct || (toks[i].text != "==" && toks[i].text != "!=") {
            continue;
        }
        if ctx.in_test[i] {
            continue;
        }
        let left = operand_back(toks, i);
        let right = operand_fwd(toks, i);
        let spans = [&left, &right];
        let has_to_bits = spans
            .iter()
            .any(|s| s.iter().any(|&j| toks[j].text == "to_bits"));
        if has_to_bits {
            continue;
        }
        let is_float_span = |s: &Vec<usize>| {
            s.iter().any(|&j| {
                toks[j].kind == TokKind::Float
                    || (ctx.float_names.contains(&toks[j].text)
                        && toks[j].kind == TokKind::Ident
                        && toks[j].text != "f64"
                        && toks[j].text != "f32")
                    || (toks[j].text == "as"
                        && toks
                            .get(j + 1)
                            .is_some_and(|n| n.text == "f64" || n.text == "f32"))
            })
        };
        if is_float_span(&left) || is_float_span(&right) {
            out.push(Diagnostic {
                file: ctx.relpath.to_string(),
                line: toks[i].line,
                rule: "r3".into(),
                msg: format!(
                    "float `{}` comparison — accumulated floats drift (PR 5 \
                     prefill-gate deadlock); compare integers, use \
                     `.to_bits()`, or justify exactness",
                    toks[i].text
                ),
            });
        }
    }
    out
}

/// Operand token indices left of comparison index `op` (balanced groups
/// included; stops at any other operator or delimiter).
fn operand_back(toks: &[Token], op: usize) -> Vec<usize> {
    let mut span = Vec::new();
    let mut depth = 0usize;
    let mut j = op;
    while j > 0 {
        j -= 1;
        let t = &toks[j];
        match t.text.as_str() {
            ")" | "]" => depth += 1,
            "(" | "[" => {
                if depth == 0 {
                    break;
                }
                depth -= 1;
            }
            _ if depth > 0 => {}
            "." | "::" => {}
            _ => {
                let atom = matches!(
                    t.kind,
                    TokKind::Ident | TokKind::Int | TokKind::Float | TokKind::Str | TokKind::Char
                );
                if !atom || matches!(t.text.as_str(), "if" | "while" | "return" | "match") {
                    break;
                }
            }
        }
        span.push(j);
    }
    span
}

/// Operand token indices right of comparison index `op`.
fn operand_fwd(toks: &[Token], op: usize) -> Vec<usize> {
    let mut span = Vec::new();
    let mut depth = 0usize;
    let mut j = op;
    // A leading unary minus / reference belongs to the operand.
    while j + 1 < toks.len() && matches!(toks[j + 1].text.as_str(), "-" | "&" | "*" | "!") {
        j += 1;
    }
    while j + 1 < toks.len() {
        j += 1;
        let t = &toks[j];
        match t.text.as_str() {
            "(" | "[" => depth += 1,
            ")" | "]" => {
                if depth == 0 {
                    break;
                }
                depth -= 1;
            }
            _ if depth > 0 => {}
            "." | "::" => {}
            _ => {
                let atom = matches!(
                    t.kind,
                    TokKind::Ident | TokKind::Int | TokKind::Float | TokKind::Str | TokKind::Char
                );
                if !atom {
                    break;
                }
            }
        }
        span.push(j);
    }
    span
}

/// r4 — raw file creation/write in crash-consistent modules.
pub fn rule_r4(ctx: &FileCtx) -> Vec<Diagnostic> {
    let mut out = Vec::new();
    if !is_crash_consistent_scope(ctx.relpath) {
        return out;
    }
    let toks = &ctx.lexed.tokens;
    for i in 0..toks.len() {
        if ctx.in_test[i] || toks[i].kind != TokKind::Ident {
            continue;
        }
        let pair = |a: &str, b: &str| {
            toks[i].text == a
                && toks.get(i + 1).is_some_and(|t| t.text == "::")
                && toks.get(i + 2).is_some_and(|t| t.text == b)
        };
        let hit = if pair("File", "create") {
            Some("`File::create`")
        } else if pair("fs", "write") {
            Some("`fs::write`")
        } else if pair("OpenOptions", "new") {
            Some("`OpenOptions::new`")
        } else {
            None
        };
        if let Some(call) = hit {
            out.push(Diagnostic {
                file: ctx.relpath.to_string(),
                line: toks[i].line,
                rule: "r4".into(),
                msg: format!(
                    "{call} in a crash-consistent module — route output \
                     through `write_atomic` or `JournalWriter` so a crash \
                     cannot leave a torn file"
                ),
            });
        }
    }
    out
}

/// r5 — every `SimResult` field must be referenced in `engine/audit.rs`.
/// Returns diagnostics anchored at the field declarations in `sim_path`.
pub fn rule_r5(
    sim_path: &str,
    sim: &Lexed,
    audit_path: &str,
    audit: &Lexed,
) -> Vec<Diagnostic> {
    let audit_idents: BTreeSet<&str> = audit
        .tokens
        .iter()
        .filter(|t| t.kind == TokKind::Ident)
        .map(|t| t.text.as_str())
        .collect();
    let mut out = Vec::new();
    for (name, line) in struct_fields(&sim.tokens, "SimResult") {
        if !audit_idents.contains(name.as_str()) {
            out.push(Diagnostic {
                file: sim_path.to_string(),
                line,
                rule: "r5".into(),
                msg: format!(
                    "`SimResult.{name}` is never referenced in {audit_path} — \
                     extend `EngineAuditor` (or `check_final`) so the new \
                     accounting cannot silently escape the auditor"
                ),
            });
        }
    }
    out
}

/// r6 — every `TraceEvent` variant must be constructed (`TraceEvent::X`)
/// outside test code in at least one emission-scope file.  A variant
/// nobody emits is dead schema: the Perfetto exporter and summarizer
/// advertise it, the auditor can never reconcile it, and the docs lie.
/// Diagnostics anchor at the variant declarations in `obs_path`.
pub fn rule_r6(
    obs_path: &str,
    obs: &Lexed,
    emitters: &[(&str, &Lexed)],
) -> Vec<Diagnostic> {
    let mut emitted: BTreeSet<String> = BTreeSet::new();
    for (_, lexed) in emitters {
        let in_test = test_regions(&lexed.tokens);
        let toks = &lexed.tokens;
        for i in 0..toks.len() {
            if toks[i].kind == TokKind::Ident
                && toks[i].text == "TraceEvent"
                && !in_test[i]
                && toks.get(i + 1).is_some_and(|t| t.text == "::")
                && toks.get(i + 2).is_some_and(|t| t.kind == TokKind::Ident)
            {
                emitted.insert(toks[i + 2].text.clone());
            }
        }
    }
    let scope: Vec<&str> = emitters.iter().map(|(p, _)| *p).collect();
    let mut out = Vec::new();
    for (variant, line) in enum_variants(&obs.tokens, "TraceEvent") {
        if !emitted.contains(&variant) {
            out.push(Diagnostic {
                file: obs_path.to_string(),
                line,
                rule: "r6".into(),
                msg: format!(
                    "`TraceEvent::{variant}` is never emitted in the emission \
                     scope ({}) — wire the event into its engine/coordinator \
                     code path or drop the variant",
                    scope.join(", ")
                ),
            });
        }
    }
    out
}

/// `(variant, line)` pairs of `enum <name> { … }` at body depth 1.
fn enum_variants(toks: &[Token], name: &str) -> Vec<(String, u32)> {
    let mut out = Vec::new();
    let mut i = 0;
    while i + 2 < toks.len() {
        if toks[i].text == "enum" && toks[i + 1].text == name && toks[i + 2].text == "{" {
            let mut depth = 1;
            let mut j = i + 3;
            // A variant ident is expected at the body's start and after
            // each depth-1 comma; payload braces/parens reset the flag so
            // field names never register as variants.
            let mut expect_variant = true;
            while j < toks.len() && depth > 0 {
                match toks[j].text.as_str() {
                    "{" | "(" | "[" | "<" => {
                        depth += 1;
                        expect_variant = false;
                    }
                    "}" | ")" | "]" | ">" => depth -= 1,
                    // `Vec<Vec<f64>>` lexes its closer as one `>>` token.
                    ">>" => depth -= 2,
                    "," => {
                        if depth == 1 {
                            expect_variant = true;
                        }
                    }
                    _ => {
                        if depth == 1 && expect_variant && toks[j].kind == TokKind::Ident {
                            out.push((toks[j].text.clone(), toks[j].line));
                            expect_variant = false;
                        }
                    }
                }
                j += 1;
            }
            return out;
        }
        i += 1;
    }
    out
}

/// `(field, line)` pairs of `struct <name> { … }` at body depth 1.
fn struct_fields(toks: &[Token], name: &str) -> Vec<(String, u32)> {
    let mut out = Vec::new();
    let mut i = 0;
    while i + 2 < toks.len() {
        if toks[i].text == "struct" && toks[i + 1].text == name && toks[i + 2].text == "{" {
            let mut depth = 1;
            let mut j = i + 3;
            while j < toks.len() && depth > 0 {
                match toks[j].text.as_str() {
                    "{" | "(" | "[" | "<" => depth += 1,
                    "}" | ")" | "]" | ">" => depth -= 1,
                    // `Vec<Vec<f64>>` lexes its closer as one `>>` token.
                    ">>" => depth -= 2,
                    ":" if depth == 1
                        && j >= 1
                        && toks[j - 1].kind == TokKind::Ident
                        && (j < 2
                            || matches!(toks[j - 2].text.as_str(), "{" | "," | "pub")) =>
                    {
                        out.push((toks[j - 1].text.clone(), toks[j - 1].line));
                    }
                    _ => {}
                }
                j += 1;
            }
            return out;
        }
        i += 1;
    }
    out
}

/// Parse `lint:allow` comments: per-line allowed rules, plus diagnostics
/// for malformed suppressions (empty reason, unknown rule).
pub fn allows(
    relpath: &str,
    lexed: &Lexed,
) -> (BTreeMap<u32, BTreeSet<String>>, Vec<Diagnostic>) {
    let mut map: BTreeMap<u32, BTreeSet<String>> = BTreeMap::new();
    let mut bad = Vec::new();
    let diag = |line: u32, msg: String| Diagnostic {
        file: relpath.to_string(),
        line,
        rule: "allow".into(),
        msg,
    };
    for c in &lexed.comments {
        let text = c.text.trim();
        let Some(rest) = text.strip_prefix("lint:allow") else { continue };
        let Some(rest) = rest.trim_start().strip_prefix('(') else {
            bad.push(diag(
                c.line,
                "malformed suppression — expected `lint:allow(<rule>) -- <reason>`".into(),
            ));
            continue;
        };
        let Some(close) = rest.find(')') else {
            bad.push(diag(c.line, "malformed suppression — missing `)`".into()));
            continue;
        };
        let (rule_list, tail) = rest.split_at(close);
        let tail = &tail[1..];
        let mut rules: BTreeSet<String> = BTreeSet::new();
        let mut ok = true;
        for r in rule_list.split(',') {
            let r = r.trim();
            if VALID_RULES.contains(&r) {
                rules.insert(r.to_string());
            } else {
                bad.push(diag(c.line, format!("unknown rule `{r}` in lint:allow (valid: r1..r6)")));
                ok = false;
            }
        }
        let reason = tail.trim_start().strip_prefix("--").map(str::trim).unwrap_or("");
        if reason.is_empty() {
            bad.push(diag(
                c.line,
                "suppression without a reason — write \
                 `lint:allow(<rule>) -- <why this site is safe>`"
                    .into(),
            ));
            continue;
        }
        if !ok || rules.is_empty() {
            continue;
        }
        // A trailing comment covers its own line; a full-line comment
        // covers the next line that carries code.
        let target = if c.trailing {
            Some(c.line)
        } else {
            lexed.tokens.iter().find(|t| t.line > c.line).map(|t| t.line)
        };
        if let Some(line) = target {
            map.entry(line).or_default().extend(rules);
        }
    }
    (map, bad)
}

/// Drop diagnostics covered by a `lint:allow` on their line.
pub fn apply_allows(
    diags: Vec<Diagnostic>,
    allow: &BTreeMap<u32, BTreeSet<String>>,
) -> Vec<Diagnostic> {
    diags
        .into_iter()
        .filter(|d| {
            d.rule == "allow"
                || !allow.get(&d.line).is_some_and(|rules| rules.contains(&d.rule))
        })
        .collect()
}

/// Run rules r1–r4 plus suppression handling on one file.  `relpath` is
/// the path relative to `rust/src` (forward slashes) — it selects which
/// rules apply.
pub fn lint_source(relpath: &str, src: &str) -> Vec<Diagnostic> {
    let lexed = lex(src);
    let ctx = FileCtx::new(relpath, &lexed);
    let mut diags = Vec::new();
    diags.extend(rule_r1(&ctx));
    diags.extend(rule_r2(&ctx));
    diags.extend(rule_r3(&ctx));
    diags.extend(rule_r4(&ctx));
    let (allow, bad) = allows(relpath, &lexed);
    let mut diags = apply_allows(diags, &allow);
    diags.extend(bad);
    diags.sort();
    diags
}
