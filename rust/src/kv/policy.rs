//! Swap-out vs discard: the per-retraction policy choice.
//!
//! A retraction victim holds `p_done - hit` privately-cached prompt
//! tokens plus `d_done` decoded tokens.  Discarding (the pre-tiering
//! path) re-prefills the prompt tail and re-runs every decode step on
//! re-admission; swapping moves the extent over the host link twice
//! (out now, back before re-admission).  The policy swaps when the
//! link round-trip — *including the wait for transfers already queued
//! on the link* — undercuts a roofline estimate of that recompute by
//! the configured margin, and host memory has room.

use crate::perfmodel::PerfModel;

/// The two costs a retraction weighs, in seconds.
#[derive(Clone, Copy, Debug)]
pub struct SwapCosts {
    /// Roofline estimate of the recompute a discard would pay.
    pub recompute_s: f64,
    /// Link round-trip for the extent, including current queue delay.
    pub transfer_s: f64,
    /// Host bytes the extent occupies.
    pub extent_bytes: f64,
}

/// Outcome of one retraction decision.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SwapDecision {
    /// Offload the extent to host and prefetch it back.
    Swap,
    /// Discard and recompute on re-admission (pre-tiering behaviour).
    Discard,
}

/// The margin-based swap policy.
#[derive(Clone, Copy, Debug)]
pub struct SwapPolicy {
    /// Swap only when `transfer_s <= margin * recompute_s`.  1.0 is
    /// break-even; < 1 demands the link win by that factor (conservative
    /// against estimate error); > 1 prefers the link even when slightly
    /// slower (frees compute for other requests).
    pub margin: f64,
}

impl SwapPolicy {
    pub fn new(margin: f64) -> Self {
        assert!(margin > 0.0, "swap margin {margin}");
        SwapPolicy { margin }
    }

    /// Decide one retraction.  `host_free_bytes` is the ledger's
    /// remaining budget.
    pub fn decide(&self, costs: &SwapCosts, host_free_bytes: f64) -> SwapDecision {
        if costs.extent_bytes <= 0.0 || costs.extent_bytes > host_free_bytes {
            return SwapDecision::Discard;
        }
        if costs.transfer_s <= self.margin * costs.recompute_s {
            SwapDecision::Swap
        } else {
            SwapDecision::Discard
        }
    }
}

/// Roofline estimate of the recompute a discarded retraction pays on
/// re-admission: re-prefilling `p_redo` prompt tokens (GEMM + quadratic
/// prefill attention ending at context `p_total`) plus re-running
/// `d_redo` decode steps (GEMM compute overlapped with streaming the
/// request's KV context each step) — the same `max(comp, mem)` shape as
/// the §4 request model.
pub fn recompute_cost(pm: &PerfModel, p_redo: usize, p_total: usize, d_redo: usize) -> f64 {
    let comp = pm.comp_tokens(p_redo + d_redo) + pm.comp_prefill_attn(p_redo, p_total);
    let mem = pm.mem_request(p_total, d_redo);
    comp.max(mem)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::presets;

    fn pm() -> PerfModel {
        PerfModel::new(presets::llama3_8b(), presets::a100_80gb(), 1)
    }

    #[test]
    fn decide_compares_costs_with_margin() {
        let p = SwapPolicy::new(1.0);
        let costs =
            SwapCosts { recompute_s: 1.0, transfer_s: 0.5, extent_bytes: 10.0 };
        assert_eq!(p.decide(&costs, 100.0), SwapDecision::Swap);
        let slow = SwapCosts { transfer_s: 1.5, ..costs };
        assert_eq!(p.decide(&slow, 100.0), SwapDecision::Discard);
        // A 2x margin tolerates a link up to twice the recompute cost.
        assert_eq!(SwapPolicy::new(2.0).decide(&slow, 100.0), SwapDecision::Swap);
    }

    #[test]
    fn decide_respects_host_budget() {
        let p = SwapPolicy::new(1.0);
        let costs =
            SwapCosts { recompute_s: 1.0, transfer_s: 0.1, extent_bytes: 10.0 };
        assert_eq!(p.decide(&costs, 9.0), SwapDecision::Discard);
        assert_eq!(p.decide(&costs, 10.0), SwapDecision::Swap);
        let empty = SwapCosts { extent_bytes: 0.0, ..costs };
        assert_eq!(p.decide(&empty, 100.0), SwapDecision::Discard);
    }

    #[test]
    fn recompute_cost_grows_with_lost_progress() {
        let pm = pm();
        let small = recompute_cost(&pm, 100, 500, 10);
        let more_prefill = recompute_cost(&pm, 400, 500, 10);
        let more_decode = recompute_cost(&pm, 100, 500, 400);
        assert!(more_prefill > small);
        assert!(more_decode > small);
        assert_eq!(recompute_cost(&pm, 0, 500, 0), 0.0);
    }

    #[test]
    fn long_decode_redo_is_memory_bound() {
        // Re-running thousands of decode steps streams the KV context
        // every step: the §4 memory term dominates, which is exactly why
        // a PCIe round-trip (one pass over the bytes instead of d_redo
        // passes) wins for decode-heavy victims.
        let pm = pm();
        let d_redo = 2000;
        let mem = pm.mem_request(200, d_redo);
        let comp = pm.comp_tokens(200 + d_redo) + pm.comp_prefill_attn(200, 200);
        assert!(mem > comp, "mem {mem} comp {comp}");
        let cost = recompute_cost(&pm, 200, 200, d_redo);
        assert_eq!(cost, mem);
        // The link round-trip for the same extent is far cheaper.
        let roundtrip = pm.link_kv_roundtrip(2200.0);
        assert!(
            roundtrip < cost,
            "roundtrip {roundtrip} not cheaper than recompute {cost}"
        );
    }
}
