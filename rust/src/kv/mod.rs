//! Tiered KV manager: HBM ↔ host offload for retracted requests
//! (DESIGN.md §9).
//!
//! BlendServe's thesis is overlapping heterogeneous resource demands;
//! until this module the simulator left one whole resource idle — the
//! host link.  Every retraction discarded the victim's KV and paid a
//! full prompt re-prefill plus a re-decode of every token it had already
//! produced, even when the GPU was compute-bound and the PCIe link was
//! doing nothing.  The tiered KV manager turns that retraction into a
//! *policy choice*:
//!
//! - [`KvLedger`] tracks per-request offloaded extents (tokens + decode
//!   progress) against a host-memory budget, with exact token
//!   conservation (`tests/kv_ledger_oracle.rs` pins it differentially).
//! - [`LinkTimeline`] models the PCIe link as a single-server FIFO
//!   queue in simulated time: swap-outs occupy it at retraction, and the
//!   matching swap-in is enqueued right behind (the prefetch), so the
//!   transfer streams back *during* subsequent engine steps — hidden
//!   under GEMM time whenever the schedule is compute-bound, exactly the
//!   overlap argument behind `blended_utilization`.  Only the residual
//!   that is not done by re-admission time surfaces as a stall.
//! - [`SwapPolicy`] compares the link round-trip (including current
//!   queue occupancy) against a roofline estimate of the recompute the
//!   swap avoids, and discards when the link is the slower path or host
//!   memory is exhausted.
//!
//! The engine integration lives in `engine/sim.rs` (`retract_one` makes
//! the swap decision; the re-admission path restores fetched extents and
//! resumes decode where it stopped).  With `kv.enabled = false`
//! (the default) none of this runs and the engine is bit-identical to
//! the discard-and-recompute path.

pub mod ledger;
pub mod policy;

pub use ledger::{KvExtent, KvLedger};
pub use policy::{recompute_cost, SwapCosts, SwapDecision, SwapPolicy};

use crate::config::KvConfig;
use crate::obs::{TraceData, TraceEvent};
use crate::perfmodel::PerfModel;

/// The PCIe link as a single-server FIFO queue over simulated time.
///
/// Transfers are issued at monotonically non-decreasing `now` values (the
/// engine clock); each occupies the link from `max(busy_until, now)` for
/// `bytes / bytes_per_s` seconds.  `busy_time` accumulates total occupied
/// seconds for the `link_busy_frac` report.
#[derive(Clone, Debug)]
pub struct LinkTimeline {
    bytes_per_s: f64,
    busy_until: f64,
    busy_time: f64,
}

impl LinkTimeline {
    pub fn new(bytes_per_s: f64) -> Self {
        LinkTimeline { bytes_per_s, busy_until: 0.0, busy_time: 0.0 }
    }

    /// Queue a transfer of `bytes` at time `now`; returns its completion
    /// time.
    pub fn transfer(&mut self, now: f64, bytes: f64) -> f64 {
        debug_assert!(self.bytes_per_s > 0.0, "transfer on a zero-bandwidth link");
        let dt = bytes / self.bytes_per_s;
        self.busy_until = self.busy_until.max(now) + dt;
        self.busy_time += dt;
        self.busy_until
    }

    /// Time a round-trip (offload + fetch) queued at `now` would take to
    /// complete, including the wait for the link to drain — the policy's
    /// link-budget-aware cost probe.  Does not mutate the timeline.
    pub fn eta_roundtrip(&self, now: f64, bytes: f64) -> f64 {
        if self.bytes_per_s <= 0.0 {
            return f64::INFINITY;
        }
        (self.busy_until - now).max(0.0) + 2.0 * bytes / self.bytes_per_s
    }

    /// Total seconds the link has been occupied.
    pub fn busy_time(&self) -> f64 {
        self.busy_time
    }

    /// Time at which the link next goes idle.
    pub fn busy_until(&self) -> f64 {
        self.busy_until
    }

    /// Current link bandwidth, bytes/s.
    pub fn bytes_per_s(&self) -> f64 {
        self.bytes_per_s
    }

    /// Change the link bandwidth mid-run (degraded mode: a preemptible
    /// host starts sharing the PCIe switch).  In-flight transfers keep
    /// their already-computed completion times; only transfers issued
    /// after this call see the new rate.
    pub fn set_bandwidth(&mut self, bytes_per_s: f64) {
        assert!(bytes_per_s >= 0.0, "negative bandwidth {bytes_per_s}");
        self.bytes_per_s = bytes_per_s;
    }
}

/// [`KvConfig`] resolved against one replica's perf model: the constants
/// the engine's swap path needs per decision, precomputed once.
#[derive(Clone, Debug)]
pub struct KvParams {
    /// Swapping active.  False when the config disables it, the hardware
    /// has no host link (`pcie_gbps = 0`), or no host memory is budgeted
    /// — any of which make swap-out pointless.
    pub enabled: bool,
    pub policy: SwapPolicy,
    /// Stream each swap-in right behind its swap-out (FIFO prefetch)
    /// instead of fetching synchronously at re-admission.
    pub prefetch: bool,
    /// Host bytes usable for offloaded KV
    /// (`host_mem_bytes * host_mem_frac`).
    pub host_capacity_bytes: f64,
    /// KV bytes per cached token (model constant).
    pub bytes_per_token: f64,
    /// Host link bandwidth of the replica, bytes/s.
    pub link_bytes_per_s: f64,
}

impl KvParams {
    /// The inert default: retraction discards, exactly the pre-tiering
    /// engine.
    pub fn disabled() -> Self {
        KvParams {
            enabled: false,
            policy: SwapPolicy::new(1.0),
            prefetch: true,
            host_capacity_bytes: 0.0,
            bytes_per_token: 1.0,
            link_bytes_per_s: 0.0,
        }
    }

    /// Resolve `cfg` against a replica's perf model.
    pub fn resolve(cfg: &KvConfig, pm: &PerfModel) -> Self {
        let host_capacity_bytes = pm.hw.host_mem_bytes * cfg.host_mem_frac;
        let link_bytes_per_s = pm.link_bandwidth();
        KvParams {
            enabled: cfg.enabled && link_bytes_per_s > 0.0 && host_capacity_bytes > 0.0,
            policy: SwapPolicy::new(cfg.swap_margin),
            prefetch: cfg.prefetch,
            host_capacity_bytes,
            bytes_per_token: pm.model.kv_bytes_per_token,
            link_bytes_per_s,
        }
    }
}

/// Per-run mutable swap state, owned by the engine's `RunState` so
/// resumable runs (fleet replicas) carry it across pauses.
#[derive(Clone, Debug)]
pub struct KvRunState {
    pub ledger: KvLedger,
    pub link: LinkTimeline,
    /// Tokens moved HBM → host at retraction.
    pub swapped_out_tokens: u64,
    /// Tokens restored host → HBM at re-admission.
    pub swapped_in_tokens: u64,
    /// Prefill + decode tokens a restore avoided re-running.
    pub recompute_saved_tokens: u64,
    /// Prompt tokens re-prefilled because a retraction discarded KV
    /// (counted whether or not swapping is enabled).
    pub recomputed_tokens: u64,
    /// Seconds the engine waited on unfinished swap-in transfers.
    pub link_stall_time: f64,
}

impl KvRunState {
    pub fn new(params: &KvParams) -> Self {
        KvRunState {
            ledger: KvLedger::new(params.host_capacity_bytes, params.bytes_per_token),
            link: LinkTimeline::new(params.link_bytes_per_s),
            swapped_out_tokens: 0,
            swapped_in_tokens: 0,
            recompute_saved_tokens: 0,
            recomputed_tokens: 0,
            link_stall_time: 0.0,
        }
    }

    /// Account an HBM → host extent move *and* trace it in one call.
    /// Counter and event cannot drift apart: the auditor's
    /// reconciliation (Σ `SwapOut` tokens == `swapped_out_tokens`)
    /// holds by construction because this is the only bump site.
    pub fn note_swap_out(
        &mut self,
        tokens: u64,
        req: u32,
        clock: f64,
        step: u64,
        trace: &mut Option<Box<TraceData>>,
    ) {
        self.swapped_out_tokens += tokens;
        if let Some(tr) = trace.as_mut() {
            tr.emit(clock, step, TraceEvent::SwapOut { req, tokens });
        }
    }

    /// Account a host → HBM extent restore and trace it — the
    /// `swapped_in_tokens` dual of [`Self::note_swap_out`].
    pub fn note_swap_in(
        &mut self,
        tokens: u64,
        req: u32,
        clock: f64,
        step: u64,
        trace: &mut Option<Box<TraceData>>,
    ) {
        self.swapped_in_tokens += tokens;
        if let Some(tr) = trace.as_mut() {
            tr.emit(clock, step, TraceEvent::SwapIn { req, tokens });
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::presets;

    #[test]
    fn link_timeline_fifo_and_busy_accounting() {
        let mut link = LinkTimeline::new(10.0); // 10 bytes/s
        // First transfer at t=0: 20 bytes -> done at 2.
        assert_eq!(link.transfer(0.0, 20.0), 2.0);
        // Queued behind it even though issued at t=1: done at 3.
        assert_eq!(link.transfer(1.0, 10.0), 3.0);
        // Issued after the queue drained: starts at now.
        assert_eq!(link.transfer(10.0, 10.0), 11.0);
        assert_eq!(link.busy_time(), 4.0);
        assert_eq!(link.busy_until(), 11.0);
    }

    #[test]
    fn set_bandwidth_affects_only_future_transfers() {
        let mut link = LinkTimeline::new(10.0);
        assert_eq!(link.transfer(0.0, 20.0), 2.0); // queued at old rate
        link.set_bandwidth(5.0);
        assert_eq!(link.bytes_per_s(), 5.0);
        // New transfer queues behind the old one at the degraded rate.
        assert_eq!(link.transfer(0.0, 20.0), 6.0);
        assert_eq!(link.busy_time(), 6.0);
    }

    #[test]
    fn eta_roundtrip_includes_queue_delay() {
        let mut link = LinkTimeline::new(10.0);
        link.transfer(0.0, 50.0); // busy until 5
        // At t=1 a 10-byte round-trip waits 4s then moves 2x1s.
        assert_eq!(link.eta_roundtrip(1.0, 10.0), 6.0);
        // After the queue drains only the transfer time remains.
        assert_eq!(link.eta_roundtrip(9.0, 10.0), 2.0);
        let idle = LinkTimeline::new(0.0);
        assert!(idle.eta_roundtrip(0.0, 1.0).is_infinite());
    }

    #[test]
    fn resolve_disables_without_link_or_host_memory() {
        let pm = PerfModel::new(presets::llama3_8b(), presets::a100_80gb(), 1);
        let cfg = KvConfig { enabled: true, ..KvConfig::default() };
        assert!(KvParams::resolve(&cfg, &pm).enabled);

        let mut no_link = pm.clone();
        no_link.hw.pcie_gbps = 0.0;
        assert!(!KvParams::resolve(&cfg, &no_link).enabled);

        let mut no_host = pm.clone();
        no_host.hw.host_mem_bytes = 0.0;
        assert!(!KvParams::resolve(&cfg, &no_host).enabled);

        // Disabled config stays disabled on capable hardware.
        assert!(!KvParams::resolve(&KvConfig::default(), &pm).enabled);
    }

    #[test]
    fn note_swap_bumps_counter_and_emits_in_lockstep() {
        let mut st = KvRunState::new(&KvParams::disabled());
        // Without a recorder: counters move, nothing else.
        let mut trace: Option<Box<TraceData>> = None;
        st.note_swap_out(100, 7, 1.5, 3, &mut trace);
        assert_eq!(st.swapped_out_tokens, 100);
        assert!(trace.is_none());
        // With a recorder: the event carries the same token count the
        // counter gained — reconciliation by construction.
        let mut trace = Some(TraceData::new(2));
        st.note_swap_out(50, 8, 2.0, 4, &mut trace);
        st.note_swap_in(50, 8, 3.0, 5, &mut trace);
        assert_eq!(st.swapped_out_tokens, 150);
        assert_eq!(st.swapped_in_tokens, 50);
        let tr = trace.unwrap();
        assert_eq!(tr.events.len(), 2);
        assert_eq!(tr.events[0].ev, TraceEvent::SwapOut { req: 8, tokens: 50 });
        assert_eq!(tr.events[1].ev, TraceEvent::SwapIn { req: 8, tokens: 50 });
        assert_eq!(tr.events[1].t, 3.0);
        assert_eq!(tr.events[1].replica, 2);
    }

    #[test]
    fn resolve_applies_host_mem_frac() {
        let pm = PerfModel::new(presets::llama3_8b(), presets::a100_80gb(), 1);
        let cfg = KvConfig { enabled: true, host_mem_frac: 0.25, ..KvConfig::default() };
        let p = KvParams::resolve(&cfg, &pm);
        assert!((p.host_capacity_bytes - pm.hw.host_mem_bytes * 0.25).abs() < 1.0);
        assert_eq!(p.bytes_per_token, pm.model.kv_bytes_per_token);
    }
}
