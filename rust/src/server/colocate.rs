//! Online/offline co-located serving entry point
//! (DESIGN.md §Co-located-Serving).
//!
//! [`serve_colocated`] runs one replica serving BlendServe's offline blend
//! schedule *and* an open stream of latency-sensitive online requests:
//! the offline pool goes through the standard §5 pipeline (output-length
//! sampling → tree transform → dual scanner) and the online stream is
//! folded in by the [`ElasticAdmitter`], which admits arrived online
//! requests immediately, reserves KV headroom for bursts, preempts
//! offline work when TTFT deadlines are at risk, and backfills offline
//! requests — in dual-scanner order, so prefix-tree DFS locality is
//! preserved — whenever the online load ebbs.
//!
//! With an empty online stream the whole path is bit-identical to
//! [`run_system`](crate::scheduler::run_system) with the BlendServe
//! config (pinned by tests here and by `examples/colocated_serving.rs`).

use crate::config::{ColocationPolicy, SystemConfig};
use crate::engine::sim::{SimEngine, SimRequest, SimResult};
use crate::kv::KvParams;
use crate::perfmodel::PerfModel;
use crate::scheduler::{prepare_blendserve, DualScanner, ElasticAdmitter};
use crate::trace::online::{generate_online, ArrivalProcess, OnlineSpec, OnlineWorkload};
use crate::trace::{TraceKind, Workload};

/// Outcome of one co-located run.
#[derive(Clone, Debug)]
pub struct ColocateReport {
    pub result: SimResult,
    pub n_offline: usize,
    pub n_online: usize,
    /// Offline goodput in tokens/s (the co-location cost metric).
    pub offline_throughput: f64,
    /// Fraction of online requests that met both TTFT and TPOT SLOs.
    pub slo_attainment: f64,
    pub mean_ttft: f64,
    pub p99_ttft: f64,
    pub mean_queue_delay: f64,
    /// Tiered-KV traffic: tokens swapped to host across all preemptions
    /// and retractions (0 with `kv.enabled = false`).
    pub swapped_out_tokens: u64,
    /// Prefill + decode tokens that swap restores avoided re-running.
    pub recompute_saved_tokens: u64,
    /// Fraction of the run the host link spent moving KV.
    pub link_busy_frac: f64,
}

/// Build the online stream described by `cfg.colocate`: `n_requests`
/// requests at the configured mean rate/burstiness with SLOs scaled by
/// `slo_scale`.  Returns an empty stream when the rate is zero.
pub fn online_stream(
    cfg: &SystemConfig,
    trace: TraceKind,
    n_requests: usize,
    seed: u64,
) -> OnlineWorkload {
    let c = &cfg.colocate;
    if c.online_rate <= 0.0 || n_requests == 0 {
        return OnlineWorkload::default();
    }
    let arrivals = if c.burst_factor > 1.0 {
        ArrivalProcess::bursty_with_mean(c.online_rate, c.burst_factor, c.phase_secs)
    } else {
        ArrivalProcess::Poisson { rate: c.online_rate }
    };
    let pm = PerfModel::new(cfg.model.clone(), cfg.hardware.clone(), cfg.gpus_per_replica);
    generate_online(
        &OnlineSpec::new(trace, c.online_rate, n_requests)
            .with_arrivals(arrivals)
            .with_slo_scale(c.slo_scale)
            .with_seed(seed),
        &pm,
    )
}

/// Serve `offline` and `online` together on one replica under
/// `cfg.colocate.policy`.  The offline pool uses the BlendServe scheduler
/// regardless of `cfg.scheduler.order` (co-location presumes the blend
/// schedule; the baselines exist as colocation *policies*, not orders).
pub fn serve_colocated(
    cfg: &SystemConfig,
    offline: &Workload,
    online: &OnlineWorkload,
) -> ColocateReport {
    // Offline preprocessing: the exact same pipeline as run_system's
    // BlendServe path (shared helper, so the two cannot drift).
    let (pm, tree, _, _) = prepare_blendserve(cfg, offline);

    // Combined engine request set: offline ids keep their workload ids,
    // online ids follow densely.  Online output lengths are served to the
    // admission accountant as exact estimates — live traffic would use a
    // §5.1-style predictor, which only shifts admission accounting, not
    // SLO measurement.
    let mut requests = SimRequest::from_workload(offline, &tree.est_output);
    // Workload::new re-densifies ids, so max+1 == len for every normal
    // pool; computing it defends against hand-built workloads with
    // sparse ids (a collision would silently corrupt the engine's
    // id -> index map).
    let id_base = requests.iter().map(|r| r.id).max().map_or(0, |m| m + 1);
    for (i, r) in online.requests.iter().enumerate() {
        requests.push(
            SimRequest::online(
                id_base + i as u32,
                r.request.prompt.clone(),
                r.request.output_len,
                r.request.output_len,
                r.arrival,
                r.ttft_slo,
                r.tpot_slo,
            )
            // Online media rides along: a multi-modal online stream must
            // pay its encoder passes like the offline pool does.
            .with_attachments(r.request.modality.attachments.clone()),
        );
    }

    let mut sched = cfg.scheduler.clone();
    sched.expected_sharing = tree.sharing_ratio();
    // Resolve the KV config against this replica's hardware *before*
    // handing the perf model to the engine: the urgency boost below must
    // key on whether swapping is actually possible (a `[kv] enabled`
    // flag on link-less hardware resolves to inert), not on the raw flag.
    let preemption_cheap = KvParams::resolve(&cfg.kv, &pm).enabled;
    let mut engine = SimEngine::new(pm, cfg.engine.clone(), sched, requests)
        .with_kv(&cfg.kv)
        .with_modality(&cfg.modality);

    let (reserve, urgency) = match cfg.colocate.policy {
        ColocationPolicy::Elastic => (cfg.colocate.online_reserve, cfg.colocate.urgency),
        ColocationPolicy::BestEffort => (0.0, 0.0),
    };
    let items = ElasticAdmitter::online_items(online, id_base);
    // With KV tiering active, SLO-driven preemption swaps the offline
    // victim instead of discarding its progress — preempting earlier is
    // cheap, so the admitter widens its urgency window.
    let mut admitter = ElasticAdmitter::new(DualScanner::new(&tree), items, reserve, urgency)
        .with_cheap_preemption(preemption_cheap);
    let result = engine.run(&mut admitter);

    ColocateReport {
        n_offline: offline.len(),
        n_online: online.len(),
        offline_throughput: result.offline_throughput,
        slo_attainment: result.slo_attainment,
        mean_ttft: result.mean_ttft,
        p99_ttft: result.p99_ttft,
        mean_queue_delay: result.mean_queue_delay,
        swapped_out_tokens: result.swapped_out_tokens,
        recompute_saved_tokens: result.recompute_saved_tokens,
        link_busy_frac: result.link_busy_frac,
        result,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::baselines;
    use crate::scheduler::run_system;
    use crate::trace::synth::{synthesize, SynthSpec};

    fn pm() -> PerfModel {
        PerfModel::new(
            crate::config::presets::llama3_8b(),
            crate::config::presets::a100_80gb(),
            1,
        )
    }

    fn offline_pool(n: usize) -> Workload {
        synthesize(&SynthSpec::new(TraceKind::BurstGpt, 1.1, 0.25, n), &pm())
    }

    fn cfg_with_rate(rate: f64) -> SystemConfig {
        let mut cfg = baselines::blendserve();
        cfg.colocate.online_rate = rate;
        cfg
    }

    #[test]
    fn zero_rate_reproduces_pure_offline_blendserve_exactly() {
        let w = offline_pool(800);
        let cfg = cfg_with_rate(0.0);
        let colocated = serve_colocated(&cfg, &w, &OnlineWorkload::default());
        let pure = run_system(&cfg, &w);
        // Same preprocessing, transparent admitter, same engine: the two
        // schedules must be bit-identical, not merely close.
        assert_eq!(colocated.result.steps, pure.result.steps);
        assert_eq!(colocated.result.total_time, pure.result.total_time);
        assert_eq!(colocated.result.total_tokens, pure.result.total_tokens);
        assert_eq!(colocated.result.hit_tokens, pure.result.hit_tokens);
        assert_eq!(colocated.slo_attainment, 1.0);
        assert_eq!(colocated.n_online, 0);
    }

    #[test]
    fn low_online_load_attains_slo_target() {
        let w = offline_pool(600);
        let cfg = cfg_with_rate(2.0);
        let online = online_stream(&cfg, TraceKind::ShareGpt, 30, 7);
        let rep = serve_colocated(&cfg, &w, &online);
        assert_eq!(rep.n_online, 30);
        assert_eq!(rep.result.n_online, 30);
        assert!(
            rep.slo_attainment >= 0.9,
            "low-load SLO attainment {}",
            rep.slo_attainment
        );
        assert!(rep.mean_ttft > 0.0 && rep.mean_ttft.is_finite());
        assert!(rep.p99_ttft >= rep.mean_ttft);
    }

    #[test]
    fn offline_throughput_degrades_monotonically_with_online_rate() {
        let w = offline_pool(600);
        let mut last = f64::INFINITY;
        for rate in [0.0, 4.0, 16.0] {
            let cfg = cfg_with_rate(rate);
            let n_online = (rate * 8.0) as usize; // ~8 s of traffic
            let online = online_stream(&cfg, TraceKind::ShareGpt, n_online, 11);
            let rep = serve_colocated(&cfg, &w, &online);
            // Offline goodput must not *increase* with more online load
            // (tiny tolerance for step-quantization).
            assert!(
                rep.offline_throughput <= last * 1.005,
                "offline tput {} at rate {rate} vs previous {last}",
                rep.offline_throughput
            );
            // All offline tokens still served.
            assert_eq!(rep.result.offline_tokens, w.total_tokens());
            last = rep.offline_throughput;
        }
    }

    #[test]
    fn colocated_schedule_is_deterministic_under_fixed_seed() {
        let w = offline_pool(400);
        let mut cfg = cfg_with_rate(6.0);
        cfg.colocate.burst_factor = 4.0;
        cfg.colocate.phase_secs = 2.0;
        let run = || {
            let online = online_stream(&cfg, TraceKind::ShareGpt, 40, 13);
            serve_colocated(&cfg, &w, &online)
        };
        let (a, b) = (run(), run());
        assert_eq!(a.result.total_time, b.result.total_time);
        assert_eq!(a.result.steps, b.result.steps);
        assert_eq!(a.slo_attainment, b.slo_attainment);
        assert_eq!(a.mean_ttft, b.mean_ttft);
        assert_eq!(a.result.retractions, b.result.retractions);
    }

    #[test]
    fn tokens_conserved_across_both_streams() {
        let w = offline_pool(300);
        let cfg = cfg_with_rate(8.0);
        let online = online_stream(&cfg, TraceKind::ShareGpt, 25, 3);
        let rep = serve_colocated(&cfg, &w, &online);
        assert_eq!(
            rep.result.total_tokens,
            w.total_tokens() + online.total_tokens()
        );
        assert_eq!(
            rep.result.total_tokens - rep.result.offline_tokens,
            online.total_tokens()
        );
    }

    #[test]
    fn elastic_beats_best_effort_on_slo_under_bursts() {
        // Under a hard burst the headroom reserve + preemption must not
        // hurt attainment; usually they help.  (Weak-inequality check: the
        // elastic policy is never *worse* by more than one request.)
        let w = offline_pool(500);
        let mut cfg = cfg_with_rate(20.0);
        cfg.colocate.burst_factor = 6.0;
        cfg.colocate.phase_secs = 1.0;
        cfg.colocate.slo_scale = 3.0;
        let online = online_stream(&cfg, TraceKind::ShareGpt, 60, 5);
        let elastic = serve_colocated(&cfg, &w, &online);
        cfg.colocate.policy = ColocationPolicy::BestEffort;
        let best_effort = serve_colocated(&cfg, &w, &online);
        assert!(
            elastic.result.slo_attained + 1 >= best_effort.result.slo_attained,
            "elastic {} vs best-effort {}",
            elastic.slo_attainment,
            best_effort.slo_attainment
        );
    }

    #[test]
    fn kv_tiering_reports_and_conserves_under_bursty_preemption() {
        // A bursty stream on a small-KV replica forces SLO preemptions;
        // with tiering on, the preempted offline work swaps instead of
        // recomputing.  Both configurations must serve every token.
        let w = offline_pool(400);
        let mut cfg = cfg_with_rate(20.0);
        cfg.hardware.memory_bytes = 22e9;
        cfg.colocate.burst_factor = 6.0;
        cfg.colocate.phase_secs = 1.0;
        cfg.colocate.slo_scale = 3.0;
        let online = online_stream(&cfg, TraceKind::ShareGpt, 40, 5);
        let off = serve_colocated(&cfg, &w, &online);
        cfg.kv.enabled = true;
        let on = serve_colocated(&cfg, &w, &online);
        assert_eq!(on.result.total_tokens, off.result.total_tokens);
        assert_eq!(off.swapped_out_tokens, 0);
        assert_eq!(off.link_busy_frac, 0.0);
        // Extents conserve exactly whether or not any retraction chose
        // to swap (a fresh victim with no progress discards).
        assert_eq!(on.result.swapped_in_tokens, on.result.swapped_out_tokens);
        assert_eq!(on.swapped_out_tokens, on.result.swapped_out_tokens);
        assert_eq!(on.recompute_saved_tokens, on.result.recompute_saved_tokens);
        if on.swapped_out_tokens > 0 {
            assert!(on.link_busy_frac > 0.0);
        }
    }

    #[test]
    fn online_prefix_sharing_spans_streams() {
        // Online requests drawn from the same trace as the offline pool
        // share its system prompt; the radix cache must convert that into
        // hits even across the online/offline boundary.
        let w = crate::trace::generators::generate_kind(TraceKind::WildChat, 300, 3);
        let cfg = cfg_with_rate(5.0);
        let online = online_stream(&cfg, TraceKind::WildChat, 20, 9);
        let rep = serve_colocated(&cfg, &w, &online);
        assert!(
            rep.result.hit_tokens > 0,
            "no cache hits in a shared-prefix colocated run"
        );
    }
}
