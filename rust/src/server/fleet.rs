//! Work-stealing DP fleet engine.
//!
//! `serve_batch` runs the §5.5 decomposition once and forks: each replica
//! owns a fixed shard until the job ends, so the whole deployment waits on
//! the slowest replica — any estimate error (§5.1 sampling noise) or unit
//! coarseness turns directly into idle GPUs.  The fleet engine replaces
//! that fork-join with an event-driven coordinator over *unit-granular*
//! shard queues:
//!
//! - Every replica runs the normal BlendServe engine + dual scanner over
//!   its shard ([`SimEngine::step_once`] keeps runs resumable).
//! - The coordinator always advances the replica with the smallest
//!   simulated clock (discrete-event order), so a steal can never observe
//!   the victim's future.
//! - When a replica drains (scanner exhausted, batch empty) it *steals*
//!   whole scheduling units from the memory end of the straggler's pending
//!   queue — the dual-scanner tail — sized to `steal_ratio` of the
//!   victim's steal-eligible work.  Whole-unit steals keep every stolen
//!   subtree's internal prefix locality; the donor keeps its compute end,
//!   so its local blend continues undisturbed (HyGen-style elastic
//!   reassignment, BatchLLM-style sharing preservation).
//! - Replicas may be heterogeneous (per-replica GPU counts / hardware
//!   presets, e.g. mixed A100/H100): the initial decomposition weights
//!   shard targets by replica FLOP/s and stealing absorbs the residual.
//!
//! With `dp_replicas = 1` (or `steal = false`) the fleet reduces exactly
//! to the static path: one replica, the same prepared tree, the same
//! scanner — bit-identical to `run_system`.

use crate::config::{presets, SystemConfig};
use crate::engine::sim::{SimEngine, SimRequest, SimResult, StepOutcome};
use crate::parallel::{assign_units, work_units, WorkUnit};
use crate::perfmodel::PerfModel;
use crate::scheduler::dual_scan::Unit;
use crate::scheduler::{prepare_blendserve, DualScanner};
use crate::trace::Workload;
use crate::tree::PrefixTree;
use crate::util::Json;

/// Outcome of one fleet job (stealing run + static reference).
#[derive(Clone, Debug)]
pub struct FleetReport {
    /// Per-replica engine results, in shard order.
    pub per_replica: Vec<SimResult>,
    /// Human-readable replica spec, e.g. `"a100-80gb-sxm x1"`.
    pub replica_desc: Vec<String>,
    /// Wall-clock makespan (slowest replica).
    pub makespan: f64,
    pub total_tokens: u64,
    pub total_throughput: f64,
    /// Per-replica end-of-job idle fraction `1 - t_r / makespan` (a
    /// stealing replica never idles mid-job: it refills the moment it
    /// drains or retires for good).
    pub idle_fracs: Vec<f64>,
    pub mean_idle_frac: f64,
    /// Steal events / whole units moved / requests moved.
    pub steals: usize,
    pub stolen_units: usize,
    pub stolen_requests: usize,
    /// Aggregate achieved prefix sharing (Σ hits / Σ prompts).
    pub sharing_achieved: f64,
    /// Static §5.5 fork-join reference on the same decomposition.
    pub static_makespan: f64,
    pub static_sharing: f64,
    /// `static_makespan / makespan` (1.0 when stealing is off).
    pub speedup_vs_static: f64,
    /// Cross-unit prefix sharing given up by moving units away from their
    /// shard (`static_sharing - sharing_achieved`, floored at 0).
    pub sharing_lost_to_steals: f64,
    /// Tiered-KV traffic summed over replicas: tokens swapped to host at
    /// retraction (0 with `kv.enabled = false`).
    pub swapped_out_tokens: u64,
    /// Prefill + decode tokens swap restores avoided re-running, summed
    /// over replicas.
    pub recompute_saved_tokens: u64,
    /// Tokens re-computed because retractions discarded KV, summed over
    /// replicas.
    pub recomputed_tokens: u64,
}

impl FleetReport {
    /// JSON document for `BENCH_fleet.json` / `blendserve fleet --out`.
    pub fn to_json(&self) -> Json {
        let replicas: Vec<Json> = self
            .per_replica
            .iter()
            .zip(&self.replica_desc)
            .zip(&self.idle_fracs)
            .map(|((r, desc), &idle)| {
                Json::obj(vec![
                    ("spec", Json::from(desc.as_str())),
                    ("total_time_s", Json::Num(r.total_time)),
                    ("total_tokens", Json::from(r.total_tokens as usize)),
                    ("sharing_achieved", Json::Num(r.sharing_achieved)),
                    ("retractions", Json::from(r.retractions as usize)),
                    ("idle_frac", Json::Num(idle)),
                    ("swapped_out_tokens", Json::from(r.swapped_out_tokens as usize)),
                    ("recomputed_tokens", Json::from(r.recomputed_tokens as usize)),
                    ("link_busy_frac", Json::Num(r.link_busy_frac)),
                ])
            })
            .collect();
        Json::obj(vec![
            ("makespan_s", Json::Num(self.makespan)),
            ("total_throughput_tok_s", Json::Num(self.total_throughput)),
            ("total_tokens", Json::from(self.total_tokens as usize)),
            ("mean_idle_frac", Json::Num(self.mean_idle_frac)),
            ("steals", Json::from(self.steals)),
            ("stolen_units", Json::from(self.stolen_units)),
            ("stolen_requests", Json::from(self.stolen_requests)),
            ("sharing_achieved", Json::Num(self.sharing_achieved)),
            ("static_makespan_s", Json::Num(self.static_makespan)),
            ("static_sharing", Json::Num(self.static_sharing)),
            ("speedup_vs_static", Json::Num(self.speedup_vs_static)),
            ("sharing_lost_to_steals", Json::Num(self.sharing_lost_to_steals)),
            ("swapped_out_tokens", Json::from(self.swapped_out_tokens as usize)),
            (
                "recompute_saved_tokens",
                Json::from(self.recompute_saved_tokens as usize),
            ),
            ("recomputed_tokens", Json::from(self.recomputed_tokens as usize)),
            ("replicas", Json::Arr(replicas)),
        ])
    }
}

/// One replica of the simulated fleet.
struct Replica {
    engine: SimEngine,
    scanner: DualScanner,
    st: crate::engine::sim::RunState,
    done: bool,
    desc: String,
}

/// Raw outcome of one fleet pass (before the static comparison).
struct FleetRun {
    results: Vec<SimResult>,
    descs: Vec<String>,
    steals: usize,
    stolen_units: usize,
    stolen_requests: usize,
}

impl FleetRun {
    fn makespan(&self) -> f64 {
        self.results.iter().map(|r| r.total_time).fold(0.0, f64::max)
    }

    fn sharing(&self) -> f64 {
        let hits: u64 = self.results.iter().map(|r| r.hit_tokens).sum();
        let prompts: u64 = self.results.iter().map(|r| r.prompt_tokens).sum();
        if prompts == 0 {
            0.0
        } else {
            hits as f64 / prompts as f64
        }
    }
}

/// Perf model of fleet replica `slot` (heterogeneous overrides fall back
/// to the homogeneous top-level spec).
fn replica_pm(cfg: &SystemConfig, slot: usize) -> PerfModel {
    let hw = cfg
        .fleet
        .hardware
        .get(slot)
        .map(|name| {
            presets::hardware_by_name(name)
                .unwrap_or_else(|| panic!("unknown hardware preset '{name}'"))
        })
        .unwrap_or_else(|| cfg.hardware.clone());
    let gpus = cfg.fleet.gpus.get(slot).copied().unwrap_or(cfg.gpus_per_replica);
    let mut pm = PerfModel::new(cfg.model.clone(), hw, gpus);
    pm.prefill_attn_flops = cfg.engine.prefill_attn_flops;
    pm.set_modality(&cfg.modality);
    pm
}

/// Scanner units (with steal costs) for a set of global unit indices.
fn scanner_units(units: &[WorkUnit], idxs: &[usize]) -> Vec<Unit> {
    idxs.iter()
        .map(|&i| Unit {
            requests: units[i].requests.clone(),
            density: units[i].density,
            est_cost: units[i].est_time(),
        })
        .collect()
}

/// Engine requests for a unit batch, in ascending request-id order (for a
/// dp=1 fleet this is exactly `SimRequest::from_workload`'s order, which
/// keeps the single-replica fleet bit-identical to `run_system`).
fn shard_requests(workload: &Workload, tree: &PrefixTree, us: &[Unit]) -> Vec<SimRequest> {
    let mut ids: Vec<u32> = us.iter().flat_map(|u| u.requests.iter().copied()).collect();
    ids.sort_unstable();
    ids.iter()
        .map(|&r| {
            let req = &workload.requests[r as usize];
            SimRequest::offline(
                req.id,
                req.prompt.clone(),
                req.output_len,
                tree.est_output[r as usize],
            )
            .with_attachments(req.modality.attachments.clone())
        })
        .collect()
}

/// The straggler: the non-done replica (other than `thief`) with the most
/// steal-eligible estimated work.
fn pick_victim(reps: &[Replica], thief: usize) -> Option<usize> {
    let mut best: Option<(usize, f64, usize)> = None;
    for (j, r) in reps.iter().enumerate() {
        if j == thief || r.done {
            continue;
        }
        let units = r.scanner.stealable_units();
        if units == 0 {
            continue;
        }
        let est = r.scanner.remaining_whole_est();
        let better = match best {
            None => true,
            Some((_, be, bu)) => est > be || (est == be && units > bu),
        };
        if better {
            best = Some((j, est, units));
        }
    }
    best.map(|(j, _, _)| j)
}

/// Deterministic global preprocessing shared by the stealing pass and its
/// static reference (one tree build / sampling / transform / unit pricing
/// / assignment instead of two identical ones).
struct PreparedFleet {
    tree: PrefixTree,
    sched: crate::config::SchedulerConfig,
    units: Vec<WorkUnit>,
    rho_root: f64,
    pms: Vec<PerfModel>,
    /// Unit indices per replica slot (empty for slots the assignment gave
    /// nothing — they start idle and join via stealing).
    parts_by_slot: Vec<Vec<usize>>,
}

fn prepare_fleet(cfg: &SystemConfig, workload: &Workload) -> PreparedFleet {
    let dp = cfg.dp_replicas.max(1);
    // Global preprocessing, identical to run_system's BlendServe path.
    let (pm, tree, _n_sampled, _splits) = prepare_blendserve(cfg, workload);
    let mut sched = cfg.scheduler.clone();
    sched.expected_sharing = tree.sharing_ratio();
    let units = work_units(&tree, &pm);
    let rho_root = tree.root_density();
    let pms: Vec<PerfModel> = (0..dp).map(|slot| replica_pm(cfg, slot)).collect();
    let weights: Vec<f64> = pms.iter().map(|p| p.compute()).collect();
    let assignment = assign_units(&units, rho_root, &weights);
    let mut parts_by_slot: Vec<Vec<usize>> = vec![Vec::new(); dp];
    for (idxs, &slot) in assignment.parts.into_iter().zip(&assignment.owners) {
        parts_by_slot[slot] = idxs;
    }
    PreparedFleet { tree, sched, units, rho_root, pms, parts_by_slot }
}

/// One fleet pass over the workload.  Every configured replica slot is
/// materialized — a slot whose initial shard came back empty (coarse
/// units, dp > #units) starts idle and immediately joins via stealing.
fn run_fleet(
    cfg: &SystemConfig,
    workload: &Workload,
    prep: &PreparedFleet,
    steal: bool,
) -> FleetRun {
    let tree = &prep.tree;
    let units = &prep.units;
    let rho_root = prep.rho_root;
    let mut reps: Vec<Replica> = prep
        .parts_by_slot
        .iter()
        .enumerate()
        .map(|(slot, idxs)| {
            let us = scanner_units(units, idxs);
            let reqs = shard_requests(workload, tree, &us);
            let engine = SimEngine::new(
                prep.pms[slot].clone(),
                cfg.engine.clone(),
                prep.sched.clone(),
                reqs,
            )
            .with_kv(&cfg.kv)
            .with_modality(&cfg.modality);
            let st = engine.begin();
            Replica {
                engine,
                scanner: DualScanner::from_units(us, rho_root),
                st,
                done: false,
                desc: format!("{} x{}", prep.pms[slot].hw.name, prep.pms[slot].n_gpus),
            }
        })
        .collect();

    let mut steals = 0usize;
    let mut stolen_units = 0usize;
    let mut stolen_requests = 0usize;
    loop {
        // Discrete-event order: always advance the earliest replica, so
        // every steal observes its victim at a clock ≥ the thief's (the
        // victim's pending set only shrinks over time — causally safe).
        let Some(i) = (0..reps.len())
            .filter(|&i| !reps[i].done)
            .min_by(|&a, &b| {
                reps[a]
                    .st
                    .clock()
                    .partial_cmp(&reps[b].st.clock())
                    .expect("replica clocks are finite")
            })
        else {
            break;
        };
        let outcome = {
            let rep = &mut reps[i];
            rep.engine.step_once(&mut rep.st, &mut rep.scanner)
        };
        if outcome == StepOutcome::Progress {
            continue;
        }
        // Done (all local work finished) or Starved (queue empty): try to
        // refill from the straggler before retiring.
        let mut refilled = false;
        if steal {
            if let Some(v) = pick_victim(&reps, i) {
                let target =
                    (reps[v].scanner.remaining_whole_est() * cfg.fleet.steal_ratio)
                        .max(f64::MIN_POSITIVE);
                let stolen = reps[v].scanner.steal_from_memory_end(target);
                if !stolen.is_empty() {
                    steals += 1;
                    stolen_units += stolen.len();
                    let stolen_ids: Vec<u32> = stolen
                        .iter()
                        .flat_map(|u| u.requests.iter().copied())
                        .collect();
                    stolen_requests += stolen_ids.len();
                    // The donor stops pacing against the stolen work; the
                    // thief starts (feed_requests re-arms stolen-back ids).
                    {
                        let victim = &mut reps[v];
                        victim.engine.unfeed_requests(&mut victim.st, &stolen_ids);
                    }
                    let reqs = shard_requests(workload, tree, &stolen);
                    let rep = &mut reps[i];
                    rep.engine.feed_requests(&mut rep.st, reqs);
                    rep.scanner.feed(stolen);
                    refilled = true;
                }
            }
        }
        if !refilled {
            reps[i].done = true;
        }
    }

    let mut results = Vec::with_capacity(reps.len());
    let mut descs = Vec::with_capacity(reps.len());
    for r in reps {
        descs.push(r.desc);
        results.push(r.engine.finalize(r.st));
    }

    // Exactly-once issuance audit (DESIGN.md §11): every workload request
    // finishes on exactly one replica.  A stolen request stays registered
    // on its donor with an infinite finish time, so a unit that was
    // double-issued (or dropped) across steals would surface here.
    if cfg!(debug_assertions) || cfg.engine.audit {
        let mut finishes = vec![0u32; workload.requests.len()];
        for res in &results {
            for t in &res.timings {
                if t.finish.is_finite() {
                    finishes[t.id as usize] += 1;
                }
            }
        }
        for (id, &n) in finishes.iter().enumerate() {
            assert!(n == 1, "fleet audit: request {id} finished on {n} replicas");
        }
    }

    FleetRun { results, descs, steals, stolen_units, stolen_requests }
}

/// Serve a request pool on the work-stealing fleet.  Runs the stealing
/// schedule per `cfg.fleet`, plus (at `dp > 1` with stealing on) the
/// static fork-join reference on the same decomposition for the
/// speedup/sharing-loss accounting.
pub fn serve_fleet(cfg: &SystemConfig, workload: &Workload) -> FleetReport {
    let prep = prepare_fleet(cfg, workload);
    let run = run_fleet(cfg, workload, &prep, cfg.fleet.steal);
    let makespan = run.makespan();
    let sharing = run.sharing();
    let (static_makespan, static_sharing) =
        if cfg.fleet.steal && cfg.dp_replicas.max(1) > 1 {
            let st = run_fleet(cfg, workload, &prep, false);
            (st.makespan(), st.sharing())
        } else {
            (makespan, sharing)
        };

    let total_tokens: u64 = run.results.iter().map(|r| r.total_tokens).sum();
    let idle_fracs: Vec<f64> = run
        .results
        .iter()
        .map(|r| (1.0 - r.total_time / makespan.max(1e-12)).max(0.0))
        .collect();
    let mean_idle_frac = if idle_fracs.is_empty() {
        0.0
    } else {
        idle_fracs.iter().sum::<f64>() / idle_fracs.len() as f64
    };
    FleetReport {
        makespan,
        total_tokens,
        total_throughput: total_tokens as f64 / makespan.max(1e-12),
        mean_idle_frac,
        idle_fracs,
        steals: run.steals,
        stolen_units: run.stolen_units,
        stolen_requests: run.stolen_requests,
        sharing_achieved: sharing,
        static_makespan,
        static_sharing,
        speedup_vs_static: static_makespan / makespan.max(1e-12),
        sharing_lost_to_steals: (static_sharing - sharing).max(0.0),
        swapped_out_tokens: run.results.iter().map(|r| r.swapped_out_tokens).sum(),
        recompute_saved_tokens: run
            .results
            .iter()
            .map(|r| r.recompute_saved_tokens)
            .sum(),
        recomputed_tokens: run.results.iter().map(|r| r.recomputed_tokens).sum(),
        per_replica: run.results,
        replica_desc: run.descs,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::baselines;
    use crate::scheduler::run_system;
    use crate::trace::synth::{synthesize, SynthSpec};
    use crate::trace::TraceKind;

    fn balanced_workload(n: usize) -> Workload {
        let pm = PerfModel::new(
            presets::llama3_8b(),
            presets::a100_80gb(),
            1,
        );
        synthesize(&SynthSpec::new(TraceKind::BurstGpt, 1.1, 0.25, n), &pm)
    }

    /// The HyGen-motivated adversary (`trace::synth::adversarial_skew`):
    /// liar prompt groups whose true output length is ~3x what §5.1
    /// sampling will estimate for the unsampled majority of them.  The
    /// static partition balances *estimated* times, so the replica that
    /// drew the under-estimated memory tail grinds for multiples of its
    /// target while the others idle — exactly the stranded capacity
    /// stealing recovers.
    fn skewed_workload(honest_groups: usize, liar_groups: usize, per: usize) -> Workload {
        crate::trace::synth::adversarial_skew(honest_groups, liar_groups, per)
    }

    fn skewed_cfg(dp: usize) -> SystemConfig {
        let mut cfg = baselines::blendserve();
        // Tight KV (~3.4k tokens after weights+reserve): each shard's
        // prompt footprint alone exceeds it, so admission pauses mid-shard
        // and the scanner retains pending whole units — the steal-eligible
        // pool.  Sparse sampling under-estimates most liar groups.
        cfg.hardware.memory_bytes = 20.5e9;
        cfg.scheduler.sample_prob = 0.02;
        cfg.dp_replicas = dp;
        cfg
    }

    #[test]
    fn dp1_fleet_bit_identical_to_run_system() {
        let w = balanced_workload(500);
        let cfg = baselines::blendserve();
        let sys = run_system(&cfg, &w);
        let fleet = serve_fleet(&cfg, &w);
        assert_eq!(fleet.per_replica.len(), 1);
        assert_eq!(fleet.steals, 0);
        let (a, b) = (&sys.result, &fleet.per_replica[0]);
        assert_eq!(a.total_time, b.total_time, "clock diverged");
        assert_eq!(a.steps, b.steps);
        assert_eq!(a.total_tokens, b.total_tokens);
        assert_eq!(a.hit_tokens, b.hit_tokens);
        assert_eq!(a.prompt_tokens, b.prompt_tokens);
        assert_eq!(a.retractions, b.retractions);
        assert_eq!(a.total_comp, b.total_comp);
        assert_eq!(a.total_mem, b.total_mem);
        assert_eq!(fleet.speedup_vs_static, 1.0);
    }

    #[test]
    fn fleet_conserves_tokens_and_sharing_on_balanced_trace() {
        let w = balanced_workload(1600);
        let mut cfg = baselines::blendserve();
        cfg.scheduler.sample_prob = 1.0; // perfect estimates: no skew
        cfg.dp_replicas = 4;
        let rep = serve_fleet(&cfg, &w);
        assert_eq!(rep.total_tokens, w.total_tokens());
        assert_eq!(rep.per_replica.len(), 4, "every configured slot materialized");
        // Within noise of the static schedule on a balanced trace…
        assert!(
            rep.makespan <= rep.static_makespan * 1.05,
            "stealing regressed a balanced trace: {} vs static {}",
            rep.makespan,
            rep.static_makespan
        );
        // …and no meaningful sharing given up.
        assert!(
            rep.sharing_achieved >= rep.static_sharing * 0.9,
            "sharing {} vs static {}",
            rep.sharing_achieved,
            rep.static_sharing
        );
    }

    #[test]
    fn stealing_beats_static_forkjoin_on_skewed_trace() {
        let w = skewed_workload(32, 16, 10);
        let cfg = skewed_cfg(4);
        let rep = serve_fleet(&cfg, &w);
        assert_eq!(rep.total_tokens, w.total_tokens());
        assert!(rep.steals > 0, "no steals on an adversarially skewed trace");
        assert!(
            rep.makespan < rep.static_makespan,
            "stealing did not beat static: {} vs {}",
            rep.makespan,
            rep.static_makespan
        );
        assert!(
            rep.sharing_achieved >= rep.static_sharing * 0.9,
            "stealing shredded sharing: {} vs static {}",
            rep.sharing_achieved,
            rep.static_sharing
        );
        // Stealing replicas only idle after global work runs out.
        assert!(rep.mean_idle_frac < 0.5, "idle {}", rep.mean_idle_frac);
    }

    #[test]
    fn stealing_reduces_tail_idle_on_skewed_trace() {
        let w = skewed_workload(32, 16, 10);
        let mut static_cfg = skewed_cfg(4);
        static_cfg.fleet.steal = false;
        let st = serve_fleet(&static_cfg, &w);
        assert_eq!(st.steals, 0);
        assert_eq!(st.speedup_vs_static, 1.0);
        let dyn_rep = serve_fleet(&skewed_cfg(4), &w);
        let static_idle =
            st.idle_fracs.iter().cloned().fold(0.0f64, f64::max);
        let steal_idle =
            dyn_rep.idle_fracs.iter().cloned().fold(0.0f64, f64::max);
        assert!(
            steal_idle < static_idle,
            "worst idle not reduced: {steal_idle} vs {static_idle}"
        );
    }

    #[test]
    fn dp_exceeding_units_materializes_all_replicas() {
        // A single-unit workload at dp=8: the assignment hands one slot
        // everything, but all eight replicas exist — the empty ones start
        // idle and try to steal (nothing is stealable here once the lone
        // unit is admitted, so they retire cleanly).
        let w = Workload::new(
            "single-unit",
            (0..6)
                .map(|i| {
                    crate::trace::Request::new(i, TraceKind::Custom, vec![1, 2, 3], 8)
                })
                .collect(),
        );
        let mut cfg = baselines::blendserve();
        cfg.dp_replicas = 8;
        let rep = serve_fleet(&cfg, &w);
        assert_eq!(rep.per_replica.len(), 8);
        assert_eq!(rep.idle_fracs.len(), 8);
        assert_eq!(rep.total_tokens, w.total_tokens());
        assert!(rep.makespan.is_finite() && rep.makespan > 0.0);
        assert!(rep.total_throughput.is_finite());
    }

    #[test]
    fn kv_tiering_threads_through_fleet_replicas() {
        // The KV-constrained skewed config retracts on at least one
        // replica; with tiering on the fleet must conserve both request
        // tokens and swap extents, and surface the traffic in its report.
        let w = skewed_workload(32, 16, 10);
        let mut cfg = skewed_cfg(4);
        cfg.kv.enabled = true;
        let rep = serve_fleet(&cfg, &w);
        assert_eq!(rep.total_tokens, w.total_tokens());
        let (swapped_in, swapped_out) = rep
            .per_replica
            .iter()
            .fold((0u64, 0u64), |acc, r| {
                (acc.0 + r.swapped_in_tokens, acc.1 + r.swapped_out_tokens)
            });
        assert_eq!(swapped_in, swapped_out, "extents lost across the fleet");
        assert_eq!(rep.swapped_out_tokens, swapped_out);
        let json = rep.to_json().to_string();
        assert!(json.contains("\"swapped_out_tokens\""));
        assert!(json.contains("\"recompute_saved_tokens\""));
        assert!(json.contains("\"link_busy_frac\""));
    }

    #[test]
    fn heterogeneous_fleet_loads_strong_replica_more() {
        let w = balanced_workload(1600);
        let mut cfg = baselines::blendserve();
        cfg.scheduler.sample_prob = 1.0;
        cfg.dp_replicas = 2;
        cfg.fleet.gpus = vec![1, 2];
        let rep = serve_fleet(&cfg, &w);
        assert_eq!(rep.total_tokens, w.total_tokens());
        assert_eq!(rep.per_replica.len(), 2);
        assert_eq!(rep.replica_desc[0], "a100-80gb-sxm x1");
        assert_eq!(rep.replica_desc[1], "a100-80gb-sxm x2");
        let (weak, strong) =
            (rep.per_replica[0].total_tokens, rep.per_replica[1].total_tokens);
        assert!(
            strong as f64 > weak as f64 * 1.2,
            "2x-GPU replica under-loaded: {strong} vs {weak}"
        );
    }

    #[test]
    fn mixed_hardware_fleet_runs_and_reports() {
        let w = balanced_workload(1200);
        let mut cfg = baselines::blendserve();
        cfg.scheduler.sample_prob = 1.0;
        cfg.dp_replicas = 2;
        cfg.fleet.hardware =
            vec!["a100-80gb-sxm".to_string(), "h100-80gb-sxm".to_string()];
        let rep = serve_fleet(&cfg, &w);
        assert_eq!(rep.total_tokens, w.total_tokens());
        assert_eq!(rep.replica_desc[1], "h100-80gb-sxm x1");
        let json = rep.to_json().to_string();
        assert!(json.contains("\"speedup_vs_static\""));
        assert!(json.contains("h100-80gb-sxm"));
    }
}
