//! Work-stealing DP fleet engine.
//!
//! `serve_batch` runs the §5.5 decomposition once and forks: each replica
//! owns a fixed shard until the job ends, so the whole deployment waits on
//! the slowest replica — any estimate error (§5.1 sampling noise) or unit
//! coarseness turns directly into idle GPUs.  The fleet engine replaces
//! that fork-join with an event-driven coordinator over *unit-granular*
//! shard queues:
//!
//! - Every replica runs the normal BlendServe engine + dual scanner over
//!   its shard ([`SimEngine::step_once`] keeps runs resumable).
//! - The coordinator always advances the replica with the smallest
//!   simulated clock (discrete-event order), so a steal can never observe
//!   the victim's future.
//! - When a replica drains (scanner exhausted, batch empty) it *steals*
//!   whole scheduling units from the memory end of the straggler's pending
//!   queue — the dual-scanner tail — sized to `steal_ratio` of the
//!   victim's steal-eligible work.  Whole-unit steals keep every stolen
//!   subtree's internal prefix locality; the donor keeps its compute end,
//!   so its local blend continues undisturbed (HyGen-style elastic
//!   reassignment, BatchLLM-style sharing preservation).
//! - Replicas may be heterogeneous (per-replica GPU counts / hardware
//!   presets, e.g. mixed A100/H100): the initial decomposition weights
//!   shard targets by replica FLOP/s and stealing absorbs the residual.
//!
//! With `dp_replicas = 1` (or `steal = false`) the fleet reduces exactly
//! to the static path: one replica, the same prepared tree, the same
//! scanner — bit-identical to `run_system`.

use crate::config::{presets, RecoveryStrategy, SystemConfig};
use crate::engine::sim::{SimEngine, SimRequest, SimResult, StepOutcome};
use crate::kv::KvExtent;
use crate::obs::{TraceData, TraceEvent};
use crate::parallel::{assign_units, work_units, WorkUnit};
use crate::perfmodel::PerfModel;
use crate::recovery::{
    self, records, FaultKind, FaultPlan, JournalWriter, ResumeState,
};
use crate::scheduler::dual_scan::Unit;
use crate::scheduler::{prepare_blendserve, DualScanner};
use crate::trace::Workload;
use crate::tree::PrefixTree;
use crate::util::Json;
use std::path::PathBuf;

/// Per-run fault-tolerance counters (DESIGN.md §12).  All-zero when the
/// `[faults]` section is disabled.
#[derive(Clone, Debug, Default)]
pub struct FaultStats {
    /// Replica preemptions that fired.
    pub deaths: usize,
    /// Death events dropped because they targeted the last live replica
    /// (killing it would strand work forever; DESIGN.md §12).
    pub suppressed_deaths: usize,
    /// Replicas that re-joined after a preemption.
    pub rejoins: usize,
    /// Fleet-wide rebuilds under [`RecoveryStrategy::Restart`].
    pub restarts: usize,
    /// Unfinished requests reclaimed from dead replicas.
    pub reclaimed_requests: usize,
    /// Host KV extents rescued from corpses and re-installed on heirs.
    pub rescued_extents: usize,
    /// Tokens those rescued extents carried.
    pub rescued_tokens: u64,
    /// In-flight prefill + decode tokens destroyed by preemptions (and,
    /// under Restart, by the fleet rebuild).
    pub lost_progress_tokens: u64,
    /// Degraded-mode events fired.
    pub host_shrinks: usize,
    pub link_degrades: usize,
    /// Host-resident tokens dropped by shrink evictions.
    pub dropped_host_tokens: u64,
    /// Records appended to the journal this run.
    pub journal_records: usize,
    /// Finishes pruned on resume (journaled by the interrupted run and
    /// cross-checked bitwise against the deterministic replay).
    pub resumed_finishes: usize,
}

impl FaultStats {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("deaths", Json::from(self.deaths)),
            ("suppressed_deaths", Json::from(self.suppressed_deaths)),
            ("rejoins", Json::from(self.rejoins)),
            ("restarts", Json::from(self.restarts)),
            ("reclaimed_requests", Json::from(self.reclaimed_requests)),
            ("rescued_extents", Json::from(self.rescued_extents)),
            ("rescued_tokens", Json::from(self.rescued_tokens as usize)),
            (
                "lost_progress_tokens",
                Json::from(self.lost_progress_tokens as usize),
            ),
            ("host_shrinks", Json::from(self.host_shrinks)),
            ("link_degrades", Json::from(self.link_degrades)),
            (
                "dropped_host_tokens",
                Json::from(self.dropped_host_tokens as usize),
            ),
            ("journal_records", Json::from(self.journal_records)),
            ("resumed_finishes", Json::from(self.resumed_finishes)),
        ])
    }
}

/// Outcome of one fleet job (stealing run + static reference).
#[derive(Clone, Debug)]
pub struct FleetReport {
    /// Per-replica engine results, in shard order.
    pub per_replica: Vec<SimResult>,
    /// Human-readable replica spec, e.g. `"a100-80gb-sxm x1"`.
    pub replica_desc: Vec<String>,
    /// Wall-clock makespan (slowest replica).
    pub makespan: f64,
    pub total_tokens: u64,
    pub total_throughput: f64,
    /// Per-replica end-of-job idle fraction `1 - t_r / makespan` (a
    /// stealing replica never idles mid-job: it refills the moment it
    /// drains or retires for good).
    pub idle_fracs: Vec<f64>,
    pub mean_idle_frac: f64,
    /// Steal events / whole units moved / requests moved.
    pub steals: usize,
    pub stolen_units: usize,
    pub stolen_requests: usize,
    /// Aggregate achieved prefix sharing (Σ hits / Σ prompts).
    pub sharing_achieved: f64,
    /// Static §5.5 fork-join reference on the same decomposition.
    pub static_makespan: f64,
    pub static_sharing: f64,
    /// `static_makespan / makespan` (1.0 when stealing is off).
    pub speedup_vs_static: f64,
    /// Cross-unit prefix sharing given up by moving units away from their
    /// shard (`static_sharing - sharing_achieved`, floored at 0).
    pub sharing_lost_to_steals: f64,
    /// Tiered-KV traffic summed over replicas: tokens swapped to host at
    /// retraction (0 with `kv.enabled = false`).
    pub swapped_out_tokens: u64,
    /// Prefill + decode tokens swap restores avoided re-running, summed
    /// over replicas.
    pub recompute_saved_tokens: u64,
    /// Tokens re-computed because retractions discarded KV, summed over
    /// replicas.
    pub recomputed_tokens: u64,
    /// Fault-tolerance counters (DESIGN.md §12; all-zero without faults).
    pub faults: FaultStats,
    /// The run was stopped by a test/checkpoint kill switch before every
    /// request finished (the exactly-once audit is skipped in that case).
    pub halted: bool,
    /// Coordinator-level trace track (steal / death / rejoin events with
    /// the dp count as pseudo replica id); `None` unless
    /// `cfg.engine.trace` was set.  Per-replica engine traces live on
    /// `per_replica[..].trace`.
    pub coord_trace: Option<Box<TraceData>>,
}

impl FleetReport {
    /// JSON document for `BENCH_fleet.json` / `blendserve fleet --out`.
    pub fn to_json(&self) -> Json {
        let replicas: Vec<Json> = self
            .per_replica
            .iter()
            .zip(&self.replica_desc)
            .zip(&self.idle_fracs)
            .map(|((r, desc), &idle)| {
                Json::obj(vec![
                    ("spec", Json::from(desc.as_str())),
                    ("total_time_s", Json::Num(r.total_time)),
                    ("total_tokens", Json::from(r.total_tokens as usize)),
                    ("sharing_achieved", Json::Num(r.sharing_achieved)),
                    ("retractions", Json::from(r.retractions as usize)),
                    ("idle_frac", Json::Num(idle)),
                    ("swapped_out_tokens", Json::from(r.swapped_out_tokens as usize)),
                    ("recomputed_tokens", Json::from(r.recomputed_tokens as usize)),
                    ("link_busy_frac", Json::Num(r.link_busy_frac)),
                ])
            })
            .collect();
        Json::obj(vec![
            ("makespan_s", Json::Num(self.makespan)),
            ("total_throughput_tok_s", Json::Num(self.total_throughput)),
            ("total_tokens", Json::from(self.total_tokens as usize)),
            ("mean_idle_frac", Json::Num(self.mean_idle_frac)),
            ("steals", Json::from(self.steals)),
            ("stolen_units", Json::from(self.stolen_units)),
            ("stolen_requests", Json::from(self.stolen_requests)),
            ("sharing_achieved", Json::Num(self.sharing_achieved)),
            ("static_makespan_s", Json::Num(self.static_makespan)),
            ("static_sharing", Json::Num(self.static_sharing)),
            ("speedup_vs_static", Json::Num(self.speedup_vs_static)),
            ("sharing_lost_to_steals", Json::Num(self.sharing_lost_to_steals)),
            ("swapped_out_tokens", Json::from(self.swapped_out_tokens as usize)),
            (
                "recompute_saved_tokens",
                Json::from(self.recompute_saved_tokens as usize),
            ),
            ("recomputed_tokens", Json::from(self.recomputed_tokens as usize)),
            ("faults", self.faults.to_json()),
            ("replicas", Json::Arr(replicas)),
        ])
    }
}

/// One replica of the simulated fleet.
struct Replica {
    engine: SimEngine,
    scanner: DualScanner,
    st: crate::engine::sim::RunState,
    done: bool,
    desc: String,
    /// Finish-log entries already journaled (per-replica cursor).
    logged: usize,
}

/// Raw outcome of one fleet pass (before the static comparison).
struct FleetRun {
    results: Vec<SimResult>,
    descs: Vec<String>,
    steals: usize,
    stolen_units: usize,
    stolen_requests: usize,
    stats: FaultStats,
    halted: bool,
    /// Coordinator-level event track (steals / deaths / rejoins); `None`
    /// unless `cfg.engine.trace` was set.
    coord_trace: Option<Box<TraceData>>,
}

/// Fault-tolerance machinery threaded through one [`run_fleet`] pass.
/// [`FtDriver::inert`] disables every hook, leaving the coordinator
/// bit-identical to the pre-fault fleet.
struct FtDriver<'a> {
    plan: FaultPlan,
    next_event: usize,
    strategy: RecoveryStrategy,
    kv_rescue: bool,
    snapshot_every: usize,
    journal: Option<JournalWriter>,
    resume: Option<&'a ResumeState>,
    halt_after_steps: Option<usize>,
}

impl FtDriver<'_> {
    fn inert() -> Self {
        FtDriver {
            plan: FaultPlan::default(),
            next_event: 0,
            strategy: RecoveryStrategy::Recover,
            kv_rescue: true,
            snapshot_every: usize::MAX,
            journal: None,
            resume: None,
            halt_after_steps: None,
        }
    }

    fn record(&mut self, stats: &mut FaultStats, rec: &Json) {
        if let Some(w) = self.journal.as_mut() {
            w.record(rec).expect("journal write failed");
            stats.journal_records += 1;
        }
    }
}

/// Checkpoint/resume + failure-injection options for
/// [`serve_fleet_opts`].  Default = plain [`serve_fleet`] behavior.
#[derive(Clone, Debug, Default)]
pub struct FleetFtOptions {
    /// Append a crash-consistent run journal here.  When equal to
    /// `resume_path`, the torn tail is cut and new records continue the
    /// same file.
    pub journal_path: Option<PathBuf>,
    /// Resume from this journal: already-finished requests are
    /// cross-checked bitwise against the deterministic replay and counted
    /// in [`FaultStats::resumed_finishes`] instead of being re-reported.
    pub resume_path: Option<PathBuf>,
    /// Test/checkpoint kill switch: stop the coordinator after this many
    /// steps, as a crash would.
    pub halt_after_steps: Option<usize>,
}

impl FleetRun {
    fn makespan(&self) -> f64 {
        self.results.iter().map(|r| r.total_time).fold(0.0, f64::max)
    }

    fn sharing(&self) -> f64 {
        let hits: u64 = self.results.iter().map(|r| r.hit_tokens).sum();
        let prompts: u64 = self.results.iter().map(|r| r.prompt_tokens).sum();
        if prompts == 0 {
            0.0
        } else {
            hits as f64 / prompts as f64
        }
    }
}

/// Perf model of fleet replica `slot` (heterogeneous overrides fall back
/// to the homogeneous top-level spec).
fn replica_pm(cfg: &SystemConfig, slot: usize) -> PerfModel {
    let hw = cfg
        .fleet
        .hardware
        .get(slot)
        .map(|name| {
            presets::hardware_by_name(name)
                .unwrap_or_else(|| panic!("unknown hardware preset '{name}'"))
        })
        .unwrap_or_else(|| cfg.hardware.clone());
    let gpus = cfg.fleet.gpus.get(slot).copied().unwrap_or(cfg.gpus_per_replica);
    let mut pm = PerfModel::new(cfg.model.clone(), hw, gpus);
    pm.prefill_attn_flops = cfg.engine.prefill_attn_flops;
    pm.set_modality(&cfg.modality);
    pm
}

/// Scanner units (with steal costs) for a set of global unit indices.
fn scanner_units(units: &[WorkUnit], idxs: &[usize]) -> Vec<Unit> {
    idxs.iter()
        .map(|&i| Unit {
            requests: units[i].requests.clone(),
            density: units[i].density,
            est_cost: units[i].est_time(),
        })
        .collect()
}

/// Engine requests for a unit batch, in ascending request-id order (for a
/// dp=1 fleet this is exactly `SimRequest::from_workload`'s order, which
/// keeps the single-replica fleet bit-identical to `run_system`).
fn shard_requests(workload: &Workload, tree: &PrefixTree, us: &[Unit]) -> Vec<SimRequest> {
    let mut ids: Vec<u32> = us.iter().flat_map(|u| u.requests.iter().copied()).collect();
    ids.sort_unstable();
    ids.iter()
        .map(|&r| {
            let req = &workload.requests[r as usize];
            SimRequest::offline(
                req.id,
                req.prompt.clone(),
                req.output_len,
                tree.est_output[r as usize],
            )
            .with_attachments(req.modality.attachments.clone())
        })
        .collect()
}

/// Monotone total-order key for a replica clock: maps any finite f64 to
/// a u64 with the same ordering (sign-flip transform), so the
/// coordinator's min-heap can carry clocks without float comparators.
/// Exact — two clocks map to the same key iff they are the same float —
/// which is what keeps heap selection bit-identical to the linear
/// `min_by` scan it replaced.
fn clock_key(x: f64) -> u64 {
    let b = x.to_bits();
    if b >> 63 == 1 {
        !b
    } else {
        b | (1 << 63)
    }
}

/// The straggler: the non-done replica (other than `thief`) with the most
/// steal-eligible estimated work.
fn pick_victim(reps: &[Replica], thief: usize) -> Option<usize> {
    let mut best: Option<(usize, f64, usize)> = None;
    for (j, r) in reps.iter().enumerate() {
        if j == thief || r.done {
            continue;
        }
        let units = r.scanner.stealable_units();
        if units == 0 {
            continue;
        }
        let est = r.scanner.remaining_whole_est();
        let better = match best {
            None => true,
            Some((_, be, bu)) => est > be || (est == be && units > bu),
        };
        if better {
            best = Some((j, est, units));
        }
    }
    best.map(|(j, _, _)| j)
}

/// Deterministic global preprocessing shared by the stealing pass and its
/// static reference (one tree build / sampling / transform / unit pricing
/// / assignment instead of two identical ones).
struct PreparedFleet {
    tree: PrefixTree,
    sched: crate::config::SchedulerConfig,
    units: Vec<WorkUnit>,
    rho_root: f64,
    pms: Vec<PerfModel>,
    /// Unit indices per replica slot (empty for slots the assignment gave
    /// nothing — they start idle and join via stealing).
    parts_by_slot: Vec<Vec<usize>>,
}

fn prepare_fleet(cfg: &SystemConfig, workload: &Workload) -> PreparedFleet {
    let dp = cfg.dp_replicas.max(1);
    // Global preprocessing, identical to run_system's BlendServe path.
    let (pm, tree, _n_sampled, _splits) = prepare_blendserve(cfg, workload);
    let mut sched = cfg.scheduler.clone();
    sched.expected_sharing = tree.sharing_ratio();
    let units = work_units(&tree, &pm);
    let rho_root = tree.root_density();
    let pms: Vec<PerfModel> = (0..dp).map(|slot| replica_pm(cfg, slot)).collect();
    let weights: Vec<f64> = pms.iter().map(|p| p.compute()).collect();
    let assignment = assign_units(&units, rho_root, &weights);
    let mut parts_by_slot: Vec<Vec<usize>> = vec![Vec::new(); dp];
    for (idxs, &slot) in assignment.parts.into_iter().zip(&assignment.owners) {
        parts_by_slot[slot] = idxs;
    }
    PreparedFleet { tree, sched, units, rho_root, pms, parts_by_slot }
}

/// Build (or rebuild) the replica for `slot` over the unit batch `us`,
/// clock pinned to `clock`, inheriting any fleet-wide degraded state
/// (`host_mult` / `link_mult` are the cumulative shrink factors applied
/// so far — a rejoined replica must not come back with pristine host
/// memory or link bandwidth).
fn build_replica(
    cfg: &SystemConfig,
    workload: &Workload,
    prep: &PreparedFleet,
    slot: usize,
    us: Vec<Unit>,
    clock: f64,
    host_mult: f64,
    link_mult: f64,
) -> Replica {
    let reqs = shard_requests(workload, &prep.tree, &us);
    let mut engine = SimEngine::new(
        prep.pms[slot].clone(),
        cfg.engine.clone(),
        prep.sched.clone(),
        reqs,
    )
    .with_kv(&cfg.kv)
    .with_modality(&cfg.modality);
    engine.set_trace_replica(slot as u32);
    let mut st = engine.begin_at(clock);
    if host_mult < 1.0 {
        engine.shrink_host_kv(&mut st, host_mult);
    }
    if link_mult < 1.0 {
        engine.degrade_link(&mut st, link_mult);
    }
    Replica {
        engine,
        scanner: DualScanner::from_units(us, prep.rho_root),
        st,
        done: false,
        desc: format!("{} x{}", prep.pms[slot].hw.name, prep.pms[slot].n_gpus),
        logged: 0,
    }
}

/// Reclaim everything a dying replica still owns — pending scanner units
/// plus admitted-but-unfinished requests (with their host KV extents when
/// `kv_rescue` is on) — into the coordinator's orphan pools, and finalize
/// the corpse's partial results.  Exactly-once hinges on this set being
/// complete: every registered request is either finished (kept in the
/// corpse's result), stolen away earlier (another replica's problem), or
/// reclaimed here.
fn reclaim_replica(
    rep: &mut Replica,
    kv_rescue: bool,
    stats: &mut FaultStats,
    orphan_units: &mut Vec<Unit>,
    orphan_reqs: &mut Vec<(SimRequest, Option<KvExtent>)>,
) -> SimResult {
    let mut units = rep.scanner.drain_pending();
    stats.reclaimed_requests += units.iter().map(|u| u.requests.len()).sum::<usize>();
    orphan_units.append(&mut units);
    let ids = rep.engine.unfinished_admitted_ids(&rep.st);
    stats.reclaimed_requests += ids.len();
    stats.lost_progress_tokens += rep.engine.inflight_progress_tokens(&rep.st);
    for id in ids {
        let Some(req) = rep.engine.request_by_id(id) else {
            continue;
        };
        let ext = if kv_rescue { rep.engine.kv_extent(&rep.st, id) } else { None };
        orphan_reqs.push((req, ext));
    }
    let fresh = rep.engine.begin();
    let st = std::mem::replace(&mut rep.st, fresh);
    rep.logged = 0;
    rep.done = true;
    rep.engine.finalize(st)
}

/// One fleet pass over the workload.  Every configured replica slot is
/// materialized — a slot whose initial shard came back empty (coarse
/// units, dp > #units) starts idle and immediately joins via stealing.
///
/// `ft` threads the fault-tolerance machinery through the pass; with
/// [`FtDriver::inert`] every fault/journal/resume branch is dead and the
/// loop is bit-identical to the pre-fault coordinator.
fn run_fleet(
    cfg: &SystemConfig,
    workload: &Workload,
    prep: &PreparedFleet,
    steal: bool,
    mut ft: FtDriver<'_>,
) -> FleetRun {
    let tree = &prep.tree;
    let units = &prep.units;
    let rho_root = prep.rho_root;
    let mut reps: Vec<Replica> = prep
        .parts_by_slot
        .iter()
        .enumerate()
        .map(|(slot, idxs)| {
            let us = scanner_units(units, idxs);
            let reqs = shard_requests(workload, tree, &us);
            let mut engine = SimEngine::new(
                prep.pms[slot].clone(),
                cfg.engine.clone(),
                prep.sched.clone(),
                reqs,
            )
            .with_kv(&cfg.kv)
            .with_modality(&cfg.modality);
            engine.set_trace_replica(slot as u32);
            let st = engine.begin();
            Replica {
                engine,
                scanner: DualScanner::from_units(us, rho_root),
                st,
                done: false,
                desc: format!("{} x{}", prep.pms[slot].hw.name, prep.pms[slot].n_gpus),
                logged: 0,
            }
        })
        .collect();

    let mut stats = FaultStats::default();
    let mut halted = false;
    // Fault bookkeeping.  `dead[r]` replicas are finalized corpses
    // (skipped at the end); `rejoin_at[r]` is the clock a dead slot comes
    // back empty.  Orphan pools hold work reclaimed from corpses until a
    // replica drains and adopts it.  The multipliers accumulate fleet-wide
    // degraded modes so rebuilt replicas inherit them.
    let mut dead: Vec<bool> = vec![false; reps.len()];
    let mut rejoin_at: Vec<f64> = vec![f64::INFINITY; reps.len()];
    let mut pre_results: Vec<SimResult> = Vec::new();
    let mut pre_descs: Vec<String> = Vec::new();
    let mut orphan_units: Vec<Unit> = Vec::new();
    let mut orphan_reqs: Vec<(SimRequest, Option<KvExtent>)> = Vec::new();
    let mut host_mult = 1.0f64;
    let mut link_mult = 1.0f64;
    let mut coord_steps = 0usize;

    let mut steals = 0usize;
    let mut stolen_units = 0usize;
    let mut stolen_requests = 0usize;
    // Coordinator-level trace track (DESIGN.md §15): steal / death /
    // rejoin events the per-replica engines cannot see.  The pseudo
    // replica id is the dp count (one past the last real slot) and the
    // step stamp is `coord_steps`, the global fleet event ordinal.
    // Adoption batches from the orphan pool are recorded as steals from
    // that same pseudo slot.
    let mut coord_trace: Option<Box<TraceData>> = if cfg.engine.trace {
        Some(TraceData::new(reps.len() as u32))
    } else {
        None
    };
    let mut adoption_events = 0usize;
    // Discrete-event order: always advance the earliest replica, so every
    // steal observes its victim at a clock ≥ the thief's (the victim's
    // pending set only shrinks over time — causally safe).  Selection is
    // a lazy-deletion min-heap keyed by (clock, replica index): every
    // clock mutation (step, wake, rebuild) pushes a fresh entry and a
    // popped entry is valid only while it matches the replica's current
    // clock, so stale entries cost one pop each instead of a per-
    // iteration O(replicas) scan.  Ties break on the lower replica
    // index — the first-minimal semantics of the linear scan this
    // replaced.
    let mut heap: std::collections::BinaryHeap<std::cmp::Reverse<(u64, usize)>> =
        (0..reps.len())
            .map(|i| std::cmp::Reverse((clock_key(reps[i].st.clock()), i)))
            .collect();
    loop {
        let Some(std::cmp::Reverse((key, i))) = heap.pop() else {
            break;
        };
        if reps[i].done || key != clock_key(reps[i].st.clock()) {
            continue; // stale: retired, or its clock moved since the push
        }
        let tmin = reps[i].st.clock();

        // Due re-joins first: a dead slot whose rejoin clock has passed
        // comes back as an empty replica (steal target) inheriting any
        // degraded state, then the coordinator re-selects.
        let mut reselect = false;
        for r in 0..reps.len() {
            if dead[r] && rejoin_at[r] <= tmin {
                if let Some(tr) = coord_trace.as_mut() {
                    tr.emit(rejoin_at[r], coord_steps as u64, TraceEvent::Rejoin { replica: r as u32 });
                }
                reps[r] =
                    build_replica(cfg, workload, prep, r, Vec::new(), rejoin_at[r], host_mult, link_mult);
                dead[r] = false;
                rejoin_at[r] = f64::INFINITY;
                stats.rejoins += 1;
                heap.push(std::cmp::Reverse((clock_key(reps[r].st.clock()), r)));
                reselect = true;
            }
        }
        if reselect {
            // `i` was not stepped: its popped entry is still its current
            // clock, so re-offer it (the rejoiner may now be earlier).
            heap.push(std::cmp::Reverse((key, i)));
            continue;
        }

        // Fire every fault whose injection clock the fleet has reached.
        while ft.next_event < ft.plan.events.len() && ft.plan.events[ft.next_event].at <= tmin {
            let ev = ft.plan.events[ft.next_event];
            ft.next_event += 1;
            let rec = records::fault(&ev);
            ft.record(&mut stats, &rec);
            match ev.kind {
                FaultKind::Death { rejoin_at: rj } => {
                    let r = ev.replica;
                    if r >= reps.len() || dead[r] {
                        continue;
                    }
                    if (0..reps.len()).filter(|&j| !dead[j]).count() <= 1 {
                        // Killing the last live replica would strand the
                        // workload forever; the preemption is suppressed
                        // (DESIGN.md §12 liveness rule).
                        stats.suppressed_deaths += 1;
                        continue;
                    }
                    stats.deaths += 1;
                    if let Some(tr) = coord_trace.as_mut() {
                        tr.emit(ev.at, coord_steps as u64, TraceEvent::ReplicaDeath { replica: r as u32 });
                    }
                    match ft.strategy {
                        RecoveryStrategy::Recover => {
                            let res = reclaim_replica(
                                &mut reps[r],
                                ft.kv_rescue,
                                &mut stats,
                                &mut orphan_units,
                                &mut orphan_reqs,
                            );
                            pre_descs.push(format!("{} (preempted)", reps[r].desc));
                            pre_results.push(res);
                            dead[r] = true;
                            rejoin_at[r] = rj;
                            // Wake every retired survivor: the orphan pool
                            // must drain, and nothing a retiree adopts may
                            // predate the death it is absorbing.
                            for j in 0..reps.len() {
                                if !dead[j] && reps[j].done {
                                    reps[j].done = false;
                                    let rep = &mut reps[j];
                                    rep.engine.bump_clock(&mut rep.st, tmin);
                                    heap.push(std::cmp::Reverse((
                                        clock_key(rep.st.clock()),
                                        j,
                                    )));
                                }
                            }
                        }
                        RecoveryStrategy::Restart => {
                            // Restart-from-scratch baseline: every death
                            // discards all fleet progress (finished work
                            // included) and the survivors re-run the whole
                            // decomposition from the failure clock.
                            stats.restarts += 1;
                            stats.lost_progress_tokens += reps
                                .iter()
                                .enumerate()
                                .filter(|(j, _)| !dead[*j])
                                .map(|(_, rep)| rep.engine.inflight_progress_tokens(&rep.st))
                                .sum::<u64>();
                            stats.reclaimed_requests += workload.requests.len();
                            dead[r] = true;
                            rejoin_at[r] = rj;
                            reps[r].done = true;
                            let alive: Vec<usize> =
                                (0..reps.len()).filter(|&j| !dead[j]).collect();
                            // Deterministic re-shard: all original units,
                            // density-descending (stable), round-robin over
                            // the survivors.
                            let mut order: Vec<usize> = (0..units.len()).collect();
                            order.sort_by(|&a, &b| {
                                units[b]
                                    .density
                                    .partial_cmp(&units[a].density)
                                    .unwrap_or(std::cmp::Ordering::Equal)
                            });
                            let mut per_slot: Vec<Vec<usize>> =
                                vec![Vec::new(); alive.len()];
                            for (k, &u) in order.iter().enumerate() {
                                per_slot[k % alive.len()].push(u);
                            }
                            pre_results.clear();
                            pre_descs.clear();
                            orphan_units.clear();
                            orphan_reqs.clear();
                            for (k, &slot) in alive.iter().enumerate() {
                                let us = scanner_units(units, &per_slot[k]);
                                reps[slot] = build_replica(
                                    cfg, workload, prep, slot, us, ev.at, host_mult, link_mult,
                                );
                                heap.push(std::cmp::Reverse((
                                    clock_key(reps[slot].st.clock()),
                                    slot,
                                )));
                            }
                        }
                    }
                    reselect = true;
                }
                FaultKind::HostShrink { frac } => {
                    stats.host_shrinks += 1;
                    host_mult *= frac;
                    for (r, rep) in reps.iter_mut().enumerate() {
                        if !dead[r] {
                            stats.dropped_host_tokens +=
                                rep.engine.shrink_host_kv(&mut rep.st, frac);
                        }
                    }
                }
                FaultKind::LinkDegrade { factor } => {
                    stats.link_degrades += 1;
                    link_mult *= factor;
                    for (r, rep) in reps.iter_mut().enumerate() {
                        if !dead[r] {
                            rep.engine.degrade_link(&mut rep.st, factor);
                        }
                    }
                }
            }
        }
        if reselect {
            // Deaths may have retired or rebuilt `i` itself; its popped
            // entry still matches its clock if it survived untouched.
            heap.push(std::cmp::Reverse((key, i)));
            continue;
        }

        let outcome = {
            let rep = &mut reps[i];
            rep.engine.step_once(&mut rep.st, &mut rep.scanner)
        };
        coord_steps += 1;

        // Journal finishes the moment they happen (append-only, framed:
        // a crash tears at most the last record) and cross-check replayed
        // finishes bitwise against a resumed journal.
        if ft.journal.is_some() || ft.resume.is_some() {
            let pending: Vec<(u32, f64)> = {
                let rep = &reps[i];
                rep.engine.finish_log(&rep.st)[rep.logged..].to_vec()
            };
            reps[i].logged += pending.len();
            for (id, t) in pending {
                if let Some(rs) = ft.resume {
                    if let Some(&jt) = rs.finished.get(&id) {
                        assert_eq!(
                            t.to_bits(),
                            jt.to_bits(),
                            "resume replay diverged on request {id}: {t} vs journaled {jt}"
                        );
                        stats.resumed_finishes += 1;
                        continue;
                    }
                }
                let rec = records::finish(id, i, t);
                ft.record(&mut stats, &rec);
            }
            if ft.journal.is_some() && coord_steps % ft.snapshot_every == 0 {
                let finished: usize = reps.iter().map(|r| r.st.finished()).sum();
                let queued: Vec<usize> = reps
                    .iter()
                    .map(|r| r.scanner.remaining() + r.st.active_requests())
                    .collect();
                let host: Vec<usize> = reps
                    .iter()
                    .map(|r| r.st.host_resident_tokens() as usize)
                    .collect();
                let rec = records::snapshot(coord_steps, tmin, finished, &queued, &host);
                ft.record(&mut stats, &rec);
            }
        }
        if let Some(h) = ft.halt_after_steps {
            if coord_steps >= h {
                halted = true;
                break;
            }
        }

        if outcome == StepOutcome::Progress {
            heap.push(std::cmp::Reverse((clock_key(reps[i].st.clock()), i)));
            continue;
        }
        // Done (all local work finished) or Starved (queue empty): adopt
        // failure orphans first, then try to refill from the straggler,
        // then retire.
        let mut refilled = false;
        if !orphan_units.is_empty() || !orphan_reqs.is_empty() {
            let mut adopted = 0usize;
            if !orphan_units.is_empty() {
                let us = std::mem::take(&mut orphan_units);
                adopted += us.iter().map(|u| u.requests.len()).sum::<usize>();
                let reqs = shard_requests(workload, tree, &us);
                let rep = &mut reps[i];
                rep.engine.feed_requests(&mut rep.st, reqs);
                rep.scanner.feed(us);
            }
            if !orphan_reqs.is_empty() {
                let adopt = std::mem::take(&mut orphan_reqs);
                adopted += adopt.len();
                let rep = &mut reps[i];
                for (req, ext) in adopt {
                    let tokens = ext.as_ref().map(|e| e.tokens).unwrap_or(0);
                    if rep.engine.adopt_retracted(&mut rep.st, req, ext) {
                        stats.rescued_extents += 1;
                        stats.rescued_tokens += tokens;
                    }
                }
            }
            let rec = records::steal(reps[i].st.clock(), reps.len(), i, adopted);
            ft.record(&mut stats, &rec);
            if let Some(tr) = coord_trace.as_mut() {
                tr.emit(
                    reps[i].st.clock(),
                    coord_steps as u64,
                    TraceEvent::Steal {
                        victim: reps.len() as u32,
                        thief: i as u32,
                        n_requests: adopted as u64,
                    },
                );
            }
            adoption_events += 1;
            refilled = true;
        } else if steal {
            if let Some(v) = pick_victim(&reps, i) {
                let target =
                    (reps[v].scanner.remaining_whole_est() * cfg.fleet.steal_ratio)
                        .max(f64::MIN_POSITIVE);
                let stolen = reps[v].scanner.steal_from_memory_end(target);
                if !stolen.is_empty() {
                    steals += 1;
                    stolen_units += stolen.len();
                    let stolen_ids: Vec<u32> = stolen
                        .iter()
                        .flat_map(|u| u.requests.iter().copied())
                        .collect();
                    stolen_requests += stolen_ids.len();
                    // The donor stops pacing against the stolen work; the
                    // thief starts (feed_requests re-arms stolen-back ids).
                    {
                        let victim = &mut reps[v];
                        victim.engine.unfeed_requests(&mut victim.st, &stolen_ids);
                    }
                    let rec =
                        records::steal(reps[i].st.clock(), v, i, stolen_ids.len());
                    ft.record(&mut stats, &rec);
                    if let Some(tr) = coord_trace.as_mut() {
                        tr.emit(
                            reps[i].st.clock(),
                            coord_steps as u64,
                            TraceEvent::Steal {
                                victim: v as u32,
                                thief: i as u32,
                                n_requests: stolen_ids.len() as u64,
                            },
                        );
                    }
                    let reqs = shard_requests(workload, tree, &stolen);
                    let rep = &mut reps[i];
                    rep.engine.feed_requests(&mut rep.st, reqs);
                    rep.scanner.feed(stolen);
                    refilled = true;
                }
            }
        }
        if !refilled {
            reps[i].done = true;
        }
        if !reps[i].done {
            heap.push(std::cmp::Reverse((clock_key(reps[i].st.clock()), i)));
        }
    }

    let mut results = pre_results;
    let mut descs = pre_descs;
    for (slot, r) in reps.into_iter().enumerate() {
        if dead[slot] {
            // A corpse's partial results were captured when it died
            // (Recover) or discarded wholesale (Restart baseline).
            continue;
        }
        descs.push(r.desc);
        results.push(r.engine.finalize(r.st));
    }

    // Exactly-once issuance audit (DESIGN.md §11/§12): every workload
    // request finishes exactly once across the whole fleet history —
    // corpses' partial results included.  A stolen request stays
    // registered on its donor with an infinite finish time, and a
    // reclaimed one on its corpse with a NaN finish, so double issuance
    // or a dropped reclamation would surface here.  Skipped when the run
    // was halted mid-flight by the checkpoint kill switch.
    if !halted && (cfg!(debug_assertions) || cfg.engine.audit) {
        let mut finishes = vec![0u32; workload.requests.len()];
        for res in &results {
            for t in &res.timings {
                if t.finish.is_finite() {
                    finishes[t.id as usize] += 1;
                }
            }
        }
        for (id, &n) in finishes.iter().enumerate() {
            assert!(n == 1, "fleet audit: request {id} finished {n} times across the fleet");
        }
        // Coordinator-trace reconciliation (DESIGN.md §15): the event
        // stream must agree exactly with the fleet counters it shadowed.
        if let Some(tr) = coord_trace.as_ref() {
            if tr.complete() {
                let (mut deaths, mut rejoins, mut steal_evs, mut moved) = (0usize, 0usize, 0usize, 0u64);
                for rec in &tr.events {
                    match rec.ev {
                        TraceEvent::ReplicaDeath { .. } => deaths += 1,
                        TraceEvent::Rejoin { .. } => rejoins += 1,
                        TraceEvent::Steal { victim, n_requests, .. } => {
                            steal_evs += 1;
                            if (victim as usize) < dead.len() {
                                moved += n_requests;
                            }
                        }
                        _ => {}
                    }
                }
                assert_eq!(deaths, stats.deaths, "fleet audit: ReplicaDeath events vs deaths counter");
                assert_eq!(rejoins, stats.rejoins, "fleet audit: Rejoin events vs rejoins counter");
                assert_eq!(
                    steal_evs,
                    steals + adoption_events,
                    "fleet audit: Steal events vs steals + orphan adoptions"
                );
                assert_eq!(
                    moved as usize, stolen_requests,
                    "fleet audit: requests moved by Steal events vs stolen_requests"
                );
            } else {
                eprintln!(
                    "fleet audit: coordinator trace dropped {} records at the cap — skipping event reconciliation",
                    tr.dropped
                );
            }
        }
    }

    FleetRun { results, descs, steals, stolen_units, stolen_requests, stats, halted, coord_trace }
}

/// Serve a request pool on the work-stealing fleet.  Runs the stealing
/// schedule per `cfg.fleet` (including any `cfg.faults` injection), plus
/// (at `dp > 1` with stealing on) the static fork-join reference on the
/// same decomposition for the speedup/sharing-loss accounting.
pub fn serve_fleet(cfg: &SystemConfig, workload: &Workload) -> FleetReport {
    serve_fleet_opts(cfg, workload, FleetFtOptions::default()).expect("fleet run failed")
}

/// [`serve_fleet`] with checkpoint/resume plumbing: optionally journal
/// every finish (crash-consistent framed records), resume from a prior —
/// possibly torn — journal, and/or halt after a fixed number of
/// coordinator steps (crash injection for tests).  Failure injection
/// itself is configured by `cfg.faults`.
pub fn serve_fleet_opts(
    cfg: &SystemConfig,
    workload: &Workload,
    opts: FleetFtOptions,
) -> anyhow::Result<FleetReport> {
    let dp = cfg.dp_replicas.max(1);
    let plan = FaultPlan::generate(&cfg.faults, dp);
    if opts.journal_path.is_some()
        && !plan.is_empty()
        && cfg.faults.strategy == RecoveryStrategy::Restart
    {
        anyhow::bail!(
            "journaling is exactly-once and the restart baseline re-runs finished \
             requests; use strategy = \"recover\" with a journal"
        );
    }
    let prep = prepare_fleet(cfg, workload);
    let wfp = recovery::workload_fingerprint(workload);
    let cfp = recovery::config_fingerprint(cfg);
    let resume: Option<ResumeState> = match &opts.resume_path {
        Some(p) => {
            let load = recovery::load_journal(p)?;
            Some(ResumeState::from_load(&load, &wfp, &cfp)?)
        }
        None => None,
    };
    let journal = match &opts.journal_path {
        Some(jp) => {
            if opts.resume_path.as_ref() == Some(jp) {
                // Same file: cut the torn tail and continue appending.
                let rs = resume.as_ref().expect("resume state loaded above");
                Some(JournalWriter::resume_append(jp, rs.valid_bytes)?)
            } else {
                let mut w = JournalWriter::create(jp)?;
                w.record(&records::meta(&wfp, &cfp, workload.requests.len(), dp))?;
                Some(w)
            }
        }
        None => None,
    };
    let ft = FtDriver {
        plan,
        next_event: 0,
        strategy: cfg.faults.strategy,
        kv_rescue: cfg.faults.kv_rescue,
        snapshot_every: cfg.faults.snapshot_every.max(1),
        journal,
        resume: resume.as_ref(),
        halt_after_steps: opts.halt_after_steps,
    };
    let run = run_fleet(cfg, workload, &prep, cfg.fleet.steal, ft);
    if let Some(rs) = resume.as_ref() {
        if !run.halted {
            anyhow::ensure!(
                run.stats.resumed_finishes == rs.finished.len(),
                "resume journaled {} finishes but the replay only crossed {}",
                rs.finished.len(),
                run.stats.resumed_finishes,
            );
        }
    }
    let makespan = run.makespan();
    let sharing = run.sharing();
    let (static_makespan, static_sharing) = if cfg.fleet.steal && dp > 1 && !run.halted {
        let st = run_fleet(cfg, workload, &prep, false, FtDriver::inert());
        (st.makespan(), st.sharing())
    } else {
        (makespan, sharing)
    };

    let total_tokens: u64 = run.results.iter().map(|r| r.total_tokens).sum();
    let idle_fracs: Vec<f64> = run
        .results
        .iter()
        .map(|r| (1.0 - r.total_time / makespan.max(1e-12)).max(0.0))
        .collect();
    let mean_idle_frac = if idle_fracs.is_empty() {
        0.0
    } else {
        idle_fracs.iter().sum::<f64>() / idle_fracs.len() as f64
    };
    Ok(FleetReport {
        makespan,
        total_tokens,
        total_throughput: total_tokens as f64 / makespan.max(1e-12),
        mean_idle_frac,
        idle_fracs,
        steals: run.steals,
        stolen_units: run.stolen_units,
        stolen_requests: run.stolen_requests,
        sharing_achieved: sharing,
        static_makespan,
        static_sharing,
        speedup_vs_static: static_makespan / makespan.max(1e-12),
        sharing_lost_to_steals: (static_sharing - sharing).max(0.0),
        swapped_out_tokens: run.results.iter().map(|r| r.swapped_out_tokens).sum(),
        recompute_saved_tokens: run
            .results
            .iter()
            .map(|r| r.recompute_saved_tokens)
            .sum(),
        recomputed_tokens: run.results.iter().map(|r| r.recomputed_tokens).sum(),
        per_replica: run.results,
        replica_desc: run.descs,
        faults: run.stats,
        halted: run.halted,
        coord_trace: run.coord_trace,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::baselines;
    use crate::scheduler::run_system;
    use crate::trace::synth::{synthesize, SynthSpec};
    use crate::trace::TraceKind;

    #[test]
    fn clock_key_is_exact_and_order_preserving() {
        // Every ordered pair from a sign/magnitude/zero spread must map
        // to keys in the same order; equal floats to equal keys.
        let xs = [
            f64::NEG_INFINITY,
            -1e300,
            -2.5,
            -1.0,
            -f64::MIN_POSITIVE,
            -0.0,
            0.0,
            f64::MIN_POSITIVE,
            1e-9,
            1.0,
            1.0 + f64::EPSILON,
            4096.75,
            1e300,
            f64::INFINITY,
        ];
        for (i, &a) in xs.iter().enumerate() {
            for &b in &xs[i..] {
                if a < b {
                    assert!(clock_key(a) < clock_key(b), "{a} vs {b}");
                } else {
                    // a == b here (the list is sorted; -0.0 and 0.0 keys
                    // may differ, which is fine: -0.0 < 0.0 is false and
                    // the heap only needs a total order refining <).
                    assert!(clock_key(a) <= clock_key(b), "{a} vs {b}");
                }
            }
        }
        assert_eq!(clock_key(17.25), clock_key(17.25));
    }

    fn balanced_workload(n: usize) -> Workload {
        let pm = PerfModel::new(
            presets::llama3_8b(),
            presets::a100_80gb(),
            1,
        );
        synthesize(&SynthSpec::new(TraceKind::BurstGpt, 1.1, 0.25, n), &pm)
    }

    /// The HyGen-motivated adversary (`trace::synth::adversarial_skew`):
    /// liar prompt groups whose true output length is ~3x what §5.1
    /// sampling will estimate for the unsampled majority of them.  The
    /// static partition balances *estimated* times, so the replica that
    /// drew the under-estimated memory tail grinds for multiples of its
    /// target while the others idle — exactly the stranded capacity
    /// stealing recovers.
    fn skewed_workload(honest_groups: usize, liar_groups: usize, per: usize) -> Workload {
        crate::trace::synth::adversarial_skew(honest_groups, liar_groups, per)
    }

    fn skewed_cfg(dp: usize) -> SystemConfig {
        let mut cfg = baselines::blendserve();
        // Tight KV (~3.4k tokens after weights+reserve): each shard's
        // prompt footprint alone exceeds it, so admission pauses mid-shard
        // and the scanner retains pending whole units — the steal-eligible
        // pool.  Sparse sampling under-estimates most liar groups.
        cfg.hardware.memory_bytes = 20.5e9;
        cfg.scheduler.sample_prob = 0.02;
        cfg.dp_replicas = dp;
        cfg
    }

    #[test]
    fn dp1_fleet_bit_identical_to_run_system() {
        let w = balanced_workload(500);
        let cfg = baselines::blendserve();
        let sys = run_system(&cfg, &w);
        let fleet = serve_fleet(&cfg, &w);
        assert_eq!(fleet.per_replica.len(), 1);
        assert_eq!(fleet.steals, 0);
        let (a, b) = (&sys.result, &fleet.per_replica[0]);
        assert_eq!(a.total_time, b.total_time, "clock diverged");
        assert_eq!(a.steps, b.steps);
        assert_eq!(a.total_tokens, b.total_tokens);
        assert_eq!(a.hit_tokens, b.hit_tokens);
        assert_eq!(a.prompt_tokens, b.prompt_tokens);
        assert_eq!(a.retractions, b.retractions);
        assert_eq!(a.total_comp, b.total_comp);
        assert_eq!(a.total_mem, b.total_mem);
        assert_eq!(fleet.speedup_vs_static, 1.0);
    }

    #[test]
    fn fleet_conserves_tokens_and_sharing_on_balanced_trace() {
        let w = balanced_workload(1600);
        let mut cfg = baselines::blendserve();
        cfg.scheduler.sample_prob = 1.0; // perfect estimates: no skew
        cfg.dp_replicas = 4;
        let rep = serve_fleet(&cfg, &w);
        assert_eq!(rep.total_tokens, w.total_tokens());
        assert_eq!(rep.per_replica.len(), 4, "every configured slot materialized");
        // Within noise of the static schedule on a balanced trace…
        assert!(
            rep.makespan <= rep.static_makespan * 1.05,
            "stealing regressed a balanced trace: {} vs static {}",
            rep.makespan,
            rep.static_makespan
        );
        // …and no meaningful sharing given up.
        assert!(
            rep.sharing_achieved >= rep.static_sharing * 0.9,
            "sharing {} vs static {}",
            rep.sharing_achieved,
            rep.static_sharing
        );
    }

    #[test]
    fn stealing_beats_static_forkjoin_on_skewed_trace() {
        let w = skewed_workload(32, 16, 10);
        let cfg = skewed_cfg(4);
        let rep = serve_fleet(&cfg, &w);
        assert_eq!(rep.total_tokens, w.total_tokens());
        assert!(rep.steals > 0, "no steals on an adversarially skewed trace");
        assert!(
            rep.makespan < rep.static_makespan,
            "stealing did not beat static: {} vs {}",
            rep.makespan,
            rep.static_makespan
        );
        assert!(
            rep.sharing_achieved >= rep.static_sharing * 0.9,
            "stealing shredded sharing: {} vs static {}",
            rep.sharing_achieved,
            rep.static_sharing
        );
        // Stealing replicas only idle after global work runs out.
        assert!(rep.mean_idle_frac < 0.5, "idle {}", rep.mean_idle_frac);
    }

    #[test]
    fn stealing_reduces_tail_idle_on_skewed_trace() {
        let w = skewed_workload(32, 16, 10);
        let mut static_cfg = skewed_cfg(4);
        static_cfg.fleet.steal = false;
        let st = serve_fleet(&static_cfg, &w);
        assert_eq!(st.steals, 0);
        assert_eq!(st.speedup_vs_static, 1.0);
        let dyn_rep = serve_fleet(&skewed_cfg(4), &w);
        let static_idle =
            st.idle_fracs.iter().cloned().fold(0.0f64, f64::max);
        let steal_idle =
            dyn_rep.idle_fracs.iter().cloned().fold(0.0f64, f64::max);
        assert!(
            steal_idle < static_idle,
            "worst idle not reduced: {steal_idle} vs {static_idle}"
        );
    }

    #[test]
    fn dp_exceeding_units_materializes_all_replicas() {
        // A single-unit workload at dp=8: the assignment hands one slot
        // everything, but all eight replicas exist — the empty ones start
        // idle and try to steal (nothing is stealable here once the lone
        // unit is admitted, so they retire cleanly).
        let w = Workload::new(
            "single-unit",
            (0..6)
                .map(|i| {
                    crate::trace::Request::new(i, TraceKind::Custom, vec![1, 2, 3], 8)
                })
                .collect(),
        );
        let mut cfg = baselines::blendserve();
        cfg.dp_replicas = 8;
        let rep = serve_fleet(&cfg, &w);
        assert_eq!(rep.per_replica.len(), 8);
        assert_eq!(rep.idle_fracs.len(), 8);
        assert_eq!(rep.total_tokens, w.total_tokens());
        assert!(rep.makespan.is_finite() && rep.makespan > 0.0);
        assert!(rep.total_throughput.is_finite());
    }

    #[test]
    fn kv_tiering_threads_through_fleet_replicas() {
        // The KV-constrained skewed config retracts on at least one
        // replica; with tiering on the fleet must conserve both request
        // tokens and swap extents, and surface the traffic in its report.
        let w = skewed_workload(32, 16, 10);
        let mut cfg = skewed_cfg(4);
        cfg.kv.enabled = true;
        let rep = serve_fleet(&cfg, &w);
        assert_eq!(rep.total_tokens, w.total_tokens());
        let (swapped_in, swapped_out) = rep
            .per_replica
            .iter()
            .fold((0u64, 0u64), |acc, r| {
                (acc.0 + r.swapped_in_tokens, acc.1 + r.swapped_out_tokens)
            });
        assert_eq!(swapped_in, swapped_out, "extents lost across the fleet");
        assert_eq!(rep.swapped_out_tokens, swapped_out);
        let json = rep.to_json().to_string();
        assert!(json.contains("\"swapped_out_tokens\""));
        assert!(json.contains("\"recompute_saved_tokens\""));
        assert!(json.contains("\"link_busy_frac\""));
    }

    #[test]
    fn heterogeneous_fleet_loads_strong_replica_more() {
        let w = balanced_workload(1600);
        let mut cfg = baselines::blendserve();
        cfg.scheduler.sample_prob = 1.0;
        cfg.dp_replicas = 2;
        cfg.fleet.gpus = vec![1, 2];
        let rep = serve_fleet(&cfg, &w);
        assert_eq!(rep.total_tokens, w.total_tokens());
        assert_eq!(rep.per_replica.len(), 2);
        assert_eq!(rep.replica_desc[0], "a100-80gb-sxm x1");
        assert_eq!(rep.replica_desc[1], "a100-80gb-sxm x2");
        let (weak, strong) =
            (rep.per_replica[0].total_tokens, rep.per_replica[1].total_tokens);
        assert!(
            strong as f64 > weak as f64 * 1.2,
            "2x-GPU replica under-loaded: {strong} vs {weak}"
        );
    }

    /// Bitwise per-request finish times of a fleet report (asserts each
    /// request finished at most once on the way).
    fn finish_bits(rep: &FleetReport) -> std::collections::HashMap<u32, u64> {
        let mut m = std::collections::HashMap::new();
        for r in &rep.per_replica {
            for t in &r.timings {
                if t.finish.is_finite() {
                    let prev = m.insert(t.id, t.finish.to_bits());
                    assert!(prev.is_none(), "request {} finished twice", t.id);
                }
            }
        }
        m
    }

    fn one_death_plan(at: f64, replica: usize, rejoin_at: f64) -> FaultPlan {
        FaultPlan {
            events: vec![crate::recovery::FaultEvent {
                at,
                replica,
                kind: FaultKind::Death { rejoin_at },
            }],
        }
    }

    #[test]
    fn preemption_with_recover_conserves_tokens_exactly_once() {
        let w = skewed_workload(32, 16, 10);
        let mut cfg = skewed_cfg(4);
        cfg.kv.enabled = true;
        let base = serve_fleet(&cfg, &w).makespan;
        let prep = prepare_fleet(&cfg, &w);
        let mut ft = FtDriver::inert();
        ft.plan = one_death_plan(base * 0.4, 0, f64::INFINITY);
        let run = run_fleet(&cfg, &w, &prep, true, ft);
        assert!(!run.halted);
        assert_eq!(run.stats.deaths, 1);
        assert!(run.stats.reclaimed_requests > 0, "mid-run victim held no work");
        // The exactly-once audit already ran inside run_fleet; token
        // conservation across corpse + heirs is the other half.
        let total: u64 = run.results.iter().map(|r| r.total_tokens).sum();
        assert_eq!(total, w.total_tokens());
        // Corpse results are kept in place of the dead slot's: the corpse
        // plus the three surviving slots (replica 0 never re-joins).
        assert_eq!(run.results.len(), 4);
        assert!(run.descs.iter().any(|d| d.contains("(preempted)")));
        // Swap conservation fleet-wide: rescued extents re-count their
        // offload on the heir, so fetches never exceed offloads.
        let (si, so) = run.results.iter().fold((0u64, 0u64), |acc, r| {
            (acc.0 + r.swapped_in_tokens, acc.1 + r.swapped_out_tokens)
        });
        assert!(si <= so, "fetched {si} > offloaded {so}");
    }

    #[test]
    fn dead_replica_rejoins_and_fleet_finishes() {
        let w = skewed_workload(32, 16, 10);
        let cfg = skewed_cfg(4);
        let base = serve_fleet(&cfg, &w).makespan;
        let prep = prepare_fleet(&cfg, &w);
        let mut ft = FtDriver::inert();
        ft.plan = one_death_plan(base * 0.2, 1, base * 0.4);
        let run = run_fleet(&cfg, &w, &prep, true, ft);
        assert_eq!(run.stats.deaths, 1);
        assert_eq!(run.stats.rejoins, 1, "replica 1 never re-joined");
        let total: u64 = run.results.iter().map(|r| r.total_tokens).sum();
        assert_eq!(total, w.total_tokens());
        // Corpse + 4 live slots (the re-joined replica is a fresh entry
        // in its old slot).
        assert_eq!(run.results.len(), 5);
    }

    #[test]
    fn killing_last_replica_is_suppressed() {
        let w = balanced_workload(200);
        let cfg = baselines::blendserve(); // dp = 1
        let prep = prepare_fleet(&cfg, &w);
        let mut ft = FtDriver::inert();
        ft.plan = one_death_plan(0.0, 0, f64::INFINITY);
        let run = run_fleet(&cfg, &w, &prep, true, ft);
        assert_eq!(run.stats.deaths, 0);
        assert_eq!(run.stats.suppressed_deaths, 1);
        let total: u64 = run.results.iter().map(|r| r.total_tokens).sum();
        assert_eq!(total, w.total_tokens());
    }

    #[test]
    fn restart_baseline_loses_to_exactly_once_recovery() {
        let w = skewed_workload(32, 16, 10);
        let cfg = skewed_cfg(4);
        let base = serve_fleet(&cfg, &w).makespan;
        let prep = prepare_fleet(&cfg, &w);

        let mut rec_ft = FtDriver::inert();
        rec_ft.plan = one_death_plan(base * 0.5, 0, f64::INFINITY);
        let recov = run_fleet(&cfg, &w, &prep, true, rec_ft);

        let mut rst_ft = FtDriver::inert();
        rst_ft.plan = one_death_plan(base * 0.5, 0, f64::INFINITY);
        rst_ft.strategy = RecoveryStrategy::Restart;
        let restart = run_fleet(&cfg, &w, &prep, true, rst_ft);

        assert_eq!(restart.stats.restarts, 1);
        for run in [&recov, &restart] {
            let total: u64 = run.results.iter().map(|r| r.total_tokens).sum();
            assert_eq!(total, w.total_tokens());
        }
        assert!(
            recov.makespan() < restart.makespan(),
            "recovery ({}) not better than restart-from-scratch ({})",
            recov.makespan(),
            restart.makespan()
        );
    }

    #[test]
    fn degraded_modes_fire_through_config_plan() {
        let w = skewed_workload(32, 16, 10);
        let mut cfg = skewed_cfg(4);
        cfg.kv.enabled = true;
        cfg.faults.enabled = true;
        cfg.faults.mtbf_s = 0.0; // no deaths, degraded modes only
        cfg.faults.host_shrink_at_s = 1e-6;
        cfg.faults.host_shrink_frac = 0.25;
        cfg.faults.link_degrade_at_s = 1e-6;
        cfg.faults.link_degrade_factor = 0.25;
        let rep = serve_fleet(&cfg, &w);
        assert_eq!(rep.faults.host_shrinks, 1);
        assert_eq!(rep.faults.link_degrades, 1);
        assert_eq!(rep.total_tokens, w.total_tokens());
        let json = rep.to_json().to_string();
        assert!(json.contains("\"host_shrinks\""));
        assert!(json.contains("\"resumed_finishes\""));
    }

    #[test]
    fn seeded_deaths_via_config_conserve_and_report() {
        let w = skewed_workload(32, 16, 10);
        let mut cfg = skewed_cfg(4);
        let base = serve_fleet(&cfg, &w).makespan;
        cfg.faults.enabled = true;
        cfg.faults.seed = 11;
        cfg.faults.mtbf_s = base * 0.3; // several deaths within the run
        cfg.faults.max_deaths = 2;
        let rep = serve_fleet(&cfg, &w);
        assert!(rep.faults.deaths + rep.faults.suppressed_deaths > 0, "no deaths fired");
        assert_eq!(rep.total_tokens, w.total_tokens());
        assert!(!rep.halted);
    }

    #[test]
    fn halt_journal_resume_is_bit_identical() {
        let w = skewed_workload(8, 4, 6);
        let mut cfg = skewed_cfg(2);
        cfg.faults.snapshot_every = 8; // journaling cadence only, not execution
        let golden = serve_fleet(&cfg, &w);
        let want = finish_bits(&golden);
        assert_eq!(want.len(), w.requests.len());

        let path = std::env::temp_dir().join("blendserve_fleet_halt_resume.journal");
        std::fs::remove_file(&path).ok();
        let halted = serve_fleet_opts(
            &cfg,
            &w,
            FleetFtOptions {
                journal_path: Some(path.clone()),
                resume_path: None,
                halt_after_steps: Some(50),
            },
        )
        .unwrap();
        assert!(halted.halted, "run finished before the kill switch");
        assert!(halted.faults.journal_records > 0);

        let resumed = serve_fleet_opts(
            &cfg,
            &w,
            FleetFtOptions {
                journal_path: Some(path.clone()),
                resume_path: Some(path.clone()),
                halt_after_steps: None,
            },
        )
        .unwrap();
        assert!(!resumed.halted);
        let got = finish_bits(&resumed);
        assert_eq!(got, want, "resumed run diverged from the uninterrupted golden");
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn mixed_hardware_fleet_runs_and_reports() {
        let w = balanced_workload(1200);
        let mut cfg = baselines::blendserve();
        cfg.scheduler.sample_prob = 1.0;
        cfg.dp_replicas = 2;
        cfg.fleet.hardware =
            vec!["a100-80gb-sxm".to_string(), "h100-80gb-sxm".to_string()];
        let rep = serve_fleet(&cfg, &w);
        assert_eq!(rep.total_tokens, w.total_tokens());
        assert_eq!(rep.replica_desc[1], "h100-80gb-sxm x1");
        let json = rep.to_json().to_string();
        assert!(json.contains("\"speedup_vs_static\""));
        assert!(json.contains("h100-80gb-sxm"));
    }
}
