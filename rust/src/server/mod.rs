//! Serving frontends: the file-based offline batch API (JSONL in, JSONL
//! out, one leader thread per DP replica) and the online/offline
//! co-located entry point ([`colocate`]).
//!
//! The offline frontend is transport-agnostic on purpose: offline
//! inference has no request path to keep hot, so a directory of JSONL
//! files *is* the queue.  Co-location adds the latency-sensitive request
//! path on top of the same engine (DESIGN.md §Co-located-Serving).

pub mod colocate;
pub mod fleet;
pub mod pool;

pub use colocate::{online_stream, serve_colocated, ColocateReport};
pub use fleet::{serve_fleet, serve_fleet_opts, FaultStats, FleetFtOptions, FleetReport};
pub use pool::{load_jsonl, load_jsonl_tolerant, save_results, JsonlRequest};

use crate::config::SystemConfig;
use crate::parallel::partition_dp;
use crate::perfmodel::PerfModel;
use crate::scheduler::{run_system, RunOutput};
use crate::trace::Workload;
use crate::tree::PrefixTree;
use std::thread;

/// Outcome of one offline batch job.
#[derive(Debug)]
pub struct BatchJobResult {
    pub per_replica: Vec<RunOutput>,
    /// Wall-clock makespan across replicas (slowest replica).
    pub makespan: f64,
    /// Aggregate throughput (tokens/s) over the whole deployment.
    pub total_throughput: f64,
    pub total_tokens: u64,
}

/// Serve a whole request pool offline.  With `dp_replicas > 1` the
/// workload is decomposed via the §5.5 dual-scanner partitioning and the
/// replicas run concurrently (one OS thread each — the simulation is
/// CPU-bound, mirroring one leader per replica).  `partition_dp` returns
/// only non-empty shards, so `per_replica.len()` can be smaller than
/// `dp_replicas` on degenerate workloads (fewer scheduling units than
/// replicas).  For elastic (work-stealing) scheduling use
/// [`fleet::serve_fleet`] instead of this static fork-join.
pub fn serve_batch(cfg: &SystemConfig, workload: &Workload) -> BatchJobResult {
    let dp = cfg.dp_replicas.max(1);
    let mut outputs: Vec<RunOutput> = if dp == 1 {
        vec![run_system(cfg, workload)]
    } else {
        // Decompose on the centralized tree.
        let mut pm =
            PerfModel::new(cfg.model.clone(), cfg.hardware.clone(), cfg.gpus_per_replica);
        pm.set_modality(&cfg.modality);
        let mut tree = PrefixTree::build(workload);
        tree.sample_outputs(cfg.scheduler.sample_prob, cfg.scheduler.seed);
        tree.recompute_aggregates(&pm);
        tree.layer_sort();
        let partition = partition_dp(&tree, &pm, dp);

        let handles: Vec<thread::JoinHandle<RunOutput>> = partition
            .replicas
            .into_iter()
            .map(|ids| {
                let sub = Workload::new(
                    &format!("{}-dp", workload.name),
                    ids.iter()
                        .map(|&r| workload.requests[r as usize].clone())
                        .collect(),
                );
                let cfg = cfg.clone();
                thread::spawn(move || run_system(&cfg, &sub))
            })
            .collect();
        handles.into_iter().map(|h| h.join().expect("replica thread")).collect()
    };
    // The fork-join threads run anonymous engines (always slot 0); give
    // each recorded trace its shard index so export renders one Perfetto
    // process per replica instead of one collided track.
    for (slot, o) in outputs.iter_mut().enumerate() {
        if let Some(tr) = o.result.trace.as_mut() {
            tr.restamp(slot as u32);
        }
    }

    let makespan = outputs
        .iter()
        .map(|o| o.result.total_time)
        .fold(0.0f64, f64::max);
    let total_tokens: u64 = outputs.iter().map(|o| o.result.total_tokens).sum();
    BatchJobResult {
        makespan,
        total_throughput: total_tokens as f64 / makespan.max(1e-12),
        total_tokens,
        per_replica: outputs,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::baselines;
    use crate::config::presets;
    use crate::trace::synth::{synthesize, SynthSpec};
    use crate::trace::TraceKind;

    fn workload(n: usize) -> Workload {
        let pm = PerfModel::new(presets::llama3_8b(), presets::a100_80gb(), 1);
        synthesize(&SynthSpec::new(TraceKind::BurstGpt, 1.1, 0.2, n), &pm)
    }

    #[test]
    fn dp1_equals_run_system() {
        let w = workload(300);
        let cfg = baselines::blendserve();
        let job = serve_batch(&cfg, &w);
        assert_eq!(job.per_replica.len(), 1);
        assert_eq!(job.total_tokens, w.total_tokens());
    }

    #[test]
    fn dp_scales_near_linearly() {
        // Table 3: DP=2 should give ~1.85-1.95x the DP=1 throughput.
        // Full-probability sampling keeps the balance estimate clean at
        // this (test-sized) request count.
        let w = workload(2000);
        let mut cfg = baselines::blendserve();
        cfg.scheduler.sample_prob = 1.0;
        let t1 = serve_batch(&cfg, &w).total_throughput;
        cfg.dp_replicas = 2;
        let t2 = serve_batch(&cfg, &w).total_throughput;
        let scale = t2 / t1;
        assert!(
            scale > 1.6 && scale < 2.15,
            "DP=2 scaling {scale} (t1={t1} t2={t2})"
        );
    }

    #[test]
    fn dp_processes_every_token() {
        let w = workload(800);
        let mut cfg = baselines::blendserve();
        cfg.dp_replicas = 4;
        let job = serve_batch(&cfg, &w);
        assert_eq!(job.per_replica.len(), 4);
        assert_eq!(job.total_tokens, w.total_tokens());
    }

    #[test]
    fn dp_exceeding_units_yields_no_empty_replicas() {
        // Regression: a single-unit workload at dp_replicas = 8 used to
        // feed seven empty workloads to run_system (degenerate tree, NaN
        // throughput).  Now only the non-empty shard runs.
        use crate::trace::Request;
        let w = crate::trace::Workload::new(
            "single-unit",
            (0..6)
                .map(|i| {
                    Request::new(i, crate::trace::TraceKind::Custom, vec![5, 6, 7, 8], 12)
                })
                .collect(),
        );
        let mut cfg = baselines::blendserve();
        cfg.dp_replicas = 8;
        let job = serve_batch(&cfg, &w);
        assert_eq!(job.per_replica.len(), 1);
        assert_eq!(job.total_tokens, w.total_tokens());
        assert!(job.makespan.is_finite() && job.makespan > 0.0);
        assert!(job.total_throughput.is_finite() && job.total_throughput > 0.0);
        for out in &job.per_replica {
            assert!(out.result.throughput.is_finite());
            assert!(!out.result.throughput.is_nan());
        }
    }
}
