//! JSONL request pool: the on-disk format of the batch API.
//!
//! One request per line:
//! `{"id": 7, "prompt": [1,2,3], "max_tokens": 64, "dataset": "Custom"}`
//!
//! Results are written back as JSONL with scheduling metadata so runs are
//! auditable.

use crate::scheduler::RunOutput;
use crate::trace::{Request, TraceKind, Workload};
use crate::util::Json;
use std::io::{BufRead, BufWriter, Write};
use std::path::Path;

/// A request as read from the pool file.
#[derive(Clone, Debug, PartialEq)]
pub struct JsonlRequest {
    pub id: u32,
    pub prompt: Vec<u32>,
    pub max_tokens: u32,
    pub dataset: String,
}

fn kind_from_name(name: &str) -> TraceKind {
    match name {
        "ShareGPT" => TraceKind::ShareGpt,
        "WildChat" => TraceKind::WildChat,
        "Azure-Trace" => TraceKind::AzureTrace,
        "BurstGPT" => TraceKind::BurstGpt,
        "OpenVid" => TraceKind::OpenVid,
        "MMLU" => TraceKind::Mmlu,
        "LIMO" => TraceKind::Limo,
        _ => TraceKind::Custom,
    }
}

/// Load a JSONL pool file into a workload.
pub fn load_jsonl(path: &Path) -> anyhow::Result<Workload> {
    let file = std::fs::File::open(path)?;
    let reader = std::io::BufReader::new(file);
    let mut requests = Vec::new();
    for (lineno, line) in reader.lines().enumerate() {
        let line = line?;
        if line.trim().is_empty() {
            continue;
        }
        let j = Json::parse(&line)
            .map_err(|e| anyhow::anyhow!("line {}: {e}", lineno + 1))?;
        let prompt_arr = j
            .req("prompt")
            .map_err(|e| anyhow::anyhow!("line {}: {e}", lineno + 1))?
            .as_arr()
            .ok_or_else(|| anyhow::anyhow!("line {}: prompt not an array", lineno + 1))?;
        // Reject malformed tokens instead of coercing them to 0: a silent
        // `unwrap_or(0.0)` corrupts the prompt AND fabricates shared
        // 0-token prefixes across every malformed request.
        let mut prompt: Vec<u32> = Vec::with_capacity(prompt_arr.len());
        for (pos, x) in prompt_arr.iter().enumerate() {
            let v = x.as_f64().ok_or_else(|| {
                anyhow::anyhow!(
                    "line {}: prompt[{pos}] is not a number (got {x})",
                    lineno + 1
                )
            })?;
            if v < 0.0 || v.fract() != 0.0 || v > u32::MAX as f64 {
                anyhow::bail!(
                    "line {}: prompt[{pos}] is not a valid token id (got {v})",
                    lineno + 1
                );
            }
            prompt.push(v as u32);
        }
        let id = j.get("id").and_then(|x| x.as_f64()).unwrap_or(0.0) as u32;
        // `max_tokens` may be absent (defaults to 16) but, like prompt
        // tokens, a present-but-malformed value is an error, not a 16.
        let max_tokens = match j.get("max_tokens") {
            None => 16,
            Some(v) => {
                let x = v.as_f64().ok_or_else(|| {
                    anyhow::anyhow!(
                        "line {}: max_tokens is not a number (got {v})",
                        lineno + 1
                    )
                })?;
                if x < 0.0 || x.fract() != 0.0 || x > u32::MAX as f64 {
                    anyhow::bail!(
                        "line {}: max_tokens is not a valid token count (got {x})",
                        lineno + 1
                    );
                }
                x as u32
            }
        };
        let dataset = j
            .get("dataset")
            .and_then(|x| x.as_str())
            .unwrap_or("Custom")
            .to_string();
        requests.push(Request::new(id, kind_from_name(&dataset), prompt, max_tokens));
    }
    Ok(Workload::new(
        path.file_stem().and_then(|s| s.to_str()).unwrap_or("pool"),
        requests,
    ))
}

/// Write a workload out as a JSONL pool file (used by `blendserve synth`).
pub fn save_jsonl(w: &Workload, path: &Path) -> anyhow::Result<()> {
    let file = std::fs::File::create(path)?;
    let mut out = BufWriter::new(file);
    for r in &w.requests {
        let j = Json::obj(vec![
            ("id", Json::from(r.id as usize)),
            (
                "prompt",
                Json::Arr(r.prompt.iter().map(|&t| Json::from(t as usize)).collect()),
            ),
            ("max_tokens", Json::from(r.output_len as usize)),
            ("dataset", Json::from(r.dataset.name())),
        ]);
        writeln!(out, "{j}")?;
    }
    Ok(())
}

/// Write a job summary + per-replica stats as JSON.
pub fn save_results(outputs: &[RunOutput], path: &Path) -> anyhow::Result<()> {
    let replicas: Vec<Json> = outputs
        .iter()
        .map(|o| {
            Json::obj(vec![
                ("system", Json::from(o.system.as_str())),
                ("total_time_s", Json::Num(o.result.total_time)),
                ("throughput_tok_s", Json::Num(o.result.throughput)),
                ("steps", Json::from(o.result.steps as usize)),
                ("sharing_achieved", Json::Num(o.result.sharing_achieved)),
                ("optimal_sharing", Json::Num(o.optimal_sharing)),
                ("optimal_fraction", Json::Num(o.optimal_fraction)),
                ("retractions", Json::from(o.result.retractions as usize)),
                (
                    "recomputed_tokens",
                    Json::from(o.result.recomputed_tokens as usize),
                ),
                (
                    "swapped_out_tokens",
                    Json::from(o.result.swapped_out_tokens as usize),
                ),
                (
                    "recompute_saved_tokens",
                    Json::from(o.result.recompute_saved_tokens as usize),
                ),
                ("link_busy_frac", Json::Num(o.result.link_busy_frac)),
            ])
        })
        .collect();
    let doc = Json::obj(vec![("replicas", Json::Arr(replicas))]);
    std::fs::write(path, doc.to_string())?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::generators::generate_kind;

    /// Every TraceKind variant — one list for both exhaustive tests below.
    const ALL_KINDS: [TraceKind; 8] = [
        TraceKind::ShareGpt,
        TraceKind::WildChat,
        TraceKind::AzureTrace,
        TraceKind::BurstGpt,
        TraceKind::OpenVid,
        TraceKind::Mmlu,
        TraceKind::Limo,
        TraceKind::Custom,
    ];

    #[test]
    fn jsonl_roundtrip_every_trace_kind() {
        // Exhaustive TraceKind ⇄ name coverage: every kind must survive
        // save → load with its dataset tag (and thus `known_output`
        // semantics) intact.
        let dir = std::env::temp_dir().join("blendserve_pool_test");
        std::fs::create_dir_all(&dir).unwrap();
        for kind in ALL_KINDS {
            let w = match kind {
                // No generator for hand-built requests; craft directly.
                TraceKind::Custom => crate::trace::Workload::new(
                    "custom",
                    (0..5)
                        .map(|i| {
                            crate::trace::Request::new(
                                i,
                                TraceKind::Custom,
                                vec![i, i + 1, i + 2],
                                4 + i,
                            )
                        })
                        .collect(),
                ),
                k => generate_kind(k, 25, 3),
            };
            let path = dir.join(format!("pool_{}.jsonl", kind.name()));
            save_jsonl(&w, &path).unwrap();
            let back = load_jsonl(&path).unwrap();
            assert_eq!(back.len(), w.len(), "{kind}");
            for (a, b) in w.requests.iter().zip(&back.requests) {
                assert_eq!(a.prompt, b.prompt, "{kind}");
                assert_eq!(a.output_len, b.output_len, "{kind}");
                assert_eq!(a.dataset, b.dataset, "{kind}");
                assert_eq!(a.known_output, b.known_output, "{kind}");
            }
        }
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn kind_names_roundtrip_through_parser() {
        for kind in ALL_KINDS {
            assert_eq!(kind_from_name(kind.name()), kind);
        }
        // Unknown tags degrade to Custom rather than erroring.
        assert_eq!(kind_from_name("SomeFutureTrace"), TraceKind::Custom);
    }

    #[test]
    fn load_rejects_bad_lines() {
        let dir = std::env::temp_dir().join("blendserve_pool_bad");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("bad.jsonl");
        std::fs::write(&path, "{\"id\": 1}\n").unwrap(); // missing prompt
        assert!(load_jsonl(&path).is_err());
        std::fs::write(&path, "not json\n").unwrap();
        assert!(load_jsonl(&path).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn load_rejects_malformed_prompt_token_with_line_number() {
        // Regression: a non-numeric token used to be coerced to 0,
        // silently corrupting the prompt and fabricating a shared 0-token
        // prefix across every malformed request.
        let dir = std::env::temp_dir().join("blendserve_pool_badtok");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("tok.jsonl");
        std::fs::write(
            &path,
            "{\"id\":1,\"prompt\":[1,2,3],\"max_tokens\":4}\n\
             {\"id\":2,\"prompt\":[4,\"oops\",6],\"max_tokens\":4}\n",
        )
        .unwrap();
        let err = load_jsonl(&path).unwrap_err().to_string();
        assert!(err.contains("line 2"), "no line number in: {err}");
        assert!(err.contains("prompt[1]"), "no token position in: {err}");

        // Negative and fractional ids are equally invalid.
        std::fs::write(&path, "{\"id\":1,\"prompt\":[1,-7],\"max_tokens\":4}\n").unwrap();
        let err = load_jsonl(&path).unwrap_err().to_string();
        assert!(err.contains("line 1"), "no line number in: {err}");
        std::fs::write(&path, "{\"id\":1,\"prompt\":[1.5],\"max_tokens\":4}\n").unwrap();
        assert!(load_jsonl(&path).is_err());

        // max_tokens: absent defaults, but malformed errors with a line.
        std::fs::write(&path, "{\"id\":1,\"prompt\":[1,2]}\n").unwrap();
        assert_eq!(load_jsonl(&path).unwrap().requests[0].output_len, 16);
        std::fs::write(
            &path,
            "{\"id\":1,\"prompt\":[1,2],\"max_tokens\":\"oops\"}\n",
        )
        .unwrap();
        let err = load_jsonl(&path).unwrap_err().to_string();
        assert!(err.contains("line 1") && err.contains("max_tokens"), "{err}");
        std::fs::write(&path, "{\"id\":1,\"prompt\":[1,2],\"max_tokens\":-4}\n").unwrap();
        assert!(load_jsonl(&path).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn blank_lines_skipped() {
        let dir = std::env::temp_dir().join("blendserve_pool_blank");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("p.jsonl");
        std::fs::write(
            &path,
            "{\"id\":1,\"prompt\":[1,2],\"max_tokens\":4}\n\n{\"id\":2,\"prompt\":[3],\"max_tokens\":2}\n",
        )
        .unwrap();
        let w = load_jsonl(&path).unwrap();
        assert_eq!(w.len(), 2);
        assert_eq!(*w.requests[1].prompt, vec![3]);
        std::fs::remove_dir_all(&dir).ok();
    }
}
