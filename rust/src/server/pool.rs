//! JSONL request pool: the on-disk format of the batch API.
//!
//! One request per line:
//! `{"id": 7, "prompt": [1,2,3], "max_tokens": 64, "dataset": "Custom"}`
//!
//! Optional fields: `"known_output": true` marks a predefined output
//! length on any dataset tag (absent → the historical
//! `dataset == "OpenVid"` derivation), and
//! `"attachments": [{"hash": 42, "tokens": 576}, ...]` carries the
//! multi-modal profile (DESIGN.md §10).  Old pool files parse unchanged;
//! a *present-but-malformed* optional field is an error naming the line
//! and position, never a silent drop.
//!
//! Results are written back as JSONL with scheduling metadata so runs are
//! auditable.

use crate::modality::Attachment;
use crate::scheduler::RunOutput;
use crate::trace::{Request, TraceKind, Workload};
use crate::util::Json;
use std::io::{BufRead, BufWriter, Write};
use std::path::{Path, PathBuf};

/// A request as read from the pool file.
#[derive(Clone, Debug, PartialEq)]
pub struct JsonlRequest {
    pub id: u32,
    pub prompt: Vec<u32>,
    pub max_tokens: u32,
    pub dataset: String,
}

fn kind_from_name(name: &str) -> TraceKind {
    match name {
        "ShareGPT" => TraceKind::ShareGpt,
        "WildChat" => TraceKind::WildChat,
        "Azure-Trace" => TraceKind::AzureTrace,
        "BurstGPT" => TraceKind::BurstGpt,
        "OpenVid" => TraceKind::OpenVid,
        "MMLU" => TraceKind::Mmlu,
        "LIMO" => TraceKind::Limo,
        "VisionArena" => TraceKind::VisionArena,
        _ => TraceKind::Custom,
    }
}

/// Largest integer exactly representable in the JSON number channel.
const MAX_JSON_INT: f64 = 9e15;

/// Parse the optional `attachments` field of one pool line.  Returns an
/// empty vec when absent; malformed entries error with line + attachment
/// index + field (the `load_jsonl` hardening policy — PR 3).
fn parse_attachments(j: &Json, lineno: usize) -> anyhow::Result<Vec<Attachment>> {
    let Some(v) = j.get("attachments") else {
        return Ok(Vec::new());
    };
    let arr = v.as_arr().ok_or_else(|| {
        anyhow::anyhow!("line {lineno}: attachments is not an array (got {v})")
    })?;
    let mut atts = Vec::with_capacity(arr.len());
    for (pos, item) in arr.iter().enumerate() {
        let int_field = |key: &str, min: f64| -> anyhow::Result<f64> {
            let f = item.req(key).map_err(|_| {
                anyhow::anyhow!("line {lineno}: attachments[{pos}] missing '{key}'")
            })?;
            let x = f.as_f64().ok_or_else(|| {
                anyhow::anyhow!(
                    "line {lineno}: attachments[{pos}].{key} is not a number (got {f})"
                )
            })?;
            // lint:allow(r3) -- fract() of an integral f64 is exactly 0.0
            if x < min || x.fract() != 0.0 || x > MAX_JSON_INT {
                anyhow::bail!(
                    "line {lineno}: attachments[{pos}].{key} is not a valid count (got {x})"
                );
            }
            Ok(x)
        };
        let hash = int_field("hash", 0.0)?;
        let tokens = int_field("tokens", 1.0)?;
        if tokens > u32::MAX as f64 {
            anyhow::bail!(
                "line {lineno}: attachments[{pos}].tokens exceeds u32 (got {tokens})"
            );
        }
        atts.push(Attachment::new(hash as u64, tokens as u32));
    }
    Ok(atts)
}

/// Parse one pool line (1-based `lineno` for error messages).
/// `att_sizes` is the cross-line hash → embedding-size registry: one
/// content hash must map to one size across the whole pool (the
/// EncoderCache dedups by hash and would otherwise serve a wrong-sized
/// embedding on the conflict).
pub(crate) fn parse_pool_line(
    line: &str,
    lineno: usize,
    att_sizes: &mut std::collections::HashMap<u64, (u32, usize)>,
) -> anyhow::Result<Request> {
    let j = Json::parse(line).map_err(|e| anyhow::anyhow!("line {lineno}: {e}"))?;
    let prompt_arr = j
        .req("prompt")
        .map_err(|e| anyhow::anyhow!("line {lineno}: {e}"))?
        .as_arr()
        .ok_or_else(|| anyhow::anyhow!("line {lineno}: prompt not an array"))?;
    // Reject malformed tokens instead of coercing them to 0: a silent
    // `unwrap_or(0.0)` corrupts the prompt AND fabricates shared
    // 0-token prefixes across every malformed request.
    let mut prompt: Vec<u32> = Vec::with_capacity(prompt_arr.len());
    for (pos, x) in prompt_arr.iter().enumerate() {
        let v = x.as_f64().ok_or_else(|| {
            anyhow::anyhow!("line {lineno}: prompt[{pos}] is not a number (got {x})")
        })?;
        // lint:allow(r3) -- fract() of an integral f64 is exactly 0.0
        if v < 0.0 || v.fract() != 0.0 || v > u32::MAX as f64 {
            anyhow::bail!("line {lineno}: prompt[{pos}] is not a valid token id (got {v})");
        }
        prompt.push(v as u32);
    }
    let id = j.get("id").and_then(|x| x.as_f64()).unwrap_or(0.0) as u32;
    // `max_tokens` may be absent (defaults to 16) but, like prompt
    // tokens, a present-but-malformed value is an error, not a 16.
    let max_tokens = match j.get("max_tokens") {
        None => 16,
        Some(v) => {
            let x = v.as_f64().ok_or_else(|| {
                anyhow::anyhow!("line {lineno}: max_tokens is not a number (got {v})")
            })?;
            // lint:allow(r3) -- fract() of an integral f64 is exactly 0.0
            if x < 0.0 || x.fract() != 0.0 || x > u32::MAX as f64 {
                anyhow::bail!(
                    "line {lineno}: max_tokens is not a valid token count (got {x})"
                );
            }
            x as u32
        }
    };
    let dataset = j
        .get("dataset")
        .and_then(|x| x.as_str())
        .unwrap_or("Custom")
        .to_string();
    let kind = kind_from_name(&dataset);
    // `known_output` may be absent (compat: derived from the dataset
    // tag) but a present non-bool is an error, not a default.
    let known_output = match j.get("known_output") {
        None => kind.default_known_output(),
        Some(v) => v.as_bool().ok_or_else(|| {
            anyhow::anyhow!("line {lineno}: known_output is not a bool (got {v})")
        })?,
    };
    let attachments = parse_attachments(&j, lineno)?;
    for (pos, a) in attachments.iter().enumerate() {
        match att_sizes.get(&a.content_hash) {
            Some(&(tokens, first_line)) if tokens != a.enc_tokens => {
                anyhow::bail!(
                    "line {lineno}: attachments[{pos}].tokens ({}) conflicts with hash {} \
                     first seen at line {first_line} with {tokens} tokens",
                    a.enc_tokens,
                    a.content_hash
                );
            }
            Some(_) => {}
            None => {
                att_sizes.insert(a.content_hash, (a.enc_tokens, lineno));
            }
        }
    }
    Ok(
        Request::with_known_output(id, kind, prompt, max_tokens, known_output)
            .with_attachments(attachments),
    )
}

/// Incremental content-line reader shared by the strict/tolerant pool
/// loaders and the streaming [`crate::stream::StreamSource`]: yields one
/// non-blank line at a time with its 1-based line number, never
/// materializing the file.  One content line of lookahead (blank lines
/// are skipped eagerly on both sides) makes `is_last` exact, which is
/// what lets the tolerant loader forgive exactly a torn FINAL line even
/// when trailing blank lines follow it.
pub(crate) struct LineSource<R: BufRead> {
    lines: std::iter::Enumerate<std::io::Lines<R>>,
    /// Pre-fetched next content line: `(1-based lineno, text)`.
    pending: Option<(usize, String)>,
    primed: bool,
}

impl<R: BufRead> LineSource<R> {
    pub(crate) fn new(reader: R) -> Self {
        LineSource { lines: reader.lines().enumerate(), pending: None, primed: false }
    }

    /// Pull the next non-blank line from the underlying reader.
    fn pull(&mut self) -> std::io::Result<Option<(usize, String)>> {
        for (idx, line) in self.lines.by_ref() {
            let line = line?;
            if !line.trim().is_empty() {
                return Ok(Some((idx + 1, line)));
            }
        }
        Ok(None)
    }

    /// Next content line as `(lineno, text, is_last)`; `is_last` means no
    /// further content line follows (trailing blanks don't count) and
    /// `lineno` is 1-based over *all* lines, blank ones included.
    pub(crate) fn next_content(&mut self) -> std::io::Result<Option<(usize, String, bool)>> {
        if !self.primed {
            self.pending = self.pull()?;
            self.primed = true;
        }
        let Some((lineno, line)) = self.pending.take() else {
            return Ok(None);
        };
        self.pending = self.pull()?;
        Ok(Some((lineno, line, self.pending.is_none())))
    }
}

fn load_jsonl_inner(path: &Path, tolerant: bool) -> anyhow::Result<(Workload, usize)> {
    let file = std::fs::File::open(path)?;
    let mut src = LineSource::new(std::io::BufReader::new(file));
    let mut requests = Vec::new();
    let mut att_sizes: std::collections::HashMap<u64, (u32, usize)> =
        std::collections::HashMap::new();
    let mut truncated = 0usize;
    while let Some((lineno, line, is_last)) = src.next_content()? {
        match parse_pool_line(&line, lineno, &mut att_sizes) {
            Ok(req) => requests.push(req),
            // Tolerant mode forgives exactly the tail a crash can tear: a
            // writer interrupted mid-append leaves at most one partial
            // FINAL line.  A malformed line anywhere earlier is
            // corruption, not a torn tail, and still errors.
            Err(e) => {
                if tolerant && is_last {
                    truncated = 1;
                    break;
                }
                return Err(e);
            }
        }
    }
    Ok((
        Workload::new(
            path.file_stem().and_then(|s| s.to_str()).unwrap_or("pool"),
            requests,
        ),
        truncated,
    ))
}

/// Load a JSONL pool file into a workload (strict: any malformed line is
/// an error naming the line and position).
pub fn load_jsonl(path: &Path) -> anyhow::Result<Workload> {
    let (w, _) = load_jsonl_inner(path, false)?;
    Ok(w)
}

/// Tolerant variant for resume-path inputs produced by a possibly
/// crash-interrupted writer: a malformed FINAL line is dropped and
/// counted (returned as `truncated_records`, 0 or 1) instead of failing
/// the load.  Earlier malformed lines still error — only the tail of an
/// append-only file can be torn by a crash.  Non-resume inputs should
/// keep using the strict [`load_jsonl`].
pub fn load_jsonl_tolerant(path: &Path) -> anyhow::Result<(Workload, usize)> {
    load_jsonl_inner(path, true)
}

fn tmp_sibling(path: &Path) -> PathBuf {
    let mut os = path.as_os_str().to_os_string();
    os.push(".tmp");
    PathBuf::from(os)
}

/// Crash-safe file replace: stream into a `.tmp` sibling, flush, then
/// rename onto the target.  The rename is atomic on POSIX filesystems,
/// so a crash at any point leaves either the old file or the new one —
/// never a half-written result a later resume would misread.  A failed
/// write removes the sibling instead of leaving it behind.
fn write_atomic(
    path: &Path,
    write: impl FnOnce(&mut BufWriter<std::fs::File>) -> anyhow::Result<()>,
) -> anyhow::Result<()> {
    let tmp = tmp_sibling(path);
    let res: anyhow::Result<()> = (|| {
        // lint:allow(r4) -- this IS write_atomic: it creates the tmp sibling
        let file = std::fs::File::create(&tmp)?;
        let mut out = BufWriter::new(file);
        write(&mut out)?;
        out.flush()?;
        Ok(())
    })();
    if let Err(e) = res {
        std::fs::remove_file(&tmp).ok();
        return Err(e);
    }
    std::fs::rename(&tmp, path)?;
    Ok(())
}

/// Write a workload out as a JSONL pool file (used by `blendserve synth`).
/// Crash-safe: the file appears atomically via a `.tmp` sibling.
pub fn save_jsonl(w: &Workload, path: &Path) -> anyhow::Result<()> {
    write_atomic(path, |out| save_jsonl_to(w, out))
}

fn save_jsonl_to(w: &Workload, out: &mut BufWriter<std::fs::File>) -> anyhow::Result<()> {
    for r in &w.requests {
        let mut fields = vec![
            ("id", Json::from(r.id as usize)),
            (
                "prompt",
                Json::Arr(r.prompt.iter().map(|&t| Json::from(t as usize)).collect()),
            ),
            ("max_tokens", Json::from(r.output_len as usize)),
            ("dataset", Json::from(r.dataset.name())),
        ];
        // Written only when they deviate from the parse-time defaults, so
        // text-only pools from older sessions stay byte-stable.
        if r.known_output != r.dataset.default_known_output() {
            fields.push(("known_output", Json::from(r.known_output)));
        }
        if !r.modality.is_empty() {
            let mut atts = Vec::with_capacity(r.modality.attachments.len());
            for a in &r.modality.attachments {
                // The JSON number channel is exact only to 2^53; a real
                // 64-bit hash would round-trip corrupted (and could
                // collapse distinct media onto one rounded hash).
                if a.content_hash as f64 > MAX_JSON_INT {
                    anyhow::bail!(
                        "request {}: content hash {} exceeds the JSONL-exact range \
                         (<= 9e15); fold your hasher output, e.g. `h % (1 << 53)`",
                        r.id,
                        a.content_hash
                    );
                }
                atts.push(Json::obj(vec![
                    ("hash", Json::from(a.content_hash as usize)),
                    ("tokens", Json::from(a.enc_tokens as usize)),
                ]));
            }
            fields.push(("attachments", Json::Arr(atts)));
        }
        let j = Json::obj(fields);
        writeln!(out, "{j}")?;
    }
    Ok(())
}

/// Write a job summary + per-replica stats as JSON.  Crash-safe via the
/// same `.tmp`-sibling + atomic-rename scheme as [`save_jsonl`].
pub fn save_results(outputs: &[RunOutput], path: &Path) -> anyhow::Result<()> {
    let replicas: Vec<Json> = outputs
        .iter()
        .map(|o| {
            Json::obj(vec![
                ("system", Json::from(o.system.as_str())),
                ("total_time_s", Json::Num(o.result.total_time)),
                ("throughput_tok_s", Json::Num(o.result.throughput)),
                ("steps", Json::from(o.result.steps as usize)),
                ("sharing_achieved", Json::Num(o.result.sharing_achieved)),
                ("optimal_sharing", Json::Num(o.optimal_sharing)),
                ("optimal_fraction", Json::Num(o.optimal_fraction)),
                (
                    "makespan_lower_bound_s",
                    Json::Num(o.makespan_lower_bound),
                ),
                ("optimality_gap", Json::Num(o.optimality_gap)),
                ("retractions", Json::from(o.result.retractions as usize)),
                (
                    "recomputed_tokens",
                    Json::from(o.result.recomputed_tokens as usize),
                ),
                (
                    "swapped_out_tokens",
                    Json::from(o.result.swapped_out_tokens as usize),
                ),
                (
                    "recompute_saved_tokens",
                    Json::from(o.result.recompute_saved_tokens as usize),
                ),
                ("link_busy_frac", Json::Num(o.result.link_busy_frac)),
                ("encode_time_s", Json::Num(o.result.encode_time)),
                (
                    "encode_overlap_frac",
                    Json::Num(o.result.encode_overlap_frac),
                ),
                (
                    "embed_cache_hit_tokens",
                    Json::from(o.result.embed_cache_hit_tokens as usize),
                ),
                ("windows", Json::from(o.result.windows as usize)),
                (
                    "peak_resident_requests",
                    Json::from(o.result.peak_resident_requests),
                ),
                (
                    "cross_window_hit_tokens",
                    Json::from(o.result.cross_window_hit_tokens as usize),
                ),
                // Surface series-cap truncation instead of letting a
                // partial roofline timeline masquerade as a full one
                // (DESIGN.md §15).
                ("series_truncated", Json::from(o.result.series_truncated)),
                (
                    "series_dropped",
                    Json::from(o.result.series_dropped as usize),
                ),
                (
                    "metrics",
                    crate::obs::metrics_report(&o.result).to_json(),
                ),
            ])
        })
        .collect();
    let doc = Json::obj(vec![("replicas", Json::Arr(replicas))]);
    write_atomic(path, |out| {
        write!(out, "{doc}")?;
        Ok(())
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::generators::generate_kind;

    /// Every TraceKind variant — one list for both exhaustive tests below.
    const ALL_KINDS: [TraceKind; 9] = [
        TraceKind::ShareGpt,
        TraceKind::WildChat,
        TraceKind::AzureTrace,
        TraceKind::BurstGpt,
        TraceKind::OpenVid,
        TraceKind::Mmlu,
        TraceKind::Limo,
        TraceKind::VisionArena,
        TraceKind::Custom,
    ];

    #[test]
    fn jsonl_roundtrip_every_trace_kind() {
        // Exhaustive TraceKind ⇄ name coverage: every kind must survive
        // save → load with its dataset tag, `known_output` semantics and
        // modality profile intact.  VisionArena rides with attachments;
        // Custom covers both hand-built text and the video-gen generator
        // (Custom tag + explicit known_output + conditioning clip).
        let dir = std::env::temp_dir().join("blendserve_pool_test");
        std::fs::create_dir_all(&dir).unwrap();
        for kind in ALL_KINDS {
            let w = match kind {
                // Hand-built text plus generated video-gen (the
                // known_output-on-Custom case).
                TraceKind::Custom => {
                    let mut reqs: Vec<crate::trace::Request> = (0..5)
                        .map(|i| {
                            crate::trace::Request::new(
                                i,
                                TraceKind::Custom,
                                vec![i, i + 1, i + 2],
                                4 + i,
                            )
                        })
                        .collect();
                    reqs.extend(
                        crate::trace::generators::generate_video_gen(10, 3).requests,
                    );
                    crate::trace::Workload::new("custom", reqs)
                }
                TraceKind::VisionArena => {
                    crate::trace::generators::generate_vision_arena(25, 3, 0.3)
                }
                k => generate_kind(k, 25, 3),
            };
            let path = dir.join(format!("pool_{}.jsonl", kind.name()));
            save_jsonl(&w, &path).unwrap();
            let back = load_jsonl(&path).unwrap();
            assert_eq!(back.len(), w.len(), "{kind}");
            for (a, b) in w.requests.iter().zip(&back.requests) {
                assert_eq!(a.prompt, b.prompt, "{kind}");
                assert_eq!(a.output_len, b.output_len, "{kind}");
                assert_eq!(a.dataset, b.dataset, "{kind}");
                assert_eq!(a.known_output, b.known_output, "{kind}");
                assert_eq!(a.modality, b.modality, "{kind}");
            }
        }
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn kind_names_roundtrip_through_parser() {
        for kind in ALL_KINDS {
            assert_eq!(kind_from_name(kind.name()), kind);
        }
        // Unknown tags degrade to Custom rather than erroring.
        assert_eq!(kind_from_name("SomeFutureTrace"), TraceKind::Custom);
    }

    #[test]
    fn load_rejects_bad_lines() {
        let dir = std::env::temp_dir().join("blendserve_pool_bad");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("bad.jsonl");
        std::fs::write(&path, "{\"id\": 1}\n").unwrap(); // missing prompt
        assert!(load_jsonl(&path).is_err());
        std::fs::write(&path, "not json\n").unwrap();
        assert!(load_jsonl(&path).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn load_rejects_malformed_prompt_token_with_line_number() {
        // Regression: a non-numeric token used to be coerced to 0,
        // silently corrupting the prompt and fabricating a shared 0-token
        // prefix across every malformed request.
        let dir = std::env::temp_dir().join("blendserve_pool_badtok");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("tok.jsonl");
        std::fs::write(
            &path,
            "{\"id\":1,\"prompt\":[1,2,3],\"max_tokens\":4}\n\
             {\"id\":2,\"prompt\":[4,\"oops\",6],\"max_tokens\":4}\n",
        )
        .unwrap();
        let err = load_jsonl(&path).unwrap_err().to_string();
        assert!(err.contains("line 2"), "no line number in: {err}");
        assert!(err.contains("prompt[1]"), "no token position in: {err}");

        // Negative and fractional ids are equally invalid.
        std::fs::write(&path, "{\"id\":1,\"prompt\":[1,-7],\"max_tokens\":4}\n").unwrap();
        let err = load_jsonl(&path).unwrap_err().to_string();
        assert!(err.contains("line 1"), "no line number in: {err}");
        std::fs::write(&path, "{\"id\":1,\"prompt\":[1.5],\"max_tokens\":4}\n").unwrap();
        assert!(load_jsonl(&path).is_err());

        // max_tokens: absent defaults, but malformed errors with a line.
        std::fs::write(&path, "{\"id\":1,\"prompt\":[1,2]}\n").unwrap();
        assert_eq!(load_jsonl(&path).unwrap().requests[0].output_len, 16);
        std::fs::write(
            &path,
            "{\"id\":1,\"prompt\":[1,2],\"max_tokens\":\"oops\"}\n",
        )
        .unwrap();
        let err = load_jsonl(&path).unwrap_err().to_string();
        assert!(err.contains("line 1") && err.contains("max_tokens"), "{err}");
        std::fs::write(&path, "{\"id\":1,\"prompt\":[1,2],\"max_tokens\":-4}\n").unwrap();
        assert!(load_jsonl(&path).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn attachments_absent_present_and_malformed() {
        let dir = std::env::temp_dir().join("blendserve_pool_att");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("att.jsonl");

        // Absent: old-format lines parse to an empty modality profile.
        std::fs::write(&path, "{\"id\":1,\"prompt\":[1,2],\"max_tokens\":4}\n").unwrap();
        let w = load_jsonl(&path).unwrap();
        assert!(w.requests[0].modality.is_empty());

        // Present: parsed into the profile, hash/tokens intact.
        std::fs::write(
            &path,
            "{\"id\":1,\"prompt\":[1,2],\"max_tokens\":4,\
             \"attachments\":[{\"hash\":42,\"tokens\":576},{\"hash\":7,\"tokens\":144}]}\n",
        )
        .unwrap();
        let w = load_jsonl(&path).unwrap();
        assert_eq!(
            w.requests[0].modality.attachments,
            vec![Attachment::new(42, 576), Attachment::new(7, 144)]
        );

        // Malformed must error with line + attachment position, never
        // silently drop (the load_jsonl hardening policy).
        let cases = [
            // not an array
            ("{\"id\":1,\"prompt\":[1],\"attachments\":7}\n", "attachments"),
            // element missing a field
            (
                "{\"id\":1,\"prompt\":[1],\"attachments\":[{\"hash\":1}]}\n",
                "attachments[0]",
            ),
            // non-numeric tokens, second element
            (
                "{\"id\":1,\"prompt\":[1],\"attachments\":[{\"hash\":1,\"tokens\":2},\
                 {\"hash\":2,\"tokens\":\"oops\"}]}\n",
                "attachments[1].tokens",
            ),
            // negative hash
            (
                "{\"id\":1,\"prompt\":[1],\"attachments\":[{\"hash\":-3,\"tokens\":2}]}\n",
                "attachments[0].hash",
            ),
            // fractional tokens
            (
                "{\"id\":1,\"prompt\":[1],\"attachments\":[{\"hash\":3,\"tokens\":1.5}]}\n",
                "attachments[0].tokens",
            ),
            // zero tokens (min 1)
            (
                "{\"id\":1,\"prompt\":[1],\"attachments\":[{\"hash\":3,\"tokens\":0}]}\n",
                "attachments[0].tokens",
            ),
        ];
        for (text, want) in cases {
            std::fs::write(&path, text).unwrap();
            let err = load_jsonl(&path).unwrap_err().to_string();
            assert!(err.contains("line 1"), "no line number in: {err}");
            assert!(err.contains(want), "no position '{want}' in: {err}");
        }

        // Cross-line hash/size conflict: the same content hash cannot
        // carry two embedding sizes (the dedup cache would serve the
        // wrong one); the error names both lines.
        std::fs::write(
            &path,
            "{\"id\":1,\"prompt\":[1],\"attachments\":[{\"hash\":5,\"tokens\":100}]}\n\
             {\"id\":2,\"prompt\":[2],\"attachments\":[{\"hash\":5,\"tokens\":200}]}\n",
        )
        .unwrap();
        let err = load_jsonl(&path).unwrap_err().to_string();
        assert!(err.contains("line 2") && err.contains("line 1"), "{err}");
        assert!(err.contains("conflicts"), "{err}");
        // Consistent repeats of one hash are the dedup case and load fine.
        std::fs::write(
            &path,
            "{\"id\":1,\"prompt\":[1],\"attachments\":[{\"hash\":5,\"tokens\":100}]}\n\
             {\"id\":2,\"prompt\":[2],\"attachments\":[{\"hash\":5,\"tokens\":100}]}\n",
        )
        .unwrap();
        assert_eq!(load_jsonl(&path).unwrap().len(), 2);

        // known_output: absent derives from the tag; malformed errors.
        std::fs::write(
            &path,
            "{\"id\":1,\"prompt\":[1],\"dataset\":\"OpenVid\"}\n\
             {\"id\":2,\"prompt\":[2],\"dataset\":\"Custom\",\"known_output\":true}\n",
        )
        .unwrap();
        let w = load_jsonl(&path).unwrap();
        assert!(w.requests[0].known_output, "OpenVid compat derivation lost");
        assert!(w.requests[1].known_output, "explicit known_output dropped");
        std::fs::write(&path, "{\"id\":1,\"prompt\":[1],\"known_output\":\"yes\"}\n")
            .unwrap();
        let err = load_jsonl(&path).unwrap_err().to_string();
        assert!(err.contains("line 1") && err.contains("known_output"), "{err}");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn tolerant_load_forgives_only_a_torn_tail() {
        let dir = std::env::temp_dir().join("blendserve_pool_torn");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("torn.jsonl");

        // A crash mid-append tears the final line.  Strict load fails;
        // tolerant load drops and counts exactly that record.
        std::fs::write(
            &path,
            "{\"id\":1,\"prompt\":[1,2],\"max_tokens\":4}\n\
             {\"id\":2,\"prompt\":[3],\"max_tokens\":2}\n\
             {\"id\":3,\"prom",
        )
        .unwrap();
        assert!(load_jsonl(&path).is_err());
        let (w, truncated) = load_jsonl_tolerant(&path).unwrap();
        assert_eq!(w.len(), 2);
        assert_eq!(truncated, 1);
        assert_eq!(*w.requests[1].prompt, vec![3]);

        // Intact files report zero truncation and identical content.
        std::fs::write(
            &path,
            "{\"id\":1,\"prompt\":[1,2],\"max_tokens\":4}\n\
             {\"id\":2,\"prompt\":[3],\"max_tokens\":2}\n",
        )
        .unwrap();
        let (w, truncated) = load_jsonl_tolerant(&path).unwrap();
        assert_eq!((w.len(), truncated), (2, 0));

        // A malformed line BEFORE the tail is corruption, not a torn
        // append — tolerant mode must still error on it.
        std::fs::write(
            &path,
            "{\"id\":1,\"prompt\":[1,\"oops\"]}\n\
             {\"id\":2,\"prompt\":[3],\"max_tokens\":2}\n",
        )
        .unwrap();
        let err = load_jsonl_tolerant(&path).unwrap_err().to_string();
        assert!(err.contains("line 1"), "{err}");

        // Torn tail followed by blank lines (editor artifacts) is still
        // the last content line, hence still forgiven.
        std::fs::write(
            &path,
            "{\"id\":1,\"prompt\":[1]}\n{\"id\":2,\"pro\n\n",
        )
        .unwrap();
        let (w, truncated) = load_jsonl_tolerant(&path).unwrap();
        assert_eq!((w.len(), truncated), (1, 1));
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn atomic_save_leaves_no_tmp_sibling_and_survives_failed_writes() {
        let dir = std::env::temp_dir().join("blendserve_pool_atomic");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("out.jsonl");
        let tmp = dir.join("out.jsonl.tmp");

        let w = crate::trace::Workload::new(
            "atomic",
            vec![crate::trace::Request::new(1, TraceKind::Custom, vec![1, 2], 4)],
        );
        save_jsonl(&w, &path).unwrap();
        assert!(path.exists());
        assert!(!tmp.exists(), "tmp sibling left behind");
        assert_eq!(load_jsonl(&path).unwrap().len(), 1);

        // A failing save (hash beyond the JSONL-exact range) must leave
        // the previous file intact and clean up its sibling — that is the
        // whole point of writing through the tmp file.
        let before = std::fs::read_to_string(&path).unwrap();
        let bad = crate::trace::Workload::new(
            "bad",
            vec![crate::trace::Request::new(2, TraceKind::Custom, vec![1], 4)
                .with_attachments(vec![Attachment::new(1u64 << 60, 16)])],
        );
        assert!(save_jsonl(&bad, &path).is_err());
        assert_eq!(std::fs::read_to_string(&path).unwrap(), before);
        assert!(!tmp.exists(), "failed save left tmp sibling");

        // save_results goes through the same scheme.
        let rpath = dir.join("results.json");
        save_results(&[], &rpath).unwrap();
        assert!(rpath.exists());
        assert!(!dir.join("results.json.tmp").exists());
        assert!(std::fs::read_to_string(&rpath).unwrap().contains("replicas"));
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn blank_lines_skipped() {
        let dir = std::env::temp_dir().join("blendserve_pool_blank");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("p.jsonl");
        std::fs::write(
            &path,
            "{\"id\":1,\"prompt\":[1,2],\"max_tokens\":4}\n\n{\"id\":2,\"prompt\":[3],\"max_tokens\":2}\n",
        )
        .unwrap();
        let w = load_jsonl(&path).unwrap();
        assert_eq!(w.len(), 2);
        assert_eq!(*w.requests[1].prompt, vec![3]);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn line_numbers_count_blank_lines() {
        // The incremental LineSource must report the same 1-based line
        // numbers the materializing loader did: blank lines advance the
        // count even though they yield no content.
        let dir = std::env::temp_dir().join("blendserve_pool_lineno");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("n.jsonl");
        std::fs::write(&path, "\n\n{\"id\":1,\"prompt\":[\"x\"]}\n").unwrap();
        let err = load_jsonl(&path).unwrap_err().to_string();
        assert!(err.contains("line 3"), "wrong line number in: {err}");
        assert!(err.contains("prompt[0]"), "no token position in: {err}");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn line_source_lookahead_is_exact() {
        use std::io::Cursor;
        // Interior blanks are skipped, numbering is absolute, and
        // `is_last` fires on the final content line even when trailing
        // blank lines follow it.
        let mut src = LineSource::new(Cursor::new("a\n\nb\n\n\n"));
        assert_eq!(src.next_content().unwrap(), Some((1, "a".to_string(), false)));
        assert_eq!(src.next_content().unwrap(), Some((3, "b".to_string(), true)));
        assert_eq!(src.next_content().unwrap(), None);
        assert_eq!(src.next_content().unwrap(), None);
        // A blank-only file yields nothing.
        let mut src = LineSource::new(Cursor::new("\n  \n"));
        assert_eq!(src.next_content().unwrap(), None);
    }
}
