//! JSONL request pool: the on-disk format of the batch API.
//!
//! One request per line:
//! `{"id": 7, "prompt": [1,2,3], "max_tokens": 64, "dataset": "Custom"}`
//!
//! Results are written back as JSONL with scheduling metadata so runs are
//! auditable.

use crate::scheduler::RunOutput;
use crate::trace::{Request, TraceKind, Workload};
use crate::util::Json;
use std::io::{BufRead, BufWriter, Write};
use std::path::Path;

/// A request as read from the pool file.
#[derive(Clone, Debug, PartialEq)]
pub struct JsonlRequest {
    pub id: u32,
    pub prompt: Vec<u32>,
    pub max_tokens: u32,
    pub dataset: String,
}

fn kind_from_name(name: &str) -> TraceKind {
    match name {
        "ShareGPT" => TraceKind::ShareGpt,
        "WildChat" => TraceKind::WildChat,
        "Azure-Trace" => TraceKind::AzureTrace,
        "BurstGPT" => TraceKind::BurstGpt,
        "OpenVid" => TraceKind::OpenVid,
        "MMLU" => TraceKind::Mmlu,
        "LIMO" => TraceKind::Limo,
        _ => TraceKind::Custom,
    }
}

/// Load a JSONL pool file into a workload.
pub fn load_jsonl(path: &Path) -> anyhow::Result<Workload> {
    let file = std::fs::File::open(path)?;
    let reader = std::io::BufReader::new(file);
    let mut requests = Vec::new();
    for (lineno, line) in reader.lines().enumerate() {
        let line = line?;
        if line.trim().is_empty() {
            continue;
        }
        let j = Json::parse(&line)
            .map_err(|e| anyhow::anyhow!("line {}: {e}", lineno + 1))?;
        let prompt: Vec<u32> = j
            .req("prompt")
            .map_err(|e| anyhow::anyhow!("line {}: {e}", lineno + 1))?
            .as_arr()
            .ok_or_else(|| anyhow::anyhow!("line {}: prompt not an array", lineno + 1))?
            .iter()
            .map(|x| x.as_f64().unwrap_or(0.0) as u32)
            .collect();
        let id = j.get("id").and_then(|x| x.as_f64()).unwrap_or(0.0) as u32;
        let max_tokens = j
            .get("max_tokens")
            .and_then(|x| x.as_f64())
            .unwrap_or(16.0) as u32;
        let dataset = j
            .get("dataset")
            .and_then(|x| x.as_str())
            .unwrap_or("Custom")
            .to_string();
        requests.push(Request::new(id, kind_from_name(&dataset), prompt, max_tokens));
    }
    Ok(Workload::new(
        path.file_stem().and_then(|s| s.to_str()).unwrap_or("pool"),
        requests,
    ))
}

/// Write a workload out as a JSONL pool file (used by `blendserve synth`).
pub fn save_jsonl(w: &Workload, path: &Path) -> anyhow::Result<()> {
    let file = std::fs::File::create(path)?;
    let mut out = BufWriter::new(file);
    for r in &w.requests {
        let j = Json::obj(vec![
            ("id", Json::from(r.id as usize)),
            (
                "prompt",
                Json::Arr(r.prompt.iter().map(|&t| Json::from(t as usize)).collect()),
            ),
            ("max_tokens", Json::from(r.output_len as usize)),
            ("dataset", Json::from(r.dataset.name())),
        ]);
        writeln!(out, "{j}")?;
    }
    Ok(())
}

/// Write a job summary + per-replica stats as JSON.
pub fn save_results(outputs: &[RunOutput], path: &Path) -> anyhow::Result<()> {
    let replicas: Vec<Json> = outputs
        .iter()
        .map(|o| {
            Json::obj(vec![
                ("system", Json::from(o.system.as_str())),
                ("total_time_s", Json::Num(o.result.total_time)),
                ("throughput_tok_s", Json::Num(o.result.throughput)),
                ("steps", Json::from(o.result.steps as usize)),
                ("sharing_achieved", Json::Num(o.result.sharing_achieved)),
                ("optimal_sharing", Json::Num(o.optimal_sharing)),
                ("optimal_fraction", Json::Num(o.optimal_fraction)),
                ("retractions", Json::from(o.result.retractions as usize)),
            ])
        })
        .collect();
    let doc = Json::obj(vec![("replicas", Json::Arr(replicas))]);
    std::fs::write(path, doc.to_string())?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::generators::generate_kind;

    #[test]
    fn jsonl_roundtrip() {
        let w = generate_kind(TraceKind::Mmlu, 25, 3);
        let dir = std::env::temp_dir().join("blendserve_pool_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("pool.jsonl");
        save_jsonl(&w, &path).unwrap();
        let back = load_jsonl(&path).unwrap();
        assert_eq!(back.len(), w.len());
        for (a, b) in w.requests.iter().zip(&back.requests) {
            assert_eq!(a.prompt, b.prompt);
            assert_eq!(a.output_len, b.output_len);
            assert_eq!(a.dataset, b.dataset);
        }
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn load_rejects_bad_lines() {
        let dir = std::env::temp_dir().join("blendserve_pool_bad");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("bad.jsonl");
        std::fs::write(&path, "{\"id\": 1}\n").unwrap(); // missing prompt
        assert!(load_jsonl(&path).is_err());
        std::fs::write(&path, "not json\n").unwrap();
        assert!(load_jsonl(&path).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn blank_lines_skipped() {
        let dir = std::env::temp_dir().join("blendserve_pool_blank");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("p.jsonl");
        std::fs::write(
            &path,
            "{\"id\":1,\"prompt\":[1,2],\"max_tokens\":4}\n\n{\"id\":2,\"prompt\":[3],\"max_tokens\":2}\n",
        )
        .unwrap();
        let w = load_jsonl(&path).unwrap();
        assert_eq!(w.len(), 2);
        assert_eq!(*w.requests[1].prompt, vec![3]);
        std::fs::remove_dir_all(&dir).ok();
    }
}
