//! Chrome-trace / Perfetto JSON export of recorded [`TraceData`]
//! streams, plus the inverse: a parser + aggregator for the
//! `blendserve trace` summarizer.
//!
//! Format: the Trace Event Format's JSON object flavor —
//! `{"traceEvents": [...], "displayTimeUnit": "ms"}` — loadable in
//! `ui.perfetto.dev` or `chrome://tracing`.  Mapping:
//!
//! - one *process* per replica (`pid` = replica id) with a named
//!   `engine` thread carrying the lifecycle slices;
//! - every lifecycle event is a zero-duration complete slice
//!   (`"ph":"X"`) named after its [`TraceEvent`] variant, with the
//!   typed payload in `args` (plus the engine step);
//! - request-bearing events additionally emit flow arrows
//!   (`"ph":"s"/"t"/"f"`, `id` = request id), so one request's journey
//!   — admit, chunked prefill, retract, swap out/in, steal to another
//!   replica, finish — renders as a connected arc across tracks;
//! - per-step counter samples become counter tracks (`"ph":"C"`):
//!   `kv_used`, `rho` (live compute density `t_comp/t_mem` of the
//!   wave), `link_backlog`, `encode_overlap`.
//!
//! Timestamps are the simulated clock in microseconds (the format's
//! native unit).  Export is deterministic: record order is the emission
//! order, every map is a sorted [`Json::obj`], and floats print with
//! Rust's shortest round-trip formatting — two runs of the same
//! scenario serialize byte-identically.

use super::{TraceData, TraceEvent};
use crate::util::Json;
use std::collections::{BTreeMap, BTreeSet};

/// Simulated seconds → Trace Event Format microseconds.
fn us(t: f64) -> Json {
    Json::Num(t * 1e6)
}

fn meta(pid: u32, name: &str, arg: &str) -> Json {
    Json::obj(vec![
        ("args", Json::obj(vec![("name", Json::from(arg))])),
        ("name", Json::from(name)),
        ("ph", Json::from("M")),
        ("pid", Json::from(pid as usize)),
        ("tid", Json::from(0usize)),
        ("ts", Json::from(0usize)),
    ])
}

fn counter(pid: u32, ts: f64, name: &str, value: f64) -> Json {
    Json::obj(vec![
        ("args", Json::obj(vec![("value", Json::Num(value))])),
        ("name", Json::from(name)),
        ("ph", Json::from("C")),
        ("pid", Json::from(pid as usize)),
        ("ts", us(ts)),
    ])
}

/// Export one or more recorded streams (single engine, or every fleet
/// replica plus the coordinator) as one Perfetto-loadable document.
pub fn export(traces: &[&TraceData], label: &str) -> Json {
    let mut events: Vec<Json> = Vec::new();
    let mut dropped = 0u64;
    for tr in traces {
        events.push(meta(tr.replica, "process_name", &format!("replica {}", tr.replica)));
        events.push(meta(tr.replica, "thread_name", "engine"));
        dropped += tr.dropped;
    }
    // Flow phase per request: "s" on its first record anywhere, "f" on
    // Finish, "t" between.  BTreeSet for determinism discipline (the
    // set is membership-only, but keep obs/ HashMap-free wholesale).
    let mut seen: BTreeSet<u32> = BTreeSet::new();
    for tr in traces {
        for r in &tr.events {
            let args = match r.ev.args() {
                Json::Obj(mut m) => {
                    m.insert("step".into(), Json::from(r.step as usize));
                    Json::Obj(m)
                }
                other => other,
            };
            events.push(Json::obj(vec![
                ("args", args),
                ("cat", Json::from("lifecycle")),
                ("dur", Json::from(0usize)),
                ("name", Json::from(r.ev.name())),
                ("ph", Json::from("X")),
                ("pid", Json::from(r.replica as usize)),
                ("tid", Json::from(0usize)),
                ("ts", us(r.t)),
            ]));
            if let Some(req) = r.ev.req() {
                let ph = if seen.insert(req) {
                    "s"
                } else if matches!(r.ev, TraceEvent::Finish { .. }) {
                    "f"
                } else {
                    "t"
                };
                let mut flow = vec![
                    ("cat", Json::from("req")),
                    ("id", Json::from(req as usize)),
                    ("name", Json::from(format!("req {req}").as_str())),
                    ("ph", Json::from(ph)),
                    ("pid", Json::from(r.replica as usize)),
                    ("tid", Json::from(0usize)),
                    ("ts", us(r.t)),
                ];
                if ph == "f" {
                    // Bind the terminating arrow to the enclosing slice.
                    flow.push(("bp", Json::from("e")));
                }
                events.push(Json::obj(flow));
            }
        }
        for c in &tr.counters {
            events.push(counter(c.replica, c.t, "kv_used", c.kv_used));
            let rho = if c.t_mem > 0.0 { c.t_comp / c.t_mem } else { 0.0 };
            events.push(counter(c.replica, c.t, "rho", rho));
            events.push(counter(c.replica, c.t, "link_backlog", c.link_backlog));
            events.push(counter(c.replica, c.t, "encode_overlap", c.encode_overlap));
        }
    }
    Json::obj(vec![
        ("displayTimeUnit", Json::from("ms")),
        (
            "otherData",
            Json::obj(vec![
                ("dropped_records", Json::from(dropped as usize)),
                ("label", Json::from(label)),
            ]),
        ),
        ("traceEvents", Json::Arr(events)),
    ])
}

/// Aggregated view of an exported trace file — what the
/// `blendserve trace --summary` table renders.  All vectors are sorted
/// (counts by name; top-k descending by value, ties by request id) so
/// rendering is deterministic.
#[derive(Clone, Debug, Default)]
pub struct TraceSummary {
    /// (event name, occurrences) over every lifecycle slice.
    pub counts: Vec<(String, u64)>,
    /// Records the exporter reported dropped at the cap.
    pub dropped: u64,
    /// Top-k requests by discarded-progress tokens (non-swapped
    /// retractions — the recompute waste).
    pub top_recompute: Vec<(u32, u64)>,
    /// Top-k requests by first-admission queue delay, seconds.
    pub top_wait: Vec<(u32, f64)>,
    /// Top-k requests by swap traffic (swap-out + swap-in tokens).
    pub top_swap: Vec<(u32, u64)>,
}

fn top_k<V: PartialOrd + Copy>(m: BTreeMap<u32, V>, k: usize) -> Vec<(u32, V)> {
    let mut v: Vec<(u32, V)> = m.into_iter().collect();
    v.sort_by(|a, b| {
        b.1.partial_cmp(&a.1)
            .expect("finite aggregate")
            .then(a.0.cmp(&b.0))
    });
    v.truncate(k);
    v
}

/// Parse an exported trace document and aggregate the triage signals.
/// Accepts exactly what [`export`] writes; unknown events are counted
/// but otherwise ignored, so the summary survives schema growth.
pub fn summarize(doc: &Json, k: usize) -> anyhow::Result<TraceSummary> {
    let events = doc
        .get("traceEvents")
        .and_then(|e| e.as_arr())
        .ok_or_else(|| anyhow::anyhow!("trace file has no traceEvents array"))?;
    let dropped = doc
        .get("otherData")
        .and_then(|o| o.get("dropped_records"))
        .and_then(|d| d.as_f64())
        .unwrap_or(0.0) as u64;
    let mut counts: BTreeMap<String, u64> = BTreeMap::new();
    let mut recompute: BTreeMap<u32, u64> = BTreeMap::new();
    let mut wait: BTreeMap<u32, f64> = BTreeMap::new();
    let mut swap: BTreeMap<u32, u64> = BTreeMap::new();
    for e in events {
        if e.get("ph").and_then(|p| p.as_str()) != Some("X") {
            continue;
        }
        let name = e
            .get("name")
            .and_then(|n| n.as_str())
            .ok_or_else(|| anyhow::anyhow!("lifecycle slice without a name"))?;
        *counts.entry(name.to_string()).or_insert(0) += 1;
        let arg = |key: &str| e.get("args").and_then(|a| a.get(key)).and_then(|v| v.as_f64());
        let Some(req) = arg("req").map(|r| r as u32) else { continue };
        match name {
            "Retract" => {
                let swapped = e
                    .get("args")
                    .and_then(|a| a.get("swapped"))
                    .and_then(|s| s.as_bool())
                    .unwrap_or(false);
                if !swapped {
                    *recompute.entry(req).or_insert(0) += arg("tokens").unwrap_or(0.0) as u64;
                }
            }
            "Admit" => {
                let w = wait.entry(req).or_insert(0.0);
                *w = w.max(arg("wait_s").unwrap_or(0.0));
            }
            "SwapOut" | "SwapIn" => {
                *swap.entry(req).or_insert(0) += arg("tokens").unwrap_or(0.0) as u64;
            }
            _ => {}
        }
    }
    Ok(TraceSummary {
        counts: counts.into_iter().collect(),
        dropped,
        top_recompute: top_k(recompute, k),
        top_wait: top_k(wait, k),
        top_swap: top_k(swap, k),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::CounterSample;

    fn sample_trace() -> Box<TraceData> {
        let mut tr = TraceData::new(0);
        tr.emit(0.0, 0, TraceEvent::Admit { req: 1, hit_tokens: 4, new_tokens: 6, wait: 0.25 });
        tr.emit(0.0, 0, TraceEvent::ChunkPrefill { req: 1, tokens: 6 });
        tr.emit(1.0, 3, TraceEvent::Retract { req: 1, tokens: 9, swapped: true });
        tr.emit(1.0, 3, TraceEvent::SwapOut { req: 1, tokens: 9 });
        tr.emit(2.0, 5, TraceEvent::Readmit { req: 1, restored_tokens: 9 });
        tr.emit(2.0, 5, TraceEvent::SwapIn { req: 1, tokens: 9 });
        tr.emit(3.0, 9, TraceEvent::Finish { req: 1 });
        tr.emit(0.5, 1, TraceEvent::Admit { req: 2, hit_tokens: 0, new_tokens: 3, wait: 0.5 });
        tr.emit(1.5, 4, TraceEvent::Retract { req: 2, tokens: 5, swapped: false });
        tr.emit(2.5, 7, TraceEvent::Readmit { req: 2, restored_tokens: 0 });
        tr.emit(3.5, 11, TraceEvent::Finish { req: 2 });
        tr.sample(CounterSample {
            t: 1.0,
            step: 3,
            replica: 0,
            kv_used: 128.0,
            t_comp: 0.3,
            t_mem: 0.2,
            link_backlog: 0.05,
            encode_overlap: 0.0,
        });
        tr
    }

    #[test]
    fn export_is_loadable_shape_and_deterministic() {
        let tr = sample_trace();
        let a = export(&[&tr], "test").to_string();
        let b = export(&[&tr], "test").to_string();
        assert_eq!(a, b, "export is not byte-deterministic");
        let doc = Json::parse(&a).expect("export emits parseable JSON");
        let events = doc.get("traceEvents").and_then(|e| e.as_arr()).unwrap();
        // 2 metadata + 11 slices + 11 flows + 4 counters.
        assert_eq!(events.len(), 2 + 11 + 11 + 4);
        // Flow phases: first record of a request opens, Finish closes.
        let phases: Vec<String> = events
            .iter()
            .filter(|e| e.get("cat").and_then(|c| c.as_str()) == Some("req"))
            .map(|e| e.get("ph").unwrap().as_str().unwrap().to_string())
            .collect();
        assert_eq!(phases, ["s", "t", "t", "t", "t", "t", "f", "s", "t", "t", "f"]);
        // Counter tracks present with µs timestamps.
        let kv = events
            .iter()
            .find(|e| e.get("name").and_then(|n| n.as_str()) == Some("kv_used"))
            .expect("kv_used counter");
        assert_eq!(kv.get("ts").unwrap().as_f64().unwrap(), 1e6);
        assert_eq!(
            kv.get("args").unwrap().get("value").unwrap().as_f64().unwrap(),
            128.0
        );
    }

    #[test]
    fn summarize_aggregates_waste_wait_and_swap() {
        let tr = sample_trace();
        let doc = export(&[&tr], "test");
        let s = summarize(&doc, 5).unwrap();
        assert_eq!(s.dropped, 0);
        let count = |name: &str| {
            s.counts
                .iter()
                .find(|(n, _)| n == name)
                .map(|(_, c)| *c)
                .unwrap_or(0)
        };
        assert_eq!(count("Admit"), 2);
        assert_eq!(count("Retract"), 2);
        assert_eq!(count("Finish"), 2);
        // Request 2 discarded 5 tokens; request 1 swapped instead.
        assert_eq!(s.top_recompute, vec![(2, 5)]);
        // Request 1 moved 18 tokens over the link.
        assert_eq!(s.top_swap, vec![(1, 18)]);
        // Waits: req 2 waited longer.
        assert_eq!(s.top_wait[0].0, 2);
        assert_eq!(s.top_wait[0].1, 0.5);
        // k truncates.
        assert_eq!(summarize(&doc, 1).unwrap().top_wait.len(), 1);
    }

    #[test]
    fn summarize_rejects_non_trace_documents() {
        assert!(summarize(&Json::parse("{}").unwrap(), 3).is_err());
    }
}
