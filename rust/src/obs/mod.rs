//! Deterministic observability layer (DESIGN.md §15): typed
//! request-lifecycle events and per-step counter samples recorded in
//! *simulated* time.
//!
//! The whole layer is a passive observer behind a zero-cost-when-off
//! handle: the engine carries an `Option<Box<TraceData>>` and every
//! emission site is an `if let Some(..)` that never touches the clock,
//! the RNG-free schedule state, or any counter the run already keeps —
//! so trace-disabled runs are bit-identical to pre-tracing behavior, and
//! trace-enabled runs are bit-identical to *each other* (pinned by
//! `tests/trace_determinism.rs`).  Events are stamped with the simulated
//! clock, the engine step index, and a replica id; there is no wall time
//! anywhere in this module (lint r2-clean by construction).
//!
//! Truthfulness: the trace is not parallel bookkeeping that can drift.
//! The swap counters are bumped *through* the same call that emits the
//! swap event ([`crate::kv::KvRunState::note_swap_out`]/`note_swap_in`),
//! and `EngineAuditor::check_final` replays the recorded stream against
//! the final `SimResult` — every `Finish` exactly once, Σ swap-event
//! tokens == the swap counters, retraction/window counts equal — so a
//! trace that disagrees with the result is a test failure, not a
//! footnote.
//!
//! Capacity: recording is bounded by [`EVENT_CAP`] per stream.  The cap
//! is never silent — beyond-cap records increment `dropped`, the auditor
//! skips (and logs) reconciliation for incomplete streams, and the
//! exporter stamps the drop count into the trace metadata.

pub mod metrics;
pub mod perfetto;

pub use metrics::{metrics_report, ChurnWindow, MetricsReport, SharingPoint};

use crate::util::Json;

/// Typed request-lifecycle event.  Payloads are simulated-time
/// quantities only (token counts, simulated seconds); the stamp lives on
/// the enclosing [`TraceRecord`].
#[derive(Clone, Debug, PartialEq)]
pub enum TraceEvent {
    /// First admission of a request into the running batch.  `wait` is
    /// the simulated queue delay (admit clock − arrival); `hit_tokens`
    /// of the prompt came from the radix cache, `new_tokens` must be
    /// prefilled.
    Admit { req: u32, hit_tokens: u64, new_tokens: u64, wait: f64 },
    /// Re-admission of a previously retracted request.
    /// `restored_tokens` is the KV extent a swap restore brought back
    /// (0 on the discard-and-recompute path).
    Readmit { req: u32, restored_tokens: u64 },
    /// Prefill chunk scheduled for one request in one engine step.
    ChunkPrefill { req: u32, tokens: u64 },
    /// Encoder work drained for one request's attachments this step;
    /// `overlapped` says whether it hid under the decode bubble or ran
    /// on dedicated (serialized) encoder time.
    EncodePass { req: u32, secs: f64, overlapped: bool },
    /// A running request was evicted from the batch under KV pressure
    /// or SLO urgency; `tokens` is the KV extent it held, `swapped`
    /// whether that extent went to host (else it is discarded and
    /// recomputed at re-admission).
    Retract { req: u32, tokens: u64, swapped: bool },
    /// KV extent moved HBM → host across the link.
    SwapOut { req: u32, tokens: u64 },
    /// KV extent restored host → HBM across the link.
    SwapIn { req: u32, tokens: u64 },
    /// Fleet coordinator moved `n_requests` queued requests from
    /// replica `victim` to replica `thief`.
    Steal { victim: u32, thief: u32, n_requests: u64 },
    /// Fault injection killed a fleet replica.
    ReplicaDeath { replica: u32 },
    /// A previously dead replica rejoined the fleet.
    Rejoin { replica: u32 },
    /// A streaming-ingest window was fed into the persistent engine.
    WindowFeed { window: u64, n_requests: u64 },
    /// A request produced its last token.
    Finish { req: u32 },
}

impl TraceEvent {
    /// Stable variant name — the Perfetto event name and the key the
    /// summarizer aggregates on.
    pub fn name(&self) -> &'static str {
        match self {
            TraceEvent::Admit { .. } => "Admit",
            TraceEvent::Readmit { .. } => "Readmit",
            TraceEvent::ChunkPrefill { .. } => "ChunkPrefill",
            TraceEvent::EncodePass { .. } => "EncodePass",
            TraceEvent::Retract { .. } => "Retract",
            TraceEvent::SwapOut { .. } => "SwapOut",
            TraceEvent::SwapIn { .. } => "SwapIn",
            TraceEvent::Steal { .. } => "Steal",
            TraceEvent::ReplicaDeath { .. } => "ReplicaDeath",
            TraceEvent::Rejoin { .. } => "Rejoin",
            TraceEvent::WindowFeed { .. } => "WindowFeed",
            TraceEvent::Finish { .. } => "Finish",
        }
    }

    /// The request id the event is about, when it is about one (fleet
    /// coordinator and window events are not).  Drives the per-request
    /// flow arrows in the Perfetto export.
    pub fn req(&self) -> Option<u32> {
        match *self {
            TraceEvent::Admit { req, .. }
            | TraceEvent::Readmit { req, .. }
            | TraceEvent::ChunkPrefill { req, .. }
            | TraceEvent::EncodePass { req, .. }
            | TraceEvent::Retract { req, .. }
            | TraceEvent::SwapOut { req, .. }
            | TraceEvent::SwapIn { req, .. }
            | TraceEvent::Finish { req } => Some(req),
            TraceEvent::Steal { .. }
            | TraceEvent::ReplicaDeath { .. }
            | TraceEvent::Rejoin { .. }
            | TraceEvent::WindowFeed { .. } => None,
        }
    }

    /// Payload as a deterministic JSON object (sorted keys via
    /// [`Json::obj`]) — the `args` of the exported Perfetto event.
    pub fn args(&self) -> Json {
        match *self {
            TraceEvent::Admit { req, hit_tokens, new_tokens, wait } => Json::obj(vec![
                ("req", Json::from(req as usize)),
                ("hit_tokens", Json::from(hit_tokens as usize)),
                ("new_tokens", Json::from(new_tokens as usize)),
                ("wait_s", Json::Num(wait)),
            ]),
            TraceEvent::Readmit { req, restored_tokens } => Json::obj(vec![
                ("req", Json::from(req as usize)),
                ("restored_tokens", Json::from(restored_tokens as usize)),
            ]),
            TraceEvent::ChunkPrefill { req, tokens } => Json::obj(vec![
                ("req", Json::from(req as usize)),
                ("tokens", Json::from(tokens as usize)),
            ]),
            TraceEvent::EncodePass { req, secs, overlapped } => Json::obj(vec![
                ("req", Json::from(req as usize)),
                ("secs", Json::Num(secs)),
                ("overlapped", Json::from(overlapped)),
            ]),
            TraceEvent::Retract { req, tokens, swapped } => Json::obj(vec![
                ("req", Json::from(req as usize)),
                ("tokens", Json::from(tokens as usize)),
                ("swapped", Json::from(swapped)),
            ]),
            TraceEvent::SwapOut { req, tokens } => Json::obj(vec![
                ("req", Json::from(req as usize)),
                ("tokens", Json::from(tokens as usize)),
            ]),
            TraceEvent::SwapIn { req, tokens } => Json::obj(vec![
                ("req", Json::from(req as usize)),
                ("tokens", Json::from(tokens as usize)),
            ]),
            TraceEvent::Steal { victim, thief, n_requests } => Json::obj(vec![
                ("victim", Json::from(victim as usize)),
                ("thief", Json::from(thief as usize)),
                ("n_requests", Json::from(n_requests as usize)),
            ]),
            TraceEvent::ReplicaDeath { replica } => {
                Json::obj(vec![("replica", Json::from(replica as usize))])
            }
            TraceEvent::Rejoin { replica } => {
                Json::obj(vec![("replica", Json::from(replica as usize))])
            }
            TraceEvent::WindowFeed { window, n_requests } => Json::obj(vec![
                ("window", Json::from(window as usize)),
                ("n_requests", Json::from(n_requests as usize)),
            ]),
            TraceEvent::Finish { req } => {
                Json::obj(vec![("req", Json::from(req as usize))])
            }
        }
    }
}

/// One recorded lifecycle event, stamped with the simulated clock, the
/// engine step index it happened in, and the recording replica.
#[derive(Clone, Debug, PartialEq)]
pub struct TraceRecord {
    /// Simulated clock, seconds.
    pub t: f64,
    /// Engine step index at emission (coordinator events use the global
    /// fleet event ordinal instead).
    pub step: u64,
    /// Recording replica (fleet slot; 0 for single-replica runs, the
    /// coordinator track uses the dp count).
    pub replica: u32,
    pub ev: TraceEvent,
}

/// Per-step counter sample — the Perfetto counter tracks (kv_used,
/// live ρ, link backlog, encoder overlap).
#[derive(Clone, Debug, PartialEq)]
pub struct CounterSample {
    pub t: f64,
    pub step: u64,
    pub replica: u32,
    /// Committed KV tokens resident after the step.
    pub kv_used: f64,
    /// Compute service demand of the step, seconds.  Live ρ of the
    /// current wave is `t_comp / t_mem`.
    pub t_comp: f64,
    /// Memory service demand of the step, seconds.
    pub t_mem: f64,
    /// Host-link backlog at the step boundary: `busy_until − clock`,
    /// clamped at 0 (seconds of queued transfer not yet drained).
    pub link_backlog: f64,
    /// Cumulative encoder seconds hidden under decode so far.
    pub encode_overlap: f64,
}

/// Hard cap on records per stream (events and counter samples each).
/// Never silent: beyond-cap records are counted in
/// [`TraceData::dropped`], reconciliation skips incomplete streams with
/// a log line, and the exporter stamps the drop count into metadata.
pub const EVENT_CAP: usize = 1_000_000;

/// One replica's recorded stream.  Owned by the engine's `RunState`
/// while running, moved into `SimResult::trace` at finalize (before the
/// auditor's `check_final` so reconciliation sees it).
#[derive(Clone, Debug, Default, PartialEq)]
pub struct TraceData {
    /// Replica id stamped on every record this stream emits.
    pub replica: u32,
    pub events: Vec<TraceRecord>,
    pub counters: Vec<CounterSample>,
    /// Records not stored because a stream hit [`EVENT_CAP`].
    pub dropped: u64,
}

impl TraceData {
    /// Boxed so the engine's off-path cost is one `Option` check, not a
    /// fat struct in `RunState`.
    pub fn new(replica: u32) -> Box<TraceData> {
        Box::new(TraceData { replica, ..TraceData::default() })
    }

    /// Record one lifecycle event at simulated time `t`, step `step`.
    pub fn emit(&mut self, t: f64, step: u64, ev: TraceEvent) {
        if self.events.len() < EVENT_CAP {
            self.events.push(TraceRecord { t, step, replica: self.replica, ev });
        } else {
            self.dropped += 1;
        }
    }

    /// Record one counter sample.
    pub fn sample(&mut self, mut c: CounterSample) {
        if self.counters.len() < EVENT_CAP {
            c.replica = self.replica;
            self.counters.push(c);
        } else {
            self.dropped += 1;
        }
    }

    /// True when nothing was dropped — the precondition for exact
    /// event-stream reconciliation in the auditor.
    pub fn complete(&self) -> bool {
        self.dropped == 0
    }

    /// Re-stamp every record with a new replica id.  Drivers that run
    /// engines without a fleet slot (the static DP fork-join spawns
    /// anonymous threads) assign track ids only after joining, so the
    /// stream is corrected in place before export.
    pub fn restamp(&mut self, replica: u32) {
        self.replica = replica;
        for r in &mut self.events {
            r.replica = replica;
        }
        for c in &mut self.counters {
            c.replica = replica;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn emit_records_and_caps_with_explicit_drop_count() {
        let mut tr = TraceData::new(3);
        tr.emit(1.0, 2, TraceEvent::Finish { req: 7 });
        assert_eq!(tr.events.len(), 1);
        assert_eq!(tr.events[0].replica, 3);
        assert_eq!(tr.events[0].t, 1.0);
        assert_eq!(tr.events[0].step, 2);
        assert!(tr.complete());

        // Fill to the cap, then overflow: the overflow is counted, not
        // silently discarded.
        let mut tr = TraceData::new(0);
        for i in 0..EVENT_CAP {
            tr.emit(0.0, i as u64, TraceEvent::Finish { req: i as u32 });
        }
        assert!(tr.complete());
        tr.emit(0.0, 0, TraceEvent::Finish { req: 0 });
        tr.emit(0.0, 0, TraceEvent::Finish { req: 1 });
        assert_eq!(tr.events.len(), EVENT_CAP);
        assert_eq!(tr.dropped, 2);
        assert!(!tr.complete());
    }

    #[test]
    fn every_variant_names_itself_and_serializes_args() {
        let evs = [
            TraceEvent::Admit { req: 1, hit_tokens: 2, new_tokens: 3, wait: 0.5 },
            TraceEvent::Readmit { req: 1, restored_tokens: 4 },
            TraceEvent::ChunkPrefill { req: 1, tokens: 8 },
            TraceEvent::EncodePass { req: 1, secs: 0.1, overlapped: true },
            TraceEvent::Retract { req: 1, tokens: 16, swapped: false },
            TraceEvent::SwapOut { req: 1, tokens: 16 },
            TraceEvent::SwapIn { req: 1, tokens: 16 },
            TraceEvent::Steal { victim: 0, thief: 1, n_requests: 5 },
            TraceEvent::ReplicaDeath { replica: 2 },
            TraceEvent::Rejoin { replica: 2 },
            TraceEvent::WindowFeed { window: 1, n_requests: 100 },
            TraceEvent::Finish { req: 1 },
        ];
        let mut names: Vec<&str> = evs.iter().map(|e| e.name()).collect();
        names.sort_unstable();
        names.dedup();
        assert_eq!(names.len(), evs.len(), "duplicate variant names");
        for ev in &evs {
            let args = ev.args().to_string();
            assert!(args.starts_with('{'), "{ev:?} args not an object: {args}");
            if let Some(req) = ev.req() {
                assert!(
                    args.contains(&format!("\"req\":{req}")),
                    "{ev:?} args lost the request id: {args}"
                );
            }
        }
    }

    #[test]
    fn counter_samples_are_stamped_with_the_stream_replica() {
        let mut tr = TraceData::new(5);
        tr.sample(CounterSample {
            t: 1.0,
            step: 3,
            replica: 0, // overwritten by the stream
            kv_used: 10.0,
            t_comp: 0.2,
            t_mem: 0.1,
            link_backlog: 0.0,
            encode_overlap: 0.0,
        });
        assert_eq!(tr.counters.len(), 1);
        assert_eq!(tr.counters[0].replica, 5);
    }
}
