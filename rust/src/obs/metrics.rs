//! Metrics registry: the explanatory timelines a raw `SimResult` can't
//! answer (DESIGN.md §15).
//!
//! [`metrics_report`] derives a [`MetricsReport`] from one finished
//! run: roofline attribution of the makespan (what fraction of
//! simulated time the blended step was compute-bound vs memory-bound,
//! plus the link-stall share — the paper's Fig. 2 argument as a
//! measurement), sharing-achieved-over-time, and retraction/readmit
//! churn windows.  The attribution comes from the recorded step series;
//! the timelines come from the trace stream when one was recorded
//! (empty otherwise — the report degrades, it never guesses).
//!
//! Everything here is a pure fold over already-deterministic data, so
//! the report (and its JSON form, persisted by `save_results`) is as
//! bit-stable as the run it describes.

use super::TraceEvent;
use crate::engine::sim::SimResult;
use crate::util::Json;

/// One point of the sharing-achieved timeline: cumulative prompt-cache
/// performance as of simulated time `t` (an admission instant).
#[derive(Clone, Debug, PartialEq)]
pub struct SharingPoint {
    pub t: f64,
    /// Prompt tokens served from the radix cache so far.
    pub cum_hit_tokens: u64,
    /// Prompt tokens admitted so far (hit + prefilled).
    pub cum_prompt_tokens: u64,
}

/// One churn bucket: retraction/readmission activity inside
/// `[t0, t1)`.  Only non-quiet buckets are reported.
#[derive(Clone, Debug, PartialEq)]
pub struct ChurnWindow {
    pub t0: f64,
    pub t1: f64,
    pub retractions: u64,
    pub readmits: u64,
    /// Swap traffic (out + in tokens) inside the bucket.
    pub swap_tokens: u64,
}

/// The registry: per-run explanatory metrics, persisted alongside the
/// raw counters by `save_results` and consumed by `paper-figures`.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct MetricsReport {
    /// Simulated seconds of stepped time whose blended step was
    /// compute-bound (`t_comp >= t_mem`).
    pub comp_bound_time: f64,
    /// Simulated seconds of stepped time that were memory-bound.
    pub mem_bound_time: f64,
    /// Seconds the engine stalled waiting on unfinished swap-ins.
    pub link_stall_time: f64,
    /// The three attributions as fractions of the makespan.
    pub comp_bound_frac: f64,
    pub mem_bound_frac: f64,
    pub link_stall_frac: f64,
    /// True when every executed step contributed a sample — i.e. the
    /// series was neither capped nor thinned by idle-skips, so the
    /// attribution covers the whole makespan exactly.
    pub attribution_exact: bool,
    /// Sharing-achieved over time (admission instants; ≤ [`MAX_POINTS`]
    /// points, evenly thinned).  Empty without a recorded trace.
    pub sharing_timeline: Vec<SharingPoint>,
    /// Non-quiet retraction/readmit buckets over the makespan.  Empty
    /// without a recorded trace.
    pub churn_windows: Vec<ChurnWindow>,
}

/// Cap on reported timeline points; thinning is even and deterministic.
pub const MAX_POINTS: usize = 128;

/// Churn buckets across the makespan.
pub const CHURN_BUCKETS: usize = 24;

/// Thin `points` to at most [`MAX_POINTS`] by even stride, always
/// keeping the final point (the run's closing state).
fn thin<T: Clone>(points: Vec<T>) -> Vec<T> {
    if points.len() <= MAX_POINTS {
        return points;
    }
    let stride = points.len().div_ceil(MAX_POINTS);
    let last = points.len() - 1;
    points
        .iter()
        .enumerate()
        .filter(|(i, _)| i % stride == 0 || *i == last)
        .map(|(_, p)| p.clone())
        .collect()
}

/// Build the metrics registry for one finished run.
pub fn metrics_report(res: &SimResult) -> MetricsReport {
    let mut comp = 0.0;
    let mut mem = 0.0;
    for s in &res.series {
        if s.t_comp >= s.t_mem {
            comp += s.step_time;
        } else {
            mem += s.step_time;
        }
    }
    let total = res.total_time.max(f64::MIN_POSITIVE);
    let mut report = MetricsReport {
        comp_bound_time: comp,
        mem_bound_time: mem,
        link_stall_time: res.link_stall_time,
        comp_bound_frac: comp / total,
        mem_bound_frac: mem / total,
        link_stall_frac: res.link_stall_time / total,
        attribution_exact: !res.series_truncated && res.series.len() as u64 == res.steps,
        sharing_timeline: Vec::new(),
        churn_windows: Vec::new(),
    };
    let Some(tr) = res.trace.as_ref() else {
        return report;
    };

    // Sharing over time: fold the admission stream.
    let mut cum_hit = 0u64;
    let mut cum_prompt = 0u64;
    let mut timeline = Vec::new();
    for r in &tr.events {
        if let TraceEvent::Admit { hit_tokens, new_tokens, .. } = r.ev {
            cum_hit += hit_tokens;
            cum_prompt += hit_tokens + new_tokens;
            timeline.push(SharingPoint {
                t: r.t,
                cum_hit_tokens: cum_hit,
                cum_prompt_tokens: cum_prompt,
            });
        }
    }
    report.sharing_timeline = thin(timeline);

    // Churn windows: bucket the retraction/readmit stream.
    let width = res.total_time / CHURN_BUCKETS as f64;
    if width > 0.0 {
        let mut buckets = vec![(0u64, 0u64, 0u64); CHURN_BUCKETS];
        for r in &tr.events {
            let b = ((r.t / width) as usize).min(CHURN_BUCKETS - 1);
            match r.ev {
                TraceEvent::Retract { .. } => buckets[b].0 += 1,
                TraceEvent::Readmit { .. } => buckets[b].1 += 1,
                TraceEvent::SwapOut { tokens, .. } | TraceEvent::SwapIn { tokens, .. } => {
                    buckets[b].2 += tokens
                }
                _ => {}
            }
        }
        report.churn_windows = buckets
            .into_iter()
            .enumerate()
            .filter(|(_, (r, a, s))| *r + *a + *s > 0)
            .map(|(i, (retractions, readmits, swap_tokens))| ChurnWindow {
                t0: i as f64 * width,
                t1: (i + 1) as f64 * width,
                retractions,
                readmits,
                swap_tokens,
            })
            .collect();
    }
    report
}

impl MetricsReport {
    /// Deterministic JSON form — embedded per replica by
    /// `save_results`.
    pub fn to_json(&self) -> Json {
        let timeline: Vec<Json> = self
            .sharing_timeline
            .iter()
            .map(|p| {
                Json::obj(vec![
                    ("t_s", Json::Num(p.t)),
                    ("cum_hit_tokens", Json::from(p.cum_hit_tokens as usize)),
                    ("cum_prompt_tokens", Json::from(p.cum_prompt_tokens as usize)),
                ])
            })
            .collect();
        let churn: Vec<Json> = self
            .churn_windows
            .iter()
            .map(|w| {
                Json::obj(vec![
                    ("t0_s", Json::Num(w.t0)),
                    ("t1_s", Json::Num(w.t1)),
                    ("retractions", Json::from(w.retractions as usize)),
                    ("readmits", Json::from(w.readmits as usize)),
                    ("swap_tokens", Json::from(w.swap_tokens as usize)),
                ])
            })
            .collect();
        Json::obj(vec![
            ("comp_bound_time_s", Json::Num(self.comp_bound_time)),
            ("mem_bound_time_s", Json::Num(self.mem_bound_time)),
            ("link_stall_time_s", Json::Num(self.link_stall_time)),
            ("comp_bound_frac", Json::Num(self.comp_bound_frac)),
            ("mem_bound_frac", Json::Num(self.mem_bound_frac)),
            ("link_stall_frac", Json::Num(self.link_stall_frac)),
            ("attribution_exact", Json::from(self.attribution_exact)),
            ("sharing_timeline", Json::Arr(timeline)),
            ("churn_windows", Json::Arr(churn)),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::sim::{SimResult, StepSample};
    use crate::obs::TraceData;

    fn base_result() -> SimResult {
        SimResult {
            total_time: 10.0,
            steps: 2,
            link_stall_time: 1.0,
            series: vec![
                StepSample {
                    step: 0,
                    step_time: 6.0,
                    t_comp: 3.0,
                    t_mem: 2.0,
                    prefill_tokens: 8,
                    decode_tokens: 0,
                    kv_used: 8.0,
                },
                StepSample {
                    step: 1,
                    step_time: 4.0,
                    t_comp: 1.0,
                    t_mem: 2.0,
                    prefill_tokens: 0,
                    decode_tokens: 4,
                    kv_used: 12.0,
                },
            ],
            ..SimResult::default()
        }
    }

    #[test]
    fn roofline_attribution_weights_by_step_time() {
        let m = metrics_report(&base_result());
        assert_eq!(m.comp_bound_time, 6.0);
        assert_eq!(m.mem_bound_time, 4.0);
        assert_eq!(m.comp_bound_frac, 0.6);
        assert_eq!(m.mem_bound_frac, 0.4);
        assert_eq!(m.link_stall_frac, 0.1);
        assert!(m.attribution_exact);
        assert!(m.sharing_timeline.is_empty(), "no trace, no timeline");
        assert!(m.churn_windows.is_empty());
    }

    #[test]
    fn truncated_or_thinned_series_is_flagged_inexact() {
        let mut res = base_result();
        res.series_truncated = true;
        assert!(!metrics_report(&res).attribution_exact);
        let mut res = base_result();
        res.steps = 5; // idle-skipped steps carry no sample
        assert!(!metrics_report(&res).attribution_exact);
    }

    #[test]
    fn trace_drives_sharing_timeline_and_churn_windows() {
        let mut res = base_result();
        let mut tr = TraceData::new(0);
        tr.emit(0.0, 0, TraceEvent::Admit { req: 1, hit_tokens: 0, new_tokens: 10, wait: 0.0 });
        tr.emit(1.0, 1, TraceEvent::Admit { req: 2, hit_tokens: 6, new_tokens: 4, wait: 0.5 });
        tr.emit(2.0, 2, TraceEvent::Retract { req: 1, tokens: 12, swapped: true });
        tr.emit(2.0, 2, TraceEvent::SwapOut { req: 1, tokens: 12 });
        tr.emit(9.9, 4, TraceEvent::Readmit { req: 1, restored_tokens: 12 });
        tr.emit(9.9, 4, TraceEvent::SwapIn { req: 1, tokens: 12 });
        res.trace = Some(tr);
        let m = metrics_report(&res);
        assert_eq!(m.sharing_timeline.len(), 2);
        assert_eq!(m.sharing_timeline[1].cum_hit_tokens, 6);
        assert_eq!(m.sharing_timeline[1].cum_prompt_tokens, 20);
        // Two active buckets: the retract/swap-out one and the final
        // readmit/swap-in one.
        assert_eq!(m.churn_windows.len(), 2);
        assert_eq!(m.churn_windows[0].retractions, 1);
        assert_eq!(m.churn_windows[0].swap_tokens, 12);
        let last = m.churn_windows.last().unwrap();
        assert_eq!(last.readmits, 1);
        assert_eq!(last.swap_tokens, 12);
        assert_eq!(last.t1, 10.0);
        // JSON form is deterministic and carries the headline numbers.
        let a = m.to_json().to_string();
        assert_eq!(a, metrics_report(&res).to_json().to_string());
        assert!(a.contains("\"comp_bound_frac\":0.6"), "{a}");
    }

    #[test]
    fn timeline_thinning_keeps_ends_and_bound() {
        let pts: Vec<usize> = (0..1000).collect();
        let t = thin(pts);
        assert!(t.len() <= MAX_POINTS);
        assert_eq!(*t.first().unwrap(), 0);
        assert_eq!(*t.last().unwrap(), 999);
    }
}
