//! Regenerate every table and figure of the BlendServe paper
//! (DESIGN.md §5 experiment index).
//!
//! ```bash
//! cargo run --release --bin paper-figures -- all            # everything
//! cargo run --release --bin paper-figures -- fig7 tab4     # a subset
//! cargo run --release --bin paper-figures -- fig7 --n 40000
//! ```
//!
//! Output: aligned text + CSV under `results/`.  Absolute numbers are from
//! the profile-guided simulator (DESIGN.md §Substitutions); the *shapes* —
//! who wins, by what factor, where the crossovers fall — are the
//! reproduction targets.

use blendserve::baselines;
use blendserve::config::presets;
use blendserve::engine::distserve::simulate_disagg;
use blendserve::engine::sim::SimRequest;
use blendserve::obs::metrics_report;
use blendserve::perfmodel::{roofline, PerfModel};
use blendserve::scheduler::{run_system, static_order};
use blendserve::server::serve_batch;
use blendserve::trace::generators::generate_kind;
use blendserve::trace::synth::{synthesize, table2_traces, SynthSpec};
use blendserve::trace::{stats, TraceKind, Workload};
use blendserve::tree::PrefixTree;
use blendserve::util::Table;
use std::path::Path;

struct Opts {
    /// Requests per synthesized workload (fig3/7/9/10).
    n: usize,
    /// Requests per grid cell (fig11/13/14/15) and per model (fig12).
    n_grid: usize,
    out: String,
}

fn pm_8b() -> PerfModel {
    PerfModel::new(presets::llama3_8b(), presets::a100_80gb(), 1)
}

fn out_dir(opts: &Opts) -> &Path {
    Path::new(&opts.out)
}

fn emit(opts: &Opts, name: &str, t: &Table) {
    println!("{}", t.to_text());
    t.save(out_dir(opts), name).expect("write results");
    println!("-> {}/{name}.{{txt,csv}}\n", opts.out);
}

// ---------------------------------------------------------------- fig2/tab4

/// Fig. 2: per-trace input/output length distributions; Table 4: density +
/// sharing.  One harness emits both views.
fn fig2_tab4(opts: &Opts) {
    let pm = pm_8b();
    let mut fig2 = Table::new(
        "Fig.2 — request length distributions per trace (Llama-3-8B tokens)",
        &["trace", "n", "in p50", "in p90", "in max", "out p50", "out p90", "out max"],
    );
    let mut tab4 = Table::new(
        "Table 4 — prefix sharing ratio and compute density per trace",
        &["trace", "prefix sharing", "compute density", "class"],
    );
    let mut kinds = TraceKind::ALL_PAPER.to_vec();
    kinds.push(TraceKind::Limo);
    for kind in kinds {
        let w = generate_kind(kind, opts.n.min(8000), 11);
        let p = stats::profile(&w, &pm);
        fig2.row(&[
            kind.name().into(),
            p.n.to_string(),
            format!("{:.0}", p.input.p50),
            format!("{:.0}", p.input.p90),
            format!("{:.0}", p.input.max),
            format!("{:.0}", p.output.p50),
            format!("{:.0}", p.output.p90),
            format!("{:.0}", p.output.max),
        ]);
        tab4.row(&[
            kind.name().into(),
            format!("{:.2}", p.sharing),
            format!("{:.2}", p.density),
            if p.density > 1.0 { "compute-intensive" } else { "memory-intensive" }
                .into(),
        ]);
    }
    emit(opts, "fig2_lengths", &fig2);
    emit(opts, "tab4_traces", &tab4);
}

// --------------------------------------------------------------------- fig3

/// Fig. 3: compute/memory-bound time share per step when serving
/// compute-intensive requests followed by memory-intensive ones.
fn fig3(opts: &Opts) {
    let n = opts.n;
    let burst = generate_kind(TraceKind::BurstGpt, n, 1);
    let vid = generate_kind(TraceKind::OpenVid, (n / 60).max(8), 2);
    let w = Workload::concat("burst-then-openvid", &[&burst, &vid]);
    for (tag, cfg) in [
        ("baseline", baselines::nanoflow_dfs()),
        ("blendserve", baselines::blendserve()),
    ] {
        let out = run_system(&cfg, &w);
        let mut t = Table::new(
            &format!(
                "Fig.3 ({tag}) — share of step time on compute- vs memory-bound ops \
                 (total {:.0}s, {:.0} tok/s)",
                out.result.total_time, out.result.throughput
            ),
            &["step", "compute share", "memory share"],
        );
        for s in out.result.downsampled(24) {
            let tot = (s.t_comp + s.t_mem).max(1e-12);
            t.row(&[
                s.step.to_string(),
                format!("{:.2}", s.t_comp / tot),
                format!("{:.2}", s.t_mem / tot),
            ]);
        }
        emit(opts, &format!("fig3_{tag}"), &t);
    }
}

// --------------------------------------------------------------------- fig4

/// Fig. 4: compute density over the (input, output) length grid.
fn fig4(opts: &Opts) {
    let pm = pm_8b();
    let ps = [128usize, 256, 512, 1024, 2048, 4096, 8192];
    let ds = [16usize, 64, 256, 1024, 4096, 16384];
    let mut t = Table::new(
        "Fig.4 — compute density ρ(p,d), Llama-3-8B on A100-80GB",
        &std::iter::once("p \\ d".to_string())
            .chain(ds.iter().map(|d| d.to_string()))
            .map(|s| Box::leak(s.into_boxed_str()) as &str)
            .collect::<Vec<_>>(),
    );
    for &p in &ps {
        let mut row = vec![p.to_string()];
        for &d in &ds {
            row.push(format!("{:.2}", pm.density(p, d)));
        }
        t.row(&row);
    }
    emit(opts, "fig4_density", &t);
}

// --------------------------------------------------------------------- tab1

/// Table 1: estimated vs measured operator time.  Two parts: (a) our
/// analytical estimates for the paper's A100 settings next to the paper's
/// own measured values; (b) estimated vs PJRT-measured step time on the
/// real CPU model (the hardware we actually have).
fn tab1(opts: &Opts) {
    let pm = pm_8b();
    let mut t = Table::new(
        "Table 1a — operator time @ seq 1024 (ms): our §4 estimate vs the paper's measured",
        &["batch", "GEMM est (ours)", "GEMM real (paper)", "Attn est (ours)", "Attn real (paper)"],
    );
    let paper = [(512usize, 1.087, 1.317), (768, 1.537, 1.913), (1024, 2.005, 2.515)];
    for (batch, gemm_real, attn_real) in paper {
        t.row(&[
            batch.to_string(),
            format!("{:.3}", roofline::gemm_time_est(&pm, batch) * 1e3),
            format!("{:.3}", gemm_real),
            format!("{:.3}", roofline::attention_time_est(&pm, batch, 1024) * 1e3),
            format!("{:.3}", attn_real),
        ]);
    }
    emit(opts, "tab1_operator_times", &t);

    // Part (b): real PJRT measurement.
    let dir = blendserve::runtime::default_artifact_dir();
    if !blendserve::runtime::artifacts_available(&dir) {
        println!("tab1b skipped: run `make artifacts` first\n");
        return;
    }
    let mut model = blendserve::runtime::RealModel::load(&dir).expect("load artifacts");
    let mut t = Table::new(
        "Table 1b — real blended-step wall time on CPU PJRT (tiny model)",
        &["step shape", "tokens", "measured ms (median of 20)"],
    );
    let s = model.manifest.max_seq as i32;
    let cases: Vec<(&str, Vec<i32>, Vec<i32>, Vec<i32>)> = vec![
        ("decode x8", vec![1; 8], (0..8).collect(), vec![s / 2; 8]),
        (
            "prefill 64",
            vec![2; 64],
            vec![0; 64],
            (0..64).collect(),
        ),
        (
            "blended 8+56",
            vec![3; 64],
            (0..8).chain(std::iter::repeat(8).take(56)).collect(),
            (0..8).map(|_| s / 2).chain(0..56).collect(),
        ),
    ];
    for (name, tok, seg, pos) in cases {
        let mut times: Vec<f64> = (0..20)
            .map(|_| {
                // lint:allow(r2) -- figure reports real kernel latency
                let t0 = std::time::Instant::now();
                model.step(&tok, &seg, &pos).expect("step");
                t0.elapsed().as_secs_f64() * 1e3
            })
            .collect();
        times.sort_by(|a, b| a.partial_cmp(b).unwrap());
        t.row(&[name.into(), tok.len().to_string(), format!("{:.2}", times[10])]);
    }
    emit(opts, "tab1b_real_steps", &t);
}

// --------------------------------------------------------------------- tab2

fn tab2(opts: &Opts) {
    let pm = pm_8b();
    let mut t = Table::new(
        "Table 2 — the four representative synthesized workloads",
        &["trace", "target ρ", "target s", "achieved ρ", "achieved s", "requests", "Mtokens"],
    );
    for (name, spec) in table2_traces(opts.n) {
        let w = synthesize(&spec, &pm);
        let (rho, s) = blendserve::trace::synth::achieved(&w, &pm);
        t.row(&[
            name,
            format!("{:.2}", spec.density),
            format!("{:.2}", spec.sharing),
            format!("{:.2}", rho),
            format!("{:.2}", s),
            w.len().to_string(),
            format!("{:.1}", w.total_tokens() as f64 / 1e6),
        ]);
    }
    emit(opts, "tab2_workloads", &t);
}

// --------------------------------------------------------------------- fig7

fn fig7(opts: &Opts) {
    for (model, gpus, tag) in [
        (presets::llama3_8b(), 1usize, "8b_1xA100"),
        (presets::llama3_70b().with_tp(8), 8, "70b_8xA100"),
    ] {
        let pm = PerfModel::new(model.clone(), presets::a100_80gb(), gpus);
        let mut t = Table::new(
            &format!(
                "Fig.7 — end-to-end throughput (tok/s), {} on {}x A100 (simulated)",
                model.name, gpus
            ),
            &["trace", "vLLM-DFS", "SGLang-DFS", "NF-Balance", "NF-DFS", "BlendServe",
              "Optimal", "Blend/NF-DFS", "Blend %opt"],
        );
        let mut speedups = Vec::new();
        let mut fracs = Vec::new();
        for (name, spec) in table2_traces(opts.n) {
            let w = synthesize(&spec, &pm);
            let mut row = vec![name.clone()];
            let mut nf_dfs = 0.0;
            let mut blend = 0.0;
            let mut opt = 0.0;
            let mut frac = 0.0;
            for (sys, cfg) in baselines::all_systems() {
                let cfg = baselines::with_model(cfg, model.clone());
                let out = run_system(&cfg, &w);
                row.push(format!("{:.0}", out.result.throughput));
                opt = out.practical_optimal_throughput;
                match sys {
                    "NanoFlow-DFS" => nf_dfs = out.result.throughput,
                    "BlendServe" => {
                        blend = out.result.throughput;
                        frac = out.optimal_fraction;
                    }
                    _ => {}
                }
            }
            row.push(format!("{:.0}", opt));
            row.push(format!("{:.2}x", blend / nf_dfs));
            row.push(format!("{:.1}%", frac * 100.0));
            speedups.push(blend / nf_dfs);
            fracs.push(frac);
            t.row(&row);
        }
        emit(opts, &format!("fig7_{tag}"), &t);
        println!(
            "  avg speedup over NanoFlow-DFS: {:.1}%  |  avg of optimal: {:.1}%  \
             (paper: +20.84%/18.6%, 86.55%/90.8%)\n",
            (speedups.iter().sum::<f64>() / speedups.len() as f64 - 1.0) * 100.0,
            fracs.iter().sum::<f64>() / fracs.len() as f64 * 100.0
        );
    }
}

// --------------------------------------------------------------------- fig8

fn fig8(opts: &Opts) {
    let pm = pm_8b();
    let mut t = Table::new(
        "Fig.8 — per-GPU throughput (tok/s): P/D disaggregation vs colocated",
        &["system", "gpus", "per-GPU tok/s", "vs vLLM"],
    );
    let spec = SynthSpec::new(TraceKind::BurstGpt, 1.1, 0.2, opts.n);
    let w = synthesize(&spec, &pm);
    let tree = PrefixTree::build(&w);
    let order = static_order(blendserve::config::OrderPolicy::Dfs, &tree, 0);
    let est: Vec<u32> = w.requests.iter().map(|r| r.output_len).collect();
    let reqs = SimRequest::from_workload(&w, &est);

    let vllm = run_system(&baselines::vllm_dfs(), &w);
    let blend = run_system(&baselines::blendserve(), &w);
    let vllm_pg = vllm.result.throughput;
    t.row(&["vLLM-DFS".into(), "1".into(), format!("{:.0}", vllm_pg), "1.00x".into()]);
    t.row(&[
        "BlendServe".into(),
        "1".into(),
        format!("{:.0}", blend.result.throughput),
        format!("{:.2}x", blend.result.throughput / vllm_pg),
    ]);
    for (x, y) in [(1usize, 1usize), (2, 1), (1, 2), (1, 3)] {
        let r = simulate_disagg(&pm, &reqs, &order, x, y);
        t.row(&[
            format!("DistServe {x}P{y}D"),
            (x + y).to_string(),
            format!("{:.0}", r.per_gpu_throughput),
            format!("{:.2}x", r.per_gpu_throughput / vllm_pg),
        ]);
    }
    emit(opts, "fig8_disagg", &t);
}

// --------------------------------------------------------------------- fig9

fn fig9(opts: &Opts) {
    let pm = pm_8b();
    let mut t = Table::new(
        "Fig.9 — achieved prefix-sharing ratio vs optimal",
        &["trace", "optimal", "BlendServe", "NF-Balance", "Blend/optimal"],
    );
    for (name, spec) in table2_traces(opts.n) {
        let w = synthesize(&spec, &pm);
        let blend = run_system(&baselines::blendserve(), &w);
        let bal = run_system(&baselines::nanoflow_balance(), &w);
        t.row(&[
            name,
            format!("{:.3}", blend.optimal_sharing),
            format!("{:.3}", blend.result.sharing_achieved),
            format!("{:.3}", bal.result.sharing_achieved),
            format!("{:.1}%", blend.result.sharing_achieved / blend.optimal_sharing * 100.0),
        ]);
    }
    emit(opts, "fig9_sharing", &t);
}

// -------------------------------------------------------------------- fig10

fn fig10(opts: &Opts) {
    let pm = pm_8b();
    let spec = &table2_traces(opts.n)[1].1; // Trace#2
    let w = synthesize(spec, &pm);
    for (tag, cfg) in [
        ("blendserve", baselines::blendserve()),
        ("nanoflow_dfs", baselines::nanoflow_dfs()),
        ("nanoflow_balance", baselines::nanoflow_balance()),
    ] {
        let out = run_system(&cfg, &w);
        let mut t = Table::new(
            &format!(
                "Fig.10 ({tag}) — per-step compute & memory time on Trace#2 \
                 (total {:.0}s)",
                out.result.total_time
            ),
            &["step", "t_comp ms", "t_mem ms", "util balance"],
        );
        for s in out.result.downsampled(24) {
            let bal = s.t_comp.min(s.t_mem) / s.t_comp.max(s.t_mem).max(1e-12);
            t.row(&[
                s.step.to_string(),
                format!("{:.2}", s.t_comp * 1e3),
                format!("{:.2}", s.t_mem * 1e3),
                format!("{:.2}", bal),
            ]);
        }
        emit(opts, &format!("fig10_{tag}"), &t);
    }
}

// ------------------------------------------------------- fig11/13/14/15

fn grid_figure(opts: &Opts, fig: &str, compute_trace: TraceKind) {
    let pm = pm_8b();
    let densities: Vec<f64> = (0..13).map(|i| 0.80 + 0.05 * i as f64).collect();
    let sharings: Vec<f64> = (0..5).map(|i| 0.05 + 0.10 * i as f64).collect();
    let mut header: Vec<String> = vec!["ρ \\ s".into()];
    header.extend(sharings.iter().map(|s| format!("{s:.2}")));
    let headers: Vec<&str> = header.iter().map(|s| s.as_str()).collect();
    let mut t = Table::new(
        &format!(
            "{} — BlendServe speedup over NanoFlow-DFS, {} + MMLU + OpenVid grid \
             ({} requests/cell)",
            fig,
            compute_trace.name(),
            opts.n_grid
        ),
        &headers,
    );
    let mut all = Vec::new();
    for &rho in &densities {
        let mut row = vec![format!("{rho:.2}")];
        for &s in &sharings {
            let spec = SynthSpec::new(compute_trace, rho, s, opts.n_grid);
            let w = synthesize(&spec, &pm);
            let blend = run_system(&baselines::blendserve(), &w);
            let nano = run_system(&baselines::nanoflow_dfs(), &w);
            let speedup = blend.result.throughput / nano.result.throughput;
            all.push(speedup);
            row.push(format!("{speedup:.2}"));
        }
        t.row(&row);
    }
    emit(opts, &format!("{fig}_grid_{}", compute_trace.name().to_lowercase()), &t);
    println!(
        "  speedup range {:.2}x-{:.2}x, mean {:.2}x (paper {}: 1.08x-1.34x)\n",
        all.iter().cloned().fold(f64::INFINITY, f64::min),
        all.iter().cloned().fold(0.0, f64::max),
        all.iter().sum::<f64>() / all.len() as f64,
        fig
    );
}

// -------------------------------------------------------------------- tab3

fn tab3(opts: &Opts) {
    let pm = pm_8b();
    let mut t = Table::new(
        "Table 3 — BlendServe DP scalability (Llama-3-8B, simulated)",
        &["trace", "DP=1", "DP=2", "DP=4", "scale@2", "scale@4"],
    );
    for (name, spec) in table2_traces(opts.n) {
        let w = synthesize(&spec, &pm);
        let mut tputs = Vec::new();
        for dp in [1usize, 2, 4] {
            let mut cfg = baselines::blendserve();
            cfg.scheduler.sample_prob = 0.05;
            cfg.dp_replicas = dp;
            tputs.push(serve_batch(&cfg, &w).total_throughput);
        }
        t.row(&[
            name,
            format!("{:.0}", tputs[0]),
            format!("{:.0}", tputs[1]),
            format!("{:.0}", tputs[2]),
            format!("{:.2}x", tputs[1] / tputs[0]),
            format!("{:.2}x", tputs[2] / tputs[0]),
        ]);
    }
    emit(opts, "tab3_dp_scaling", &t);
}

// -------------------------------------------------------------------- fig12

fn fig12(opts: &Opts) {
    let mut t = Table::new(
        "Fig.12 — other models: BlendServe vs NanoFlow-DFS (simulated)",
        &["model", "gpus", "trace", "NF-DFS", "BlendServe", "speedup", "%opt"],
    );
    for (model, gpus) in [
        (presets::qwen25_7b(), 1usize),
        (presets::llama2_7b(), 1),
        (presets::qwen25_72b().with_tp(8), 8),
        (presets::deepseek_67b().with_tp(8), 8),
    ] {
        let pm = PerfModel::new(model.clone(), presets::a100_80gb(), gpus);
        // Re-synthesize per model (§6.6: density depends on the model).
        for (name, base_spec) in table2_traces(opts.n_grid).into_iter().take(2) {
            let spec = SynthSpec::new(
                base_spec.compute_trace,
                base_spec.density,
                base_spec.sharing,
                opts.n_grid,
            );
            let w = synthesize(&spec, &pm);
            let nano = run_system(
                &baselines::with_model(baselines::nanoflow_dfs(), model.clone()),
                &w,
            );
            let blend = run_system(
                &baselines::with_model(baselines::blendserve(), model.clone()),
                &w,
            );
            t.row(&[
                model.name.clone(),
                gpus.to_string(),
                name,
                format!("{:.0}", nano.result.throughput),
                format!("{:.0}", blend.result.throughput),
                format!("{:.2}x", blend.result.throughput / nano.result.throughput),
                format!("{:.1}%", blend.optimal_fraction * 100.0),
            ]);
        }
    }
    emit(opts, "fig12_models", &t);
}

// ---------------------------------------------------------------- figobs

/// Observability figure (DESIGN.md §15): roofline attribution of the
/// makespan per canonical trace, *measured* from the metrics registry
/// rather than inferred from workload stats — which fraction of stepped
/// time was compute-bound vs memory-bound, how much the engine stalled
/// on the offload link, and the sharing ratio the radix cache actually
/// delivered by the end of the run (from the traced admission stream).
fn figobs(opts: &Opts) {
    let mut t = Table::new(
        "Obs — measured roofline attribution per trace (BlendServe, simulated)",
        &["trace", "makespan s", "comp frac", "mem frac", "link stall", "exact",
          "final sharing", "churn windows"],
    );
    let mut cfg = baselines::blendserve();
    cfg.engine.trace = true;
    for kind in [
        TraceKind::BurstGpt,
        TraceKind::ShareGpt,
        TraceKind::WildChat,
        TraceKind::AzureTrace,
    ] {
        let w = generate_kind(kind, opts.n_grid.min(2000), 11);
        let out = run_system(&cfg, &w);
        let m = metrics_report(&out.result);
        let sharing = m
            .sharing_timeline
            .last()
            .map(|p| p.cum_hit_tokens as f64 / p.cum_prompt_tokens.max(1) as f64)
            .unwrap_or(0.0);
        t.row(&[
            kind.name().into(),
            format!("{:.0}", out.result.total_time),
            format!("{:.2}", m.comp_bound_frac),
            format!("{:.2}", m.mem_bound_frac),
            format!("{:.3}", m.link_stall_frac),
            if m.attribution_exact { "yes" } else { "no" }.into(),
            format!("{sharing:.3}"),
            m.churn_windows.len().to_string(),
        ]);
    }
    emit(opts, "figobs_roofline", &t);
}

// --------------------------------------------------------------------- main

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let mut opts = Opts { n: 20_000, n_grid: 5_000, out: "results".into() };
    let mut which: Vec<String> = Vec::new();
    let mut i = 0;
    while i < args.len() {
        match args[i].as_str() {
            "--n" => {
                i += 1;
                opts.n = args[i].parse().expect("--n <requests>");
            }
            "--n-grid" => {
                i += 1;
                opts.n_grid = args[i].parse().expect("--n-grid <requests>");
            }
            "--out" => {
                i += 1;
                opts.out = args[i].clone();
            }
            other => which.push(other.to_string()),
        }
        i += 1;
    }
    if which.is_empty() {
        eprintln!(
            "usage: paper-figures [--n N] [--n-grid N] [--out DIR] \
             <all | fig2 fig3 fig4 tab1 tab2 fig7 fig8 fig9 fig10 fig11 \
             tab3 fig12 fig13 fig14 fig15 tab4 figobs>"
        );
        std::process::exit(2);
    }
    let all = which.iter().any(|w| w == "all");
    let want = |k: &str| all || which.iter().any(|w| w == k);

    if want("fig2") || want("tab4") {
        fig2_tab4(&opts);
    }
    if want("fig3") {
        fig3(&opts);
    }
    if want("fig4") {
        fig4(&opts);
    }
    if want("tab1") {
        tab1(&opts);
    }
    if want("tab2") {
        tab2(&opts);
    }
    if want("fig7") {
        fig7(&opts);
    }
    if want("fig8") {
        fig8(&opts);
    }
    if want("fig9") {
        fig9(&opts);
    }
    if want("fig10") {
        fig10(&opts);
    }
    if want("fig11") {
        grid_figure(&opts, "Fig.11", TraceKind::BurstGpt);
    }
    if want("tab3") {
        tab3(&opts);
    }
    if want("fig12") {
        fig12(&opts);
    }
    if want("fig13") {
        grid_figure(&opts, "Fig.13", TraceKind::AzureTrace);
    }
    if want("fig14") {
        grid_figure(&opts, "Fig.14", TraceKind::ShareGpt);
    }
    if want("fig15") {
        grid_figure(&opts, "Fig.15", TraceKind::WildChat);
    }
    if want("figobs") {
        figobs(&opts);
    }
}
