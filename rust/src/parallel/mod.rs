//! Distributed deployment (§5.5): data parallelism via dual-scanner tree
//! decomposition, and tensor parallelism as resource scaling.
//!
//! **DP**: the centralized resource-aware prefix tree is decomposed into
//! `dp` *parallelized subtrees* with (a) balanced estimated processing
//! time and (b) per-partition density close to the global root density, so
//! every replica can blend locally.  The decomposition reuses the dual
//! scanner: units are taken from the compute end or the memory end
//! depending on which keeps the open partition's density near ρ(rt); a
//! partition closes when it reaches the per-replica time target.
//!
//! **TP**: both compute and bandwidth scale with the replica's GPU count
//! (communication assumed overlappable, as in NanoFlow/Centauri); this is
//! already captured by `PerfModel::new(model, hw, n_gpus)`.

use crate::perfmodel::PerfModel;
use crate::tree::PrefixTree;

/// Result of a DP decomposition: request ids per replica.
#[derive(Clone, Debug)]
pub struct DpPartition {
    pub replicas: Vec<Vec<u32>>,
    /// Estimated optimal processing time per replica (balance diagnostic).
    pub est_times: Vec<f64>,
}

impl DpPartition {
    /// Max/mean imbalance of the estimated replica times.
    pub fn imbalance(&self) -> f64 {
        let max = self.est_times.iter().cloned().fold(0.0f64, f64::max);
        let mean =
            self.est_times.iter().sum::<f64>() / self.est_times.len().max(1) as f64;
        if mean <= 0.0 {
            1.0
        } else {
            max / mean
        }
    }
}

/// Decompose a transformed tree into `dp` balanced partitions (§5.5).
///
/// The tree must have been `transform`ed (or at least have aggregates
/// recomputed) so scheduling units carry densities; estimates come from
/// `est_output`.
pub fn partition_dp(tree: &PrefixTree, pm: &PerfModel, dp: usize) -> DpPartition {
    assert!(dp >= 1);
    let units = tree.scheduling_units();
    // Per-unit demand (comp discounted by the unit's amortized sharing —
    // approximated with the unit density which already includes it).
    struct U {
        reqs: Vec<u32>,
        comp_eff: f64,
        mem: f64,
    }
    let mut us: Vec<U> = Vec::with_capacity(units.len());
    for (id, density) in &units {
        let node = &tree.nodes[*id];
        let mut mem = 0.0;
        for &r in &node.requests {
            let p = tree.input_len(r);
            let d = tree.est_output[r as usize].max(1) as usize;
            mem += pm.mem_request(p, d);
        }
        // density = comp_eff / mem  =>  comp_eff = density * mem.
        let comp_eff = if mem > 0.0 { density * mem } else { 0.0 };
        us.push(U { reqs: node.requests.clone(), comp_eff, mem });
    }
    let rho_root = tree.root_density();

    let mut replicas: Vec<Vec<u32>> = Vec::with_capacity(dp);
    let mut est_times: Vec<f64> = Vec::with_capacity(dp);
    let (mut l, mut r) = (0usize, us.len());
    let mut remaining_time = {
        let c: f64 = us.iter().map(|u| u.comp_eff).sum();
        let m: f64 = us.iter().map(|u| u.mem).sum();
        c.max(m)
    };
    for rep in 0..dp {
        // Remaining-aware target keeps later partitions from starving when
        // earlier ones overshoot on a coarse unit.
        let parts_left = dp - rep;
        let target = remaining_time / parts_left as f64;
        let mut reqs = Vec::new();
        let (mut c, mut m) = (0.0f64, 0.0f64);
        let last = rep + 1 == dp;
        while l < r {
            // Density-steered side choice (dual-scanner reuse).
            let take_left = if m <= 0.0 { true } else { (c / m) <= rho_root };
            let u_idx = if take_left { l } else { r - 1 };
            let u = &us[u_idx];
            let after = (c + u.comp_eff).max(m + u.mem);
            if !last && after >= target {
                // Close before or after this unit, whichever lands nearer
                // the target.
                let before = c.max(m);
                if after - target <= target - before {
                    if take_left {
                        l += 1;
                    } else {
                        r -= 1;
                    }
                    reqs.extend_from_slice(&u.reqs);
                    c += u.comp_eff;
                    m += u.mem;
                }
                break;
            }
            if take_left {
                l += 1;
            } else {
                r -= 1;
            }
            reqs.extend_from_slice(&u.reqs);
            c += u.comp_eff;
            m += u.mem;
        }
        let t = c.max(m);
        remaining_time = (remaining_time - t).max(0.0);
        est_times.push(t);
        replicas.push(reqs);
    }
    DpPartition { replicas, est_times }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::presets;
    use crate::trace::synth::{synthesize, SynthSpec};
    use crate::trace::TraceKind;

    fn setup(n: usize) -> (PrefixTree, PerfModel, usize) {
        let pm = PerfModel::new(presets::llama3_8b(), presets::a100_80gb(), 1);
        let w = synthesize(&SynthSpec::new(TraceKind::BurstGpt, 1.1, 0.25, n), &pm);
        let mut tree = PrefixTree::build(&w);
        tree.sample_outputs(1.0, 3);
        tree.transform(&pm, 0.99);
        (tree, pm, w.len())
    }

    #[test]
    fn partitions_cover_all_requests() {
        let (tree, pm, n) = setup(1200);
        for dp in [1, 2, 4] {
            let part = partition_dp(&tree, &pm, dp);
            assert_eq!(part.replicas.len(), dp);
            let mut all: Vec<u32> =
                part.replicas.iter().flatten().copied().collect();
            all.sort_unstable();
            assert_eq!(all, (0..n as u32).collect::<Vec<_>>(), "dp={dp}");
        }
    }

    #[test]
    fn partitions_balanced() {
        let (tree, pm, _) = setup(2400);
        // Balance is granularity-limited: at test size (~2.4k requests) a
        // single OpenVid unit is ~half a partition's work, so the bound is
        // loose; at the paper's 400k-request scale imbalance is ~1.05
        // (Table 3 harness measures the end metric).
        for dp in [2, 4] {
            let part = partition_dp(&tree, &pm, dp);
            assert!(
                part.imbalance() < 1.35,
                "dp={dp}: imbalance {}",
                part.imbalance()
            );
        }
    }

    #[test]
    fn dp1_single_partition() {
        let (tree, pm, n) = setup(300);
        let part = partition_dp(&tree, &pm, 1);
        assert_eq!(part.replicas.len(), 1);
        assert_eq!(part.replicas[0].len(), n);
        assert!((part.imbalance() - 1.0).abs() < 1e-9);
    }

    #[test]
    fn partitions_each_contain_blendable_mix() {
        // Every partition should carry both compute- and memory-intensive
        // requests so each replica can blend locally (§5.5).
        let pm = PerfModel::new(presets::llama3_8b(), presets::a100_80gb(), 1);
        let w = synthesize(&SynthSpec::new(TraceKind::BurstGpt, 0.9, 0.2, 3000), &pm);
        let mut tree = PrefixTree::build(&w);
        tree.sample_outputs(1.0, 3);
        tree.transform(&pm, 0.99);
        let part = partition_dp(&tree, &pm, 2);
        for (i, reqs) in part.replicas.iter().enumerate() {
            let has_video = reqs
                .iter()
                .any(|&r| w.requests[r as usize].dataset == TraceKind::OpenVid);
            let has_compute = reqs
                .iter()
                .any(|&r| w.requests[r as usize].dataset == TraceKind::BurstGpt);
            assert!(has_video && has_compute, "replica {i} not blendable");
        }
    }
}
