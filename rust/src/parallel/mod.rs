//! Distributed deployment (§5.5): data parallelism via dual-scanner tree
//! decomposition, and tensor parallelism as resource scaling.
//!
//! **DP**: the centralized resource-aware prefix tree is decomposed into
//! `dp` *parallelized subtrees* with (a) balanced estimated processing
//! time and (b) per-partition density close to the global root density, so
//! every replica can blend locally.  The decomposition reuses the dual
//! scanner: units are taken from the compute end or the memory end
//! depending on which keeps the open partition's density near ρ(rt); a
//! partition closes when it reaches the per-replica time target.
//!
//! **TP**: both compute and bandwidth scale with the replica's GPU count
//! (communication assumed overlappable, as in NanoFlow/Centauri); this is
//! already captured by `PerfModel::new(model, hw, n_gpus)`.
//!
//! The decomposition is exposed at two granularities: [`partition_dp`]
//! flattens to request ids (the static fork-join used by
//! `server::serve_batch`), while [`work_units`] + [`assign_units`] keep
//! whole scheduling units so `server::fleet` can re-assign them at runtime
//! (work stealing) without shredding intra-unit prefix locality.

use crate::perfmodel::PerfModel;
use crate::tree::PrefixTree;

/// One scheduling unit priced for partitioning: the requests of one tree
/// node plus its estimated resource demand.  Units inherit the transformed
/// tree's DFS order, so a contiguous slice of a `WorkUnit` list is itself
/// in dual-scanner (density-descending) order.
#[derive(Clone, Debug)]
pub struct WorkUnit {
    pub requests: Vec<u32>,
    /// Sharing-discounted compute density of the unit.
    pub density: f64,
    /// Sharing-discounted compute seconds (`density * mem`).
    pub comp_eff: f64,
    /// Memory-bound seconds.
    pub mem: f64,
}

impl WorkUnit {
    /// Estimated optimal processing time of the unit in isolation.
    pub fn est_time(&self) -> f64 {
        self.comp_eff.max(self.mem)
    }
}

/// Price every scheduling unit of a transformed tree (estimated output
/// lengths must be filled in; aggregates recomputed).
pub fn work_units(tree: &PrefixTree, pm: &PerfModel) -> Vec<WorkUnit> {
    tree.scheduling_units()
        .into_iter()
        .map(|(id, density)| {
            let node = &tree.nodes[id];
            let mut mem = 0.0;
            for &r in &node.requests {
                let p = tree.input_len(r);
                let d = tree.est_output[r as usize].max(1) as usize;
                mem += pm.mem_request(p, d);
            }
            // density = comp_eff / mem  =>  comp_eff = density * mem.
            let comp_eff = if mem > 0.0 { density * mem } else { 0.0 };
            WorkUnit { requests: node.requests.clone(), density, comp_eff, mem }
        })
        .collect()
}

/// Unit-granular decomposition: which units go to which replica.
#[derive(Clone, Debug)]
pub struct UnitAssignment {
    /// Unit indices per replica, ascending (global density order), so each
    /// shard is itself a valid dual-scanner queue.  Only non-empty shards
    /// are returned: with fewer units than replicas (or a pathologically
    /// coarse unit), `parts.len() < weights.len()`.
    pub parts: Vec<Vec<usize>>,
    /// Estimated optimal processing time per returned shard.
    pub est_times: Vec<f64>,
    /// Which `weights` slot each returned shard was built for (identity
    /// mapping unless empty shards were dropped) — heterogeneous fleets
    /// use it to pair shards with their replica spec.
    pub owners: Vec<usize>,
}

/// Decompose a unit list into at most `weights.len()` shards whose
/// estimated times are proportional to `weights` (per-replica capability:
/// equal weights for a homogeneous deployment, relative FLOP/s for a
/// heterogeneous one).  Reuses the dual-scanner side choice so every open
/// shard tracks the root density ρ(rt).
pub fn assign_units(units: &[WorkUnit], rho_root: f64, weights: &[f64]) -> UnitAssignment {
    let dp = weights.len();
    assert!(dp >= 1, "need at least one replica weight");
    assert!(
        weights.iter().all(|w| *w > 0.0),
        "replica weights must be positive"
    );
    let mut parts: Vec<Vec<usize>> = Vec::with_capacity(dp);
    let mut est_times: Vec<f64> = Vec::with_capacity(dp);
    let mut owners: Vec<usize> = Vec::with_capacity(dp);
    let (mut l, mut r) = (0usize, units.len());
    let mut remaining_time = {
        let c: f64 = units.iter().map(|u| u.comp_eff).sum();
        let m: f64 = units.iter().map(|u| u.mem).sum();
        c.max(m)
    };
    let mut weight_left: f64 = weights.iter().sum();
    for (rep, &w) in weights.iter().enumerate() {
        // Remaining-aware, capability-weighted target keeps later shards
        // from starving when earlier ones overshoot on a coarse unit.
        let target = remaining_time * w / weight_left;
        weight_left -= w;
        let mut idxs = Vec::new();
        let (mut c, mut m) = (0.0f64, 0.0f64);
        let last = rep + 1 == dp;
        while l < r {
            // Density-steered side choice (dual-scanner reuse).
            let take_left = if m <= 0.0 { true } else { (c / m) <= rho_root };
            let u_idx = if take_left { l } else { r - 1 };
            let u = &units[u_idx];
            let after = (c + u.comp_eff).max(m + u.mem);
            if !last && after >= target {
                // Close before or after this unit, whichever lands nearer
                // the target.
                let before = c.max(m);
                if after - target <= target - before {
                    if take_left {
                        l += 1;
                    } else {
                        r -= 1;
                    }
                    idxs.push(u_idx);
                    c += u.comp_eff;
                    m += u.mem;
                }
                break;
            }
            if take_left {
                l += 1;
            } else {
                r -= 1;
            }
            idxs.push(u_idx);
            c += u.comp_eff;
            m += u.mem;
        }
        if idxs.is_empty() {
            // A shard that would start with a unit ≥ 2x its target closes
            // empty; dropping it (instead of handing run_system an empty
            // workload) re-targets the leftover weight onto later shards.
            continue;
        }
        idxs.sort_unstable();
        let t = c.max(m);
        remaining_time = (remaining_time - t).max(0.0);
        est_times.push(t);
        parts.push(idxs);
        owners.push(rep);
    }
    UnitAssignment { parts, est_times, owners }
}

/// Result of a DP decomposition: request ids per replica.  Contains only
/// non-empty replicas — `replicas.len()` may be smaller than the requested
/// `dp` when the workload has fewer scheduling units than replicas.
#[derive(Clone, Debug)]
pub struct DpPartition {
    pub replicas: Vec<Vec<u32>>,
    /// Estimated optimal processing time per replica (balance diagnostic).
    pub est_times: Vec<f64>,
}

impl DpPartition {
    /// Max/mean imbalance of the estimated replica times.  Replicas with
    /// zero estimated time (degenerate demands) are ignored so they cannot
    /// deflate the mean.
    pub fn imbalance(&self) -> f64 {
        let mut max = 0.0f64;
        let mut sum = 0.0f64;
        let mut n = 0usize;
        for &t in &self.est_times {
            if t > 0.0 {
                max = max.max(t);
                sum += t;
                n += 1;
            }
        }
        if n == 0 || sum <= 0.0 {
            1.0
        } else {
            max / (sum / n as f64)
        }
    }
}

/// Decompose a transformed tree into at most `dp` balanced partitions
/// (§5.5).
///
/// The tree must have been `transform`ed (or at least have aggregates
/// recomputed) so scheduling units carry densities; estimates come from
/// `est_output`.
pub fn partition_dp(tree: &PrefixTree, pm: &PerfModel, dp: usize) -> DpPartition {
    partition_dp_weighted(tree, pm, &vec![1.0; dp.max(1)])
}

/// [`partition_dp`] with per-replica capability weights (heterogeneous
/// fleets: a replica with 2x the FLOP/s gets a 2x share of the work).
pub fn partition_dp_weighted(
    tree: &PrefixTree,
    pm: &PerfModel,
    weights: &[f64],
) -> DpPartition {
    let units = work_units(tree, pm);
    let assignment = assign_units(&units, tree.root_density(), weights);
    let replicas = assignment
        .parts
        .iter()
        .map(|idxs| {
            idxs.iter()
                .flat_map(|&i| units[i].requests.iter().copied())
                .collect()
        })
        .collect();
    DpPartition { replicas, est_times: assignment.est_times }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::presets;
    use crate::trace::generators::generate_kind;
    use crate::trace::synth::{synthesize, SynthSpec};
    use crate::trace::TraceKind;

    fn setup(n: usize) -> (PrefixTree, PerfModel, usize) {
        let pm = PerfModel::new(presets::llama3_8b(), presets::a100_80gb(), 1);
        let w = synthesize(&SynthSpec::new(TraceKind::BurstGpt, 1.1, 0.25, n), &pm);
        let mut tree = PrefixTree::build(&w);
        tree.sample_outputs(1.0, 3);
        tree.transform(&pm, 0.99);
        (tree, pm, w.len())
    }

    #[test]
    fn partitions_cover_all_requests() {
        let (tree, pm, n) = setup(1200);
        for dp in [1, 2, 4] {
            let part = partition_dp(&tree, &pm, dp);
            assert_eq!(part.replicas.len(), dp);
            let mut all: Vec<u32> =
                part.replicas.iter().flatten().copied().collect();
            all.sort_unstable();
            assert_eq!(all, (0..n as u32).collect::<Vec<_>>(), "dp={dp}");
        }
    }

    #[test]
    fn partitions_balanced() {
        let (tree, pm, _) = setup(2400);
        // Balance is granularity-limited: at test size (~2.4k requests) a
        // single OpenVid unit is ~half a partition's work, so the bound is
        // loose; at the paper's 400k-request scale imbalance is ~1.05
        // (Table 3 harness measures the end metric).
        for dp in [2, 4] {
            let part = partition_dp(&tree, &pm, dp);
            assert!(
                part.imbalance() < 1.35,
                "dp={dp}: imbalance {}",
                part.imbalance()
            );
        }
    }

    #[test]
    fn dp1_single_partition() {
        let (tree, pm, n) = setup(300);
        let part = partition_dp(&tree, &pm, 1);
        assert_eq!(part.replicas.len(), 1);
        assert_eq!(part.replicas[0].len(), n);
        assert!((part.imbalance() - 1.0).abs() < 1e-9);
    }

    #[test]
    fn dp_exceeding_units_returns_fewer_nonempty_partitions() {
        // All requests share one prompt: a single scheduling unit.  Asking
        // for 8 replicas must yield one non-empty partition, not seven
        // empty workloads (which run_system would turn into NaN
        // throughputs), and imbalance must stay well-defined.
        let pm = PerfModel::new(presets::llama3_8b(), presets::a100_80gb(), 1);
        let w = crate::trace::Workload::new(
            "single-unit",
            (0..5)
                .map(|i| {
                    crate::trace::Request::new(i, TraceKind::Custom, vec![1, 2, 3], 16)
                })
                .collect(),
        );
        let mut tree = PrefixTree::build(&w);
        tree.sample_outputs(1.0, 3);
        tree.transform(&pm, 0.99);
        let part = partition_dp(&tree, &pm, 8);
        assert_eq!(part.replicas.len(), 1, "only one non-empty shard exists");
        assert_eq!(part.replicas[0].len(), 5);
        assert!(part.est_times[0] > 0.0);
        assert!((part.imbalance() - 1.0).abs() < 1e-9);
        assert!(part.imbalance().is_finite());
    }

    #[test]
    fn imbalance_ignores_empty_and_zero_entries() {
        let part = DpPartition {
            replicas: vec![vec![0], vec![1]],
            est_times: vec![2.0, 0.0],
        };
        // The zero entry must not halve the mean.
        assert!((part.imbalance() - 1.0).abs() < 1e-9);
        let empty = DpPartition { replicas: vec![], est_times: vec![] };
        assert_eq!(empty.imbalance(), 1.0);
    }

    #[test]
    fn weighted_partition_tracks_capability() {
        let (tree, pm, _) = setup(2400);
        let part = partition_dp_weighted(&tree, &pm, &[2.0, 1.0]);
        assert_eq!(part.replicas.len(), 2);
        let ratio = part.est_times[0] / part.est_times[1].max(1e-12);
        // Granularity-limited at test scale; the 2x-capable replica must
        // still clearly carry more estimated work.
        assert!(ratio > 1.3 && ratio < 3.1, "ratio {ratio}");
    }

    #[test]
    fn assign_units_empty_and_singleton() {
        let a = assign_units(&[], 1.0, &[1.0, 1.0]);
        assert!(a.parts.is_empty());
        let unit = WorkUnit {
            requests: vec![0, 1],
            density: 1.0,
            comp_eff: 2.0,
            mem: 2.0,
        };
        let a = assign_units(&[unit], 1.0, &[1.0, 1.0, 1.0]);
        assert_eq!(a.parts.len(), 1);
        assert_eq!(a.parts[0], vec![0]);
        assert_eq!(a.est_times, vec![2.0]);
    }

    #[test]
    fn assign_units_preserves_order_within_shards() {
        let (tree, pm, _) = setup(1500);
        let units = work_units(&tree, &pm);
        let a = assign_units(&units, tree.root_density(), &[1.0; 4]);
        let mut seen = vec![false; units.len()];
        for part in &a.parts {
            assert!(!part.is_empty());
            assert!(part.windows(2).all(|w| w[0] < w[1]), "shard not ascending");
            for &i in part {
                assert!(!seen[i], "unit {i} assigned twice");
                seen[i] = true;
            }
        }
        assert!(seen.iter().all(|&s| s), "unit dropped by assignment");
    }

    #[test]
    fn partitions_each_contain_blendable_mix() {
        // Every partition should carry both compute- and memory-intensive
        // requests so each replica can blend locally (§5.5).
        let pm = PerfModel::new(presets::llama3_8b(), presets::a100_80gb(), 1);
        let w = synthesize(&SynthSpec::new(TraceKind::BurstGpt, 0.9, 0.2, 3000), &pm);
        let mut tree = PrefixTree::build(&w);
        tree.sample_outputs(1.0, 3);
        tree.transform(&pm, 0.99);
        let part = partition_dp(&tree, &pm, 2);
        for (i, reqs) in part.replicas.iter().enumerate() {
            let has_video = reqs
                .iter()
                .any(|&r| w.requests[r as usize].dataset == TraceKind::OpenVid);
            let has_compute = reqs
                .iter()
                .any(|&r| w.requests[r as usize].dataset == TraceKind::BurstGpt);
            assert!(has_video && has_compute, "replica {i} not blendable");
        }
    }

    #[test]
    fn work_units_match_scheduling_units() {
        let pm = PerfModel::new(presets::llama3_8b(), presets::a100_80gb(), 1);
        let w = generate_kind(TraceKind::Mmlu, 400, 3);
        let mut tree = PrefixTree::build(&w);
        tree.sample_outputs(1.0, 3);
        tree.transform(&pm, 0.99);
        let units = work_units(&tree, &pm);
        let sched = tree.scheduling_units();
        assert_eq!(units.len(), sched.len());
        for (u, (id, density)) in units.iter().zip(&sched) {
            assert_eq!(u.requests, tree.nodes[*id].requests);
            assert!((u.density - density).abs() < 1e-12);
            assert!(u.est_time() >= 0.0);
        }
    }
}
