//! The embedding dedup cache (DESIGN.md §10): content-hash → encoder
//! embedding, refcounted against live requests, LRU-evicted under a byte
//! budget.
//!
//! This is the multi-modal analog of the runtime prefix cache: where the
//! radix cache deduplicates *token-sequence* prefixes, the encoder cache
//! deduplicates *media* — a popular image attached to many chat requests,
//! a conditioning clip reused across video-generation requests — so the
//! vision encoder runs once per distinct content hash instead of once per
//! attachment.
//!
//! Semantics (pinned by `tests/encoder_cache_oracle.rs` against a naive
//! reference):
//!
//! - [`EncoderCache::acquire`] looks up a content hash.  A **hit** pins
//!   the entry (refcount +1) and costs no encoder work.  A miss is
//!   cached-and-pinned only past two admission filters (below); otherwise
//!   it is **transient** — encoded but never cached (nor released).
//! - **Second-touch admission** (TinyLFU-style): the first sighting of a
//!   hash is never cached.  A dedup cache exists for *shared* content;
//!   one-off media — above all large unique video conditioning clips —
//!   would otherwise pin-starve and evict the reusable image embeddings.
//! - **Oversize bypass**: an entry larger than capacity/8 is never
//!   cached, bounding what any single medium can claim.
//! - [`EncoderCache::release`] unpins one reference; the entry stays
//!   resident (ordinary LRU candidate) until capacity pressure evicts it.
//! - Eviction strictly observes refcounts: a pinned entry is never
//!   evicted, exactly like the radix cache's pinned prefixes.
//!
//! Determinism: eviction picks the minimum `(last_use, hash)` key.  Ticks
//! are already unique (one per touch), so the hash tie-break is a
//! belt-and-suspenders guarantee that the iteration order of the backing
//! map can never influence behaviour, even if a future change makes
//! ticks collide.

use std::collections::{HashMap, HashSet};

/// Outcome of one [`EncoderCache::acquire`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Acquire {
    /// Embedding already resident: no encoder work, entry pinned.
    Hit,
    /// Not resident; inserted and pinned.  The caller owes one encoder
    /// pass (shared with any concurrent acquirer of the same hash).
    MissCached,
    /// Not resident and does not fit (pinned entries block eviction).
    /// The caller owes an encoder pass and must NOT release afterwards.
    MissTransient,
}

#[derive(Clone, Debug)]
struct Entry {
    tokens: u32,
    refs: u32,
    last_use: u64,
}

/// Content-hash dedup cache for encoder embeddings.
#[derive(Clone, Debug)]
pub struct EncoderCache {
    capacity_bytes: u64,
    bytes_per_token: f64,
    entries: HashMap<u64, Entry>,
    /// Hashes sighted at least once — the second-touch admission filter.
    seen: HashSet<u64>,
    used_bytes: u64,
    tick: u64,
    hit_tokens: u64,
    evictions: u64,
}

impl EncoderCache {
    /// Cache-admission bypass: an entry larger than `capacity / 8` is
    /// never cached.  One oversized one-off (a video conditioning clip)
    /// would otherwise pin-starve or evict dozens of small *reusable*
    /// embeddings — dedup targets shared content, and shared content is
    /// small and frequent.
    pub const OVERSIZED_DIVISOR: u64 = 8;

    pub fn new(capacity_bytes: u64, bytes_per_token: f64) -> Self {
        assert!(bytes_per_token > 0.0, "embed bytes/token must be positive");
        EncoderCache {
            capacity_bytes,
            bytes_per_token,
            entries: HashMap::new(),
            seen: HashSet::new(),
            used_bytes: 0,
            tick: 0,
            hit_tokens: 0,
            evictions: 0,
        }
    }

    fn entry_bytes(&self, tokens: u32) -> u64 {
        (tokens as f64 * self.bytes_per_token).ceil() as u64
    }

    /// Look up `content_hash`, pinning on hit or cacheable miss.
    pub fn acquire(&mut self, content_hash: u64, tokens: u32) -> Acquire {
        self.tick += 1;
        if let Some(e) = self.entries.get_mut(&content_hash) {
            debug_assert_eq!(
                e.tokens, tokens,
                "content hash {content_hash} reused with a different token count"
            );
            e.refs += 1;
            e.last_use = self.tick;
            self.hit_tokens += e.tokens as u64;
            return Acquire::Hit;
        }
        let need = self.entry_bytes(tokens);
        if need > self.capacity_bytes / Self::OVERSIZED_DIVISOR {
            return Acquire::MissTransient;
        }
        if self.seen.insert(content_hash) {
            // First touch: encoded but not cached.  Only content that
            // proves shared (a second sighting) earns residency.
            return Acquire::MissTransient;
        }
        // Evict unreferenced LRU entries until the new entry fits.
        while self.used_bytes + need > self.capacity_bytes {
            let victim = self
                .entries
                // lint:allow(r1) -- min over the total order (last_use, hash): visit
                // order cannot change which victim wins
                .iter()
                .filter(|(_, e)| e.refs == 0)
                .min_by_key(|(&h, e)| (e.last_use, h))
                .map(|(&h, _)| h);
            match victim {
                Some(h) => {
                    let e = self.entries.remove(&h).expect("victim present");
                    self.used_bytes -= self.entry_bytes(e.tokens);
                    self.evictions += 1;
                }
                // Everything resident is pinned: the embedding is
                // computed for this request but never cached.
                None => return Acquire::MissTransient,
            }
        }
        self.used_bytes += need;
        self.entries
            .insert(content_hash, Entry { tokens, refs: 1, last_use: self.tick });
        Acquire::MissCached
    }

    /// Unpin one reference on `content_hash`.  Panics (debug) on an
    /// unknown hash or a refcount underflow — callers track which
    /// attachments they actually pinned (`Acquire::MissTransient` pins
    /// nothing).
    pub fn release(&mut self, content_hash: u64) {
        let e = self
            .entries
            .get_mut(&content_hash)
            .expect("release of an attachment that was never pinned");
        assert!(e.refs > 0, "encoder cache refcount underflow");
        e.refs -= 1;
    }

    /// Bytes currently resident (pinned + reclaimable).
    pub fn used_bytes(&self) -> u64 {
        self.used_bytes
    }

    /// Total pinned references across all entries — the engine auditor's
    /// cross-check against the attachment pins held by active requests.
    pub fn total_refs(&self) -> u64 {
        // lint:allow(r1) -- commutative integer sum; iteration order is immaterial
        self.entries.values().map(|e| e.refs as u64).sum()
    }

    /// Tokens held by pinned (refcount > 0) entries.
    pub fn pinned_tokens(&self) -> u64 {
        self.entries
            // lint:allow(r1) -- commutative integer sum; iteration order is immaterial
            .values()
            .filter(|e| e.refs > 0)
            .map(|e| e.tokens as u64)
            .sum()
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    pub fn capacity_bytes(&self) -> u64 {
        self.capacity_bytes
    }

    /// Encoder tokens served from cache over the cache's lifetime.
    pub fn hit_tokens(&self) -> u64 {
        self.hit_tokens
    }

    pub fn evictions(&self) -> u64 {
        self.evictions
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cache(cap_tokens: u64) -> EncoderCache {
        // 1 byte per token keeps the arithmetic readable.
        EncoderCache::new(cap_tokens, 1.0)
    }

    /// First sighting of a hash: transient by the second-touch filter.
    fn prime(c: &mut EncoderCache, h: u64, tok: u32) {
        assert_eq!(c.acquire(h, tok), Acquire::MissTransient, "first touch cached");
    }

    #[test]
    fn second_touch_then_hit_then_dedup() {
        let mut c = cache(1000);
        prime(&mut c, 7, 100);
        assert!(c.is_empty(), "first touch must not cache");
        assert_eq!(c.acquire(7, 100), Acquire::MissCached);
        assert_eq!(c.acquire(7, 100), Acquire::Hit);
        assert_eq!(c.len(), 1);
        assert_eq!(c.used_bytes(), 100);
        assert_eq!(c.hit_tokens(), 100);
        assert_eq!(c.pinned_tokens(), 100); // two pins, one entry
        c.release(7);
        c.release(7);
        assert_eq!(c.pinned_tokens(), 0);
        assert_eq!(c.used_bytes(), 100); // stays resident for reuse
    }

    #[test]
    fn lru_eviction_spares_pinned() {
        // Capacity 800 fits eight 100-token entries (each exactly at the
        // oversize threshold of cap/8).
        let mut c = cache(800);
        for h in 1..=8u64 {
            prime(&mut c, h, 100);
            assert_eq!(c.acquire(h, 100), Acquire::MissCached);
        }
        c.release(2); // entry 2 unreferenced, LRU among unreferenced
        c.release(5);
        // Full: inserting 9 (primed) evicts the LRU unreferenced victim.
        prime(&mut c, 9, 100);
        assert_eq!(c.acquire(9, 100), Acquire::MissCached);
        assert_eq!(c.evictions(), 1);
        assert_eq!(c.acquire(1, 100), Acquire::Hit, "pinned entry evicted");
        assert_eq!(c.acquire(5, 100), Acquire::Hit, "MRU evicted before LRU");
        // The evicted victim is already `seen`, so its next acquire is a
        // (re-)insert attempt — blocked because everything is pinned.
        assert_eq!(c.acquire(2, 100), Acquire::MissTransient, "victim resident");
    }

    #[test]
    fn transient_when_pins_block() {
        let mut c = cache(800);
        for h in 1..=8u64 {
            prime(&mut c, h, 100);
            assert_eq!(c.acquire(h, 100), Acquire::MissCached); // all pinned
        }
        prime(&mut c, 99, 100);
        assert_eq!(c.acquire(99, 100), Acquire::MissTransient);
        assert_eq!(c.len(), 8);
        c.release(3);
        assert_eq!(c.acquire(99, 100), Acquire::MissCached); // 3 evictable
        assert_eq!(c.acquire(3, 100), Acquire::MissTransient);
    }

    #[test]
    fn oversized_entries_bypass_the_cache() {
        // Larger than capacity / OVERSIZED_DIVISOR: never cached — even
        // on repeated touches — so a huge conditioning clip cannot starve
        // reusable image embeds.
        let mut c = cache(800);
        assert_eq!(c.acquire(9, 101), Acquire::MissTransient);
        assert_eq!(c.acquire(9, 101), Acquire::MissTransient);
        assert!(c.is_empty());
        // At-threshold content follows the normal second-touch path.
        prime(&mut c, 8, 100);
        assert_eq!(c.acquire(8, 100), Acquire::MissCached);
        // Zero-capacity cache (modality cache disabled): everything
        // transient, nothing resident.
        let mut z = cache(0);
        assert_eq!(z.acquire(1, 1), Acquire::MissTransient);
        assert_eq!(z.acquire(1, 1), Acquire::MissTransient);
        assert_eq!(z.used_bytes(), 0);
    }

    #[test]
    #[should_panic(expected = "never pinned")]
    fn release_unknown_hash_panics() {
        cache(10).release(42);
    }

    #[test]
    fn fractional_bytes_round_up() {
        let mut c = EncoderCache::new(80, 1.5);
        prime(&mut c, 1, 3);
        assert_eq!(c.acquire(1, 3), Acquire::MissCached); // ceil(4.5) = 5
        assert_eq!(c.used_bytes(), 5);
        assert_eq!(c.acquire(2, 7), Acquire::MissTransient); // ceil(10.5) > 80/8
    }
}
