//! Multi-modal request subsystem (DESIGN.md §10): vision-encoder demand
//! modeling and the embedding dedup cache.
//!
//! BlendServe's premise is that modality diversity widens the compute /
//! memory demand spread the dual scanner blends over (§1, §6).  Until this
//! module every request was a bare token list; here a request may carry
//! image/video [`Attachment`]s that expand into *encoder* work:
//!
//! - **Demand**: an encoder pass is pure compute — patch/frame embeddings
//!   are produced once and occupy no KV cache — so attachments add a
//!   compute-only term to the §4 demand model
//!   ([`crate::perfmodel::Demand::enc`]).  A video-generation request that
//!   is deeply memory-bound on the LM side can be compute-bound overall
//!   once its conditioning frames are priced in, which is precisely the
//!   density spread the scanner partitions (§5.3).
//! - **Dedup**: shared media (a popular image, a re-used conditioning
//!   clip) is the multi-modal analog of prefix sharing.  [`EncoderCache`]
//!   deduplicates embeddings by content hash with a byte budget carved
//!   from device memory, refcounted against live requests and LRU-evicted
//!   (BatchLLM-style global dedup of shared content).
//! - **Overlap**: the engine (`engine/sim.rs`) schedules pending encoder
//!   passes into the compute headroom of memory-bound decode steps — the
//!   paper's resource overlapping with a third demand source.
//!
//! The `[modality]` config section controls *scheduler awareness* (whether
//! tree / dual-scan densities include the encoder term) and the cache
//! sizing; the engine always simulates the physics of whatever attachments
//! a workload carries, so attachment-free workloads are bit-identical to
//! the pre-modality engine no matter the config.

pub mod cache;

pub use cache::{Acquire, EncoderCache};

use crate::config::ModalityConfig;
use crate::perfmodel::PerfModel;

/// One image or video attached to a request, as the scheduler sees it:
/// a content identity plus the encoder-token count it expands to.
///
/// The patch/frame → token mapping is the *generator's* job (a ViT
/// tokenizes an image into its patch count; a video into
/// frames × patches-per-frame); the scheduler and engine only ever see
/// the resulting encoder-token count.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Attachment {
    /// Content hash of the raw media — the dedup key.  Two attachments
    /// with equal hashes share one encoder pass and one cached embedding.
    /// Kept ≤ 2^53 so it survives the JSONL number representation.
    pub content_hash: u64,
    /// Encoder tokens this attachment expands to (image: patches; video:
    /// frames × patches per frame).
    pub enc_tokens: u32,
}

impl Attachment {
    pub fn new(content_hash: u64, enc_tokens: u32) -> Self {
        Attachment { content_hash, enc_tokens }
    }
}

/// Modality profile of one request: its media attachments.  Empty for
/// text-only requests (the default), which keeps every pre-modality code
/// path untouched.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct ModalityProfile {
    pub attachments: Vec<Attachment>,
}

impl ModalityProfile {
    pub const EMPTY: ModalityProfile = ModalityProfile { attachments: Vec::new() };

    pub fn new(attachments: Vec<Attachment>) -> Self {
        ModalityProfile { attachments }
    }

    pub fn is_empty(&self) -> bool {
        self.attachments.is_empty()
    }

    /// Total encoder tokens over all attachments (before dedup — the
    /// scheduler prices the worst case; the cache only makes it cheaper).
    pub fn encoder_tokens(&self) -> u64 {
        self.attachments.iter().map(|a| a.enc_tokens as u64).sum()
    }
}

/// [`ModalityConfig`] resolved against one replica's perf model: the
/// constants the engine's encode path needs, precomputed once.
#[derive(Clone, Debug)]
pub struct ModalityParams {
    /// Embedding-cache capacity in bytes, carved from the replica's KV
    /// budget (`embed_cache_frac` × KV-capacity bytes).  The carve is
    /// only applied when the workload actually carries attachments
    /// (`SimEngine` checks), so text-only runs keep their full KV.
    pub cache_bytes: f64,
    /// Bytes one cached embedding token occupies.
    pub embed_bytes_per_token: f64,
}

impl ModalityParams {
    /// Resolve `cfg` against a replica's perf model.
    pub fn resolve(cfg: &ModalityConfig, pm: &PerfModel) -> Self {
        let kv_bytes = pm.kv_capacity_tokens() * pm.model.kv_bytes_per_token;
        ModalityParams {
            cache_bytes: cfg.embed_cache_frac * kv_bytes,
            embed_bytes_per_token: cfg.embed_bytes_per_token,
        }
    }

    /// KV tokens the embedding cache displaces on this model.
    pub fn carve_tokens(&self, kv_bytes_per_token: f64) -> f64 {
        self.cache_bytes / kv_bytes_per_token
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::presets;

    #[test]
    fn profile_token_accounting() {
        let p = ModalityProfile::new(vec![
            Attachment::new(1, 576),
            Attachment::new(2, 1152),
            Attachment::new(1, 576), // duplicate content still billed here
        ]);
        assert_eq!(p.encoder_tokens(), 576 + 1152 + 576);
        assert!(!p.is_empty());
        assert!(ModalityProfile::default().is_empty());
        assert_eq!(ModalityProfile::default().encoder_tokens(), 0);
    }

    #[test]
    fn resolve_carves_fraction_of_kv() {
        let pm = PerfModel::new(presets::llama3_8b(), presets::a100_80gb(), 1);
        let cfg = ModalityConfig { embed_cache_frac: 0.1, ..ModalityConfig::default() };
        let p = ModalityParams::resolve(&cfg, &pm);
        let kv_bytes = pm.kv_capacity_tokens() * pm.model.kv_bytes_per_token;
        assert!((p.cache_bytes - 0.1 * kv_bytes).abs() < 1.0);
        // Carving the cache back out displaces exactly its byte budget.
        let carved = p.carve_tokens(pm.model.kv_bytes_per_token);
        assert!((carved * pm.model.kv_bytes_per_token - p.cache_bytes).abs() < 1.0);
        assert!(carved < pm.kv_capacity_tokens());
    }
}
