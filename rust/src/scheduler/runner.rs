//! End-to-end driver: workload → prefix tree → (sampling, transform) →
//! admitter → engine → results.  Every paper experiment goes through
//! [`run_system`], so baselines and BlendServe differ only in their
//! `SystemConfig`.

use super::dual_scan::DualScanner;
use super::static_order;
use crate::config::{OrderPolicy, SystemConfig};
use crate::engine::sim::{SimEngine, SimRequest, SimResult, StaticOrder};
use crate::perfmodel::PerfModel;
use crate::trace::{stats, Workload};
use crate::tree::PrefixTree;

/// Everything a figure harness needs from one system run.
#[derive(Clone, Debug)]
pub struct RunOutput {
    pub system: String,
    pub result: SimResult,
    /// Optimal sharing ratio s_o of the workload (tree property).
    pub optimal_sharing: f64,
    /// Idealized optimal time T_o = max((1-s_o)·T_comp, T_mem).
    pub optimal_time: f64,
    /// Practical optimal (interference-inflated; §6.2).
    pub practical_optimal_time: f64,
    /// Practical optimal throughput (tokens/s).
    pub practical_optimal_throughput: f64,
    /// Fraction of practical optimal achieved.
    pub optimal_fraction: f64,
    /// Planner resource-area lower bound on makespan (DESIGN.md §11):
    /// valid for *any* scheduler on this workload/replica.
    pub makespan_lower_bound: f64,
    /// Measured optimality gap `total_time / makespan_lower_bound` (≥ 1
    /// up to model slack — the bound omits attention + chunk overheads).
    pub optimality_gap: f64,
    /// Tree-transform statistics (BlendServe only).
    pub transform_splits: usize,
    /// Warm-up samples drawn (BlendServe only).
    pub n_sampled: usize,
}

/// The BlendServe preprocessing pipeline: perf model + prefix tree with
/// §5.1 output sampling and the §5.2 transform applied.  Shared by
/// [`run_system`] and `server::colocate` so the "rate-0 co-location is
/// bit-identical to pure offline" invariant cannot drift between the two
/// paths.  Returns `(pm, tree, n_sampled, transform_splits)`.
pub fn prepare_blendserve(
    cfg: &SystemConfig,
    workload: &Workload,
) -> (PerfModel, PrefixTree, usize, usize) {
    let mut pm = PerfModel::new(
        cfg.model.clone(),
        cfg.hardware.clone(),
        cfg.gpus_per_replica,
    );
    pm.prefill_attn_flops = cfg.engine.prefill_attn_flops;
    // Modality awareness (encoder term in densities) keys on [modality];
    // with `enabled = false` the scheduler stays attachment-blind.
    pm.set_modality(&cfg.modality);
    let mut tree = PrefixTree::build(workload);
    let n = tree.sample_outputs(cfg.scheduler.sample_prob, cfg.scheduler.seed);
    let stats = tree.transform(&pm, cfg.scheduler.split_sharing_floor);
    (pm, tree, n, stats.splits)
}

/// Run one system configuration over a workload.
pub fn run_system(cfg: &SystemConfig, workload: &Workload) -> RunOutput {
    // Baselines schedule with no output-length knowledge; BlendServe
    // samples.  (Estimates only affect admission accounting + ordering.)
    let (pm, tree, n_sampled, transform_splits) = match cfg.scheduler.order {
        OrderPolicy::BlendServe => prepare_blendserve(cfg, workload),
        _ => {
            let mut pm = PerfModel::new(
                cfg.model.clone(),
                cfg.hardware.clone(),
                cfg.gpus_per_replica,
            );
            pm.prefill_attn_flops = cfg.engine.prefill_attn_flops;
            pm.set_modality(&cfg.modality);
            let mut tree = PrefixTree::build(workload);
            // Baselines still need *some* estimate for admission
            // accounting; use the same sampling mechanism (they all run
            // continuous batching with KV-aware admission in practice).
            let n = tree.sample_outputs(cfg.scheduler.sample_prob, cfg.scheduler.seed);
            tree.recompute_aggregates(&pm);
            (pm, tree, n, 0)
        }
    };

    let requests = SimRequest::from_workload(workload, &tree.est_output);
    let mut sched = cfg.scheduler.clone();
    // The chunk pacer discounts shared prefill compute (§5.3 C_L/C_R).
    sched.expected_sharing = tree.sharing_ratio();
    let mut engine = SimEngine::new(pm.clone(), cfg.engine.clone(), sched, requests)
        .with_kv(&cfg.kv)
        .with_modality(&cfg.modality);

    let result = match cfg.scheduler.order {
        OrderPolicy::BlendServe => {
            let mut admitter = DualScanner::new(&tree);
            engine.run(&mut admitter)
        }
        policy => {
            let order = static_order(policy, &tree, cfg.scheduler.seed);
            let mut admitter = StaticOrder::new(order);
            engine.run(&mut admitter)
        }
    };

    // Bounds (true output lengths; the bound is workload-intrinsic).
    let total = stats::total_demand(workload, &pm);
    let s_o = stats::optimal_sharing_ratio(workload);
    let t_o = pm.optimal_time(total, s_o);
    let t_po = pm.practical_optimal_time(total, s_o);
    let opt_tput = workload.total_tokens() as f64 / t_po.max(1e-12);
    let lb = crate::planner::workload_lower_bound(workload, &pm);

    RunOutput {
        system: format!("{}+{}", cfg.scheduler.order, cfg.engine.overlap.name()),
        optimal_sharing: s_o,
        optimal_time: t_o,
        practical_optimal_time: t_po,
        practical_optimal_throughput: opt_tput,
        optimal_fraction: result.throughput / opt_tput.max(1e-12),
        makespan_lower_bound: lb,
        optimality_gap: result.total_time / lb.max(1e-12),
        transform_splits,
        n_sampled,
        result,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::baselines;
    use crate::config::presets;
    use crate::trace::synth::{synthesize, SynthSpec};
    use crate::trace::TraceKind;

    fn workload(rho: f64, s: f64, n: usize) -> Workload {
        let pm = PerfModel::new(presets::llama3_8b(), presets::a100_80gb(), 1);
        synthesize(&SynthSpec::new(TraceKind::BurstGpt, rho, s, n), &pm)
    }

    #[test]
    fn blendserve_completes_and_reports_bounds() {
        let w = workload(1.2, 0.2, 600);
        let out = run_system(&baselines::blendserve(), &w);
        assert_eq!(
            out.result.total_tokens,
            w.total_tokens(),
            "all tokens processed"
        );
        assert!(out.optimal_fraction > 0.3 && out.optimal_fraction <= 1.05,
            "optimal fraction {}", out.optimal_fraction);
        assert!(out.optimal_time <= out.practical_optimal_time);
    }

    #[test]
    fn lower_bound_below_every_scheduler() {
        // DESIGN.md §11: the resource-area bound is valid for *any*
        // scheduler — no simulated makespan may undercut it.
        let w = workload(1.0, 0.3, 400);
        let mut systems = baselines::all_systems();
        systems.push(("Prefix-Aligned", baselines::prefix_aligned()));
        for (name, cfg) in systems {
            let out = run_system(&cfg, &w);
            assert!(
                out.makespan_lower_bound > 0.0 && out.makespan_lower_bound.is_finite(),
                "{name}: bound {}",
                out.makespan_lower_bound
            );
            assert!(
                out.result.total_time >= out.makespan_lower_bound * (1.0 - 1e-9),
                "{name}: makespan {} below lower bound {}",
                out.result.total_time,
                out.makespan_lower_bound
            );
            assert!(out.optimality_gap >= 1.0 - 1e-9, "{name}: gap {}", out.optimality_gap);
        }
    }

    #[test]
    fn prefix_aligned_is_a_working_system() {
        let w = workload(1.1, 0.3, 500);
        let out = run_system(&baselines::prefix_aligned(), &w);
        assert_eq!(out.result.total_tokens, w.total_tokens());
        // Alignment exists to preserve sharing: it must land in the same
        // league as DFS, far above the shuffled baseline.
        let dfs = run_system(&baselines::nanoflow_dfs(), &w);
        assert!(
            out.result.sharing_achieved >= dfs.result.sharing_achieved * 0.9,
            "aligned sharing {} vs dfs {}",
            out.result.sharing_achieved,
            dfs.result.sharing_achieved
        );
    }

    #[test]
    fn blendserve_beats_nanoflow_dfs_on_mixed_workload() {
        // The paper's headline (Fig. 7): on a density~1 workload with
        // sharing, BlendServe > NanoFlow-DFS.
        let w = workload(1.0, 0.3, 1500);
        let blend = run_system(&baselines::blendserve(), &w);
        let nano = run_system(&baselines::nanoflow_dfs(), &w);
        assert!(
            blend.result.throughput > nano.result.throughput,
            "blend {} vs nanoflow-dfs {}",
            blend.result.throughput,
            nano.result.throughput
        );
    }

    #[test]
    fn nanoflow_dfs_beats_vllm() {
        let w = workload(1.2, 0.3, 800);
        let nano = run_system(&baselines::nanoflow_dfs(), &w);
        let vllm = run_system(&baselines::vllm_dfs(), &w);
        assert!(
            nano.result.throughput > vllm.result.throughput,
            "nanoflow {} vs vllm {}",
            nano.result.throughput,
            vllm.result.throughput
        );
    }

    #[test]
    fn dfs_achieves_more_sharing_than_random() {
        // Use a small-memory GPU so the prefix cache is much smaller than
        // the workload footprint — the Fig. 9 regime (400k requests vs a
        // ~500k-token cache on the real A100).
        let w = workload(1.2, 0.35, 1000);
        let mut dfs_cfg = baselines::nanoflow_dfs();
        dfs_cfg.hardware.memory_bytes = 24e9;
        let mut bal_cfg = baselines::nanoflow_balance();
        bal_cfg.hardware.memory_bytes = 24e9;
        let dfs = run_system(&dfs_cfg, &w);
        let bal = run_system(&bal_cfg, &w);
        assert!(
            dfs.result.sharing_achieved > bal.result.sharing_achieved * 1.5,
            "dfs {} vs random {}",
            dfs.result.sharing_achieved,
            bal.result.sharing_achieved
        );
    }

    #[test]
    fn modality_pipeline_end_to_end() {
        // The full aware pipeline on the canonical mixed-modal trace:
        // every request completes, encoder work runs and overlaps into
        // decode headroom, and duplicate attachments dedup through the
        // embedding cache.  (The aware-vs-blind throughput comparison is
        // asserted in benches/modality.rs, where the pressure fixture
        // and seed aggregation control the margin.)
        use crate::trace::synth::mixed_modal;
        let w = mixed_modal(160, 80, 60, 0.5, 7);
        let mut cfg = baselines::blendserve();
        cfg.modality.enabled = true;
        let aware = run_system(&cfg, &w);
        assert_eq!(aware.result.total_tokens, w.total_tokens());
        assert!(aware.result.encode_time > 0.0, "no encoder work simulated");
        assert!(
            aware.result.encode_overlap_frac > 0.0,
            "no encoder work hidden under decode headroom"
        );
        assert!(aware.result.encode_overlap_frac <= 1.0);
        assert!(
            aware.result.embed_cache_hit_tokens > 0,
            "duplicate attachments never hit the dedup cache"
        );
        // Blind run: same physics (encode still happens), blind pricing.
        cfg.modality.enabled = false;
        let blind = run_system(&cfg, &w);
        assert_eq!(blind.result.total_tokens, w.total_tokens());
        assert!(blind.result.encode_time > 0.0);
        // The encoder term must widen the scheduler's view of the
        // workload: the aware bound prices more compute.
        let mut pm_blind =
            PerfModel::new(cfg.model.clone(), cfg.hardware.clone(), cfg.gpus_per_replica);
        pm_blind.set_modality(&cfg.modality);
        cfg.modality.enabled = true;
        let mut pm_aware =
            PerfModel::new(cfg.model.clone(), cfg.hardware.clone(), cfg.gpus_per_replica);
        pm_aware.set_modality(&cfg.modality);
        let db = stats::total_demand(&w, &pm_blind);
        let da = stats::total_demand(&w, &pm_aware);
        assert_eq!(db.enc, 0.0);
        assert!(da.enc > 0.0);
        assert!(da.density() > db.density());
    }

    #[test]
    fn blendserve_keeps_near_optimal_sharing() {
        // Fig. 9: ≥ 97% of the optimal prefix-sharing ratio.
        let w = workload(1.1, 0.3, 1500);
        let out = run_system(&baselines::blendserve(), &w);
        assert!(
            out.result.sharing_achieved >= out.optimal_sharing * 0.90,
            "achieved {} vs optimal {}",
            out.result.sharing_achieved,
            out.optimal_sharing
        );
    }
}
