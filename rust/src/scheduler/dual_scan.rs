//! The dual scanner (§5.3, Alg. 3): scan the density-sorted tree's
//! scheduling units from both ends simultaneously, partitioning KV memory
//! `M` into `M_L` (compute-intensive side) and `M_R` (memory-intensive
//! side) so the blended batch's density tracks the workload's root density
//! ρ(rt):
//!
//! ```text
//! M_L + M_R = M
//! M_L·ρ(R_L) + M_R·ρ(R_R) = M·ρ(rt)
//! ```
//!
//! Because both cursors traverse the (sorted) tree in DFS order, prefix
//! locality — and therefore the prefix-sharing ratio — is preserved on each
//! side.

use crate::engine::sim::{Admitter, EngineView, Side};
use crate::perfmodel::partition_memory;
use crate::tree::PrefixTree;

/// One scheduling unit: the requests attached to one tree node, plus the
/// unit's compute density.
#[derive(Clone, Debug)]
pub struct Unit {
    pub requests: Vec<u32>,
    pub density: f64,
    /// Estimated processing seconds of the unit in isolation — consulted
    /// only by the fleet coordinator when sizing steals (0 when the
    /// scanner was built without a perf model).
    pub est_cost: f64,
}

/// Dual-ended admitter over the transformed tree.
pub struct DualScanner {
    units: Vec<Unit>,
    rho_root: f64,
    // Left cursor: (unit, position); scans forward.
    l: (usize, usize),
    // Right cursor: scans backward; r.0 is one-past when exhausted.
    r: (usize, usize),
    /// Requests handed out (for exhaustion accounting).
    issued: usize,
    total: usize,
    last_side: Side,
}

impl DualScanner {
    /// Build from a transformed tree (children density-sorted).
    pub fn new(tree: &PrefixTree) -> Self {
        let units: Vec<Unit> = tree
            .scheduling_units()
            .into_iter()
            .map(|(id, density)| Unit {
                requests: tree.nodes[id].requests.clone(),
                density,
                est_cost: 0.0,
            })
            .collect();
        Self::from_units(units, tree.root_density())
    }

    /// Build from an explicit unit queue (the fleet path: a shard of the
    /// global density-sorted unit list, or a stolen slice of one).  The
    /// list must already be in dual-scanner order (density descending).
    pub fn from_units(units: Vec<Unit>, rho_root: f64) -> Self {
        let total = units.iter().map(|u| u.requests.len()).sum();
        let n = units.len();
        DualScanner {
            units,
            rho_root,
            l: (0, 0),
            r: (n.saturating_sub(1), 0),
            issued: 0,
            total,
            last_side: Side::Left,
        }
    }

    /// Replace a drained scanner's queue with freshly assigned units
    /// (work-stealing refill).  Only valid once the scanner is exhausted —
    /// a thief steals exactly when it has nothing left to issue.
    pub fn feed(&mut self, units: Vec<Unit>) {
        assert!(self.exhausted(), "feed is only valid on a drained scanner");
        let total = units.iter().map(|u| u.requests.len()).sum();
        let n = units.len();
        self.units = units;
        self.l = (0, 0);
        self.r = (n.saturating_sub(1), 0);
        self.issued = 0;
        self.total = total;
        self.last_side = Side::Left;
    }

    pub fn rho_root(&self) -> f64 {
        self.rho_root
    }

    /// Number of requests remaining.
    pub fn remaining(&self) -> usize {
        self.total - self.issued
    }

    /// Index range `[lo, hi)` of whole units neither cursor has touched —
    /// the only units a coordinator may steal without splitting a unit.
    fn whole_pending_range(&self) -> (usize, usize) {
        if self.crossed() {
            return (0, 0);
        }
        let lo = (self.l.0 + usize::from(self.l.1 > 0)).min(self.units.len());
        let hi = if self.r.0 == usize::MAX {
            lo
        } else if self.r.1 > 0 {
            self.r.0
        } else {
            self.r.0 + 1
        };
        (lo, hi.max(lo).min(self.units.len()))
    }

    /// Number of whole (steal-eligible) units still pending.
    pub fn stealable_units(&self) -> usize {
        let (lo, hi) = self.whole_pending_range();
        hi - lo
    }

    /// Total estimated cost of the steal-eligible units.
    pub fn remaining_whole_est(&self) -> f64 {
        let (lo, hi) = self.whole_pending_range();
        self.units[lo..hi].iter().map(|u| u.est_cost.max(0.0)).sum()
    }

    /// Remove whole pending units from the memory end (lowest-density end
    /// of the queue) until their accumulated `est_cost` reaches
    /// `target_est`, and return them in dual-scanner order.  The donor
    /// keeps its compute end and both partially-consumed cursor units, so
    /// its local blend continues undisturbed; each stolen unit keeps its
    /// internal prefix locality.
    pub fn steal_from_memory_end(&mut self, target_est: f64) -> Vec<Unit> {
        if target_est <= 0.0 {
            return Vec::new();
        }
        let (lo, hi) = self.whole_pending_range();
        if hi <= lo {
            return Vec::new();
        }
        let mut k = 0usize;
        let mut est = 0.0f64;
        while k < hi - lo && est < target_est {
            est += self.units[hi - 1 - k].est_cost.max(0.0);
            k += 1;
        }
        if k == 0 {
            return Vec::new();
        }
        let stolen: Vec<Unit> = self.units.drain(hi - k..hi).collect();
        let stolen_reqs: usize = stolen.iter().map(|u| u.requests.len()).sum();
        self.total -= stolen_reqs;
        if self.r.0 != usize::MAX {
            if self.r.1 > 0 {
                // The right cursor's partially-consumed unit sits just past
                // the stolen range (`r.0 == hi`); the drain shifted it down
                // by `k`.
                debug_assert_eq!(self.r.0, hi);
                self.r.0 -= k;
            } else {
                // The right cursor's untouched unit (`r.0 == hi - 1`) was
                // itself stolen: retarget to the new memory end, or the
                // exhausted sentinel when nothing remains to its left.
                debug_assert_eq!(self.r.0 + 1, hi);
                match (hi - k).checked_sub(1) {
                    Some(new_r) => self.r = (new_r, 0),
                    None => self.r = (usize::MAX, 0),
                }
            }
        }
        stolen
    }

    /// Remove and return every request neither cursor has issued, as
    /// rump units in dual-scanner order — the reclamation path when this
    /// scanner's replica dies (DESIGN.md §12).  Unlike
    /// [`Self::steal_from_memory_end`], which may only take whole
    /// untouched units (the donor keeps scanning its partial ones), a
    /// dead replica scans nothing ever again, so the cursor-partial units
    /// are cut down to their unissued remainders and handed back too.
    /// Each rump keeps its density (a property of the shared prefix, not
    /// of the count) and scales `est_cost` by the fraction of requests
    /// remaining.  The scanner is left exhausted (and may be re-armed
    /// with [`Self::feed`], though a dead replica's scanner never is).
    pub fn drain_pending(&mut self) -> Vec<Unit> {
        if self.crossed() {
            self.units.clear();
            self.total = self.issued;
            return Vec::new();
        }
        let mut out = Vec::new();
        for (i, u) in self.units.iter().enumerate() {
            let n = u.requests.len();
            let left_taken = if i < self.l.0 {
                n
            } else if i == self.l.0 {
                self.l.1.min(n)
            } else {
                0
            };
            let right_taken = if self.r.0 == usize::MAX || i > self.r.0 {
                n
            } else if i == self.r.0 {
                self.r.1.min(n)
            } else {
                0
            };
            if left_taken + right_taken >= n {
                continue;
            }
            let remaining = &u.requests[left_taken..n - right_taken];
            out.push(Unit {
                requests: remaining.to_vec(),
                density: u.density,
                est_cost: u.est_cost.max(0.0) * remaining.len() as f64 / n as f64,
            });
        }
        debug_assert_eq!(
            out.iter().map(|u| u.requests.len()).sum::<usize>(),
            self.total - self.issued,
            "drain_pending dropped or duplicated requests"
        );
        self.units.clear();
        self.total = self.issued;
        self.l = (0, 0);
        self.r = (usize::MAX, 0);
        out
    }

    fn left_req(&self) -> Option<u32> {
        self.units
            .get(self.l.0)
            .and_then(|u| u.requests.get(self.l.1).copied())
    }

    /// Right cursor position `r.1` counts from the unit's tail.
    fn right_req(&self) -> Option<u32> {
        let u = self.units.get(self.r.0)?;
        let n = u.requests.len();
        if self.r.1 < n {
            u.requests.get(n - 1 - self.r.1).copied()
        } else {
            None
        }
    }

    /// Do the cursors still point at distinct requests?
    fn crossed(&self) -> bool {
        self.issued >= self.total
    }

    fn advance_left(&mut self) {
        self.l.1 += 1;
        while self.l.0 < self.units.len()
            && self.l.1 >= self.units[self.l.0].requests.len()
        {
            self.l.0 += 1;
            self.l.1 = 0;
        }
    }

    fn advance_right(&mut self) {
        self.r.1 += 1;
        while self.r.1 >= self.units.get(self.r.0).map(|u| u.requests.len()).unwrap_or(0)
        {
            if self.r.0 == 0 {
                self.r = (usize::MAX, 0); // exhausted sentinel
                return;
            }
            self.r.0 -= 1;
            self.r.1 = 0;
        }
    }

    /// Current densities at the cursors (for tests / diagnostics).
    pub fn cursor_densities(&self) -> (f64, f64) {
        let dl = self.units.get(self.l.0).map(|u| u.density).unwrap_or(0.0);
        let dr = self.units.get(self.r.0).map(|u| u.density).unwrap_or(0.0);
        (dl, dr)
    }

    /// The same request must not be handed out by both cursors: when the
    /// cursors sit in the same unit, the left cursor owns positions
    /// `< len - r.1`.
    fn same_unit_clash(&self) -> bool {
        self.l.0 == self.r.0
            && self.l.1 + self.r.1 >= self.units.get(self.l.0).map(|u| u.requests.len()).unwrap_or(0)
    }
}

impl Admitter for DualScanner {
    fn peek(&mut self, view: &EngineView) -> Option<(u32, Side)> {
        if self.crossed() {
            return None;
        }
        let left_ok = self.left_req().is_some() && !self.same_unit_clash()
            || (self.left_req().is_some() && self.right_req().is_none());
        let right_ok = self.right_req().is_some() && !self.same_unit_clash()
            || (self.right_req().is_some() && self.left_req().is_none());
        // When the cursors collide in one unit, drain it from the left.
        if self.same_unit_clash() || !right_ok {
            if let Some(r) = self.left_req() {
                self.last_side = Side::Left;
                return Some((r, Side::Left));
            }
            // Left exhausted: fall through to right.
        }
        if !left_ok {
            if let Some(r) = self.right_req() {
                self.last_side = Side::Right;
                return Some((r, Side::Right));
            }
            return None;
        }

        // Both sides available: partition memory by the §5.3 equations and
        // admit into the side that is under its target.
        let (rho_l, rho_r) = self.cursor_densities();
        let (ml, mr) = partition_memory(view.kv_capacity, self.rho_root, rho_l, rho_r);
        let side = if view.used_left < ml {
            Side::Left
        } else if view.used_right < mr {
            Side::Right
        } else {
            // Both at target (numerically full): admit to the relatively
            // emptier side so progress continues.
            if view.used_left / ml.max(1e-9) <= view.used_right / mr.max(1e-9) {
                Side::Left
            } else {
                Side::Right
            }
        };
        self.last_side = side;
        match side {
            Side::Left => self.left_req().map(|r| (r, Side::Left)),
            Side::Right => self.right_req().map(|r| (r, Side::Right)),
        }
    }

    fn pop(&mut self) {
        match self.last_side {
            Side::Left => self.advance_left(),
            Side::Right => self.advance_right(),
        }
        self.issued += 1;
    }

    fn exhausted(&self) -> bool {
        self.crossed()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::presets;
    use crate::perfmodel::PerfModel;
    use crate::trace::synth::{synthesize, SynthSpec};
    use crate::trace::TraceKind;

    fn pm() -> PerfModel {
        PerfModel::new(presets::llama3_8b(), presets::a100_80gb(), 1)
    }

    fn scanner_for(n: usize) -> (DualScanner, usize) {
        let pm = pm();
        let w = synthesize(&SynthSpec::new(TraceKind::BurstGpt, 1.0, 0.2, n), &pm);
        let mut tree = PrefixTree::build(&w);
        tree.sample_outputs(1.0, 3);
        tree.transform(&pm, 0.99);
        (DualScanner::new(&tree), w.len())
    }

    fn view(cap: f64, left: f64, right: f64) -> EngineView {
        EngineView {
            step: 1,
            now: 0.0,
            kv_capacity: cap,
            kv_used: left + right,
            active_requests: 0,
            used_left: left,
            used_right: right,
        }
    }

    #[test]
    fn issues_each_request_exactly_once() {
        let (mut s, n) = scanner_for(800);
        let mut seen = std::collections::HashSet::new();
        let mut flips = 0usize;
        let mut last = None;
        while let Some((r, side)) = s.peek(&view(1e6, 0.0, 0.0)) {
            assert!(seen.insert(r), "request {r} issued twice");
            if last.is_some() && last != Some(side) {
                flips += 1;
            }
            last = Some(side);
            s.pop();
        }
        assert_eq!(seen.len(), n);
        assert!(s.exhausted());
        // With used=0 the scanner always wants the left side first; flips
        // happen as sides saturate in real runs — here we just require the
        // iteration to terminate cleanly.
        let _ = flips;
    }

    #[test]
    fn left_cursor_yields_denser_requests_than_right() {
        let (mut s, _) = scanner_for(1000);
        // Force alternating sides via the view: saturate left, then right.
        let (dl0, dr0) = s.cursor_densities();
        assert!(dl0 > dr0, "left {dl0} right {dr0}");
        // Peek left request.
        let (rl, sl) = s.peek(&view(1e6, 0.0, 1e9)).unwrap();
        assert_eq!(sl, Side::Left);
        // Saturate left: next peek must go right.
        let (rr, sr) = s.peek(&view(1e6, 1e9, 0.0)).unwrap();
        assert_eq!(sr, Side::Right);
        assert_ne!(rl, rr);
    }

    #[test]
    fn memory_partition_steers_admission() {
        let (mut s, _) = scanner_for(1000);
        let (rho_l, rho_r) = s.cursor_densities();
        let cap = 1e6;
        let (ml, mr) = partition_memory(cap, s.rho_root(), rho_l, rho_r);
        assert!(ml > 0.0 && mr > 0.0, "ml={ml} mr={mr}");
        // Under-target left -> Left.
        assert_eq!(s.peek(&view(cap, ml * 0.5, 0.0)).unwrap().1, Side::Left);
        // Left at target, right under -> Right.
        assert_eq!(s.peek(&view(cap, ml * 1.01, 0.0)).unwrap().1, Side::Right);
    }

    #[test]
    fn single_unit_workload_drains_left() {
        // All requests identical density: one unit; left drains it.
        let pm = pm();
        let w = crate::trace::generators::generate_kind(TraceKind::BurstGpt, 50, 3);
        let mut tree = PrefixTree::build(&w);
        tree.sample_outputs(1.0, 3);
        tree.transform(&pm, 0.99);
        let mut s = DualScanner::new(&tree);
        let mut count = 0;
        while let Some((_, _)) = s.peek(&view(1e6, 0.0, 0.0)) {
            s.pop();
            count += 1;
        }
        assert_eq!(count, 50);
    }

    #[test]
    fn blended_admission_tracks_root_density() {
        // Simulate admission accounting: charge each admitted request's
        // est kv to its side; the weighted density of admitted requests
        // should approach rho_root.
        let pm = pm();
        let w = synthesize(&SynthSpec::new(TraceKind::BurstGpt, 1.1, 0.2, 2000), &pm);
        let mut tree = PrefixTree::build(&w);
        tree.sample_outputs(1.0, 3);
        tree.transform(&pm, 0.99);
        let rho_root = tree.root_density();
        let mut s = DualScanner::new(&tree);
        let cap = pm.kv_capacity_tokens();
        let (mut used_l, mut used_r) = (0.0, 0.0);
        let mut comp = 0.0;
        let mut mem = 0.0;
        // Admit until capacity (one "batch snapshot").
        while used_l + used_r < cap {
            let v = view(cap, used_l, used_r);
            let Some((r, side)) = s.peek(&v) else { break };
            s.pop();
            let req = &w.requests[r as usize];
            let est = req.input_len() as f64 + req.output_len as f64 / 2.0;
            match side {
                Side::Left => used_l += est,
                Side::Right => used_r += est,
            }
            let d = pm.demand(req.input_len(), req.output_len as usize);
            comp += d.comp;
            mem += d.mem;
        }
        let batch_density = comp / mem.max(1e-12);
        // The admitted blend should sit near rho_root — far from the pure
        // left (compute) or right (memory) densities.  (Sharing discounts
        // make exact equality impossible; 2x is the sanity band.)
        assert!(
            batch_density > rho_root * 0.4 && batch_density < rho_root * 3.0,
            "batch density {batch_density} vs root {rho_root}"
        );
    }

    // ---- unit-queue API (fleet path) ----

    fn unit(ids: std::ops::Range<u32>, density: f64, est: f64) -> Unit {
        Unit { requests: ids.collect(), density, est_cost: est }
    }

    #[test]
    fn from_units_empty_list_is_exhausted() {
        let mut s = DualScanner::from_units(vec![], 1.0);
        assert!(s.exhausted());
        assert_eq!(s.remaining(), 0);
        assert_eq!(s.peek(&view(1e6, 0.0, 0.0)), None);
        assert_eq!(s.stealable_units(), 0);
        assert_eq!(s.remaining_whole_est(), 0.0);
        assert!(s.steal_from_memory_end(1e9).is_empty());
    }

    #[test]
    fn from_units_singleton_drains_and_steals() {
        // Untouched singleton: the one unit is steal-eligible.
        let mut s = DualScanner::from_units(vec![unit(0..3, 2.0, 5.0)], 1.0);
        assert_eq!(s.stealable_units(), 1);
        assert!((s.remaining_whole_est() - 5.0).abs() < 1e-12);
        let stolen = s.steal_from_memory_end(1.0);
        assert_eq!(stolen.len(), 1);
        assert_eq!(stolen[0].requests, vec![0, 1, 2]);
        assert!(s.exhausted(), "donor empty after losing its only unit");
        assert_eq!(s.peek(&view(1e6, 0.0, 0.0)), None);

        // Touched singleton: nothing whole remains, stealing is refused
        // and the cursor drains the unit normally.
        let mut s = DualScanner::from_units(vec![unit(0..3, 2.0, 5.0)], 1.0);
        assert!(s.peek(&view(1e6, 0.0, 0.0)).is_some());
        s.pop();
        assert_eq!(s.stealable_units(), 0);
        assert!(s.steal_from_memory_end(1e9).is_empty());
        let mut n = 1;
        while s.peek(&view(1e6, 0.0, 0.0)).is_some() {
            s.pop();
            n += 1;
        }
        assert_eq!(n, 3);
        assert!(s.exhausted());
    }

    #[test]
    fn steal_mid_scan_preserves_exactly_once_issue() {
        let units = vec![
            unit(0..3, 3.0, 1.0),
            unit(3..6, 2.0, 1.0),
            unit(6..9, 1.0, 1.0),
            unit(9..12, 0.5, 1.0),
        ];
        let mut s = DualScanner::from_units(units, 1.5);
        let mut issued = std::collections::HashSet::new();
        // Consume two from the compute end and one from the memory end.
        for _ in 0..2 {
            let (r, side) = s.peek(&view(1e6, 0.0, 1e9)).unwrap();
            assert_eq!(side, Side::Left);
            issued.insert(r);
            s.pop();
        }
        let (r, side) = s.peek(&view(1e6, 1e9, 0.0)).unwrap();
        assert_eq!(side, Side::Right);
        issued.insert(r);
        s.pop();
        // Whole pending units: 1 and 2 (unit 0 and 3 are cursor-partial).
        assert_eq!(s.stealable_units(), 2);
        let stolen = s.steal_from_memory_end(1.5);
        assert_eq!(stolen.len(), 2, "1.5s target takes both 1s units");
        let stolen_reqs: Vec<u32> =
            stolen.iter().flat_map(|u| u.requests.iter().copied()).collect();
        assert_eq!(stolen_reqs, vec![3, 4, 5, 6, 7, 8], "dual-scanner order kept");
        // Donor drains the rest of its two partial units.
        while let Some((r, _)) = s.peek(&view(1e6, 0.0, 0.0)) {
            assert!(issued.insert(r), "request {r} issued twice");
            s.pop();
        }
        assert!(s.exhausted());
        let mut all: Vec<u32> = issued.into_iter().collect();
        all.extend(stolen_reqs);
        all.sort_unstable();
        assert_eq!(all, (0..12).collect::<Vec<u32>>());

        // The stolen slice drives a thief scanner to completion.
        let mut thief = DualScanner::from_units(stolen, 1.5);
        let mut got = Vec::new();
        while let Some((r, _)) = thief.peek(&view(1e6, 0.0, 0.0)) {
            got.push(r);
            thief.pop();
        }
        got.sort_unstable();
        assert_eq!(got, (3..9).collect::<Vec<u32>>());
    }

    #[test]
    fn steal_respects_target_and_leaves_compute_end() {
        let units: Vec<Unit> =
            (0..6).map(|i| unit(i * 2..i * 2 + 2, (6 - i) as f64, 2.0)).collect();
        let mut s = DualScanner::from_units(units, 3.0);
        // Steal ~half the 12s of whole pending work: 3 memory-end units.
        let stolen = s.steal_from_memory_end(6.0);
        assert_eq!(stolen.len(), 3);
        assert_eq!(stolen[0].requests, vec![6, 7], "compute end stays with donor");
        assert_eq!(s.stealable_units(), 3);
        assert_eq!(s.remaining(), 6);
        let mut got = Vec::new();
        while let Some((r, _)) = s.peek(&view(1e6, 0.0, 0.0)) {
            got.push(r);
            s.pop();
        }
        got.sort_unstable();
        assert_eq!(got, (0..6).collect::<Vec<u32>>());
    }

    #[test]
    fn drain_pending_returns_everything_on_a_fresh_scanner() {
        let units = vec![unit(0..3, 3.0, 1.0), unit(3..6, 1.0, 2.0)];
        let mut s = DualScanner::from_units(units.clone(), 1.5);
        let drained = s.drain_pending();
        assert_eq!(drained.len(), 2);
        assert_eq!(drained[0].requests, vec![0, 1, 2]);
        assert_eq!(drained[1].requests, vec![3, 4, 5]);
        assert_eq!(drained[1].est_cost, 2.0, "untouched unit keeps full est");
        assert!(s.exhausted());
        assert_eq!(s.remaining(), 0);
        assert_eq!(s.peek(&view(1e6, 0.0, 0.0)), None);
    }

    #[test]
    fn drain_pending_mid_scan_partitions_exactly_once() {
        let units = vec![
            unit(0..3, 3.0, 3.0),
            unit(3..6, 2.0, 3.0),
            unit(6..9, 1.0, 3.0),
            unit(9..12, 0.5, 3.0),
        ];
        let mut s = DualScanner::from_units(units, 1.5);
        let mut issued = Vec::new();
        // Two from the compute end, one from the memory end.
        for _ in 0..2 {
            let (r, _) = s.peek(&view(1e6, 0.0, 1e9)).unwrap();
            issued.push(r);
            s.pop();
        }
        let (r, _) = s.peek(&view(1e6, 1e9, 0.0)).unwrap();
        issued.push(r);
        s.pop();
        let drained = s.drain_pending();
        // Rump of unit 0 (one request), whole units 1 and 2, rump of
        // unit 3 — dual-scanner order, cursor-partials cut down.
        assert_eq!(drained.len(), 4);
        assert_eq!(drained[0].requests, vec![2]);
        assert!((drained[0].est_cost - 1.0).abs() < 1e-12, "est scaled 1/3");
        assert_eq!(drained[1].requests, vec![3, 4, 5]);
        assert_eq!(drained[2].requests, vec![6, 7, 8]);
        assert_eq!(drained[3].requests, vec![9, 10]);
        assert!((drained[3].est_cost - 2.0).abs() < 1e-12, "est scaled 2/3");
        // Issued + drained = every request exactly once.
        let mut all: Vec<u32> = issued;
        all.extend(drained.iter().flat_map(|u| u.requests.iter().copied()));
        all.sort_unstable();
        assert_eq!(all, (0..12).collect::<Vec<u32>>());
        assert!(s.exhausted());
        // The corpse's scanner can still be re-armed (feed asserts
        // exhausted) even though the fleet never does this.
        s.feed(vec![unit(20..22, 1.0, 1.0)]);
        assert_eq!(s.remaining(), 2);
    }

    #[test]
    fn drain_pending_on_exhausted_scanner_is_empty() {
        let mut s = DualScanner::from_units(vec![unit(0..2, 1.0, 1.0)], 1.0);
        while s.peek(&view(1e6, 0.0, 0.0)).is_some() {
            s.pop();
        }
        assert!(s.drain_pending().is_empty());
        assert!(s.exhausted());
        assert!(DualScanner::from_units(vec![], 1.0).drain_pending().is_empty());
    }

    #[test]
    fn feed_refills_a_drained_scanner() {
        let mut s = DualScanner::from_units(vec![unit(0..2, 1.0, 1.0)], 1.0);
        while s.peek(&view(1e6, 0.0, 0.0)).is_some() {
            s.pop();
        }
        assert!(s.exhausted());
        s.feed(vec![unit(5..8, 2.0, 1.0), unit(8..10, 0.5, 1.0)]);
        assert!(!s.exhausted());
        assert_eq!(s.remaining(), 5);
        let mut got = Vec::new();
        while let Some((r, _)) = s.peek(&view(1e6, 0.0, 0.0)) {
            got.push(r);
            s.pop();
        }
        got.sort_unstable();
        assert_eq!(got, (5..10).collect::<Vec<u32>>());
        assert!(s.exhausted());
        // Feeding an empty batch keeps the scanner exhausted.
        s.feed(vec![]);
        assert!(s.exhausted());
        assert_eq!(s.peek(&view(1e6, 0.0, 0.0)), None);
    }
}
