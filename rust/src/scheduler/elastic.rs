//! SLO-aware elastic admission for online/offline co-located serving
//! (DESIGN.md §Co-located-Serving).
//!
//! [`ElasticAdmitter`] interleaves an *open* stream of latency-sensitive
//! online requests into BlendServe's offline blend schedule.  It wraps the
//! [`DualScanner`] (§5.3) unchanged, so the offline side keeps its
//! density-blending and prefix-tree DFS locality, and layers three
//! policies on top:
//!
//! 1. **Immediate online admission** — an online request that has arrived
//!    (`arrival <= now`) is always the next candidate, ahead of offline
//!    work and even ahead of the engine's retraction queue when urgent.
//! 2. **Elastic headroom** — while online requests remain in the stream,
//!    offline admissions are withheld whenever committed KV exceeds
//!    `(1 - reserve_frac) · capacity`, keeping a burst buffer warm.  The
//!    reserve evaporates the moment the online stream is exhausted (and is
//!    never allowed to idle an empty engine), so a zero-rate stream is
//!    bit-identical to pure offline BlendServe.
//! 3. **SLO-risk preemption** — when the TTFT slack of the
//!    head-of-line online request falls below `urgency · ttft_slo`, the
//!    admitter reports *urgent* and the engine retracts the newest
//!    offline request to make room (engine/sim.rs).  When the tiered KV
//!    manager is active ([`ElasticAdmitter::with_cheap_preemption`]),
//!    a preempted offline request swaps to host instead of losing its
//!    progress, so the admitter widens the urgency window by
//!    [`CHEAP_PREEMPT_BOOST`] — it can afford to preempt earlier because
//!    being wrong no longer costs a full recompute.
//!
//! When the online load ebbs, 1-3 all go quiescent and the dual scanner's
//! schedule flows through verbatim — offline backfill costs nothing in
//! mechanism, only the headroom reserve.

use super::dual_scan::DualScanner;
use crate::engine::sim::{Admitter, EngineView, Side};
use crate::trace::online::OnlineWorkload;

/// Factor applied to the urgency threshold when offline preemption is
/// cheap (tiered KV swap active): the TTFT-slack window that triggers
/// preemption widens by this much, capped at the full SLO.
pub const CHEAP_PREEMPT_BOOST: f64 = 1.5;

/// One online request as the admitter tracks it.
#[derive(Clone, Copy, Debug)]
pub struct OnlineItem {
    /// Engine request id (index into the combined `SimRequest` set).
    pub id: u32,
    pub arrival: f64,
    pub ttft_slo: f64,
}

/// Which queue served the most recent `peek` (consumed by `pop`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum LastQueue {
    Online,
    Offline,
}

/// SLO-aware admitter blending an online stream into the dual scanner.
pub struct ElasticAdmitter {
    offline: DualScanner,
    /// Online stream sorted by arrival; `online_pos` is the cursor.
    online: Vec<OnlineItem>,
    online_pos: usize,
    /// Fraction of KV capacity withheld from offline admission while
    /// online requests remain (0 disables the reserve).
    reserve_frac: f64,
    /// TTFT-slack fraction below which the pending online admission
    /// becomes urgent (0 disables preemption).
    urgency: f64,
    last: LastQueue,
}

impl ElasticAdmitter {
    /// `online` items need not be sorted; they are ordered by arrival.
    pub fn new(
        offline: DualScanner,
        mut online: Vec<OnlineItem>,
        reserve_frac: f64,
        urgency: f64,
    ) -> Self {
        assert!((0.0..1.0).contains(&reserve_frac), "reserve_frac {reserve_frac}");
        assert!((0.0..=1.0).contains(&urgency), "urgency {urgency}");
        online.sort_by(|a, b| a.arrival.partial_cmp(&b.arrival).unwrap());
        ElasticAdmitter {
            offline,
            online,
            online_pos: 0,
            reserve_frac,
            urgency,
            last: LastQueue::Offline,
        }
    }

    /// Widen the urgency window when offline preemption is cheap (the
    /// engine swaps the victim's KV to host instead of discarding it).
    /// With `cheap = false` this is the identity, so a kv-disabled
    /// co-located run stays bit-identical to the pre-tiering admitter.
    pub fn with_cheap_preemption(mut self, cheap: bool) -> Self {
        if cheap {
            self.urgency = (self.urgency * CHEAP_PREEMPT_BOOST).min(1.0);
        }
        self
    }

    /// Convenience: build the online side from a generated stream whose
    /// engine ids start at `id_base` (requests keep stream order).
    pub fn online_items(stream: &OnlineWorkload, id_base: u32) -> Vec<OnlineItem> {
        stream
            .requests
            .iter()
            .enumerate()
            .map(|(i, r)| OnlineItem {
                id: id_base + i as u32,
                arrival: r.arrival,
                ttft_slo: r.ttft_slo,
            })
            .collect()
    }

    /// Online requests not yet handed to the engine.
    pub fn remaining_online(&self) -> usize {
        self.online.len() - self.online_pos
    }

    /// Offline requests not yet handed to the engine.
    pub fn remaining_offline(&self) -> usize {
        self.offline.remaining()
    }

    /// Head-of-line online request, if it has already arrived.
    fn arrived_online(&self, now: f64) -> Option<OnlineItem> {
        self.online
            .get(self.online_pos)
            .filter(|item| item.arrival <= now)
            .copied()
    }

    /// True while the offline side must leave the burst reserve free.
    fn offline_gated(&self, view: &EngineView) -> bool {
        self.online_pos < self.online.len()
            && self.reserve_frac > 0.0
            // Never idle an empty engine for the sake of headroom.
            && view.active_requests > 0
            && view.kv_used >= view.kv_capacity * (1.0 - self.reserve_frac)
    }
}

impl Admitter for ElasticAdmitter {
    fn peek(&mut self, view: &EngineView) -> Option<(u32, Side)> {
        if let Some(item) = self.arrived_online(view.now) {
            // Online prefills are compute-bound work; charge them to the
            // scanner's compute-intensive (left) partition.
            self.last = LastQueue::Online;
            return Some((item.id, Side::Left));
        }
        if self.offline_gated(view) {
            return None; // hold the burst reserve
        }
        self.last = LastQueue::Offline;
        self.offline.peek(view)
    }

    fn pop(&mut self) {
        match self.last {
            LastQueue::Online => self.online_pos += 1,
            LastQueue::Offline => self.offline.pop(),
        }
    }

    fn exhausted(&self) -> bool {
        self.offline.exhausted() && self.online_pos >= self.online.len()
    }

    fn next_arrival(&self) -> Option<f64> {
        self.online.get(self.online_pos).map(|item| item.arrival)
    }

    fn urgent(&mut self, view: &EngineView) -> bool {
        if self.urgency <= 0.0 {
            return false;
        }
        match self.arrived_online(view.now) {
            Some(item) if item.ttft_slo.is_finite() => {
                // Urgent only while the deadline is still reachable: once
                // it has passed, preempting more offline work cannot buy
                // back the SLO, so the request falls back to normal
                // (arrival-priority) admission.
                let slack = item.arrival + item.ttft_slo - view.now;
                slack >= 0.0 && slack < self.urgency * item.ttft_slo
            }
            _ => false,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::presets;
    use crate::perfmodel::PerfModel;
    use crate::trace::synth::{synthesize, SynthSpec};
    use crate::trace::TraceKind;
    use crate::tree::PrefixTree;

    fn pm() -> PerfModel {
        PerfModel::new(presets::llama3_8b(), presets::a100_80gb(), 1)
    }

    fn scanner(n: usize) -> DualScanner {
        let pm = pm();
        let w = synthesize(&SynthSpec::new(TraceKind::BurstGpt, 1.0, 0.2, n), &pm);
        let mut tree = PrefixTree::build(&w);
        tree.sample_outputs(1.0, 3);
        tree.transform(&pm, 0.99);
        DualScanner::new(&tree)
    }

    fn view(now: f64, cap: f64, used: f64, active: usize) -> EngineView {
        EngineView {
            step: 1,
            now,
            kv_capacity: cap,
            kv_used: used,
            active_requests: active,
            used_left: used / 2.0,
            used_right: used / 2.0,
        }
    }

    fn item(id: u32, arrival: f64, ttft: f64) -> OnlineItem {
        OnlineItem { id, arrival, ttft_slo: ttft }
    }

    #[test]
    fn empty_online_stream_is_transparent() {
        // With no online requests the elastic admitter must replay the
        // dual scanner's admission sequence exactly.
        let n = 400;
        let mut plain = scanner(n);
        let mut elastic = ElasticAdmitter::new(scanner(n), vec![], 0.2, 0.5);
        loop {
            let v = view(0.0, 1e6, 0.0, 0);
            let a = plain.peek(&v);
            let b = elastic.peek(&v);
            assert_eq!(a, b);
            if a.is_none() {
                break;
            }
            plain.pop();
            elastic.pop();
        }
        assert!(elastic.exhausted());
    }

    #[test]
    fn online_waits_for_arrival_then_preempts_offline_order() {
        let online = vec![item(10_000, 5.0, 1.0)];
        let mut ad = ElasticAdmitter::new(scanner(50), online, 0.2, 0.5);
        // Before arrival: offline flows.
        let (r0, _) = ad.peek(&view(0.0, 1e6, 0.0, 0)).unwrap();
        assert_ne!(r0, 10_000);
        // After arrival: the online request is next regardless of the
        // offline cursor position.
        let (r1, side) = ad.peek(&view(5.0, 1e6, 0.0, 4)).unwrap();
        assert_eq!(r1, 10_000);
        assert_eq!(side, crate::engine::sim::Side::Left);
        ad.pop();
        assert_eq!(ad.remaining_online(), 0);
        // Stream drained: back to offline.
        let (r2, _) = ad.peek(&view(6.0, 1e6, 0.0, 4)).unwrap();
        assert_ne!(r2, 10_000);
    }

    #[test]
    fn headroom_gates_offline_only_while_online_pending() {
        let cap = 1000.0;
        let online = vec![item(10_000, 50.0, 1.0)];
        let mut ad = ElasticAdmitter::new(scanner(50), online, 0.2, 0.5);
        // Used beyond (1 - 0.2) * cap with actives: offline withheld.
        assert_eq!(ad.peek(&view(0.0, cap, 850.0, 3)), None);
        // Same usage but empty engine: progress wins, offline admitted.
        assert!(ad.peek(&view(0.0, cap, 850.0, 0)).is_some());
        // Below the reserve line: offline flows.
        assert!(ad.peek(&view(0.0, cap, 700.0, 3)).is_some());
        // Drain the online stream: the reserve evaporates.
        let (r, _) = ad.peek(&view(50.0, cap, 850.0, 3)).unwrap();
        assert_eq!(r, 10_000);
        ad.pop();
        assert!(ad.peek(&view(50.0, cap, 850.0, 3)).is_some());
    }

    #[test]
    fn urgency_tracks_ttft_slack() {
        let online = vec![item(10_000, 10.0, 2.0)];
        let mut ad = ElasticAdmitter::new(scanner(10), online, 0.2, 0.5);
        // Not yet arrived: not urgent.
        assert!(!ad.urgent(&view(9.0, 1e6, 0.0, 0)));
        // Arrived with plenty of slack (deadline 12, slack 2 >= 1).
        assert!(!ad.urgent(&view(10.5, 1e6, 0.0, 0)));
        // Slack below 50% of the SLO (deadline 12, now 11.2 -> slack 0.8).
        assert!(ad.urgent(&view(11.2, 1e6, 0.0, 0)));
        // Deadline already missed: no point preempting offline work.
        assert!(!ad.urgent(&view(12.5, 1e6, 0.0, 0)));
        // Urgency disabled: never urgent.
        let online = vec![item(10_000, 10.0, 2.0)];
        let mut off = ElasticAdmitter::new(scanner(10), online, 0.2, 0.0);
        assert!(!off.urgent(&view(11.9, 1e6, 0.0, 0)));
    }

    #[test]
    fn cheap_preemption_widens_urgency_window() {
        // Request arrives at 10 with a 2 s TTFT SLO (deadline 12).  At
        // urgency 0.5 the urgent window opens at slack < 1.0; with the
        // 1.5x cheap-preemption boost it opens at slack < 1.5.
        let mk = |cheap: bool| {
            ElasticAdmitter::new(scanner(10), vec![item(10_000, 10.0, 2.0)], 0.2, 0.5)
                .with_cheap_preemption(cheap)
        };
        // Slack 1.2: inside the boosted window only.
        let v = view(10.8, 1e6, 0.0, 0);
        assert!(!mk(false).urgent(&v));
        assert!(mk(true).urgent(&v));
        // Slack 0.8: urgent either way.
        let v = view(11.2, 1e6, 0.0, 0);
        assert!(mk(false).urgent(&v));
        assert!(mk(true).urgent(&v));
        // The boost saturates at the full SLO.
        let saturated =
            ElasticAdmitter::new(scanner(10), vec![item(10_000, 10.0, 2.0)], 0.2, 0.9)
                .with_cheap_preemption(true);
        assert_eq!(saturated.urgency, 1.0);
        // Identity when preemption is not cheap.
        assert_eq!(mk(false).urgency, 0.5);
    }

    #[test]
    fn next_arrival_reports_head_of_stream() {
        let online = vec![item(1000, 7.0, 1.0), item(1001, 9.0, 1.0)];
        let mut ad = ElasticAdmitter::new(scanner(10), online, 0.1, 0.5);
        assert_eq!(ad.next_arrival(), Some(7.0));
        let _ = ad.peek(&view(8.0, 1e6, 0.0, 0)).unwrap();
        ad.pop();
        assert_eq!(ad.next_arrival(), Some(9.0));
    }

    #[test]
    fn issues_every_request_exactly_once_across_both_streams() {
        let n = 300;
        let online: Vec<OnlineItem> =
            (0..40).map(|i| item(10_000 + i, i as f64 * 0.5, 1.0)).collect();
        let mut ad = ElasticAdmitter::new(scanner(n), online, 0.1, 0.5);
        let mut seen = std::collections::HashSet::new();
        let mut now = 0.0;
        while let Some((r, _)) = ad.peek(&view(now, 1e6, 0.0, 1)) {
            assert!(seen.insert(r), "request {r} issued twice");
            ad.pop();
            now += 0.1; // advancing clock releases arrivals gradually
        }
        // Clock stopped short of late arrivals: drain at a large time.
        while let Some((r, _)) = ad.peek(&view(1e9, 1e6, 0.0, 1)) {
            assert!(seen.insert(r), "request {r} issued twice");
            ad.pop();
        }
        assert!(ad.exhausted());
        assert_eq!(seen.len(), n + 40);
    }
}
