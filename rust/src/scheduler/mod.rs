//! Request scheduling (§5): ordering policies and the dual-scanner
//! admission algorithm, the SLO-aware elastic admitter for co-located
//! online/offline serving, plus the end-to-end driver that wires
//! workload → prefix tree → transform → admitter → engine.

pub mod dual_scan;
pub mod elastic;
pub mod runner;

pub use dual_scan::DualScanner;
pub use elastic::{ElasticAdmitter, OnlineItem};
pub use runner::{prepare_blendserve, run_system, RunOutput};

use crate::config::OrderPolicy;
use crate::tree::PrefixTree;
use crate::util::DetRng;

/// Materialize a static request order for the baseline policies.
///
/// - `Fcfs`: arrival order (request ids).
/// - `Dfs`: depth-first traversal of the *untransformed* prefix tree —
///   maximal prefix sharing, the strongest baseline ordering (§6.2 reorders
///   every baseline's trace into DFS order).
/// - `Random`: deterministic shuffle — "NanoFlow-Balance".
/// - `PrefixAligned`: sharing-savings-sorted DFS
///   ([`crate::planner::prefix_aligned_order`]) — the AlignedServe-style
///   strong baseline of the optimality-gap bench.
///
/// `BlendServe` has no static order; it uses [`DualScanner`].
pub fn static_order(policy: OrderPolicy, tree: &PrefixTree, seed: u64) -> Vec<u32> {
    match policy {
        OrderPolicy::Fcfs => (0..tree.n_requests() as u32).collect(),
        OrderPolicy::Dfs => tree.dfs_requests(),
        OrderPolicy::Random => {
            let mut order: Vec<u32> = (0..tree.n_requests() as u32).collect();
            DetRng::new(seed ^ 0xbada_55).shuffle(&mut order);
            order
        }
        OrderPolicy::PrefixAligned => crate::planner::prefix_aligned_order(tree),
        OrderPolicy::BlendServe => {
            panic!("BlendServe uses the dual scanner, not a static order")
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::generators::generate_kind;
    use crate::trace::TraceKind;

    #[test]
    fn orders_are_permutations() {
        let w = generate_kind(TraceKind::Mmlu, 200, 3);
        let tree = PrefixTree::build(&w);
        for policy in [
            OrderPolicy::Fcfs,
            OrderPolicy::Dfs,
            OrderPolicy::Random,
            OrderPolicy::PrefixAligned,
        ] {
            let mut o = static_order(policy, &tree, 7);
            o.sort_unstable();
            assert_eq!(o, (0..200).collect::<Vec<u32>>(), "{policy}");
        }
    }

    #[test]
    fn random_differs_from_fcfs() {
        let w = generate_kind(TraceKind::BurstGpt, 100, 3);
        let tree = PrefixTree::build(&w);
        assert_ne!(
            static_order(OrderPolicy::Random, &tree, 7),
            static_order(OrderPolicy::Fcfs, &tree, 7)
        );
    }

    #[test]
    #[should_panic(expected = "dual scanner")]
    fn blendserve_has_no_static_order() {
        let w = generate_kind(TraceKind::BurstGpt, 10, 3);
        let tree = PrefixTree::build(&w);
        static_order(OrderPolicy::BlendServe, &tree, 0);
    }
}
