//! Configuration system: model/hardware/scheduler/engine configs, a
//! TOML-subset codec (in-tree, offline build), and presets for every model
//! and GPU the paper evaluates (§6.2).
//!
//! All perf-model math (§4) reads only the architecture constants collected
//! here, so adding a model is a one-preset change.

pub mod presets;

use crate::util::toml::{TomlDoc, TomlError};
use std::path::Path;

/// Architecture constants of a served model (the paper's §4 notation:
/// `P_model`, `H`, `H_kv`, `L`).
#[derive(Clone, Debug, PartialEq)]
pub struct ModelSpec {
    pub name: String,
    /// Total parameter count `P_model`.
    pub params: f64,
    /// Hidden dimension `H` (model width).
    pub hidden: usize,
    /// KV feature dimension per layer: `n_kv_heads * head_dim` (so that
    /// bytes/token/layer = 4 * h_kv in FP16, counting K and V).
    pub h_kv: usize,
    /// Decoder layers `L`.
    pub layers: usize,
    /// Bytes per cached token across all layers (FP16 K+V):
    /// 2 (K,V) * 2 (bytes) * h_kv * layers.
    pub kv_bytes_per_token: f64,
    /// Tensor-parallel degree this spec is deployed with (scales per-GPU
    /// weights and KV capacity; see `parallel::tp`).
    pub tp_degree: usize,
}

impl ModelSpec {
    pub fn new(name: &str, params: f64, hidden: usize, h_kv: usize, layers: usize) -> Self {
        let mut m = ModelSpec {
            name: name.to_string(),
            params,
            hidden,
            h_kv,
            layers,
            kv_bytes_per_token: 0.0,
            tp_degree: 1,
        };
        m.kv_bytes_per_token = m.derive_kv_bytes();
        m
    }

    pub fn derive_kv_bytes(&self) -> f64 {
        4.0 * self.h_kv as f64 * self.layers as f64
    }

    pub fn with_tp(mut self, tp: usize) -> Self {
        assert!(tp >= 1);
        self.tp_degree = tp;
        self
    }

    /// FP16 weight bytes.
    pub fn weight_bytes(&self) -> f64 {
        2.0 * self.params
    }
}

/// One GPU's capability (the paper's `compute`, `bandwidth` constants) and
/// an interference factor for spatial-sharing overlap (§6.2 "practical
/// optimal throughput": perfect `max(comp, mem)` is unachievable; profiled
/// overlapped execution runs `1 + interference` slower).
#[derive(Clone, Debug, PartialEq)]
pub struct HardwareSpec {
    pub name: String,
    /// Peak FP16 tensor compute, FLOP/s.
    pub compute_flops: f64,
    /// Memory bandwidth, bytes/s.
    pub bandwidth: f64,
    /// Device memory, bytes.
    pub memory_bytes: f64,
    /// Fraction of `max(comp,mem)` added when compute- and memory-bound
    /// kernels run concurrently (GPU spatial-sharing interference).
    pub interference: f64,
    /// Memory reserved for activations / temp buffers (bytes), in addition
    /// to weights.
    pub reserve_bytes: f64,
    /// Host-link (PCIe) bandwidth per GPU, GB/s (decimal).  The tiered
    /// KV manager (`kv` module) swaps retracted requests' KV over this
    /// link; 0 means no host link (offload disabled regardless of
    /// `[kv] enabled`).
    pub pcie_gbps: f64,
    /// Host (CPU DRAM) bytes available to one replica for offloaded KV.
    pub host_mem_bytes: f64,
}

impl HardwareSpec {
    /// Fallback host-link bandwidth for config files predating KV
    /// tiering (PCIe 4.0 x16).
    pub const DEFAULT_PCIE_GBPS: f64 = 32.0;
    /// Fallback per-replica host memory for config files predating KV
    /// tiering.
    pub const DEFAULT_HOST_MEM_BYTES: f64 = 256e9;

    /// KV-cache capacity in bytes for a model replica on `n_gpus` GPUs
    /// (weights sharded by TP).
    pub fn kv_capacity_bytes(&self, model: &ModelSpec, n_gpus: usize) -> f64 {
        let total_mem = self.memory_bytes * n_gpus as f64;
        let cap = total_mem - model.weight_bytes() - self.reserve_bytes * n_gpus as f64;
        assert!(
            cap > 0.0,
            "model {} does not fit on {} x {}",
            model.name,
            n_gpus,
            self.name
        );
        cap
    }

    /// KV capacity in *tokens*.
    pub fn kv_capacity_tokens(&self, model: &ModelSpec, n_gpus: usize) -> f64 {
        self.kv_capacity_bytes(model, n_gpus) / model.kv_bytes_per_token
    }
}

/// How per-step compute and memory times combine into wall-clock time.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum OverlapMode {
    /// Sequential execution of compute- and memory-bound operators
    /// (vLLM/SGLang-style): `f = sum`.
    Sequential,
    /// NanoFlow-style operator-level overlap: `f = max * (1+interference)`.
    Overlapped,
}

impl OverlapMode {
    pub fn name(&self) -> &'static str {
        match self {
            OverlapMode::Sequential => "sequential",
            OverlapMode::Overlapped => "overlapped",
        }
    }
    pub fn from_name(s: &str) -> Option<Self> {
        match s {
            "sequential" => Some(OverlapMode::Sequential),
            "overlapped" => Some(OverlapMode::Overlapped),
            _ => None,
        }
    }
}

/// Request ordering policy fed to the batcher.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum OrderPolicy {
    /// Arrival order (first-come-first-served).
    Fcfs,
    /// Depth-first traversal of the prefix tree (max prefix sharing).
    Dfs,
    /// Uniform random shuffle ("NanoFlow-Balance" in the paper).
    Random,
    /// AlignedServe-style prefix-aligned DFS: children visited by
    /// descending sharing savings (`planner::prefix_aligned_order`).
    PrefixAligned,
    /// BlendServe: density-sorted tree + dual scanner.
    BlendServe,
}

impl OrderPolicy {
    pub fn name(&self) -> &'static str {
        match self {
            OrderPolicy::Fcfs => "fcfs",
            OrderPolicy::Dfs => "dfs",
            OrderPolicy::Random => "random",
            OrderPolicy::PrefixAligned => "prefix-aligned",
            OrderPolicy::BlendServe => "blendserve",
        }
    }
    pub fn from_name(s: &str) -> Option<Self> {
        match s {
            "fcfs" => Some(OrderPolicy::Fcfs),
            "dfs" => Some(OrderPolicy::Dfs),
            "random" => Some(OrderPolicy::Random),
            "prefix-aligned" => Some(OrderPolicy::PrefixAligned),
            "blendserve" => Some(OrderPolicy::BlendServe),
            _ => None,
        }
    }
}

impl std::fmt::Display for OrderPolicy {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}", self.name())
    }
}

/// How online requests are folded into the offline blend schedule
/// (DESIGN.md §Co-located-Serving).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ColocationPolicy {
    /// SLO-aware: arrival priority + KV headroom reserve + SLO-risk
    /// preemption of offline work.
    Elastic,
    /// Arrival priority only — no reserve, no preemption.  The ablation
    /// baseline for the elastic policy.
    BestEffort,
}

impl ColocationPolicy {
    pub fn name(&self) -> &'static str {
        match self {
            ColocationPolicy::Elastic => "elastic",
            ColocationPolicy::BestEffort => "best-effort",
        }
    }
    pub fn from_name(s: &str) -> Option<Self> {
        match s {
            "elastic" => Some(ColocationPolicy::Elastic),
            "best-effort" => Some(ColocationPolicy::BestEffort),
            _ => None,
        }
    }
}

impl std::fmt::Display for ColocationPolicy {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}", self.name())
    }
}

/// Online/offline co-location knobs.  The default (`online_rate = 0`)
/// means pure offline serving; every path then reduces to BlendServe
/// exactly (`server::colocate` tests pin this down).
#[derive(Clone, Debug, PartialEq)]
pub struct ColocateConfig {
    /// Mean online arrival rate, requests/s (0 = no online stream).
    pub online_rate: f64,
    /// SLO slack multiplier over the idle-replica baseline latency
    /// (HyGen-style; 1.0 = tightest, larger = more relaxed).
    pub slo_scale: f64,
    pub policy: ColocationPolicy,
    /// Fraction of KV capacity reserved for online bursts (Elastic only).
    pub online_reserve: f64,
    /// TTFT slack fraction that makes an admission urgent enough to
    /// preempt offline work (Elastic only).
    pub urgency: f64,
    /// Burstiness of the arrival process: 1.0 = Poisson; > 1 = bursty
    /// with this peak-to-calm rate ratio (mean rate stays `online_rate`).
    pub burst_factor: f64,
    /// Mean calm/burst phase length in seconds (used when bursty).
    pub phase_secs: f64,
}

impl Default for ColocateConfig {
    fn default() -> Self {
        ColocateConfig {
            online_rate: 0.0,
            slo_scale: 5.0,
            policy: ColocationPolicy::Elastic,
            online_reserve: 0.1,
            urgency: 0.5,
            burst_factor: 1.0,
            phase_secs: 30.0,
        }
    }
}

/// Work-stealing fleet knobs (`server::fleet`).  The default is a
/// homogeneous stealing-enabled fleet; `steal = false` reproduces the
/// static §5.5 fork-join schedule exactly.
#[derive(Clone, Debug, PartialEq)]
pub struct FleetConfig {
    /// Enable work stealing: a drained replica pulls whole scheduling
    /// units from the memory end of the straggler's pending queue.
    pub steal: bool,
    /// Fraction of the victim's steal-eligible estimated work taken per
    /// steal event, in (0, 1].
    pub steal_ratio: f64,
    /// Per-replica GPU counts for heterogeneous fleets; replicas beyond
    /// the list (or an empty list) use `gpus_per_replica`.
    pub gpus: Vec<usize>,
    /// Per-replica hardware preset names (see
    /// [`presets::hardware_by_name`]); replicas beyond the list (or an
    /// empty list) use the top-level `hardware`.
    pub hardware: Vec<String>,
}

impl Default for FleetConfig {
    fn default() -> Self {
        FleetConfig {
            steal: true,
            steal_ratio: 0.5,
            gpus: Vec::new(),
            hardware: Vec::new(),
        }
    }
}

impl FleetConfig {
    /// Semantic validation shared by the TOML and CLI construction paths
    /// (one source of truth, so the two cannot drift).
    pub fn validate(&self, dp_replicas: usize) -> Result<(), String> {
        if !(self.steal_ratio > 0.0 && self.steal_ratio <= 1.0) {
            return Err(format!(
                "steal_ratio must be in (0, 1], got {}",
                self.steal_ratio
            ));
        }
        if self.gpus.iter().any(|&g| g == 0) {
            return Err("gpus entries must be >= 1".to_string());
        }
        if self.gpus.len() > dp_replicas {
            return Err(format!(
                "gpus lists {} replicas but dp_replicas is {dp_replicas}",
                self.gpus.len()
            ));
        }
        if self.hardware.len() > dp_replicas {
            return Err(format!(
                "hardware lists {} replicas but dp_replicas is {dp_replicas}",
                self.hardware.len()
            ));
        }
        for name in &self.hardware {
            if presets::hardware_by_name(name).is_none() {
                return Err(format!("unknown hardware preset '{name}'"));
            }
        }
        Ok(())
    }
}

/// Tiered KV manager knobs (`kv` module, DESIGN.md §9).  Disabled by
/// default: retraction then discards KV and re-prefills on re-admission,
/// bit-identical to the pre-tiering engine (pinned by tests in
/// `engine/sim.rs` and `rust/benches/kv_offload.rs`).
#[derive(Clone, Debug, PartialEq)]
pub struct KvConfig {
    /// Master switch for host offload on retraction.
    pub enabled: bool,
    /// Swap only when the link round-trip costs at most `swap_margin`
    /// times the roofline recompute estimate (1.0 = break-even).
    pub swap_margin: f64,
    /// Fraction of `hardware.host_mem_bytes` usable for offloaded KV.
    pub host_mem_frac: f64,
    /// Stream each swap-in right behind its swap-out on the FIFO link
    /// (overlapped prefetch) instead of fetching synchronously at
    /// re-admission.
    pub prefetch: bool,
}

impl Default for KvConfig {
    fn default() -> Self {
        KvConfig {
            enabled: false,
            swap_margin: 1.0,
            host_mem_frac: 1.0,
            prefetch: true,
        }
    }
}

impl KvConfig {
    /// Every key the `[kv]` TOML section accepts; anything else is a
    /// config error naming the offending key (a typo in a policy switch
    /// must not silently no-op).
    pub const TOML_KEYS: [&'static str; 4] =
        ["enabled", "swap_margin", "host_mem_frac", "prefetch"];

    /// Semantic validation shared by the TOML and CLI construction paths.
    pub fn validate(&self) -> Result<(), String> {
        if !(self.swap_margin > 0.0) {
            return Err(format!("swap_margin must be > 0, got {}", self.swap_margin));
        }
        if !(self.host_mem_frac > 0.0 && self.host_mem_frac <= 1.0) {
            return Err(format!(
                "host_mem_frac must be in (0, 1], got {}",
                self.host_mem_frac
            ));
        }
        Ok(())
    }
}

/// Streaming ingest knobs (`stream` module, DESIGN.md §14): window sizing
/// for the bounded-memory windowed driver behind `blendserve stream`.
/// Inert for every other entry point.  Both knobs at 0 mean one unbounded
/// window — bit-identical (per-request finish order and every counter)
/// to the monolithic engine, which the stream tests pin.
#[derive(Clone, Debug, PartialEq)]
pub struct StreamConfig {
    /// Maximum requests per scheduling window (0 = unbounded).
    pub window_requests: usize,
    /// Maximum Σ(prompt + max_tokens) tokens per window (0 = unbounded).
    /// A window closes when either bound is reached; every window always
    /// carries at least one request, so an oversized single request
    /// streams rather than wedging the reader.
    pub window_tokens: u64,
}

impl Default for StreamConfig {
    fn default() -> Self {
        StreamConfig { window_requests: 8192, window_tokens: 0 }
    }
}

impl StreamConfig {
    /// Every key the `[stream]` TOML section accepts; anything else is a
    /// config error naming the offending key.
    pub const TOML_KEYS: [&'static str; 2] = ["window_requests", "window_tokens"];

    /// Semantic validation shared by the TOML and CLI construction paths.
    /// Every non-negative integer is meaningful (0 = unbounded), so this
    /// only rejects values past the TOML-exact float-integer range, which
    /// would silently round on the next save/load cycle.
    pub fn validate(&self) -> Result<(), String> {
        const MAX_EXACT: u64 = 1 << 53;
        if self.window_tokens > MAX_EXACT {
            return Err(format!(
                "window_tokens {} exceeds the TOML-exact integer range (<= 2^53)",
                self.window_tokens
            ));
        }
        if self.window_requests as u64 > MAX_EXACT {
            return Err(format!(
                "window_requests {} exceeds the TOML-exact integer range (<= 2^53)",
                self.window_requests
            ));
        }
        Ok(())
    }
}

/// Multi-modal subsystem knobs (`modality` module, DESIGN.md §10).
///
/// `enabled` gates *scheduler awareness only*: whether tree / dual-scan
/// densities include the vision-encoder compute term.  The engine always
/// simulates the physics of whatever attachments a workload carries
/// (encoder passes, embedding dedup cache), so attachment-free workloads
/// are bit-identical to the pre-modality engine regardless of this
/// section (pinned by tests in `engine/sim.rs`).
#[derive(Clone, Debug, PartialEq)]
pub struct ModalityConfig {
    /// Include encoder compute in scheduling densities (modality-aware
    /// ordering).  Off = modality-blind: the scheduler prices attachments
    /// at zero, the ablation baseline.
    pub enabled: bool,
    /// Vision-encoder parameter count (FLOPs/token = 2·params).  The
    /// default is a video-capable ~2B tower (EVA/ViT-bigG scale); set
    /// ~3.0e8 for a ViT-L/14 image-chat-only deployment.
    pub encoder_params: f64,
    /// Fraction of the replica's KV-capacity bytes carved out for the
    /// embedding dedup cache (applied only when the workload carries
    /// attachments).
    pub embed_cache_frac: f64,
    /// Bytes one cached embedding token occupies (hidden · 2 for FP16;
    /// 8192 matches a 4096-wide projector).
    pub embed_bytes_per_token: f64,
}

impl Default for ModalityConfig {
    fn default() -> Self {
        ModalityConfig {
            enabled: false,
            encoder_params: Self::DEFAULT_ENCODER_PARAMS,
            embed_cache_frac: 0.05,
            embed_bytes_per_token: 8192.0,
        }
    }
}

impl ModalityConfig {
    /// Default vision-encoder size (video-capable ~2B tower).
    pub const DEFAULT_ENCODER_PARAMS: f64 = 2e9;

    /// Every key the `[modality]` TOML section accepts; anything else is
    /// a config error naming the offending key (same policy as `[kv]`).
    pub const TOML_KEYS: [&'static str; 4] = [
        "enabled",
        "encoder_params",
        "embed_cache_frac",
        "embed_bytes_per_token",
    ];

    /// Semantic validation shared by the TOML and CLI construction paths.
    pub fn validate(&self) -> Result<(), String> {
        if !(self.encoder_params > 0.0) {
            return Err(format!(
                "encoder_params must be > 0, got {}",
                self.encoder_params
            ));
        }
        if !(self.embed_cache_frac >= 0.0 && self.embed_cache_frac < 1.0) {
            return Err(format!(
                "embed_cache_frac must be in [0, 1), got {}",
                self.embed_cache_frac
            ));
        }
        if !(self.embed_bytes_per_token > 0.0) {
            return Err(format!(
                "embed_bytes_per_token must be > 0, got {}",
                self.embed_bytes_per_token
            ));
        }
        Ok(())
    }
}

/// What the fleet does with a dead replica's work (`server::fleet`,
/// DESIGN.md §12).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RecoveryStrategy {
    /// Exactly-once recovery: reclaim the victim's unfinished requests,
    /// re-price them and redistribute to surviving replicas (rescuing
    /// swapped-out KV where the ledger holds it).
    Recover,
    /// Restart-from-scratch baseline: every death discards all fleet
    /// progress and the whole run restarts at the failure clock.
    Restart,
}

impl RecoveryStrategy {
    pub fn name(&self) -> &'static str {
        match self {
            RecoveryStrategy::Recover => "recover",
            RecoveryStrategy::Restart => "restart",
        }
    }
    pub fn from_name(s: &str) -> Option<Self> {
        match s {
            "recover" => Some(RecoveryStrategy::Recover),
            "restart" => Some(RecoveryStrategy::Restart),
            _ => None,
        }
    }
}

impl std::fmt::Display for RecoveryStrategy {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}", self.name())
    }
}

/// Failure-injection and recovery knobs (`recovery` module + fault-aware
/// `server::fleet`, DESIGN.md §12).  Disabled by default: the fleet runs
/// bit-identically to the pre-recovery coordinator (pinned by tests in
/// `server/fleet.rs`).  All injected faults are derived deterministically
/// from `seed`, so a failure run replays exactly.
#[derive(Clone, Debug, PartialEq)]
pub struct FaultsConfig {
    /// Master switch for fault injection.
    pub enabled: bool,
    /// Seed for the per-replica preemption trace (`recovery::FaultPlan`).
    pub seed: u64,
    /// Mean time between failures per replica, seconds (exponential
    /// inter-arrival); 0 disables replica deaths.
    pub mtbf_s: f64,
    /// A dead replica re-joins (empty, at the failure-time clock plus this
    /// delay) and becomes a steal target again; 0 = never re-joins.
    pub rejoin_delay_s: f64,
    /// Cap on total death events across the fleet (keeps seeded plans
    /// finite even with small `mtbf_s`).
    pub max_deaths: usize,
    /// Degraded mode: at this clock every replica's host KV budget shrinks
    /// to `host_shrink_frac` of its capacity (evicting offloaded extents
    /// deterministically); 0 = never.
    pub host_shrink_at_s: f64,
    /// Remaining fraction of the host KV budget after the shrink, in (0, 1].
    pub host_shrink_frac: f64,
    /// Degraded mode: at this clock every replica's PCIe link slows to
    /// `link_degrade_factor` of its bandwidth; 0 = never.
    pub link_degrade_at_s: f64,
    /// Remaining fraction of link bandwidth after the slowdown, in (0, 1].
    pub link_degrade_factor: f64,
    /// Adopt a victim's swapped-out KV extents on the heir replica (resume
    /// decode from host KV) instead of restarting those requests from
    /// scratch.
    pub kv_rescue: bool,
    /// What a death does to the fleet: exactly-once recovery or the
    /// restart-from-scratch baseline.
    pub strategy: RecoveryStrategy,
    /// Journal a fleet snapshot every this many coordinator steps.
    pub snapshot_every: usize,
}

impl Default for FaultsConfig {
    fn default() -> Self {
        FaultsConfig {
            enabled: false,
            seed: 0,
            mtbf_s: 0.0,
            rejoin_delay_s: 0.0,
            max_deaths: 4,
            host_shrink_at_s: 0.0,
            host_shrink_frac: 0.5,
            link_degrade_at_s: 0.0,
            link_degrade_factor: 0.25,
            kv_rescue: true,
            strategy: RecoveryStrategy::Recover,
            snapshot_every: 64,
        }
    }
}

impl FaultsConfig {
    /// Every key the `[faults]` TOML section accepts; anything else is a
    /// config error naming the offending key (same policy as `[kv]`).
    pub const TOML_KEYS: [&'static str; 12] = [
        "enabled",
        "seed",
        "mtbf_s",
        "rejoin_delay_s",
        "max_deaths",
        "host_shrink_at_s",
        "host_shrink_frac",
        "link_degrade_at_s",
        "link_degrade_factor",
        "kv_rescue",
        "strategy",
        "snapshot_every",
    ];

    /// Semantic validation shared by the TOML and CLI construction paths.
    pub fn validate(&self) -> Result<(), String> {
        if !(self.mtbf_s >= 0.0) {
            return Err(format!("mtbf_s must be >= 0, got {}", self.mtbf_s));
        }
        if !(self.rejoin_delay_s >= 0.0) {
            return Err(format!(
                "rejoin_delay_s must be >= 0, got {}",
                self.rejoin_delay_s
            ));
        }
        if !(self.host_shrink_at_s >= 0.0) {
            return Err(format!(
                "host_shrink_at_s must be >= 0, got {}",
                self.host_shrink_at_s
            ));
        }
        if !(self.host_shrink_frac > 0.0 && self.host_shrink_frac <= 1.0) {
            return Err(format!(
                "host_shrink_frac must be in (0, 1], got {}",
                self.host_shrink_frac
            ));
        }
        if !(self.link_degrade_at_s >= 0.0) {
            return Err(format!(
                "link_degrade_at_s must be >= 0, got {}",
                self.link_degrade_at_s
            ));
        }
        if !(self.link_degrade_factor > 0.0 && self.link_degrade_factor <= 1.0) {
            return Err(format!(
                "link_degrade_factor must be in (0, 1], got {}",
                self.link_degrade_factor
            ));
        }
        if self.snapshot_every == 0 {
            return Err("snapshot_every must be >= 1".to_string());
        }
        Ok(())
    }
}

/// Scheduler knobs (§5).
#[derive(Clone, Debug, PartialEq)]
pub struct SchedulerConfig {
    pub order: OrderPolicy,
    /// Chunked-prefill token budget per engine step.
    pub chunk_tokens: usize,
    /// Batch sizes are rounded to a multiple of this (§A.2 uses 128).
    pub batch_quantum: usize,
    /// Max concurrent requests in the on-the-fly batch.
    pub max_batch_requests: usize,
    /// Output-length sampling probability (§5.1); 0.01 in the paper.
    pub sample_prob: f64,
    /// Node-split budget expressed as the fraction of prefix sharing that
    /// must be preserved (§5.2: "preserve 99% of prefix sharing ratio").
    pub split_sharing_floor: f64,
    /// Enable the online adaptation of §5.4 (re-admit on early finish,
    /// relocate on underestimation).
    pub online_adapt: bool,
    /// Alg. 3 chunk budgets: meter each step's prefill tokens so per-step
    /// compute time tracks (remaining-comp / remaining-mem) x memory time,
    /// spreading compute across the decode steps instead of front-loading
    /// it.  BlendServe-only; baselines use the fixed `chunk_tokens`.
    pub balanced_chunk: bool,
    /// Workload prefix-sharing ratio estimate used by the chunk pacer to
    /// discount remaining prefill compute (set by the runner from the
    /// tree's root sharing).
    pub expected_sharing: f64,
    /// RNG seed for sampling / random ordering.
    pub seed: u64,
}

impl Default for SchedulerConfig {
    fn default() -> Self {
        SchedulerConfig {
            order: OrderPolicy::BlendServe,
            chunk_tokens: 2048,
            batch_quantum: 128,
            max_batch_requests: 8192,
            sample_prob: 0.01,
            split_sharing_floor: 0.99,
            online_adapt: true,
            balanced_chunk: false,
            expected_sharing: 0.0,
            seed: 0,
        }
    }
}

/// Engine knobs.
#[derive(Clone, Debug, PartialEq)]
pub struct EngineConfig {
    pub overlap: OverlapMode,
    /// Enable the runtime prefix cache (radix KV reuse).
    pub prefix_cache: bool,
    /// Include the quadratic prefill-attention FLOPs term (the paper's
    /// model derives then omits it; we keep it for accuracy).
    pub prefill_attn_flops: bool,
    /// Force the [`crate::engine::EngineAuditor`] cross-subsystem
    /// invariant checks on every `step_once` even in release builds.
    /// Debug builds always audit regardless of this flag (that is how CI's
    /// test job exercises the auditor); release runs skip it by default so
    /// the hot path pays nothing.
    pub audit: bool,
    /// Record the observability stream (DESIGN.md §15): typed lifecycle
    /// events + per-step counter samples, exportable as a Perfetto
    /// trace.  Off by default — the `None` handle keeps untraced runs
    /// bit-identical to pre-tracing behavior.
    pub trace: bool,
}

impl Default for EngineConfig {
    fn default() -> Self {
        EngineConfig {
            overlap: OverlapMode::Overlapped,
            prefix_cache: true,
            prefill_attn_flops: true,
            audit: false,
            trace: false,
        }
    }
}

impl EngineConfig {
    /// Whether a run under this config carries the auditor: always in
    /// debug builds, opt-in (`audit = true`) in release.
    pub fn audit_enabled(&self) -> bool {
        self.audit || cfg!(debug_assertions)
    }
}

/// Top-level system configuration (one serving deployment).
#[derive(Clone, Debug, PartialEq)]
pub struct SystemConfig {
    pub model: ModelSpec,
    pub hardware: HardwareSpec,
    pub scheduler: SchedulerConfig,
    pub engine: EngineConfig,
    /// Online/offline co-location knobs (inert at `online_rate = 0`).
    pub colocate: ColocateConfig,
    /// Work-stealing fleet knobs (`server::fleet`).
    pub fleet: FleetConfig,
    /// Tiered KV manager knobs (inert at `enabled = false`).
    pub kv: KvConfig,
    /// Multi-modal subsystem knobs (scheduler awareness + embed cache).
    pub modality: ModalityConfig,
    /// Failure-injection + recovery knobs (inert at `enabled = false`).
    pub faults: FaultsConfig,
    /// Streaming-ingest window sizing (`blendserve stream` only).
    pub stream: StreamConfig,
    /// GPUs per model replica (tensor parallel group size).
    pub gpus_per_replica: usize,
    /// Data-parallel replicas.
    pub dp_replicas: usize,
}

impl SystemConfig {
    pub fn new(model: ModelSpec, hardware: HardwareSpec) -> Self {
        let gpus = model.tp_degree;
        SystemConfig {
            model,
            hardware,
            scheduler: SchedulerConfig::default(),
            engine: EngineConfig::default(),
            colocate: ColocateConfig::default(),
            fleet: FleetConfig::default(),
            kv: KvConfig::default(),
            modality: ModalityConfig::default(),
            faults: FaultsConfig::default(),
            stream: StreamConfig::default(),
            gpus_per_replica: gpus,
            dp_replicas: 1,
        }
    }

    pub fn total_gpus(&self) -> usize {
        self.gpus_per_replica * self.dp_replicas
    }

    pub fn kv_capacity_tokens(&self) -> f64 {
        self.hardware
            .kv_capacity_tokens(&self.model, self.gpus_per_replica)
    }

    pub fn to_toml(&self) -> String {
        let mut d = TomlDoc::new();
        d.set_num("", "gpus_per_replica", self.gpus_per_replica as f64);
        d.set_num("", "dp_replicas", self.dp_replicas as f64);

        d.set_str("model", "name", &self.model.name);
        d.set_num("model", "params", self.model.params);
        d.set_num("model", "hidden", self.model.hidden as f64);
        d.set_num("model", "h_kv", self.model.h_kv as f64);
        d.set_num("model", "layers", self.model.layers as f64);
        d.set_num("model", "kv_bytes_per_token", self.model.kv_bytes_per_token);
        d.set_num("model", "tp_degree", self.model.tp_degree as f64);

        d.set_str("hardware", "name", &self.hardware.name);
        d.set_num("hardware", "compute_flops", self.hardware.compute_flops);
        d.set_num("hardware", "bandwidth", self.hardware.bandwidth);
        d.set_num("hardware", "memory_bytes", self.hardware.memory_bytes);
        d.set_num("hardware", "interference", self.hardware.interference);
        d.set_num("hardware", "reserve_bytes", self.hardware.reserve_bytes);
        d.set_num("hardware", "pcie_gbps", self.hardware.pcie_gbps);
        d.set_num("hardware", "host_mem_bytes", self.hardware.host_mem_bytes);

        d.set_str("scheduler", "order", self.scheduler.order.name());
        d.set_num("scheduler", "chunk_tokens", self.scheduler.chunk_tokens as f64);
        d.set_num("scheduler", "batch_quantum", self.scheduler.batch_quantum as f64);
        d.set_num(
            "scheduler",
            "max_batch_requests",
            self.scheduler.max_batch_requests as f64,
        );
        d.set_num("scheduler", "sample_prob", self.scheduler.sample_prob);
        d.set_num(
            "scheduler",
            "split_sharing_floor",
            self.scheduler.split_sharing_floor,
        );
        d.set_bool("scheduler", "online_adapt", self.scheduler.online_adapt);
        d.set_bool("scheduler", "balanced_chunk", self.scheduler.balanced_chunk);
        d.set_num("scheduler", "expected_sharing", self.scheduler.expected_sharing);
        d.set_num("scheduler", "seed", self.scheduler.seed as f64);

        d.set_str("engine", "overlap", self.engine.overlap.name());
        d.set_bool("engine", "prefix_cache", self.engine.prefix_cache);
        d.set_bool("engine", "prefill_attn_flops", self.engine.prefill_attn_flops);
        d.set_bool("engine", "audit", self.engine.audit);
        d.set_bool("engine", "trace", self.engine.trace);

        d.set_num("colocate", "online_rate", self.colocate.online_rate);
        d.set_num("colocate", "slo_scale", self.colocate.slo_scale);
        d.set_str("colocate", "policy", self.colocate.policy.name());
        d.set_num("colocate", "online_reserve", self.colocate.online_reserve);
        d.set_num("colocate", "urgency", self.colocate.urgency);
        d.set_num("colocate", "burst_factor", self.colocate.burst_factor);
        d.set_num("colocate", "phase_secs", self.colocate.phase_secs);

        d.set_bool("fleet", "steal", self.fleet.steal);
        d.set_num("fleet", "steal_ratio", self.fleet.steal_ratio);
        let gpus_csv = self
            .fleet
            .gpus
            .iter()
            .map(|g| g.to_string())
            .collect::<Vec<_>>()
            .join(",");
        d.set_str("fleet", "gpus", &gpus_csv);
        d.set_str("fleet", "hardware", &self.fleet.hardware.join(","));

        d.set_bool("kv", "enabled", self.kv.enabled);
        d.set_num("kv", "swap_margin", self.kv.swap_margin);
        d.set_num("kv", "host_mem_frac", self.kv.host_mem_frac);
        d.set_bool("kv", "prefetch", self.kv.prefetch);

        d.set_bool("modality", "enabled", self.modality.enabled);
        d.set_num("modality", "encoder_params", self.modality.encoder_params);
        d.set_num("modality", "embed_cache_frac", self.modality.embed_cache_frac);
        d.set_num(
            "modality",
            "embed_bytes_per_token",
            self.modality.embed_bytes_per_token,
        );

        d.set_bool("faults", "enabled", self.faults.enabled);
        d.set_num("faults", "seed", self.faults.seed as f64);
        d.set_num("faults", "mtbf_s", self.faults.mtbf_s);
        d.set_num("faults", "rejoin_delay_s", self.faults.rejoin_delay_s);
        d.set_num("faults", "max_deaths", self.faults.max_deaths as f64);
        d.set_num("faults", "host_shrink_at_s", self.faults.host_shrink_at_s);
        d.set_num("faults", "host_shrink_frac", self.faults.host_shrink_frac);
        d.set_num("faults", "link_degrade_at_s", self.faults.link_degrade_at_s);
        d.set_num(
            "faults",
            "link_degrade_factor",
            self.faults.link_degrade_factor,
        );
        d.set_bool("faults", "kv_rescue", self.faults.kv_rescue);
        d.set_str("faults", "strategy", self.faults.strategy.name());
        d.set_num("faults", "snapshot_every", self.faults.snapshot_every as f64);

        d.set_num("stream", "window_requests", self.stream.window_requests as f64);
        d.set_num("stream", "window_tokens", self.stream.window_tokens as f64);
        d.to_string_pretty()
    }

    pub fn from_toml(text: &str) -> anyhow::Result<Self> {
        let d = TomlDoc::parse(text)?;
        let s = |sec: &str, key: &str| -> Result<String, TomlError> {
            Ok(d.req(sec, key)?
                .as_str()
                .ok_or_else(|| TomlError(format!("[{sec}] {key}: expected string")))?
                .to_string())
        };
        let n = |sec: &str, key: &str| -> Result<f64, TomlError> {
            d.req(sec, key)?
                .as_f64()
                .ok_or_else(|| TomlError(format!("[{sec}] {key}: expected number")))
        };
        let b = |sec: &str, key: &str| -> Result<bool, TomlError> {
            d.req(sec, key)?
                .as_bool()
                .ok_or_else(|| TomlError(format!("[{sec}] {key}: expected bool")))
        };

        let model = ModelSpec {
            name: s("model", "name")?,
            params: n("model", "params")?,
            hidden: n("model", "hidden")? as usize,
            h_kv: n("model", "h_kv")? as usize,
            layers: n("model", "layers")? as usize,
            kv_bytes_per_token: n("model", "kv_bytes_per_token")?,
            tp_degree: n("model", "tp_degree")? as usize,
        };
        // The link fields are optional (config files predating KV tiering
        // carry neither); absent keys use the documented fallbacks.
        let hnum_opt = |key: &str, def: f64| -> Result<f64, TomlError> {
            match d.get("hardware", key) {
                None => Ok(def),
                Some(v) => v
                    .as_f64()
                    .ok_or_else(|| TomlError(format!("[hardware] {key}: expected number"))),
            }
        };
        let hardware = HardwareSpec {
            name: s("hardware", "name")?,
            compute_flops: n("hardware", "compute_flops")?,
            bandwidth: n("hardware", "bandwidth")?,
            memory_bytes: n("hardware", "memory_bytes")?,
            interference: n("hardware", "interference")?,
            reserve_bytes: n("hardware", "reserve_bytes")?,
            pcie_gbps: hnum_opt("pcie_gbps", HardwareSpec::DEFAULT_PCIE_GBPS)?,
            host_mem_bytes: hnum_opt("host_mem_bytes", HardwareSpec::DEFAULT_HOST_MEM_BYTES)?,
        };
        let order_name = s("scheduler", "order")?;
        let scheduler = SchedulerConfig {
            order: OrderPolicy::from_name(&order_name)
                .ok_or_else(|| TomlError(format!("unknown order '{order_name}'")))?,
            chunk_tokens: n("scheduler", "chunk_tokens")? as usize,
            batch_quantum: n("scheduler", "batch_quantum")? as usize,
            max_batch_requests: n("scheduler", "max_batch_requests")? as usize,
            sample_prob: n("scheduler", "sample_prob")?,
            split_sharing_floor: n("scheduler", "split_sharing_floor")?,
            online_adapt: b("scheduler", "online_adapt")?,
            balanced_chunk: b("scheduler", "balanced_chunk")?,
            expected_sharing: n("scheduler", "expected_sharing")?,
            seed: n("scheduler", "seed")? as u64,
        };
        let overlap_name = s("engine", "overlap")?;
        // `audit` is optional (config files predating the auditor carry
        // no such key); absent means the debug-build default.
        let audit = match d.get("engine", "audit") {
            None => false,
            Some(v) => v
                .as_bool()
                .ok_or_else(|| TomlError("[engine] audit: expected bool".into()))?,
        };
        // `trace` is optional for the same reason (pre-§15 config files).
        let trace = match d.get("engine", "trace") {
            None => false,
            Some(v) => v
                .as_bool()
                .ok_or_else(|| TomlError("[engine] trace: expected bool".into()))?,
        };
        let engine = EngineConfig {
            overlap: OverlapMode::from_name(&overlap_name)
                .ok_or_else(|| TomlError(format!("unknown overlap '{overlap_name}'")))?,
            prefix_cache: b("engine", "prefix_cache")?,
            prefill_attn_flops: b("engine", "prefill_attn_flops")?,
            audit,
            trace,
        };
        // The [colocate] section is optional (older config files predate
        // co-located serving); absent keys fall back to the inert default.
        let cdef = ColocateConfig::default();
        let cnum = |key: &str, def: f64| -> Result<f64, TomlError> {
            match d.get("colocate", key) {
                None => Ok(def),
                Some(v) => v
                    .as_f64()
                    .ok_or_else(|| TomlError(format!("[colocate] {key}: expected number"))),
            }
        };
        let policy = match d.get("colocate", "policy") {
            None => cdef.policy,
            Some(v) => {
                let s = v
                    .as_str()
                    .ok_or_else(|| TomlError("[colocate] policy: expected string".into()))?;
                ColocationPolicy::from_name(s)
                    .ok_or_else(|| TomlError(format!("unknown colocation policy '{s}'")))?
            }
        };
        let colocate = ColocateConfig {
            online_rate: cnum("online_rate", cdef.online_rate)?,
            slo_scale: cnum("slo_scale", cdef.slo_scale)?,
            policy,
            online_reserve: cnum("online_reserve", cdef.online_reserve)?,
            urgency: cnum("urgency", cdef.urgency)?,
            burst_factor: cnum("burst_factor", cdef.burst_factor)?,
            phase_secs: cnum("phase_secs", cdef.phase_secs)?,
        };
        // Range-check here so a bad config file is a parse error, not a
        // panic from the admitter/generator asserts downstream.
        fn check(cond: bool, msg: &str) -> Result<(), TomlError> {
            if cond {
                Ok(())
            } else {
                Err(TomlError(format!("[colocate] {msg}")))
            }
        }
        check(colocate.online_rate >= 0.0, "online_rate must be >= 0")?;
        check(colocate.slo_scale > 0.0, "slo_scale must be > 0")?;
        check(
            (0.0..1.0).contains(&colocate.online_reserve),
            "online_reserve must be in [0, 1)",
        )?;
        check((0.0..=1.0).contains(&colocate.urgency), "urgency must be in [0, 1]")?;
        check(colocate.burst_factor >= 1.0, "burst_factor must be >= 1 (1 = Poisson)")?;
        check(colocate.phase_secs > 0.0, "phase_secs must be > 0")?;

        // The [fleet] section is likewise optional (older config files
        // predate the work-stealing fleet); absent keys use the default.
        let fdef = FleetConfig::default();
        let steal = match d.get("fleet", "steal") {
            None => fdef.steal,
            Some(v) => v
                .as_bool()
                .ok_or_else(|| TomlError("[fleet] steal: expected bool".into()))?,
        };
        let steal_ratio = match d.get("fleet", "steal_ratio") {
            None => fdef.steal_ratio,
            Some(v) => v
                .as_f64()
                .ok_or_else(|| TomlError("[fleet] steal_ratio: expected number".into()))?,
        };
        let fleet_csv = |key: &str| -> Result<Vec<String>, TomlError> {
            match d.get("fleet", key) {
                None => Ok(Vec::new()),
                Some(v) => Ok(v
                    .as_str()
                    .ok_or_else(|| TomlError(format!("[fleet] {key}: expected string")))?
                    .split(',')
                    .map(str::trim)
                    .filter(|s| !s.is_empty())
                    .map(str::to_string)
                    .collect()),
            }
        };
        let mut gpus = Vec::new();
        for s in fleet_csv("gpus")? {
            let g: usize = s
                .parse()
                .map_err(|_| TomlError(format!("[fleet] gpus: '{s}' is not an integer")))?;
            gpus.push(g);
        }
        let fleet = FleetConfig {
            steal,
            steal_ratio,
            gpus,
            hardware: fleet_csv("hardware")?,
        };
        // The [kv] section is optional (older config files predate KV
        // tiering; the default is the inert `enabled = false`), but a
        // *present* section is validated strictly: unknown keys are an
        // error naming the key, so a typo'd policy switch cannot
        // silently no-op.
        if let Some(sec) = d.sections.get("kv") {
            for key in sec.keys() {
                if !KvConfig::TOML_KEYS.contains(&key.as_str()) {
                    return Err(TomlError(format!(
                        "[kv] unknown key '{key}' (expected one of: {})",
                        KvConfig::TOML_KEYS.join(", ")
                    ))
                    .into());
                }
            }
        }
        let kdef = KvConfig::default();
        let kbool = |key: &str, def: bool| -> Result<bool, TomlError> {
            match d.get("kv", key) {
                None => Ok(def),
                Some(v) => v
                    .as_bool()
                    .ok_or_else(|| TomlError(format!("[kv] {key}: expected bool"))),
            }
        };
        let knum = |key: &str, def: f64| -> Result<f64, TomlError> {
            match d.get("kv", key) {
                None => Ok(def),
                Some(v) => v
                    .as_f64()
                    .ok_or_else(|| TomlError(format!("[kv] {key}: expected number"))),
            }
        };
        let kv = KvConfig {
            enabled: kbool("enabled", kdef.enabled)?,
            swap_margin: knum("swap_margin", kdef.swap_margin)?,
            host_mem_frac: knum("host_mem_frac", kdef.host_mem_frac)?,
            prefetch: kbool("prefetch", kdef.prefetch)?,
        };
        kv.validate().map_err(|e| TomlError(format!("[kv] {e}")))?;

        // The [modality] section is optional (older config files predate
        // the multi-modal subsystem; the default is the modality-blind
        // scheduler), with the same strictness policy as [kv]: a present
        // section rejects unknown keys by name.
        if let Some(sec) = d.sections.get("modality") {
            for key in sec.keys() {
                if !ModalityConfig::TOML_KEYS.contains(&key.as_str()) {
                    return Err(TomlError(format!(
                        "[modality] unknown key '{key}' (expected one of: {})",
                        ModalityConfig::TOML_KEYS.join(", ")
                    ))
                    .into());
                }
            }
        }
        let mdef = ModalityConfig::default();
        let mbool = |key: &str, def: bool| -> Result<bool, TomlError> {
            match d.get("modality", key) {
                None => Ok(def),
                Some(v) => v
                    .as_bool()
                    .ok_or_else(|| TomlError(format!("[modality] {key}: expected bool"))),
            }
        };
        let mnum = |key: &str, def: f64| -> Result<f64, TomlError> {
            match d.get("modality", key) {
                None => Ok(def),
                Some(v) => v
                    .as_f64()
                    .ok_or_else(|| TomlError(format!("[modality] {key}: expected number"))),
            }
        };
        let modality = ModalityConfig {
            enabled: mbool("enabled", mdef.enabled)?,
            encoder_params: mnum("encoder_params", mdef.encoder_params)?,
            embed_cache_frac: mnum("embed_cache_frac", mdef.embed_cache_frac)?,
            embed_bytes_per_token: mnum(
                "embed_bytes_per_token",
                mdef.embed_bytes_per_token,
            )?,
        };
        modality
            .validate()
            .map_err(|e| TomlError(format!("[modality] {e}")))?;

        // The [faults] section is optional (older config files predate the
        // fault-tolerance layer; the default is the inert `enabled =
        // false`), with the same strictness policy as [kv]: a present
        // section rejects unknown keys by name.
        if let Some(sec) = d.sections.get("faults") {
            for key in sec.keys() {
                if !FaultsConfig::TOML_KEYS.contains(&key.as_str()) {
                    return Err(TomlError(format!(
                        "[faults] unknown key '{key}' (expected one of: {})",
                        FaultsConfig::TOML_KEYS.join(", ")
                    ))
                    .into());
                }
            }
        }
        let fadef = FaultsConfig::default();
        let fabool = |key: &str, def: bool| -> Result<bool, TomlError> {
            match d.get("faults", key) {
                None => Ok(def),
                Some(v) => v
                    .as_bool()
                    .ok_or_else(|| TomlError(format!("[faults] {key}: expected bool"))),
            }
        };
        let fanum = |key: &str, def: f64| -> Result<f64, TomlError> {
            match d.get("faults", key) {
                None => Ok(def),
                Some(v) => v
                    .as_f64()
                    .ok_or_else(|| TomlError(format!("[faults] {key}: expected number"))),
            }
        };
        let strategy = match d.get("faults", "strategy") {
            None => fadef.strategy,
            Some(v) => {
                let s = v
                    .as_str()
                    .ok_or_else(|| TomlError("[faults] strategy: expected string".into()))?;
                RecoveryStrategy::from_name(s)
                    .ok_or_else(|| TomlError(format!("unknown recovery strategy '{s}'")))?
            }
        };
        let faults = FaultsConfig {
            enabled: fabool("enabled", fadef.enabled)?,
            seed: fanum("seed", fadef.seed as f64)? as u64,
            mtbf_s: fanum("mtbf_s", fadef.mtbf_s)?,
            rejoin_delay_s: fanum("rejoin_delay_s", fadef.rejoin_delay_s)?,
            max_deaths: fanum("max_deaths", fadef.max_deaths as f64)? as usize,
            host_shrink_at_s: fanum("host_shrink_at_s", fadef.host_shrink_at_s)?,
            host_shrink_frac: fanum("host_shrink_frac", fadef.host_shrink_frac)?,
            link_degrade_at_s: fanum("link_degrade_at_s", fadef.link_degrade_at_s)?,
            link_degrade_factor: fanum("link_degrade_factor", fadef.link_degrade_factor)?,
            kv_rescue: fabool("kv_rescue", fadef.kv_rescue)?,
            strategy,
            snapshot_every: fanum("snapshot_every", fadef.snapshot_every as f64)? as usize,
        };
        faults
            .validate()
            .map_err(|e| TomlError(format!("[faults] {e}")))?;

        // The [stream] section is optional (older config files predate the
        // streaming ingest engine; the default window applies), with the
        // same strictness policy as [kv]: a present section rejects
        // unknown keys by name.
        if let Some(sec) = d.sections.get("stream") {
            for key in sec.keys() {
                if !StreamConfig::TOML_KEYS.contains(&key.as_str()) {
                    return Err(TomlError(format!(
                        "[stream] unknown key '{key}' (expected one of: {})",
                        StreamConfig::TOML_KEYS.join(", ")
                    ))
                    .into());
                }
            }
        }
        let sdef = StreamConfig::default();
        let snum = |key: &str, def: f64| -> Result<f64, TomlError> {
            let x = match d.get("stream", key) {
                None => def,
                Some(v) => v
                    .as_f64()
                    .ok_or_else(|| TomlError(format!("[stream] {key}: expected number")))?,
            };
            // Window sizes are counts: reject negatives and fractions
            // before the `as` cast silently truncates them.
            // lint:allow(r3) -- fract() of an integral f64 is exactly 0.0
            if x < 0.0 || x.fract() != 0.0 {
                return Err(TomlError(format!(
                    "[stream] {key}: expected a non-negative integer, got {x}"
                )));
            }
            Ok(x)
        };
        let stream = StreamConfig {
            window_requests: snum("window_requests", sdef.window_requests as f64)? as usize,
            window_tokens: snum("window_tokens", sdef.window_tokens as f64)? as u64,
        };
        stream
            .validate()
            .map_err(|e| TomlError(format!("[stream] {e}")))?;

        let gpus_per_replica = n("", "gpus_per_replica")? as usize;
        let dp_replicas = n("", "dp_replicas")? as usize;
        fleet
            .validate(dp_replicas)
            .map_err(|e| TomlError(format!("[fleet] {e}")))?;
        Ok(SystemConfig {
            model,
            hardware,
            scheduler,
            engine,
            colocate,
            fleet,
            kv,
            modality,
            faults,
            stream,
            gpus_per_replica,
            dp_replicas,
        })
    }

    pub fn load(path: &Path) -> anyhow::Result<Self> {
        Self::from_toml(&std::fs::read_to_string(path)?)
    }

    pub fn save(&self, path: &Path) -> anyhow::Result<()> {
        std::fs::write(path, self.to_toml())?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::presets;
    use super::*;

    #[test]
    fn llama3_8b_kv_bytes_per_token() {
        // Known value: Llama-3-8B has 8 kv heads * 128 dim * 32 layers
        // -> 128 KiB per token in FP16.
        let m = presets::llama3_8b();
        assert_eq!(m.kv_bytes_per_token, 131072.0);
    }

    #[test]
    fn kv_capacity_positive_and_sane() {
        let m = presets::llama3_8b();
        let hw = presets::a100_80gb();
        let tokens = hw.kv_capacity_tokens(&m, 1);
        // ~ (80e9 - 16e9 - reserve) / 131072 — a few hundred thousand.
        assert!(tokens > 100_000.0 && tokens < 1_000_000.0, "{tokens}");
    }

    #[test]
    #[should_panic(expected = "does not fit")]
    fn oversized_model_panics() {
        let m = presets::llama3_70b(); // 140 GB of weights
        let hw = presets::a100_80gb();
        hw.kv_capacity_bytes(&m, 1);
    }

    #[test]
    fn toml_roundtrip() {
        let mut cfg = SystemConfig::new(presets::llama3_8b(), presets::a100_80gb());
        cfg.scheduler.order = OrderPolicy::Dfs;
        cfg.engine.overlap = OverlapMode::Sequential;
        cfg.dp_replicas = 4;
        let s = cfg.to_toml();
        let back = SystemConfig::from_toml(&s).unwrap();
        assert_eq!(cfg, back);
    }

    #[test]
    fn from_toml_rejects_unknown_policy() {
        let cfg = SystemConfig::new(presets::llama3_8b(), presets::a100_80gb());
        let text = cfg.to_toml().replace("blendserve", "magic");
        assert!(SystemConfig::from_toml(&text).is_err());
    }

    #[test]
    fn tp_scaling_gives_more_kv() {
        let m = presets::llama3_70b().with_tp(8);
        let hw = presets::a100_80gb();
        let tokens = hw.kv_capacity_tokens(&m, 8);
        assert!(tokens > 1_000_000.0, "{tokens}");
    }

    #[test]
    fn save_load_roundtrip() {
        let cfg = SystemConfig::new(presets::qwen25_7b(), presets::a100_80gb());
        let dir = std::env::temp_dir().join("blendserve_cfg_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("cfg.toml");
        cfg.save(&path).unwrap();
        assert_eq!(SystemConfig::load(&path).unwrap(), cfg);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn colocate_roundtrip_and_defaults() {
        let mut cfg = SystemConfig::new(presets::llama3_8b(), presets::a100_80gb());
        cfg.colocate.online_rate = 3.5;
        cfg.colocate.policy = ColocationPolicy::BestEffort;
        cfg.colocate.burst_factor = 4.0;
        let back = SystemConfig::from_toml(&cfg.to_toml()).unwrap();
        assert_eq!(cfg, back);

        // Config files predating co-location (no [colocate] section) must
        // parse with the inert default.
        let mut stripped = String::new();
        let mut in_coloc = false;
        for line in cfg.to_toml().lines() {
            if line.trim() == "[colocate]" {
                in_coloc = true;
                continue;
            }
            if in_coloc && line.trim().starts_with('[') {
                in_coloc = false;
            }
            if !in_coloc {
                stripped.push_str(line);
                stripped.push('\n');
            }
        }
        let parsed = SystemConfig::from_toml(&stripped).unwrap();
        assert_eq!(parsed.colocate, ColocateConfig::default());
    }

    #[test]
    fn from_toml_rejects_out_of_range_colocate_values() {
        let mut cfg = SystemConfig::new(presets::llama3_8b(), presets::a100_80gb());
        cfg.colocate.online_reserve = 0.5;
        let text = cfg.to_toml().replace("online_reserve = 0.5", "online_reserve = 1");
        assert!(SystemConfig::from_toml(&text).is_err());
        let text = cfg.to_toml().replace("slo_scale = 5", "slo_scale = 0");
        assert!(SystemConfig::from_toml(&text).is_err());
    }

    #[test]
    fn fleet_roundtrip_and_defaults() {
        let mut cfg = SystemConfig::new(presets::llama3_8b(), presets::a100_80gb());
        cfg.dp_replicas = 3;
        cfg.fleet.steal = false;
        cfg.fleet.steal_ratio = 0.25;
        cfg.fleet.gpus = vec![1, 1, 2];
        cfg.fleet.hardware =
            vec!["a100-80gb-sxm".to_string(), "h100-80gb-sxm".to_string()];
        let back = SystemConfig::from_toml(&cfg.to_toml()).unwrap();
        assert_eq!(cfg, back);

        // Config files predating the fleet (no [fleet] section) must parse
        // with the inert default.
        let mut stripped = String::new();
        let mut in_fleet = false;
        for line in cfg.to_toml().lines() {
            if line.trim() == "[fleet]" {
                in_fleet = true;
                continue;
            }
            if in_fleet && line.trim().starts_with('[') {
                in_fleet = false;
            }
            if !in_fleet {
                stripped.push_str(line);
                stripped.push('\n');
            }
        }
        let parsed = SystemConfig::from_toml(&stripped).unwrap();
        assert_eq!(parsed.fleet, FleetConfig::default());
    }

    #[test]
    fn from_toml_rejects_bad_fleet_values() {
        let cfg = SystemConfig::new(presets::llama3_8b(), presets::a100_80gb());
        let text = cfg.to_toml().replace("steal_ratio = 0.5", "steal_ratio = 0");
        assert!(SystemConfig::from_toml(&text).is_err());
        let text = cfg.to_toml().replace("steal_ratio = 0.5", "steal_ratio = 1.5");
        assert!(SystemConfig::from_toml(&text).is_err());
        let text = cfg
            .to_toml()
            .replace("hardware = \"\"", "hardware = \"gpu-from-the-future\"");
        assert!(SystemConfig::from_toml(&text).is_err());
        let text = cfg.to_toml().replace("gpus = \"\"", "gpus = \"1,0\"");
        assert!(SystemConfig::from_toml(&text).is_err());
        // Per-replica lists longer than dp_replicas are a misconfiguration
        // (the tail would be silently ignored), not a truncation.
        let text = cfg.to_toml().replace("gpus = \"\"", "gpus = \"1,1\"");
        assert!(SystemConfig::from_toml(&text).is_err(), "dp=1 with 2 gpu entries");
        assert!(cfg.fleet.validate(cfg.dp_replicas).is_ok());
    }

    #[test]
    fn kv_roundtrip_and_defaults() {
        let mut cfg = SystemConfig::new(presets::llama3_8b(), presets::a100_80gb());
        cfg.kv.enabled = true;
        cfg.kv.swap_margin = 0.8;
        cfg.kv.host_mem_frac = 0.5;
        cfg.kv.prefetch = false;
        let back = SystemConfig::from_toml(&cfg.to_toml()).unwrap();
        assert_eq!(cfg, back);

        // Config files predating KV tiering (no [kv] section) must parse
        // with the inert default — and that default must be *disabled*.
        let mut stripped = String::new();
        let mut in_kv = false;
        for line in cfg.to_toml().lines() {
            if line.trim() == "[kv]" {
                in_kv = true;
                continue;
            }
            if in_kv && line.trim().starts_with('[') {
                in_kv = false;
            }
            if !in_kv {
                stripped.push_str(line);
                stripped.push('\n');
            }
        }
        let parsed = SystemConfig::from_toml(&stripped).unwrap();
        assert_eq!(parsed.kv, KvConfig::default());
        assert!(!parsed.kv.enabled, "kv must default to disabled");
        assert!(!KvConfig::default().enabled);
    }

    #[test]
    fn modality_roundtrip_and_defaults() {
        let mut cfg = SystemConfig::new(presets::llama3_8b(), presets::a100_80gb());
        cfg.modality.enabled = true;
        cfg.modality.encoder_params = 3.04e8;
        cfg.modality.embed_cache_frac = 0.1;
        cfg.modality.embed_bytes_per_token = 2048.0;
        let back = SystemConfig::from_toml(&cfg.to_toml()).unwrap();
        assert_eq!(cfg, back);

        // Config files predating the multi-modal subsystem (no [modality]
        // section) must parse with the modality-blind default.
        let mut stripped = String::new();
        let mut in_mm = false;
        for line in cfg.to_toml().lines() {
            if line.trim() == "[modality]" {
                in_mm = true;
                continue;
            }
            if in_mm && line.trim().starts_with('[') {
                in_mm = false;
            }
            if !in_mm {
                stripped.push_str(line);
                stripped.push('\n');
            }
        }
        let parsed = SystemConfig::from_toml(&stripped).unwrap();
        assert_eq!(parsed.modality, ModalityConfig::default());
        assert!(!parsed.modality.enabled, "modality must default to blind");
    }

    #[test]
    fn from_toml_rejects_unknown_modality_key_by_name() {
        let cfg = SystemConfig::new(presets::llama3_8b(), presets::a100_80gb());
        let text = cfg
            .to_toml()
            .replace("[modality]", "[modality]\nencodr_params = 1e9");
        let err = SystemConfig::from_toml(&text).unwrap_err().to_string();
        assert!(err.contains("encodr_params"), "key name missing from: {err}");
        assert!(err.contains("[modality]"), "section missing from: {err}");
    }

    #[test]
    fn from_toml_rejects_bad_modality_values() {
        let cfg = SystemConfig::new(presets::llama3_8b(), presets::a100_80gb());
        let text = cfg
            .to_toml()
            .replace("encoder_params = 2000000000", "encoder_params = 0");
        assert!(SystemConfig::from_toml(&text).is_err());
        let text = cfg
            .to_toml()
            .replace("embed_cache_frac = 0.05", "embed_cache_frac = 1");
        assert!(SystemConfig::from_toml(&text).is_err());
        let text = cfg
            .to_toml()
            .replace("embed_bytes_per_token = 8192", "embed_bytes_per_token = -1");
        assert!(SystemConfig::from_toml(&text).is_err());
        assert!(ModalityConfig::default().validate().is_ok());
    }

    #[test]
    fn faults_roundtrip_and_defaults() {
        let mut cfg = SystemConfig::new(presets::llama3_8b(), presets::a100_80gb());
        cfg.faults.enabled = true;
        cfg.faults.seed = 99;
        cfg.faults.mtbf_s = 120.0;
        cfg.faults.rejoin_delay_s = 30.0;
        cfg.faults.max_deaths = 2;
        cfg.faults.host_shrink_at_s = 50.0;
        cfg.faults.host_shrink_frac = 0.25;
        cfg.faults.link_degrade_at_s = 10.0;
        cfg.faults.link_degrade_factor = 0.5;
        cfg.faults.kv_rescue = false;
        cfg.faults.strategy = RecoveryStrategy::Restart;
        cfg.faults.snapshot_every = 16;
        let back = SystemConfig::from_toml(&cfg.to_toml()).unwrap();
        assert_eq!(cfg, back);

        // Config files predating the fault-tolerance layer (no [faults]
        // section) must parse with the inert default — and that default
        // must be *disabled*.
        let mut stripped = String::new();
        let mut in_faults = false;
        for line in cfg.to_toml().lines() {
            if line.trim() == "[faults]" {
                in_faults = true;
                continue;
            }
            if in_faults && line.trim().starts_with('[') {
                in_faults = false;
            }
            if !in_faults {
                stripped.push_str(line);
                stripped.push('\n');
            }
        }
        let parsed = SystemConfig::from_toml(&stripped).unwrap();
        assert_eq!(parsed.faults, FaultsConfig::default());
        assert!(!parsed.faults.enabled, "faults must default to disabled");
    }

    #[test]
    fn from_toml_rejects_unknown_faults_key_by_name() {
        let cfg = SystemConfig::new(presets::llama3_8b(), presets::a100_80gb());
        let text = cfg.to_toml().replace("[faults]", "[faults]\nmtbf = 10");
        let err = SystemConfig::from_toml(&text).unwrap_err().to_string();
        assert!(err.contains("mtbf"), "key name missing from: {err}");
        assert!(err.contains("[faults]"), "section missing from: {err}");
    }

    #[test]
    fn from_toml_rejects_bad_faults_values() {
        let cfg = SystemConfig::new(presets::llama3_8b(), presets::a100_80gb());
        let text = cfg.to_toml().replace("mtbf_s = 0", "mtbf_s = -1");
        assert!(SystemConfig::from_toml(&text).is_err());
        let text = cfg
            .to_toml()
            .replace("host_shrink_frac = 0.5", "host_shrink_frac = 0");
        assert!(SystemConfig::from_toml(&text).is_err());
        let text = cfg
            .to_toml()
            .replace("link_degrade_factor = 0.25", "link_degrade_factor = 1.5");
        assert!(SystemConfig::from_toml(&text).is_err());
        let text = cfg.to_toml().replace("snapshot_every = 64", "snapshot_every = 0");
        assert!(SystemConfig::from_toml(&text).is_err());
        let text = cfg.to_toml().replace("\"recover\"", "\"hope\"");
        assert!(SystemConfig::from_toml(&text).is_err());
        assert!(FaultsConfig::default().validate().is_ok());
    }

    #[test]
    fn stream_roundtrip_and_defaults() {
        let mut cfg = SystemConfig::new(presets::llama3_8b(), presets::a100_80gb());
        cfg.stream.window_requests = 4096;
        cfg.stream.window_tokens = 2_000_000;
        let back = SystemConfig::from_toml(&cfg.to_toml()).unwrap();
        assert_eq!(cfg, back);

        // Config files predating the streaming ingest engine (no [stream]
        // section) must parse with the default window.
        let mut stripped = String::new();
        let mut in_stream = false;
        for line in cfg.to_toml().lines() {
            if line.trim() == "[stream]" {
                in_stream = true;
                continue;
            }
            if in_stream && line.trim().starts_with('[') {
                in_stream = false;
            }
            if !in_stream {
                stripped.push_str(line);
                stripped.push('\n');
            }
        }
        let parsed = SystemConfig::from_toml(&stripped).unwrap();
        assert_eq!(parsed.stream, StreamConfig::default());
    }

    #[test]
    fn from_toml_rejects_unknown_stream_key_by_name() {
        let cfg = SystemConfig::new(presets::llama3_8b(), presets::a100_80gb());
        let text = cfg
            .to_toml()
            .replace("[stream]", "[stream]\nwindw_requests = 4");
        let err = SystemConfig::from_toml(&text).unwrap_err().to_string();
        assert!(err.contains("windw_requests"), "key name missing from: {err}");
        assert!(err.contains("[stream]"), "section missing from: {err}");
    }

    #[test]
    fn from_toml_rejects_bad_stream_values() {
        let cfg = SystemConfig::new(presets::llama3_8b(), presets::a100_80gb());
        let text = cfg
            .to_toml()
            .replace("window_requests = 8192", "window_requests = -1");
        assert!(SystemConfig::from_toml(&text).is_err());
        let text = cfg
            .to_toml()
            .replace("window_requests = 8192", "window_requests = 1.5");
        assert!(SystemConfig::from_toml(&text).is_err());
        // Beyond 2^53 an f64 can no longer represent the count exactly.
        let text = cfg
            .to_toml()
            .replace("window_tokens = 0", "window_tokens = 1e16");
        assert!(SystemConfig::from_toml(&text).is_err());
        assert!(StreamConfig::default().validate().is_ok());
    }

    #[test]
    fn recovery_strategy_names_roundtrip() {
        for s in [RecoveryStrategy::Recover, RecoveryStrategy::Restart] {
            assert_eq!(RecoveryStrategy::from_name(s.name()), Some(s));
        }
        assert_eq!(RecoveryStrategy::from_name("bogus"), None);
    }

    #[test]
    fn from_toml_rejects_unknown_kv_key_by_name() {
        let cfg = SystemConfig::new(presets::llama3_8b(), presets::a100_80gb());
        let text = cfg
            .to_toml()
            .replace("[kv]", "[kv]\nswap_margn = 2.0");
        let err = SystemConfig::from_toml(&text).unwrap_err().to_string();
        assert!(err.contains("swap_margn"), "key name missing from: {err}");
        assert!(err.contains("[kv]"), "section missing from: {err}");
    }

    #[test]
    fn from_toml_rejects_bad_kv_values() {
        let cfg = SystemConfig::new(presets::llama3_8b(), presets::a100_80gb());
        let text = cfg.to_toml().replace("swap_margin = 1", "swap_margin = 0");
        assert!(SystemConfig::from_toml(&text).is_err());
        let text = cfg
            .to_toml()
            .replace("host_mem_frac = 1", "host_mem_frac = 1.5");
        assert!(SystemConfig::from_toml(&text).is_err());
        let text = cfg.to_toml().replace("enabled = false", "enabled = 7");
        assert!(SystemConfig::from_toml(&text).is_err());
    }

    #[test]
    fn hardware_link_fields_default_when_absent() {
        let cfg = SystemConfig::new(presets::llama3_8b(), presets::a100_80gb());
        let stripped: String = cfg
            .to_toml()
            .lines()
            .filter(|l| {
                !l.trim_start().starts_with("pcie_gbps")
                    && !l.trim_start().starts_with("host_mem_bytes")
            })
            .map(|l| format!("{l}\n"))
            .collect();
        let parsed = SystemConfig::from_toml(&stripped).unwrap();
        assert_eq!(parsed.hardware.pcie_gbps, HardwareSpec::DEFAULT_PCIE_GBPS);
        assert_eq!(
            parsed.hardware.host_mem_bytes,
            HardwareSpec::DEFAULT_HOST_MEM_BYTES
        );
    }

    #[test]
    fn from_toml_rejects_unknown_colocation_policy() {
        let cfg = SystemConfig::new(presets::llama3_8b(), presets::a100_80gb());
        let text = cfg.to_toml().replace("\"elastic\"", "\"psychic\"");
        assert!(SystemConfig::from_toml(&text).is_err());
    }

    #[test]
    fn colocation_policy_names_roundtrip() {
        for p in [ColocationPolicy::Elastic, ColocationPolicy::BestEffort] {
            assert_eq!(ColocationPolicy::from_name(p.name()), Some(p));
        }
        assert_eq!(ColocationPolicy::from_name("bogus"), None);
    }

    #[test]
    fn policy_names_roundtrip() {
        for p in [
            OrderPolicy::Fcfs,
            OrderPolicy::Dfs,
            OrderPolicy::Random,
            OrderPolicy::BlendServe,
        ] {
            assert_eq!(OrderPolicy::from_name(p.name()), Some(p));
        }
        assert_eq!(OrderPolicy::from_name("bogus"), None);
    }
}
