//! Model and hardware presets for everything the paper evaluates (§6.2,
//! §6.6): Llama-3-8B/70B, Llama-2-7B, Qwen-2.5-7B/72B, DeepSeek-67B on
//! A100-80GB-SXM, plus the tiny CPU model actually served end-to-end.

use super::{HardwareSpec, ModelSpec};

/// Llama-3(.1)-8B: 32 layers, H=4096, 8 KV heads x 128 = 1024.
pub fn llama3_8b() -> ModelSpec {
    ModelSpec::new("llama-3-8b", 8.03e9, 4096, 1024, 32)
}

/// Llama-3(.1)-70B: 80 layers, H=8192, 8 KV heads x 128 = 1024 (GQA).
pub fn llama3_70b() -> ModelSpec {
    ModelSpec::new("llama-3-70b", 70.6e9, 8192, 1024, 80)
}

/// Llama-2-7B: MHA (32 kv heads x 128 = 4096), 32 layers, H=4096.
pub fn llama2_7b() -> ModelSpec {
    ModelSpec::new("llama-2-7b", 6.74e9, 4096, 4096, 32)
}

/// Qwen-2.5-7B: 28 layers, H=3584, GQA 4 kv heads x 128 = 512.
pub fn qwen25_7b() -> ModelSpec {
    ModelSpec::new("qwen-2.5-7b", 7.62e9, 3584, 512, 28)
}

/// Qwen-2.5-72B: 80 layers, H=8192, GQA 8 kv heads x 128 = 1024.
pub fn qwen25_72b() -> ModelSpec {
    ModelSpec::new("qwen-2.5-72b", 72.7e9, 8192, 1024, 80)
}

/// DeepSeek-67B: 95 layers, H=8192, GQA 8 kv heads x 128 = 1024.
pub fn deepseek_67b() -> ModelSpec {
    ModelSpec::new("deepseek-67b", 67.0e9, 8192, 1024, 95)
}

/// The 3.4M-parameter model really served via PJRT on CPU
/// (python/compile/model.py; constants must match ModelConfig there).
pub fn tiny_cpu() -> ModelSpec {
    // vocab=2048 d=256 L=4 nq=8 nkv=2 hd=32 ffn=688 -> h_kv = 2*32 = 64.
    ModelSpec::new("tiny-cpu", 3.295488e6, 256, 64, 4)
}

/// NVIDIA A100-80GB SXM: 312 TFLOPS FP16 tensor, 2039 GB/s HBM2e.
///
/// `interference = 0.15` is the calibrated spatial-sharing penalty: the
/// paper's "practical optimal" profiles overlapped GEMM+attention execution
/// instead of assuming a perfect `max(comp, mem)` (§6.2); NanoFlow reports
/// roughly 10-20% overhead from SM contention, and 15% reproduces the
/// paper's optimal-vs-achieved gaps.
pub fn a100_80gb() -> HardwareSpec {
    HardwareSpec {
        name: "a100-80gb-sxm".to_string(),
        compute_flops: 312e12,
        bandwidth: 2.039e12,
        memory_bytes: 80e9,
        interference: 0.15,
        reserve_bytes: 4e9,
        // PCIe 4.0 x16 host link; DGX-A100-class hosts give each GPU a
        // ~256 GB share of CPU DRAM for KV offload (kv module).
        pcie_gbps: 32.0,
        host_mem_bytes: 256e9,
    }
}

/// NVIDIA H100-80GB SXM: 989 TFLOPS dense FP16 tensor, 3.35 TB/s HBM3.
///
/// Same interference model as the A100 (NanoFlow-style spatial sharing);
/// used by heterogeneous `server::fleet` deployments (mixed A100/H100).
pub fn h100_80gb() -> HardwareSpec {
    HardwareSpec {
        name: "h100-80gb-sxm".to_string(),
        compute_flops: 989e12,
        bandwidth: 3.35e12,
        memory_bytes: 80e9,
        interference: 0.15,
        reserve_bytes: 4e9,
        // PCIe 5.0 x16 host link.
        pcie_gbps: 64.0,
        host_mem_bytes: 256e9,
    }
}

/// The host CPU as PJRT sees it — used only by the real-model runtime's
/// perf accounting; numbers are order-of-magnitude (single socket).
pub fn cpu_host() -> HardwareSpec {
    HardwareSpec {
        name: "cpu-host".to_string(),
        compute_flops: 2e11,
        bandwidth: 4e10,
        memory_bytes: 16e9,
        interference: 0.0,
        reserve_bytes: 1e9,
        // The "device" already lives in host memory: no offload tier.
        pcie_gbps: 0.0,
        host_mem_bytes: 0.0,
    }
}

/// All GPU-model presets the paper's figures touch, keyed by name.
pub fn model_by_name(name: &str) -> Option<ModelSpec> {
    match name {
        "llama-3-8b" => Some(llama3_8b()),
        "llama-3-70b" => Some(llama3_70b()),
        "llama-2-7b" => Some(llama2_7b()),
        "qwen-2.5-7b" => Some(qwen25_7b()),
        "qwen-2.5-72b" => Some(qwen25_72b()),
        "deepseek-67b" => Some(deepseek_67b()),
        "tiny-cpu" => Some(tiny_cpu()),
        _ => None,
    }
}

/// GPU hardware presets keyed by name (heterogeneous fleet specs).
pub fn hardware_by_name(name: &str) -> Option<HardwareSpec> {
    match name {
        "a100-80gb-sxm" => Some(a100_80gb()),
        "h100-80gb-sxm" => Some(h100_80gb()),
        "cpu-host" => Some(cpu_host()),
        _ => None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_models_resolvable_by_name() {
        for name in [
            "llama-3-8b",
            "llama-3-70b",
            "llama-2-7b",
            "qwen-2.5-7b",
            "qwen-2.5-72b",
            "deepseek-67b",
            "tiny-cpu",
        ] {
            let m = model_by_name(name).unwrap();
            assert_eq!(m.name, name);
            assert!(m.kv_bytes_per_token > 0.0);
        }
        assert!(model_by_name("gpt-5").is_none());
    }

    #[test]
    fn llama2_is_mha_heavy() {
        // MHA Llama-2-7B stores 4x the KV bytes of GQA Llama-3-8B.
        assert_eq!(
            llama2_7b().kv_bytes_per_token,
            4.0 * llama3_8b().kv_bytes_per_token
        );
    }

    #[test]
    fn a100_constants() {
        let hw = a100_80gb();
        assert_eq!(hw.compute_flops, 312e12);
        assert_eq!(hw.bandwidth, 2.039e12);
    }

    #[test]
    fn hardware_resolvable_by_name() {
        for name in ["a100-80gb-sxm", "h100-80gb-sxm", "cpu-host"] {
            let hw = hardware_by_name(name).unwrap();
            assert_eq!(hw.name, name);
            assert!(hw.compute_flops > 0.0 && hw.bandwidth > 0.0);
        }
        assert!(hardware_by_name("tpu-v9").is_none());
        // H100 strictly dominates A100 on both axes (fleet weighting
        // assumes capability ordering is meaningful).
        let (a, h) = (a100_80gb(), h100_80gb());
        assert!(h.compute_flops > a.compute_flops);
        assert!(h.bandwidth > a.bandwidth);
        // ...and on the host link (PCIe 5 vs 4).
        assert!(h.pcie_gbps > a.pcie_gbps);
    }

    #[test]
    fn gpu_presets_have_host_link_cpu_does_not() {
        for hw in [a100_80gb(), h100_80gb()] {
            assert!(hw.pcie_gbps > 0.0, "{}", hw.name);
            assert!(hw.host_mem_bytes > hw.memory_bytes, "{}", hw.name);
        }
        let cpu = cpu_host();
        assert_eq!(cpu.pcie_gbps, 0.0);
        assert_eq!(cpu.host_mem_bytes, 0.0);
    }
}
