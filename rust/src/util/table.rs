//! Plain-text table formatting for the paper-figure harnesses: every table
//! and figure in the paper is re-emitted as an aligned text table (plus CSV)
//! so runs diff cleanly.

/// A simple aligned text table with a title and column headers.
#[derive(Clone, Debug, Default)]
pub struct Table {
    pub title: String,
    pub headers: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, headers: &[&str]) -> Self {
        Table {
            title: title.to_string(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: &[String]) -> &mut Self {
        assert_eq!(
            cells.len(),
            self.headers.len(),
            "row width != header width in table '{}'",
            self.title
        );
        self.rows.push(cells.to_vec());
        self
    }

    /// Convenience: build a row from display-ables.
    pub fn rowf(&mut self, cells: &[&dyn std::fmt::Display]) -> &mut Self {
        let cells: Vec<String> = cells.iter().map(|c| c.to_string()).collect();
        self.row(&cells)
    }

    pub fn to_text(&self) -> String {
        let ncols = self.headers.len();
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, cell) in row.iter().enumerate() {
                widths[i] = widths[i].max(cell.len());
            }
        }
        let mut out = String::new();
        out.push_str(&format!("# {}\n", self.title));
        let fmt_row = |cells: &[String]| -> String {
            let mut line = String::new();
            for i in 0..ncols {
                if i > 0 {
                    line.push_str("  ");
                }
                line.push_str(&format!("{:<width$}", cells[i], width = widths[i]));
            }
            line.trim_end().to_string()
        };
        out.push_str(&fmt_row(&self.headers));
        out.push('\n');
        out.push_str(&"-".repeat(widths.iter().sum::<usize>() + 2 * (ncols - 1)));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row));
            out.push('\n');
        }
        out
    }

    pub fn to_csv(&self) -> String {
        let esc = |s: &str| -> String {
            if s.contains(',') || s.contains('"') {
                format!("\"{}\"", s.replace('"', "\"\""))
            } else {
                s.to_string()
            }
        };
        let mut out = String::new();
        out.push_str(
            &self.headers.iter().map(|h| esc(h)).collect::<Vec<_>>().join(","),
        );
        out.push('\n');
        for row in &self.rows {
            out.push_str(&row.iter().map(|c| esc(c)).collect::<Vec<_>>().join(","));
            out.push('\n');
        }
        out
    }

    /// Write `<dir>/<name>.txt` and `<dir>/<name>.csv`.
    pub fn save(&self, dir: &std::path::Path, name: &str) -> std::io::Result<()> {
        std::fs::create_dir_all(dir)?;
        std::fs::write(dir.join(format!("{name}.txt")), self.to_text())?;
        std::fs::write(dir.join(format!("{name}.csv")), self.to_csv())?;
        Ok(())
    }
}

/// Format a float with 2 decimal places (table cells).
pub fn f2(x: f64) -> String {
    format!("{x:.2}")
}

/// Format a float with 3 decimal places.
pub fn f3(x: f64) -> String {
    format!("{x:.3}")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new("demo", &["name", "val"]);
        t.row(&["a".into(), "1".into()]);
        t.row(&["longer".into(), "22".into()]);
        let text = t.to_text();
        assert!(text.contains("# demo"));
        assert!(text.contains("longer  22"));
    }

    #[test]
    #[should_panic(expected = "row width")]
    fn rejects_ragged_rows() {
        let mut t = Table::new("demo", &["a", "b"]);
        t.row(&["only-one".into()]);
    }

    #[test]
    fn csv_escapes_commas() {
        let mut t = Table::new("demo", &["a"]);
        t.row(&["x,y".into()]);
        assert!(t.to_csv().contains("\"x,y\""));
    }

    #[test]
    fn save_writes_both_files() {
        let dir = std::env::temp_dir().join("blendserve_table_test");
        let mut t = Table::new("demo", &["a"]);
        t.row(&["1".into()]);
        t.save(&dir, "t").unwrap();
        assert!(dir.join("t.txt").exists());
        assert!(dir.join("t.csv").exists());
        std::fs::remove_dir_all(&dir).ok();
    }
}
