//! Summary statistics used by trace characterization and benchmarks.

/// Arithmetic mean; 0.0 for empty input.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Linear-interpolated percentile, `q` in [0, 100].
pub fn percentile(xs: &[f64], q: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v: Vec<f64> = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let pos = (q / 100.0) * (v.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        let frac = pos - lo as f64;
        v[lo] * (1.0 - frac) + v[hi] * frac
    }
}

/// Population standard deviation.
pub fn stddev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64).sqrt()
}

/// Five-number-ish summary for report tables.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub std: f64,
    pub min: f64,
    pub p50: f64,
    pub p90: f64,
    pub p99: f64,
    pub max: f64,
}

impl Summary {
    pub fn of(xs: &[f64]) -> Self {
        let (mut min, mut max) = (f64::INFINITY, f64::NEG_INFINITY);
        for &x in xs {
            min = min.min(x);
            max = max.max(x);
        }
        if xs.is_empty() {
            min = 0.0;
            max = 0.0;
        }
        Summary {
            n: xs.len(),
            mean: mean(xs),
            std: stddev(xs),
            min,
            p50: percentile(xs, 50.0),
            p90: percentile(xs, 90.0),
            p99: percentile(xs, 99.0),
            max,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_and_percentiles() {
        let xs: Vec<f64> = (1..=100).map(|x| x as f64).collect();
        assert!((mean(&xs) - 50.5).abs() < 1e-9);
        assert!((percentile(&xs, 0.0) - 1.0).abs() < 1e-9);
        assert!((percentile(&xs, 100.0) - 100.0).abs() < 1e-9);
        assert!((percentile(&xs, 50.0) - 50.5).abs() < 1e-9);
    }

    #[test]
    fn empty_inputs_are_zero() {
        assert_eq!(mean(&[]), 0.0);
        assert_eq!(percentile(&[], 50.0), 0.0);
        let s = Summary::of(&[]);
        assert_eq!(s.n, 0);
        assert_eq!(s.min, 0.0);
    }

    #[test]
    fn summary_of_constant() {
        let s = Summary::of(&[3.0; 10]);
        assert_eq!(s.mean, 3.0);
        assert_eq!(s.std, 0.0);
        assert_eq!(s.p99, 3.0);
    }

    #[test]
    fn stddev_known_value() {
        // Var([1..5]) (population) = 2.
        let s = stddev(&[1.0, 2.0, 3.0, 4.0, 5.0]);
        assert!((s - 2.0f64.sqrt()).abs() < 1e-12);
    }
}
