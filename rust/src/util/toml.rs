//! Minimal TOML-subset codec for the config system (offline build: no
//! `toml` crate).  Supports `[section]` / `[a.b]` headers and
//! `key = value` lines where value ∈ {string, float, int, bool}.
//! Comments (`#`) and blank lines are ignored.  This covers everything
//! `SystemConfig` needs; nested arrays/tables are intentionally out of
//! scope.

use std::collections::BTreeMap;
use std::fmt;

/// A scalar TOML value.
#[derive(Clone, Debug, PartialEq)]
pub enum TomlValue {
    Str(String),
    Num(f64),
    Bool(bool),
}

impl TomlValue {
    pub fn as_str(&self) -> Option<&str> {
        match self {
            TomlValue::Str(s) => Some(s),
            _ => None,
        }
    }
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            TomlValue::Num(x) => Some(*x),
            _ => None,
        }
    }
    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|x| x as usize)
    }
    pub fn as_u64(&self) -> Option<u64> {
        self.as_f64().map(|x| x as u64)
    }
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            TomlValue::Bool(b) => Some(*b),
            _ => None,
        }
    }
}

impl fmt::Display for TomlValue {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            TomlValue::Str(s) => write!(f, "\"{}\"", s.replace('\\', "\\\\").replace('"', "\\\"")),
            TomlValue::Num(x) => {
                // lint:allow(r3) -- fract() of an integral f64 is exactly 0.0
                if x.fract() == 0.0 && x.abs() < 9e15 {
                    write!(f, "{}", *x as i64)
                } else {
                    write!(f, "{x}")
                }
            }
            TomlValue::Bool(b) => write!(f, "{b}"),
        }
    }
}

/// A flat document: section path -> (key -> value).  The empty path ""
/// holds top-level keys.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct TomlDoc {
    pub sections: BTreeMap<String, BTreeMap<String, TomlValue>>,
}

#[derive(Debug, Clone)]
pub struct TomlError(pub String);

impl fmt::Display for TomlError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "toml error: {}", self.0)
    }
}
impl std::error::Error for TomlError {}

impl TomlDoc {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn set(&mut self, section: &str, key: &str, value: TomlValue) {
        self.sections
            .entry(section.to_string())
            .or_default()
            .insert(key.to_string(), value);
    }

    pub fn set_str(&mut self, section: &str, key: &str, v: &str) {
        self.set(section, key, TomlValue::Str(v.to_string()));
    }
    pub fn set_num(&mut self, section: &str, key: &str, v: f64) {
        self.set(section, key, TomlValue::Num(v));
    }
    pub fn set_bool(&mut self, section: &str, key: &str, v: bool) {
        self.set(section, key, TomlValue::Bool(v));
    }

    pub fn get(&self, section: &str, key: &str) -> Option<&TomlValue> {
        self.sections.get(section)?.get(key)
    }

    /// Fail-loud accessor used by config deserialization.
    pub fn req(&self, section: &str, key: &str) -> Result<&TomlValue, TomlError> {
        self.get(section, key)
            .ok_or_else(|| TomlError(format!("missing [{section}] {key}")))
    }

    pub fn parse(text: &str) -> Result<TomlDoc, TomlError> {
        let mut doc = TomlDoc::new();
        let mut section = String::new();
        for (lineno, raw) in text.lines().enumerate() {
            let line = strip_comment(raw).trim().to_string();
            if line.is_empty() {
                continue;
            }
            if let Some(rest) = line.strip_prefix('[') {
                let name = rest
                    .strip_suffix(']')
                    .ok_or_else(|| TomlError(format!("line {}: bad section", lineno + 1)))?;
                section = name.trim().to_string();
                doc.sections.entry(section.clone()).or_default();
                continue;
            }
            let eq = line
                .find('=')
                .ok_or_else(|| TomlError(format!("line {}: expected key = value", lineno + 1)))?;
            let key = line[..eq].trim().to_string();
            let val = line[eq + 1..].trim();
            let value = parse_value(val)
                .ok_or_else(|| TomlError(format!("line {}: bad value '{val}'", lineno + 1)))?;
            doc.sections.entry(section.clone()).or_default().insert(key, value);
        }
        Ok(doc)
    }

    pub fn to_string_pretty(&self) -> String {
        let mut out = String::new();
        // Top-level keys first.
        if let Some(top) = self.sections.get("") {
            for (k, v) in top {
                out.push_str(&format!("{k} = {v}\n"));
            }
            if !top.is_empty() {
                out.push('\n');
            }
        }
        for (name, kv) in &self.sections {
            if name.is_empty() {
                continue;
            }
            out.push_str(&format!("[{name}]\n"));
            for (k, v) in kv {
                out.push_str(&format!("{k} = {v}\n"));
            }
            out.push('\n');
        }
        out
    }
}

fn strip_comment(line: &str) -> &str {
    // A '#' outside a string starts a comment.
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(s: &str) -> Option<TomlValue> {
    if let Some(rest) = s.strip_prefix('"') {
        let body = rest.strip_suffix('"')?;
        return Some(TomlValue::Str(body.replace("\\\"", "\"").replace("\\\\", "\\")));
    }
    match s {
        "true" => return Some(TomlValue::Bool(true)),
        "false" => return Some(TomlValue::Bool(false)),
        _ => {}
    }
    s.parse::<f64>().ok().map(TomlValue::Num)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_sections_and_values() {
        let doc = TomlDoc::parse(
            r#"
            # top comment
            top = 1
            [model]
            name = "llama-3-8b"   # inline comment
            params = 8.03e9
            layers = 32
            [scheduler]
            online_adapt = true
            "#,
        )
        .unwrap();
        assert_eq!(doc.get("", "top").unwrap().as_f64(), Some(1.0));
        assert_eq!(doc.get("model", "name").unwrap().as_str(), Some("llama-3-8b"));
        assert_eq!(doc.get("model", "params").unwrap().as_f64(), Some(8.03e9));
        assert_eq!(doc.get("scheduler", "online_adapt").unwrap().as_bool(), Some(true));
    }

    #[test]
    fn roundtrip() {
        let mut doc = TomlDoc::new();
        doc.set_str("model", "name", "x");
        doc.set_num("model", "params", 1.5);
        doc.set_bool("engine", "prefix_cache", false);
        doc.set_num("", "seed", 7.0);
        let s = doc.to_string_pretty();
        assert_eq!(TomlDoc::parse(&s).unwrap(), doc);
    }

    #[test]
    fn hash_inside_string_not_comment() {
        let doc = TomlDoc::parse("k = \"a#b\"").unwrap();
        assert_eq!(doc.get("", "k").unwrap().as_str(), Some("a#b"));
    }

    #[test]
    fn errors_are_located() {
        let e = TomlDoc::parse("line-without-equals").unwrap_err();
        assert!(e.0.contains("line 1"));
        let e = TomlDoc::parse("[unclosed").unwrap_err();
        assert!(e.0.contains("bad section"));
    }

    #[test]
    fn req_reports_path() {
        let doc = TomlDoc::new();
        let e = doc.req("model", "name").unwrap_err();
        assert!(e.0.contains("[model] name"));
    }

    #[test]
    fn escaped_quotes_roundtrip() {
        let mut doc = TomlDoc::new();
        doc.set_str("", "k", "say \"hi\"");
        let s = doc.to_string_pretty();
        assert_eq!(
            TomlDoc::parse(&s).unwrap().get("", "k").unwrap().as_str(),
            Some("say \"hi\"")
        );
    }
}
