//! Minimal micro-benchmark harness (offline build: no criterion).
//!
//! Used by the `rust/benches/*.rs` binaries (`cargo bench`): adaptive
//! iteration count, warmup, median/mean/p10/p90 reporting, and a
//! `black_box` to defeat const-folding.

use std::hint;
use std::time::{Duration, Instant};

pub fn black_box<T>(x: T) -> T {
    hint::black_box(x)
}

/// Result of one benchmark.
#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub mean: Duration,
    pub median: Duration,
    pub p10: Duration,
    pub p90: Duration,
}

impl BenchResult {
    pub fn report(&self) -> String {
        format!(
            "{:<44} {:>12} median {:>12} mean {:>12} p90 ({} iters)",
            self.name,
            fmt_dur(self.median),
            fmt_dur(self.mean),
            fmt_dur(self.p90),
            self.iters
        )
    }
}

pub fn fmt_dur(d: Duration) -> String {
    let ns = d.as_nanos();
    if ns < 1_000 {
        format!("{ns} ns")
    } else if ns < 1_000_000 {
        format!("{:.2} µs", ns as f64 / 1e3)
    } else if ns < 1_000_000_000 {
        format!("{:.2} ms", ns as f64 / 1e6)
    } else {
        format!("{:.3} s", ns as f64 / 1e9)
    }
}

/// Benchmark runner with a global time budget per benchmark.
pub struct Bench {
    /// Target wall-clock spent measuring each benchmark.
    pub budget: Duration,
    /// Minimum sample count.
    pub min_samples: usize,
    results: Vec<BenchResult>,
}

impl Default for Bench {
    fn default() -> Self {
        Bench { budget: Duration::from_secs(2), min_samples: 10, results: Vec::new() }
    }
}

impl Bench {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn with_budget(mut self, budget: Duration) -> Self {
        self.budget = budget;
        self
    }

    /// Measure `f`, printing the result immediately.
    pub fn run<T>(&mut self, name: &str, mut f: impl FnMut() -> T) -> &BenchResult {
        // Warmup + calibration: find an iteration time estimate.
        // lint:allow(r2) -- a benchmark harness measures the real wall clock
        let t0 = Instant::now();
        black_box(f());
        let once = t0.elapsed().max(Duration::from_nanos(1));

        let target_samples = (self.budget.as_nanos() / once.as_nanos().max(1))
            .clamp(self.min_samples as u128, 10_000) as usize;

        let mut samples = Vec::with_capacity(target_samples);
        let deadline = Instant::now() + self.budget; // lint:allow(r2) -- real time budget
        for _ in 0..target_samples {
            let t = Instant::now(); // lint:allow(r2) -- the measurement itself
            black_box(f());
            samples.push(t.elapsed());
            // lint:allow(r2) -- budget check against the real clock
            if Instant::now() > deadline && samples.len() >= self.min_samples {
                break;
            }
        }
        samples.sort();
        let n = samples.len();
        let mean = samples.iter().sum::<Duration>() / n as u32;
        let result = BenchResult {
            name: name.to_string(),
            iters: n,
            mean,
            median: samples[n / 2],
            p10: samples[n / 10],
            p90: samples[(n * 9) / 10],
        };
        println!("{}", result.report());
        self.results.push(result);
        self.results.last().unwrap()
    }

    pub fn results(&self) -> &[BenchResult] {
        &self.results
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something() {
        let mut b = Bench::new().with_budget(Duration::from_millis(50));
        let r = b.run("spin", || {
            let mut x = 0u64;
            for i in 0..1000 {
                x = x.wrapping_add(black_box(i));
            }
            x
        });
        assert!(r.iters >= 10);
        assert!(r.median.as_nanos() > 0);
        assert!(r.p90 >= r.p10);
    }

    #[test]
    fn fmt_dur_units() {
        assert_eq!(fmt_dur(Duration::from_nanos(5)), "5 ns");
        assert!(fmt_dur(Duration::from_micros(5)).contains("µs"));
        assert!(fmt_dur(Duration::from_millis(5)).contains("ms"));
        assert!(fmt_dur(Duration::from_secs(5)).contains(" s"));
    }
}
