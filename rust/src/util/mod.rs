//! Shared substrates, all implemented in-tree (the build is offline):
//! deterministic RNG, summary statistics, text/CSV tables, JSON and
//! TOML-subset codecs, a micro-benchmark harness and a property-testing
//! helper.

pub mod bench;
pub mod check;
pub mod json;
pub mod rng;
pub mod stats;
pub mod table;
pub mod toml;

pub use json::Json;
pub use rng::DetRng;
pub use stats::{mean, percentile, Summary};
pub use table::Table;
pub use toml::{TomlDoc, TomlValue};
