//! Deterministic, splittable RNG used everywhere (trace synthesis, output
//! length sampling, ordering baselines) so every experiment is reproducible
//! byte-for-byte from a seed.
//!
//! The build environment is offline, so this is a from-scratch
//! xoshiro256** generator seeded through splitmix64 (the reference
//! initialization recommended by the xoshiro authors).

/// Project-wide deterministic RNG.
#[derive(Clone, Debug)]
pub struct DetRng {
    s: [u64; 4],
}

fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9e37_79b9_7f4a_7c15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
    z ^ (z >> 31)
}

impl DetRng {
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        Self {
            s: [
                splitmix64(&mut sm),
                splitmix64(&mut sm),
                splitmix64(&mut sm),
                splitmix64(&mut sm),
            ],
        }
    }

    /// Derive an independent child stream (stable: hashes the label into
    /// the parent's current state without advancing the parent).
    pub fn child(&self, label: &str) -> Self {
        let mut h: u64 = 0xcbf2_9ce4_8422_2325;
        for b in label.bytes() {
            h ^= b as u64;
            h = h.wrapping_mul(0x1000_0000_01b3);
        }
        Self::new(h ^ self.s[0] ^ self.s[2].rotate_left(17))
    }

    /// xoshiro256** next.
    pub fn u64(&mut self) -> u64 {
        let result = self.s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1) with 53-bit resolution.
    pub fn f64(&mut self) -> f64 {
        (self.u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in [lo, hi] inclusive (Lemire-style rejection-free
    /// for our purposes; bias < 2^-32 for the ranges used here).
    pub fn range(&mut self, lo: u64, hi: u64) -> u64 {
        assert!(lo <= hi, "bad range [{lo}, {hi}]");
        let span = hi - lo + 1;
        if span == 0 {
            return self.u64(); // full range
        }
        lo + (((self.u64() as u128 * span as u128) >> 64) as u64)
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.f64().max(1e-12);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Log-normal with the given *linear-space* mean and sigma (of log).
    /// Parameterized by the target mean so trace generators can say
    /// "mean output 256 tokens, spread sigma" directly.
    pub fn lognormal_mean(&mut self, mean: f64, sigma: f64) -> f64 {
        // E[X] = exp(mu + sigma^2/2)  =>  mu = ln(mean) - sigma^2/2.
        let mu = mean.ln() - sigma * sigma / 2.0;
        (mu + sigma * self.normal()).exp()
    }

    /// Sample an index from unnormalized weights.
    pub fn weighted(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        assert!(total > 0.0, "weights sum to zero");
        let mut x = self.f64() * total;
        for (i, w) in weights.iter().enumerate() {
            x -= w;
            if x <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.range(0, i as u64) as usize;
            xs.swap(i, j);
        }
    }

    /// Bernoulli draw.
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_across_instances() {
        let mut a = DetRng::new(42);
        let mut b = DetRng::new(42);
        for _ in 0..100 {
            assert_eq!(a.u64(), b.u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = DetRng::new(1);
        let mut b = DetRng::new(2);
        assert_ne!(
            (0..8).map(|_| a.u64()).collect::<Vec<_>>(),
            (0..8).map(|_| b.u64()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn children_independent_and_stable() {
        let root = DetRng::new(1);
        let mut a1 = root.child("traces");
        let mut a2 = root.child("traces");
        let mut b = root.child("sampling");
        let xs: Vec<u64> = (0..8).map(|_| a1.u64()).collect();
        assert_eq!(xs, (0..8).map(|_| a2.u64()).collect::<Vec<_>>());
        assert_ne!(xs, (0..8).map(|_| b.u64()).collect::<Vec<_>>());
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut rng = DetRng::new(5);
        for _ in 0..10_000 {
            let x = rng.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn f64_mean_near_half() {
        let mut rng = DetRng::new(6);
        let n = 50_000;
        let mean: f64 = (0..n).map(|_| rng.f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "{mean}");
    }

    #[test]
    fn normal_moments() {
        let mut rng = DetRng::new(8);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "{mean}");
        assert!((var - 1.0).abs() < 0.05, "{var}");
    }

    #[test]
    fn lognormal_mean_close() {
        let mut rng = DetRng::new(7);
        let n = 40_000;
        let mean: f64 =
            (0..n).map(|_| rng.lognormal_mean(256.0, 0.8)).sum::<f64>() / n as f64;
        assert!((mean - 256.0).abs() / 256.0 < 0.05, "mean={mean}");
    }

    #[test]
    fn range_inclusive() {
        let mut rng = DetRng::new(3);
        let mut seen_lo = false;
        let mut seen_hi = false;
        for _ in 0..1000 {
            let x = rng.range(2, 4);
            assert!((2..=4).contains(&x));
            seen_lo |= x == 2;
            seen_hi |= x == 4;
        }
        assert!(seen_lo && seen_hi);
    }

    #[test]
    fn weighted_prefers_heavy() {
        let mut rng = DetRng::new(9);
        let mut counts = [0usize; 3];
        for _ in 0..3000 {
            counts[rng.weighted(&[1.0, 0.0, 9.0])] += 1;
        }
        assert_eq!(counts[1], 0);
        assert!(counts[2] > counts[0] * 5);
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut rng = DetRng::new(11);
        let mut xs: Vec<u32> = (0..50).collect();
        rng.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
        assert_ne!(xs, (0..50).collect::<Vec<_>>()); // astronomically unlikely
    }

    #[test]
    fn chance_extremes() {
        let mut rng = DetRng::new(13);
        assert!(!(0..100).any(|_| rng.chance(0.0)));
        assert!((0..100).all(|_| rng.chance(1.0)));
    }
}
