//! Tiny property-testing harness (offline build: no proptest).
//!
//! `forall(cases, seed, f)` runs `f` against `cases` independent random
//! states; on failure it panics with the exact per-case seed so the case
//! replays deterministically:
//!
//! ```
//! use blendserve::util::check::forall;
//! use blendserve::util::DetRng;
//! forall("addition commutes", 64, 0, |rng: &mut DetRng| {
//!     let (a, b) = (rng.range(0, 100), rng.range(0, 100));
//!     if a + b == b + a { Ok(()) } else { Err(format!("{a} {b}")) }
//! });
//! ```

use super::DetRng;

/// Run `f` for `cases` random cases.  Panics on the first failure with a
/// replayable seed and the failure message.
pub fn forall(
    name: &str,
    cases: usize,
    seed: u64,
    mut f: impl FnMut(&mut DetRng) -> Result<(), String>,
) {
    for case in 0..cases {
        let case_seed = seed ^ (0x9e37_79b9_7f4a_7c15u64.wrapping_mul(case as u64 + 1));
        let mut rng = DetRng::new(case_seed);
        if let Err(msg) = f(&mut rng) {
            panic!(
                "property '{name}' failed at case {case} (replay seed {case_seed:#x}): {msg}"
            );
        }
    }
}

/// Helper: assert two floats are within relative tolerance.
pub fn close(a: f64, b: f64, rel: f64) -> Result<(), String> {
    let denom = a.abs().max(b.abs()).max(1e-12);
    if (a - b).abs() / denom <= rel {
        Ok(())
    } else {
        Err(format!("{a} !~ {b} (rel {rel})"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_completes() {
        forall("trivially true", 32, 1, |rng| {
            let x = rng.u64();
            if x == x {
                Ok(())
            } else {
                Err("impossible".into())
            }
        });
    }

    #[test]
    #[should_panic(expected = "replay seed")]
    fn failing_property_panics_with_seed() {
        forall("always false", 4, 2, |_| Err("nope".into()));
    }

    #[test]
    fn close_tolerances() {
        assert!(close(1.0, 1.005, 0.01).is_ok());
        assert!(close(1.0, 1.5, 0.01).is_err());
        assert!(close(0.0, 0.0, 1e-9).is_ok());
    }
}
