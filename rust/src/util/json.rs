//! Minimal JSON codec (offline build: no serde).  Covers the full JSON
//! grammar minus exotic number forms; used for the artifact manifest, the
//! batch-API request pool, and machine-readable experiment output.

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(s: &str) -> Result<Json, JsonError> {
        let mut p = Parser { bytes: s.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    // -- accessors --

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// `get` that fails loudly with the missing path.
    pub fn req(&self, key: &str) -> Result<&Json, JsonError> {
        self.get(key)
            .ok_or_else(|| JsonError(format!("missing key '{key}'")))
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|x| x as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    // -- builders --

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn arr_usize(xs: &[usize]) -> Json {
        Json::Arr(xs.iter().map(|&x| Json::Num(x as f64)).collect())
    }
}

impl From<&str> for Json {
    fn from(s: &str) -> Self {
        Json::Str(s.to_string())
    }
}
impl From<f64> for Json {
    fn from(x: f64) -> Self {
        Json::Num(x)
    }
}
impl From<usize> for Json {
    fn from(x: usize) -> Self {
        Json::Num(x as f64)
    }
}
impl From<bool> for Json {
    fn from(b: bool) -> Self {
        Json::Bool(b)
    }
}

#[derive(Debug, Clone, PartialEq)]
pub struct JsonError(pub String);

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error: {}", self.0)
    }
}
impl std::error::Error for JsonError {}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError(format!("{msg} at byte {}", self.pos))
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.peek();
        if b.is_some() {
            self.pos += 1;
        }
        b
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), JsonError> {
        if self.bump() == Some(b) {
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, JsonError> {
        if self.bytes[self.pos..].starts_with(s.as_bytes()) {
            self.pos += s.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{s}'")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let v = self.value()?;
            m.insert(key, v);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(m)),
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut v = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            v.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(v)),
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.bump() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => return Ok(out),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => out.push('"'),
                    Some(b'\\') => out.push('\\'),
                    Some(b'/') => out.push('/'),
                    Some(b'n') => out.push('\n'),
                    Some(b't') => out.push('\t'),
                    Some(b'r') => out.push('\r'),
                    Some(b'b') => out.push('\u{8}'),
                    Some(b'f') => out.push('\u{c}'),
                    Some(b'u') => {
                        let mut code = 0u32;
                        for _ in 0..4 {
                            let c = self.bump().ok_or_else(|| self.err("bad \\u"))?;
                            code = code * 16
                                + (c as char)
                                    .to_digit(16)
                                    .ok_or_else(|| self.err("bad hex"))?;
                        }
                        out.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                    }
                    _ => return Err(self.err("bad escape")),
                },
                Some(c) if c < 0x80 => out.push(c as char),
                Some(c) => {
                    // Re-decode multi-byte UTF-8: back up and take the char.
                    self.pos -= 1;
                    let s = std::str::from_utf8(&self.bytes[self.pos..])
                        .map_err(|_| self.err("bad utf8"))?;
                    let ch = s.chars().next().unwrap();
                    out.push(ch);
                    self.pos += ch.len_utf8();
                    let _ = c;
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
        {
            self.pos += 1;
        }
        let s = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        s.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }
}

/// Serialize (compact).
impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => write!(f, "null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Num(x) => {
                // lint:allow(r3) -- fract() of an integral f64 is exactly 0.0
                if x.fract() == 0.0 && x.abs() < 9e15 {
                    write!(f, "{}", *x as i64)
                } else {
                    write!(f, "{x}")
                }
            }
            Json::Str(s) => write_escaped(f, s),
            Json::Arr(v) => {
                write!(f, "[")?;
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{x}")?;
                }
                write!(f, "]")
            }
            Json::Obj(m) => {
                write!(f, "{{")?;
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write_escaped(f, k)?;
                    write!(f, ":{v}")?;
                }
                write!(f, "}}")
            }
        }
    }
}

fn write_escaped(f: &mut fmt::Formatter<'_>, s: &str) -> fmt::Result {
    write!(f, "\"")?;
    for c in s.chars() {
        match c {
            '"' => write!(f, "\\\"")?,
            '\\' => write!(f, "\\\\")?,
            '\n' => write!(f, "\\n")?,
            '\t' => write!(f, "\\t")?,
            '\r' => write!(f, "\\r")?,
            c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
            c => write!(f, "{c}")?,
        }
    }
    write!(f, "\"")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_manifest_like_document() {
        let doc = r#"{
            "model": {"vocab": 2048, "rope_theta": 10000.0},
            "kv_shape": [4, 2, 9, 256, 2, 32],
            "step_variants": {"16": "step_t16.hlo.txt"},
            "ok": true, "missing": null
        }"#;
        let j = Json::parse(doc).unwrap();
        assert_eq!(j.req("model").unwrap().req("vocab").unwrap().as_usize(), Some(2048));
        let kv: Vec<usize> = j
            .get("kv_shape")
            .unwrap()
            .as_arr()
            .unwrap()
            .iter()
            .map(|x| x.as_usize().unwrap())
            .collect();
        assert_eq!(kv, vec![4, 2, 9, 256, 2, 32]);
        assert_eq!(
            j.get("step_variants").unwrap().get("16").unwrap().as_str(),
            Some("step_t16.hlo.txt")
        );
        assert_eq!(j.get("ok").unwrap().as_bool(), Some(true));
        assert_eq!(j.get("missing"), Some(&Json::Null));
    }

    #[test]
    fn roundtrip() {
        let j = Json::obj(vec![
            ("a", Json::Num(1.5)),
            ("b", Json::Arr(vec![Json::Bool(false), Json::Null])),
            ("s", Json::from("hi \"there\"\n")),
        ]);
        let s = j.to_string();
        assert_eq!(Json::parse(&s).unwrap(), j);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1, 2,]").is_err());
        assert!(Json::parse("nul").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("\"abc").is_err());
    }

    #[test]
    fn numbers() {
        assert_eq!(Json::parse("-3.25e2").unwrap().as_f64(), Some(-325.0));
        assert_eq!(Json::parse("0").unwrap().as_f64(), Some(0.0));
        // Integer-valued floats print without the decimal point.
        assert_eq!(Json::Num(42.0).to_string(), "42");
        assert_eq!(Json::Num(1.5).to_string(), "1.5");
    }

    #[test]
    fn unicode_escapes() {
        let j = Json::parse(r#""éx""#).unwrap();
        assert_eq!(j.as_str(), Some("éx"));
        let j = Json::parse("\"naïve\"").unwrap();
        assert_eq!(j.as_str(), Some("naïve"));
    }

    #[test]
    fn req_reports_missing_key() {
        let j = Json::parse("{}").unwrap();
        let e = j.req("nope").unwrap_err();
        assert!(e.0.contains("nope"));
    }
}
