//! Fault tolerance for the fleet (DESIGN.md §12): deterministic failure
//! injection, a crash-consistent run journal, and the resume bookkeeping
//! the coordinator uses to prune already-finished work.
//!
//! Offline batch inference runs on preemptible capacity by design — the
//! relaxed latency requirement that lets BlendServe batch aggressively is
//! the same one that makes spot GPUs economical.  That puts replica death
//! and coordinator crashes on the *expected* path, so this module treats
//! them as schedulable events rather than exceptions:
//!
//! - [`FaultPlan`] expands a `[faults]` config section into a sorted,
//!   fully seeded event trace (per-replica exponential preemptions with
//!   optional re-join, plus two degraded modes: a mid-run host-memory
//!   shrink and a PCIe link slowdown).  The same seed always yields the
//!   same plan, so a failure run replays bit-for-bit.
//! - [`JournalWriter`] / [`load_journal`] implement an append-only journal
//!   of length+hash-framed single-line JSON records.  Each record is
//!   framed as `<8 hex len><16 hex fnv64><payload>\n`; a crash can only
//!   tear the final record, and the loader truncates a torn tail cleanly
//!   (counting it in [`JournalLoad::truncated_records`]) instead of
//!   erroring the whole run.
//! - [`ResumeState`] folds a loaded journal into what the coordinator
//!   needs: the set of requests that already finished (pruned on resume
//!   and cross-checked against the deterministic replay), plus snapshot /
//!   fault / steal counts for reporting.
//!
//! Recovery itself is *deterministic replay*: the coordinator re-runs the
//! seeded schedule and skips re-reporting journaled work, which makes the
//! remaining results bit-identical to an uninterrupted run by
//! construction (`rust/tests/recovery_resume.rs` pins it at every kill
//! step).  The journal's finish records double as a corruption check —
//! replay must reproduce each journaled finish exactly.

use crate::config::FaultsConfig;
use crate::trace::Workload;
use crate::util::json::Json;
use crate::util::DetRng;
use std::collections::HashMap;
use std::io::Write;
use std::path::{Path, PathBuf};

// ---------------------------------------------------------------------
// Hashing / fingerprints
// ---------------------------------------------------------------------

/// FNV-1a over bytes — the journal's record checksum and the fingerprint
/// primitive.  Not cryptographic: it detects torn writes and bit rot, not
/// adversaries (same stance as the rest of the repo's golden hashing).
pub fn fnv64(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x100_0000_01b3);
    }
    h
}

/// Content fingerprint of a workload: ids, prompts, output lengths and
/// attachment profiles.  A journal recorded against one pool must not be
/// resumed against another.
pub fn workload_fingerprint(w: &Workload) -> String {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    let mut mix = |x: u64| {
        for b in x.to_le_bytes() {
            h ^= b as u64;
            h = h.wrapping_mul(0x100_0000_01b3);
        }
    };
    mix(w.len() as u64);
    for r in &w.requests {
        mix(r.id as u64);
        mix(r.prompt.len() as u64);
        for &t in r.prompt.iter() {
            mix(t as u64);
        }
        mix(r.output_len as u64);
        mix(r.known_output as u64);
        for a in &r.modality.attachments {
            mix(a.content_hash);
            mix(a.enc_tokens as u64);
        }
    }
    format!("{h:016x}")
}

/// Fingerprint of the serialized system config.  Resuming under different
/// knobs would silently change the schedule; the fingerprint makes that a
/// hard error instead.
pub fn config_fingerprint(cfg: &crate::config::SystemConfig) -> String {
    format!("{:016x}", fnv64(cfg.to_toml().as_bytes()))
}

// ---------------------------------------------------------------------
// Fault plan
// ---------------------------------------------------------------------

/// Marker for fleet-wide degraded-mode events (host shrink, link
/// slowdown), which hit every replica at once.
pub const ALL_REPLICAS: usize = usize::MAX;

/// What happens when a [`FaultEvent`] fires.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum FaultKind {
    /// The replica is preempted: its in-flight work is lost to it and the
    /// coordinator reclaims its unfinished requests.  `rejoin_at` is the
    /// clock at which the replica comes back empty ([`f64::INFINITY`] =
    /// never).
    Death { rejoin_at: f64 },
    /// Every replica's host KV budget shrinks to `frac` of its capacity.
    HostShrink { frac: f64 },
    /// Every replica's PCIe link slows to `factor` of its bandwidth.
    LinkDegrade { factor: f64 },
}

/// One injected fault: `kind` fires on `replica` the first time that
/// replica is stepped at clock >= `at`.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct FaultEvent {
    pub at: f64,
    /// Victim replica index, or [`ALL_REPLICAS`] for degraded modes.
    pub replica: usize,
    pub kind: FaultKind,
}

/// The full, pre-expanded failure trace for one fleet run, sorted by
/// `(at, replica)`.
#[derive(Clone, Debug, Default)]
pub struct FaultPlan {
    pub events: Vec<FaultEvent>,
}

impl FaultPlan {
    /// Expand `cfg` into a deterministic event trace for `n_replicas`
    /// replicas.  Each replica draws its preemption times from an
    /// independent child stream of `cfg.seed` (exponential inter-arrival
    /// with mean `mtbf_s`, restarting after each re-join), and the fleet
    /// keeps only the first `max_deaths` deaths overall.  Disabled
    /// configs produce an empty plan.
    pub fn generate(cfg: &FaultsConfig, n_replicas: usize) -> FaultPlan {
        let mut events: Vec<FaultEvent> = Vec::new();
        if cfg.enabled && cfg.mtbf_s > 0.0 && cfg.max_deaths > 0 {
            let root = DetRng::new(cfg.seed);
            let mut deaths: Vec<FaultEvent> = Vec::new();
            for r in 0..n_replicas {
                let mut rng = root.child(&format!("replica-{r}"));
                // Without re-join a replica can die at most once; with it,
                // cap per-replica draws at the global budget (any excess
                // is truncated after the merge anyway).
                let draws = if cfg.rejoin_delay_s > 0.0 { cfg.max_deaths } else { 1 };
                let mut t = 0.0;
                for _ in 0..draws {
                    // Exponential inter-arrival: -mtbf * ln(1 - u),
                    // u in [0, 1) so the argument stays in (0, 1].
                    t += -cfg.mtbf_s * (1.0 - rng.f64()).ln();
                    let rejoin_at = if cfg.rejoin_delay_s > 0.0 {
                        t + cfg.rejoin_delay_s
                    } else {
                        f64::INFINITY
                    };
                    deaths.push(FaultEvent {
                        at: t,
                        replica: r,
                        kind: FaultKind::Death { rejoin_at },
                    });
                    // The next preemption can only hit after the replica
                    // is back.
                    t += cfg.rejoin_delay_s;
                }
            }
            deaths.sort_by(|a, b| {
                a.at.partial_cmp(&b.at).expect("finite death times").then(a.replica.cmp(&b.replica))
            });
            deaths.truncate(cfg.max_deaths);
            events.extend(deaths);
        }
        if cfg.enabled && cfg.host_shrink_at_s > 0.0 {
            events.push(FaultEvent {
                at: cfg.host_shrink_at_s,
                replica: ALL_REPLICAS,
                kind: FaultKind::HostShrink { frac: cfg.host_shrink_frac },
            });
        }
        if cfg.enabled && cfg.link_degrade_at_s > 0.0 {
            events.push(FaultEvent {
                at: cfg.link_degrade_at_s,
                replica: ALL_REPLICAS,
                kind: FaultKind::LinkDegrade { factor: cfg.link_degrade_factor },
            });
        }
        events.sort_by(|a, b| {
            a.at.partial_cmp(&b.at).expect("finite fault times").then(a.replica.cmp(&b.replica))
        });
        FaultPlan { events }
    }

    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }
}

// ---------------------------------------------------------------------
// Journal framing
// ---------------------------------------------------------------------

/// Header bytes per record: 8 hex chars of payload length + 16 hex chars
/// of payload FNV-1a.
const FRAME_HEADER: usize = 24;

/// Frame one single-line JSON payload as a journal record.
pub fn frame_record(payload: &str) -> String {
    debug_assert!(!payload.contains('\n'), "journal payloads are single-line");
    format!("{:08x}{:016x}{payload}\n", payload.len(), fnv64(payload.as_bytes()))
}

/// Result of loading a journal: the records that verified, plus how the
/// file ended.
#[derive(Debug)]
pub struct JournalLoad {
    pub records: Vec<Json>,
    /// 1 when the file ends in a torn/corrupt tail (the crash-consistent
    /// case: everything after the last intact record is dropped), else 0.
    pub truncated_records: usize,
    /// Byte length of the intact prefix — the offset appending resumes at.
    pub valid_bytes: u64,
}

/// Read a journal tolerantly: verified records parse in order; the first
/// framing/checksum failure ends the read and everything after it counts
/// as one truncated record.  A missing file is an error (resuming from
/// nothing is a caller bug); an empty file is an empty journal.
pub fn load_journal(path: &Path) -> anyhow::Result<JournalLoad> {
    let bytes = std::fs::read(path)
        .map_err(|e| anyhow::anyhow!("journal {}: {e}", path.display()))?;
    Ok(parse_journal_bytes(&bytes))
}

fn parse_journal_bytes(bytes: &[u8]) -> JournalLoad {
    let mut records = Vec::new();
    let mut pos = 0usize;
    loop {
        if pos == bytes.len() {
            return JournalLoad { records, truncated_records: 0, valid_bytes: pos as u64 };
        }
        let torn = JournalLoad {
            records: Vec::new(),
            truncated_records: 1,
            valid_bytes: pos as u64,
        };
        if bytes.len() - pos < FRAME_HEADER {
            return JournalLoad { records, ..torn };
        }
        let hex = |range: std::ops::Range<usize>| -> Option<u64> {
            std::str::from_utf8(&bytes[range])
                .ok()
                .and_then(|s| u64::from_str_radix(s, 16).ok())
        };
        let (len, want_hash) = match (hex(pos..pos + 8), hex(pos + 8..pos + FRAME_HEADER)) {
            (Some(l), Some(h)) => (l as usize, h),
            _ => return JournalLoad { records, ..torn },
        };
        let body_start = pos + FRAME_HEADER;
        // Need the payload plus its terminating newline.
        if bytes.len() - body_start < len + 1 || bytes[body_start + len] != b'\n' {
            return JournalLoad { records, ..torn };
        }
        let payload = &bytes[body_start..body_start + len];
        if fnv64(payload) != want_hash {
            return JournalLoad { records, ..torn };
        }
        let parsed = std::str::from_utf8(payload).ok().and_then(|s| Json::parse(s).ok());
        match parsed {
            Some(j) => records.push(j),
            None => return JournalLoad { records, ..torn },
        }
        pos = body_start + len + 1;
    }
}

/// Append-only journal writer.  Every record goes to disk in a single
/// `write_all` before `record` returns, so a process crash can tear at
/// most the final record — which the loader then drops.
pub struct JournalWriter {
    file: std::fs::File,
    path: PathBuf,
}

impl JournalWriter {
    /// Start a fresh journal (truncates an existing file).
    pub fn create(path: &Path) -> anyhow::Result<Self> {
        // lint:allow(r4) -- this IS JournalWriter: truncating start of a fresh log
        let file = std::fs::File::create(path)
            .map_err(|e| anyhow::anyhow!("journal {}: {e}", path.display()))?;
        Ok(JournalWriter { file, path: path.to_path_buf() })
    }

    /// Re-open an existing journal for appending: the intact prefix is
    /// kept, a torn tail is cut off first (crash recovery), and new
    /// records continue from there.
    pub fn resume_append(path: &Path, valid_bytes: u64) -> anyhow::Result<Self> {
        // lint:allow(r4) -- JournalWriter's own crash-recovery append path
        let file = std::fs::OpenOptions::new()
            .write(true)
            .open(path)
            .map_err(|e| anyhow::anyhow!("journal {}: {e}", path.display()))?;
        file.set_len(valid_bytes)
            .map_err(|e| anyhow::anyhow!("journal {}: truncate: {e}", path.display()))?;
        use std::io::Seek;
        let mut file = file;
        file.seek(std::io::SeekFrom::End(0))
            .map_err(|e| anyhow::anyhow!("journal {}: seek: {e}", path.display()))?;
        Ok(JournalWriter { file, path: path.to_path_buf() })
    }

    /// Append one record durably.
    pub fn record(&mut self, payload: &Json) -> anyhow::Result<()> {
        let framed = frame_record(&payload.to_string());
        self.file
            .write_all(framed.as_bytes())
            .map_err(|e| anyhow::anyhow!("journal {}: {e}", self.path.display()))?;
        Ok(())
    }

    pub fn path(&self) -> &Path {
        &self.path
    }
}

// ---------------------------------------------------------------------
// Record constructors
// ---------------------------------------------------------------------

/// Typed constructors for the journal's record kinds.  All payloads are
/// flat JSON objects with a `type` tag; floats round-trip exactly through
/// the repo's JSON codec (integral values print as integers, everything
/// else uses shortest-round-trip formatting), so a replayed finish clock
/// can be compared bitwise against its journaled value.
pub mod records {
    use super::Json;

    /// Journal header: what run this is.  Always the first record.
    pub fn meta(workload_fp: &str, config_fp: &str, n_requests: usize, dp: usize) -> Json {
        Json::obj(vec![
            ("type", Json::from("meta")),
            ("workload_fp", Json::from(workload_fp)),
            ("config_fp", Json::from(config_fp)),
            ("n_requests", Json::from(n_requests)),
            ("dp", Json::from(dp)),
        ])
    }

    /// One request finished on `replica` at engine clock `finish`.
    pub fn finish(id: u32, replica: usize, finish: f64) -> Json {
        Json::obj(vec![
            ("type", Json::from("finish")),
            ("id", Json::from(id as usize)),
            ("replica", Json::from(replica)),
            ("finish", Json::Num(finish)),
        ])
    }

    /// Periodic fleet snapshot: coordinator progress + per-replica queue
    /// depths (scanner pending + engine actives) and cache summaries.
    pub fn snapshot(
        step: usize,
        clock: f64,
        finished: usize,
        queued: &[usize],
        host_resident: &[usize],
    ) -> Json {
        Json::obj(vec![
            ("type", Json::from("snap")),
            ("step", Json::from(step)),
            ("clock", Json::Num(clock)),
            ("finished", Json::from(finished)),
            ("queued", Json::arr_usize(queued)),
            ("host_resident_tokens", Json::arr_usize(host_resident)),
        ])
    }

    /// A fault fired.
    pub fn fault(ev: &super::FaultEvent) -> Json {
        let (kind, detail) = match ev.kind {
            super::FaultKind::Death { rejoin_at } => ("death", ("rejoin_at", Json::Num(rejoin_at))),
            super::FaultKind::HostShrink { frac } => ("host_shrink", ("frac", Json::Num(frac))),
            super::FaultKind::LinkDegrade { factor } => {
                ("link_degrade", ("factor", Json::Num(factor)))
            }
        };
        Json::obj(vec![
            ("type", Json::from("fault")),
            ("kind", Json::from(kind)),
            ("at", Json::Num(ev.at)),
            ("replica", Json::from(ev.replica)),
            detail,
        ])
    }

    /// Work moved between replicas (steal or death reclamation).
    pub fn steal(clock: f64, from: usize, to: usize, n_requests: usize) -> Json {
        Json::obj(vec![
            ("type", Json::from("steal")),
            ("clock", Json::Num(clock)),
            ("from", Json::from(from)),
            ("to", Json::from(to)),
            ("n_requests", Json::from(n_requests)),
        ])
    }
}

// ---------------------------------------------------------------------
// Resume state
// ---------------------------------------------------------------------

/// A loaded journal folded into coordinator-usable form.
#[derive(Debug, Default)]
pub struct ResumeState {
    /// Requests already finished, with their journaled finish clocks.
    /// The resuming coordinator prunes these from its output and
    /// cross-checks each one against the deterministic replay.
    pub finished: HashMap<u32, f64>,
    /// Torn-tail count from the load (0 or 1).
    pub truncated_records: usize,
    /// Snapshot records seen.
    pub snapshots: usize,
    /// Coordinator step of the latest snapshot.
    pub last_snapshot_step: usize,
    /// Fault records seen.
    pub faults: usize,
    /// Steal records seen.
    pub steals: usize,
    /// Intact journal prefix length (where appends resume).
    pub valid_bytes: u64,
}

impl ResumeState {
    /// Validate and fold a journal load.  The first record must be a
    /// `meta` whose fingerprints match the workload and config being
    /// resumed — resuming a journal against the wrong pool or knobs is an
    /// error, not a silent re-schedule.
    pub fn from_load(
        load: &JournalLoad,
        want_workload_fp: &str,
        want_config_fp: &str,
    ) -> anyhow::Result<ResumeState> {
        let mut st = ResumeState {
            truncated_records: load.truncated_records,
            valid_bytes: load.valid_bytes,
            ..ResumeState::default()
        };
        let Some(first) = load.records.first() else {
            anyhow::bail!("journal holds no intact records (nothing to resume)");
        };
        anyhow::ensure!(
            first.get("type").and_then(Json::as_str) == Some("meta"),
            "journal does not start with a meta record"
        );
        let wfp = first.req("workload_fp")?.as_str().unwrap_or_default().to_string();
        let cfp = first.req("config_fp")?.as_str().unwrap_or_default().to_string();
        anyhow::ensure!(
            wfp == want_workload_fp,
            "journal was recorded against a different workload \
             (journal {wfp}, resuming {want_workload_fp})"
        );
        anyhow::ensure!(
            cfp == want_config_fp,
            "journal was recorded under a different config \
             (journal {cfp}, resuming {want_config_fp})"
        );
        for rec in &load.records[1..] {
            match rec.get("type").and_then(Json::as_str) {
                Some("finish") => {
                    let id = rec.req("id")?.as_usize().unwrap_or(u32::MAX as usize) as u32;
                    let t = rec.req("finish")?.as_f64().unwrap_or(f64::NAN);
                    anyhow::ensure!(
                        st.finished.insert(id, t).is_none(),
                        "journal finishes request {id} twice (exactly-once violated)"
                    );
                }
                Some("snap") => {
                    st.snapshots += 1;
                    st.last_snapshot_step = rec.req("step")?.as_usize().unwrap_or(0);
                }
                Some("fault") => st.faults += 1,
                Some("steal") => st.steals += 1,
                Some("meta") => anyhow::bail!("journal holds a second meta record"),
                other => anyhow::bail!("journal holds unknown record type {other:?}"),
            }
        }
        Ok(st)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{presets, SystemConfig};
    use crate::trace::generators::generate_kind;
    use crate::trace::TraceKind;

    fn tmp(name: &str) -> PathBuf {
        let dir = std::env::temp_dir().join("blendserve_recovery_test");
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(name)
    }

    fn sample_records() -> Vec<Json> {
        vec![
            records::meta("aaaa", "bbbb", 10, 2),
            records::finish(3, 0, 12.5),
            records::finish(7, 1, 13.0625),
            records::snapshot(64, 14.0, 2, &[4, 3], &[0, 128]),
            records::steal(15.5, 1, 0, 2),
        ]
    }

    #[test]
    fn journal_roundtrip() {
        let path = tmp("roundtrip.journal");
        let recs = sample_records();
        let mut w = JournalWriter::create(&path).unwrap();
        for r in &recs {
            w.record(r).unwrap();
        }
        drop(w);
        let load = load_journal(&path).unwrap();
        assert_eq!(load.truncated_records, 0);
        assert_eq!(load.records, recs);
        assert_eq!(load.valid_bytes, std::fs::metadata(&path).unwrap().len());
        std::fs::remove_file(&path).ok();
    }

    /// The crash-consistency property proper: a journal cut at *any* byte
    /// boundary loads the longest intact record prefix, flags exactly the
    /// torn tail, and never errors.
    #[test]
    fn journal_tolerates_truncation_at_every_byte() {
        let recs = sample_records();
        let full: String = recs.iter().map(|r| frame_record(&r.to_string())).collect();
        let bytes = full.as_bytes();
        // Record boundaries (byte offsets at which the file is clean).
        let mut boundaries = vec![0usize];
        let mut off = 0;
        for r in &recs {
            off += frame_record(&r.to_string()).len();
            boundaries.push(off);
        }
        for cut in 0..=bytes.len() {
            let load = parse_journal_bytes(&bytes[..cut]);
            let n_complete = boundaries.iter().filter(|&&b| b <= cut && b > 0).count();
            assert_eq!(load.records.len(), n_complete, "cut at byte {cut}");
            assert_eq!(load.records[..], recs[..n_complete], "cut at byte {cut}");
            let at_boundary = boundaries.contains(&cut);
            assert_eq!(
                load.truncated_records,
                usize::from(!at_boundary),
                "cut at byte {cut}"
            );
            assert_eq!(load.valid_bytes as usize, boundaries[n_complete], "cut at byte {cut}");
        }
    }

    #[test]
    fn journal_stops_at_corrupt_record() {
        let recs = sample_records();
        let mut bytes: Vec<u8> = recs
            .iter()
            .map(|r| frame_record(&r.to_string()))
            .collect::<String>()
            .into_bytes();
        // Flip one payload byte inside record 2 (records 0 and 1 intact).
        let prefix: usize =
            recs[..2].iter().map(|r| frame_record(&r.to_string()).len()).sum();
        bytes[prefix + FRAME_HEADER + 3] ^= 0x40;
        let load = parse_journal_bytes(&bytes);
        assert_eq!(load.records.len(), 2);
        assert_eq!(load.records[..], recs[..2]);
        assert_eq!(load.truncated_records, 1);
        assert_eq!(load.valid_bytes as usize, prefix);
    }

    #[test]
    fn resume_append_cuts_torn_tail_then_continues() {
        let path = tmp("resume_append.journal");
        let recs = sample_records();
        let mut text: String = recs.iter().map(|r| frame_record(&r.to_string())).collect();
        let clean_len = text.len();
        text.push_str("0000001fdeadbeef"); // torn header, no payload
        std::fs::write(&path, &text).unwrap();

        let load = load_journal(&path).unwrap();
        assert_eq!(load.truncated_records, 1);
        assert_eq!(load.valid_bytes as usize, clean_len);

        let mut w = JournalWriter::resume_append(&path, load.valid_bytes).unwrap();
        let extra = records::finish(9, 0, 20.25);
        w.record(&extra).unwrap();
        drop(w);

        let reload = load_journal(&path).unwrap();
        assert_eq!(reload.truncated_records, 0);
        assert_eq!(reload.records.len(), recs.len() + 1);
        assert_eq!(*reload.records.last().unwrap(), extra);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn finish_clock_roundtrips_bitwise() {
        // Non-trivial f64s must survive journal serialization exactly —
        // the resume cross-check compares replayed finish clocks bitwise.
        for &t in &[12.5, 1.0 / 3.0, 1e-17, 123456.789012345, f64::MIN_POSITIVE] {
            let rec = records::finish(1, 0, t);
            let framed = frame_record(&rec.to_string());
            let load = parse_journal_bytes(framed.as_bytes());
            let back = load.records[0].req("finish").unwrap().as_f64().unwrap();
            assert_eq!(back.to_bits(), t.to_bits(), "t = {t:?}");
        }
    }

    #[test]
    fn fault_plan_is_deterministic_and_seed_sensitive() {
        let mut cfg = FaultsConfig { enabled: true, mtbf_s: 100.0, ..FaultsConfig::default() };
        cfg.max_deaths = 8;
        cfg.rejoin_delay_s = 10.0;
        let a = FaultPlan::generate(&cfg, 4);
        let b = FaultPlan::generate(&cfg, 4);
        assert_eq!(a.events, b.events);
        assert!(!a.is_empty());
        assert!(a.events.len() <= 8);
        // Sorted by time.
        for w in a.events.windows(2) {
            assert!(w[0].at <= w[1].at);
        }
        cfg.seed = 1;
        let c = FaultPlan::generate(&cfg, 4);
        assert_ne!(a.events, c.events, "seed must move the plan");
    }

    #[test]
    fn fault_plan_respects_caps_and_disable() {
        let off = FaultsConfig::default();
        assert!(FaultPlan::generate(&off, 4).is_empty());

        let mut cfg = FaultsConfig { enabled: true, mtbf_s: 1.0, ..FaultsConfig::default() };
        cfg.max_deaths = 3;
        // No rejoin: at most one death per replica, truncated to the cap.
        let plan = FaultPlan::generate(&cfg, 8);
        assert_eq!(plan.events.len(), 3);
        for ev in &plan.events {
            match ev.kind {
                FaultKind::Death { rejoin_at } => assert!(rejoin_at.is_infinite()),
                other => panic!("unexpected {other:?}"),
            }
        }
        // mtbf = 0 disables deaths even when enabled.
        cfg.mtbf_s = 0.0;
        assert!(FaultPlan::generate(&cfg, 8).is_empty());
    }

    #[test]
    fn fault_plan_includes_degraded_modes() {
        let cfg = FaultsConfig {
            enabled: true,
            host_shrink_at_s: 5.0,
            host_shrink_frac: 0.5,
            link_degrade_at_s: 2.0,
            link_degrade_factor: 0.25,
            ..FaultsConfig::default()
        };
        let plan = FaultPlan::generate(&cfg, 2);
        assert_eq!(plan.events.len(), 2);
        assert_eq!(
            plan.events[0].kind,
            FaultKind::LinkDegrade { factor: 0.25 },
            "events sorted by time"
        );
        assert_eq!(plan.events[1].kind, FaultKind::HostShrink { frac: 0.5 });
        assert!(plan.events.iter().all(|e| e.replica == ALL_REPLICAS));
    }

    #[test]
    fn resume_state_folds_and_validates() {
        let load = JournalLoad {
            records: sample_records(),
            truncated_records: 0,
            valid_bytes: 100,
        };
        let st = ResumeState::from_load(&load, "aaaa", "bbbb").unwrap();
        assert_eq!(st.finished.len(), 2);
        assert_eq!(st.finished[&3], 12.5);
        assert_eq!(st.snapshots, 1);
        assert_eq!(st.last_snapshot_step, 64);
        assert_eq!(st.steals, 1);

        // Wrong fingerprints are hard errors.
        assert!(ResumeState::from_load(&load, "zzzz", "bbbb").is_err());
        assert!(ResumeState::from_load(&load, "aaaa", "zzzz").is_err());

        // Duplicate finish violates exactly-once.
        let mut dup = sample_records();
        dup.push(records::finish(3, 1, 99.0));
        let load = JournalLoad { records: dup, truncated_records: 0, valid_bytes: 0 };
        let err = ResumeState::from_load(&load, "aaaa", "bbbb").unwrap_err().to_string();
        assert!(err.contains("exactly-once"), "{err}");

        // A journal without records cannot be resumed.
        let empty = JournalLoad { records: vec![], truncated_records: 0, valid_bytes: 0 };
        assert!(ResumeState::from_load(&empty, "a", "b").is_err());
    }

    #[test]
    fn fingerprints_are_content_sensitive() {
        let w1 = generate_kind(TraceKind::BurstGpt, 20, 42);
        let w2 = generate_kind(TraceKind::BurstGpt, 20, 43);
        assert_eq!(workload_fingerprint(&w1), workload_fingerprint(&w1));
        assert_ne!(workload_fingerprint(&w1), workload_fingerprint(&w2));

        let cfg = SystemConfig::new(presets::llama3_8b(), presets::a100_80gb());
        let mut cfg2 = cfg.clone();
        cfg2.scheduler.chunk_tokens += 1;
        assert_eq!(config_fingerprint(&cfg), config_fingerprint(&cfg));
        assert_ne!(config_fingerprint(&cfg), config_fingerprint(&cfg2));
    }
}
