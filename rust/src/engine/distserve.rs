//! DistServe-style prefill/decode (P/D) disaggregation baseline (§6.3,
//! Fig. 8): `x` GPUs form a prefill cluster, `y` GPUs a decode cluster.
//!
//! The prefill cluster runs prompts at full compute utilization (with its
//! own prefix cache); finished prefills stream to the decode cluster, which
//! runs memory-bound continuous batching.  KV transfer is assumed perfectly
//! overlapped (generous to DistServe).  The expected result — which Fig. 8
//! reproduces — is that *per-GPU* throughput trails colocated serving
//! because each cluster leaves one resource idle: prefill GPUs underuse
//! memory bandwidth, decode GPUs underuse compute.

use super::prefix_cache::RadixCache;
use super::sim::SimRequest;
use crate::perfmodel::PerfModel;

/// Result of an xPyD simulation.
#[derive(Clone, Debug)]
pub struct DisaggResult {
    pub total_time: f64,
    pub total_tokens: u64,
    /// Aggregate throughput over the whole deployment (tokens/s).
    pub throughput: f64,
    /// Per-GPU throughput (the Fig. 8 metric).
    pub per_gpu_throughput: f64,
    pub prefill_cluster_busy: f64,
    pub decode_cluster_busy: f64,
    pub n_gpus: usize,
}

/// Simulate an `xPyD` deployment over `requests` processed in the given
/// order (DFS order gives it the same sharing benefit as the baselines).
pub fn simulate_disagg(
    pm: &PerfModel,
    requests: &[SimRequest],
    order: &[u32],
    x_prefill: usize,
    y_decode: usize,
) -> DisaggResult {
    assert!(x_prefill >= 1 && y_decode >= 1);
    let by_id: std::collections::HashMap<u32, usize> =
        requests.iter().enumerate().map(|(i, r)| (r.id, i)).collect();

    // ---- prefill cluster: sequential chunked prefill at x-way speed ----
    // The cluster's aggregate compute is x * per-GPU compute; its prefix
    // cache spans the cluster's KV (requests are routed by prefix).
    let mut cache = RadixCache::new((pm.kv_capacity_tokens() * x_prefill as f64) as u64);
    let mut clock_p = 0.0f64;
    let mut ready: Vec<(f64, u32)> = Vec::with_capacity(order.len());
    for &id in order {
        let r = &requests[by_id[&id]];
        // Combined walk: the route-by-prefix admission is the same hot
        // path the colocated engine runs.
        let (hit, _new, pin) = cache.lookup_insert_pinned(&r.prompt);
        cache.release(pin);
        let new_tokens = r.input_len() - hit;
        let t = (pm.comp_tokens(new_tokens)
            + pm.comp_prefill_attn(new_tokens, r.input_len()))
            / x_prefill as f64;
        clock_p += t;
        ready.push((clock_p, id));
    }
    let prefill_busy = clock_p;

    // ---- decode cluster: continuous batching, y-way resources ----
    let mut pm_d = pm.clone();
    pm_d.n_gpus = pm.n_gpus * y_decode;
    let kv_cap = pm_d.kv_capacity_tokens();
    let mut clock_d = 0.0f64;
    let mut busy_d = 0.0f64;
    let mut next = 0usize;
    let mut active: Vec<(usize, u32)> = Vec::new(); // (req idx, decoded)
    let mut ctx_sum = 0.0f64;
    let mut kv_used = 0.0f64;
    let mut total_tokens = 0u64;
    let mut done = 0usize;

    while done < requests.len() {
        // Admit everything that is prefilled and fits.
        while next < ready.len() {
            let (t_ready, id) = ready[next];
            if t_ready > clock_d && !active.is_empty() {
                break;
            }
            let idx = by_id[&id];
            let r = &requests[idx];
            let need = r.input_len() as f64 + r.est_output as f64 / 2.0;
            if kv_used + need > kv_cap && !active.is_empty() {
                break;
            }
            clock_d = clock_d.max(t_ready);
            active.push((idx, 0));
            ctx_sum += r.input_len() as f64;
            kv_used += need;
            next += 1;
        }
        if active.is_empty() {
            break; // defensive; cannot happen while done < len
        }
        // One decode step for the whole batch.
        let n = active.len();
        let t_comp = pm_d.comp_tokens(n);
        let t_mem = pm_d.mem_kv_load(ctx_sum);
        let dt = t_comp.max(t_mem) + pm_d.hw.interference.min(0.0); // decode-only: no overlap penalty
        clock_d += dt;
        busy_d += dt;
        ctx_sum += n as f64;
        let mut i = 0;
        while i < active.len() {
            active[i].1 += 1;
            let (idx, dec) = active[i];
            let r = &requests[idx];
            if dec >= r.true_output {
                ctx_sum -= (r.input_len() + dec as usize) as f64;
                kv_used -= r.input_len() as f64 + r.est_output as f64 / 2.0;
                total_tokens += (r.input_len() as u64) + r.true_output as u64;
                active.swap_remove(i);
                done += 1;
            } else {
                i += 1;
            }
        }
    }

    let total_time = clock_p.max(clock_d);
    let n_gpus = x_prefill + y_decode;
    DisaggResult {
        total_time,
        total_tokens,
        throughput: total_tokens as f64 / total_time.max(1e-12),
        per_gpu_throughput: total_tokens as f64 / total_time.max(1e-12) / n_gpus as f64,
        prefill_cluster_busy: prefill_busy,
        decode_cluster_busy: busy_d,
        n_gpus,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::presets;
    use std::sync::Arc;

    fn pm() -> PerfModel {
        PerfModel::new(presets::llama3_8b(), presets::a100_80gb(), 1)
    }

    fn reqs(n: usize, p: usize, d: u32) -> Vec<SimRequest> {
        (0..n)
            .map(|i| {
                SimRequest::offline(
                    i as u32,
                    Arc::new((0..p).map(|k| (i * p + k) as u32).collect()),
                    d,
                    d,
                )
            })
            .collect()
    }

    #[test]
    fn completes_and_reports() {
        let rs = reqs(50, 400, 60);
        let order: Vec<u32> = (0..50).collect();
        let r = simulate_disagg(&pm(), &rs, &order, 1, 1);
        assert_eq!(r.total_tokens, 50 * 460);
        assert!(r.total_time > 0.0);
        assert_eq!(r.n_gpus, 2);
        assert!((r.per_gpu_throughput * 2.0 - r.throughput).abs() < 1e-6);
    }

    #[test]
    fn decode_heavy_wants_more_decode_gpus() {
        // With long outputs, 1P2D beats 2P1D per-GPU (the Fig. 8 trend).
        let rs = reqs(60, 200, 800);
        let order: Vec<u32> = (0..60).collect();
        let r_1p2d = simulate_disagg(&pm(), &rs, &order, 1, 2);
        let r_2p1d = simulate_disagg(&pm(), &rs, &order, 2, 1);
        assert!(
            r_1p2d.per_gpu_throughput > r_2p1d.per_gpu_throughput,
            "1P2D={} 2P1D={}",
            r_1p2d.per_gpu_throughput,
            r_2p1d.per_gpu_throughput
        );
    }

    #[test]
    fn one_cluster_is_always_underutilized() {
        let rs = reqs(80, 500, 200);
        let order: Vec<u32> = (0..80).collect();
        let r = simulate_disagg(&pm(), &rs, &order, 1, 1);
        // Busy fractions cannot both be ~1.0: disaggregation idles one side.
        let f_p = r.prefill_cluster_busy / r.total_time;
        let f_d = r.decode_cluster_busy / r.total_time;
        assert!(
            f_p.min(f_d) < 0.95,
            "both clusters ~fully busy: p={f_p} d={f_d}"
        );
    }
}
