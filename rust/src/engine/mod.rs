//! Execution engine: the profile-guided GPU step simulator plus the
//! runtime prefix cache.
//!
//! The engine models a NanoFlow-style serving backend at *step* (iteration)
//! granularity: every step executes one chunked-prefill slice plus one
//! decode token for every decoding request, with compute- and memory-bound
//! operator times from the §4 perf model and an overlap function `f`:
//!
//! - `Sequential` (vLLM/SGLang-like): `step = t_comp + t_mem`
//! - `Overlapped` (NanoFlow-like):    `step = max + interference·min`
//!   (perfectly balanced steps pay `(1+i)·max`, matching the paper's
//!   "practical optimal" profiling; one-sided steps pay no penalty)
//!
//! The paper's own large-scale evaluation (§6.5, Figs. 11-15, Table 3,
//! Fig. 12) runs exactly this kind of simulated backend and reports a 0.91%
//! deviation from real-GPU speedups; DESIGN.md §Substitutions documents our
//! calibration.

pub mod distserve;
pub mod prefix_cache;
pub mod sim;

pub use prefix_cache::{PinHandle, RadixCache};
pub use sim::audit::EngineAuditor;
pub use sim::{
    Admitter, EngineView, RequestTiming, RunState, SimEngine, SimRequest, SimResult,
    StaticOrder, StepOutcome, StepSample,
};

use crate::config::OverlapMode;

/// Combine per-step compute and memory operator time into wall-clock time.
pub fn overlap_time(mode: OverlapMode, interference: f64, t_comp: f64, t_mem: f64) -> f64 {
    match mode {
        OverlapMode::Sequential => t_comp + t_mem,
        OverlapMode::Overlapped => t_comp.max(t_mem) + interference * t_comp.min(t_mem),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn overlap_modes() {
        let seq = overlap_time(OverlapMode::Sequential, 0.15, 2.0, 3.0);
        assert_eq!(seq, 5.0);
        let ovl = overlap_time(OverlapMode::Overlapped, 0.15, 2.0, 3.0);
        assert!((ovl - (3.0 + 0.15 * 2.0)).abs() < 1e-12);
        // One-sided steps pay no interference.
        let one = overlap_time(OverlapMode::Overlapped, 0.15, 2.0, 0.0);
        assert_eq!(one, 2.0);
    }

    #[test]
    fn overlapped_never_slower_than_sequential() {
        for (c, m) in [(1.0, 1.0), (5.0, 0.1), (0.0, 2.0), (3.0, 2.9)] {
            let s = overlap_time(OverlapMode::Sequential, 0.2, c, m);
            let o = overlap_time(OverlapMode::Overlapped, 0.2, c, m);
            assert!(o <= s + 1e-12, "c={c} m={m}: {o} > {s}");
        }
    }
}
