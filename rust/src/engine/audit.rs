//! The engine invariant auditor (DESIGN.md §11): cross-subsystem
//! conservation laws checked after every [`SimEngine::step_once`].
//!
//! Five PRs of engine growth — retraction, the radix prefix cache, the
//! tiered-KV ledger/link, the encoder cache, fleet stealing — are each
//! pinned by per-subsystem oracle tests, but nothing watched the *seams*
//! between them: a retraction that forgets to unpin an embedding, a
//! restore that double-counts recompute, side accounting that drifts
//! from the actives actually holding charges.  The auditor recomputes
//! the engine's running aggregates from first principles each step and
//! asserts they match, so any future change that breaks a conservation
//! law fails the first test that exercises it instead of skewing results
//! silently.
//!
//! Gating: debug builds always audit (CI's test job runs the dev
//! profile, so every existing test doubles as an auditor test); release
//! builds skip it unless `EngineConfig::audit` opts in — the checks walk
//! the active set, so the hot path must not pay for them by default.
//!
//! Invariants (each `check` call):
//!
//! 1. **Progress ≤ demand** — per active: `prefill_pos ≤ input_len`,
//!    `decoded ≤ true_output`, `encode_left ≥ 0`, and
//!    `private_prompt = input_len − pin.len()` exactly (admission's
//!    split of the prompt between cache-pinned and privately-charged
//!    tokens never drifts).
//! 2. **Aggregate conservation** — `private_tokens`, `decode_ctx_sum`,
//!    `used_left`/`used_right` equal their recomputed per-active sums.
//! 3. **Exactly-once residency** — no request is active twice; the
//!    retract queue holds no duplicates and no currently-active request.
//! 4. **KV budget** — `peak_kv_used` is monotone; committed tokens may
//!    exceed capacity only as a lone oversized request or in a step that
//!    made retraction progress (the engine retracts one victim per
//!    step).
//! 5. **Host ledger** — host bytes within the configured budget;
//!    `offloaded = fetched + dropped + resident` conservation (dropped
//!    extents come from degraded-mode host shrinks, DESIGN.md §12); the
//!    run counters mirror the ledger; swap counters frozen at zero when
//!    tiering is disabled.
//! 6. **Link FIFO causality** — `busy_until` and `busy_time` are
//!    monotone and `busy_until ≥ busy_time` (transfers are issued at
//!    non-negative times, FIFO, never retroactively).
//! 7. **Recompute accounting** — `recomputed_tokens` only grows in steps
//!    with a retraction or a swap restore; swap-outs only happen in
//!    retraction steps.
//! 8. **Cache refcounts** — encoder-cache pinned references equal the
//!    attachment pins held by actives; prefix-cache pinned tokens are
//!    bounded by the actives' pin lengths.
//! 9. **Token conservation at completion** — when a run reaches `Done`,
//!    the finished timings account for exactly `total_tokens`.
//! 10. **Result coherence** ([`EngineAuditor::check_final`], called from
//!    `finalize`) — every derived metric in [`SimResult`] matches its
//!    definition recomputed from the raw counters: throughputs, sharing,
//!    SLO attainment, overlap/busy fractions in `[0, 1]`, swap/recompute
//!    implications, and the step series summing back to the aggregate
//!    busy times.  The static linter's rule r5 (DESIGN.md §13) enforces
//!    that every `SimResult` field stays referenced here, so new
//!    accounting cannot ship without a final audit.
//! 11. **Trace reconciliation** (DESIGN.md §15, inside `check_final`) —
//!    when a trace stream was recorded, replay it against the result it
//!    narrates: every `Finish` exactly once, Σ swap-event tokens equal
//!    the swap counters, retraction / window / admission-sharing event
//!    sums equal their counters.  A stream that hit its cap (dropped
//!    records) is skipped with an explicit log line, never trusted
//!    partially.

use super::{RunState, SimEngine, SimResult};
use crate::obs::TraceEvent;
use std::collections::BTreeSet;

/// Relative slack for float aggregate comparisons.  Every audited sum is
/// dyadic (token counts and `d̂/2` halves), so f64 accumulation is exact;
/// the slack only guards against a future non-dyadic term.
const REL_EPS: f64 = 1e-9;

fn close(what: &str, engine_val: f64, recomputed: f64) {
    let tol = REL_EPS * engine_val.abs().max(recomputed.abs()).max(1.0);
    assert!(
        (engine_val - recomputed).abs() <= tol,
        "audit: {what} drifted — engine {engine_val} vs recomputed {recomputed}"
    );
}

/// Step-over-step auditor state: previous counter values for the
/// monotonicity and delta-gated checks.
#[derive(Clone, Debug, Default)]
pub struct EngineAuditor {
    prev_clock: f64,
    prev_peak_kv: f64,
    prev_retractions: u64,
    prev_recomputed: u64,
    prev_swapped_out: u64,
    prev_swapped_in: u64,
    prev_link_busy_until: f64,
    prev_link_busy_time: f64,
    checks: u64,
}

impl EngineAuditor {
    /// The auditor a run under `cfg` carries: present in debug builds or
    /// when `engine.audit = true`, absent otherwise.
    pub fn maybe(cfg: &crate::config::EngineConfig) -> Option<Box<EngineAuditor>> {
        if cfg.audit_enabled() {
            Some(Box::new(EngineAuditor::default()))
        } else {
            None
        }
    }

    /// Number of steps audited so far.
    pub fn checks(&self) -> u64 {
        self.checks
    }

    /// Re-baseline the delta-gated counters after a *coordinator-level*
    /// mutation (cross-replica adoption of a rescued extent, a host-KV
    /// shrink).  Those legitimately grow `swapped_out_tokens` /
    /// `recomputed_tokens` outside a retraction step, which invariant 7
    /// would otherwise flag; conservation invariants still apply in full
    /// at the next `check`.
    pub(crate) fn resync_external(&mut self, swapped_out_tokens: u64, recomputed_tokens: u64) {
        self.prev_swapped_out = self.prev_swapped_out.max(swapped_out_tokens);
        self.prev_recomputed = self.prev_recomputed.max(recomputed_tokens);
    }

    /// Verify every invariant against the post-step state.  Panics with
    /// the violated law on failure.
    pub fn check(&mut self, eng: &SimEngine, st: &RunState) {
        // ---- (1) per-active progress bounds + (2) aggregate sums ----
        let mut private = 0.0f64;
        let mut ctx = 0.0f64;
        let mut left = 0.0f64;
        let mut right = 0.0f64;
        let mut waiting = 0usize;
        let mut att_refs = 0u64;
        let mut pin_sum = 0u64;
        let mut ids: Vec<u32> = Vec::with_capacity(st.active.len());
        for a in &st.active {
            let idx = eng.by_id[a.req as usize];
            assert!(idx != usize::MAX, "audit: active request {} unknown to engine", a.req);
            let r = &eng.requests[idx];
            let p = r.input_len();
            assert!(
                a.prefill_pos <= p,
                "audit: request {} prefill {} beyond prompt {p}",
                a.req,
                a.prefill_pos
            );
            assert!(
                a.decoded <= r.true_output,
                "audit: request {} decoded {} beyond demand {}",
                a.req,
                a.decoded,
                r.true_output
            );
            // Admission sets `private_prompt = prompt − pinned`, and
            // neither side changes until finish/retraction releases both.
            assert!(
                // lint:allow(r3) -- both sides are exact small-integer-valued f64s,
                // set once at admission and never accumulated
                a.private_prompt == (p - a.pin.len()) as f64,
                "audit: request {} private prompt {} != prompt {p} − pinned {}",
                a.req,
                a.private_prompt,
                a.pin.len()
            );
            assert!(
                a.encode_left >= 0.0 && a.charge >= 0.0,
                "audit: request {} negative accounting (encode_left {}, charge {})",
                a.req,
                a.encode_left,
                a.charge
            );
            private += a.private_prompt + a.decoded as f64;
            if a.decoding {
                ctx += (p + a.decoded as usize) as f64;
            }
            match a.side {
                super::Side::Left => left += a.charge,
                super::Side::Right => right += a.charge,
            }
            if a.encode_left > 0.0 {
                waiting += 1;
            }
            att_refs += a.att_pins.len() as u64;
            pin_sum += a.pin.len() as u64;
            ids.push(a.req);
        }
        close("private_tokens", st.private_tokens, private);
        close("decode_ctx_sum", st.decode_ctx_sum, ctx);
        close("used_left", st.used_left, left);
        close("used_right", st.used_right, right);
        assert_eq!(
            st.mm.waiting, waiting,
            "audit: mm.waiting {} vs {} actives still owing encoder work",
            st.mm.waiting, waiting
        );
        assert!(
            st.mm.encode_time >= st.mm.overlapped - REL_EPS,
            "audit: overlapped encoder seconds {} exceed executed {}",
            st.mm.overlapped,
            st.mm.encode_time
        );

        // ---- (3) exactly-once residency ----
        ids.sort_unstable();
        for w in ids.windows(2) {
            assert!(w[0] != w[1], "audit: request {} active twice", w[0]);
        }
        let mut rq: Vec<u32> = st.retract_queue.iter().copied().collect();
        rq.sort_unstable();
        for w in rq.windows(2) {
            assert!(w[0] != w[1], "audit: request {} retract-queued twice", w[0]);
        }
        for &q in &rq {
            assert!(
                ids.binary_search(&q).is_err(),
                "audit: request {q} both active and retract-queued"
            );
        }

        // ---- (8) cache refcount consistency ----
        assert_eq!(
            eng.ecache.total_refs(),
            att_refs,
            "audit: encoder cache holds {} pinned refs but actives hold {} attachment pins",
            eng.ecache.total_refs(),
            att_refs
        );
        let pinned = eng.cache.pinned_tokens();
        assert!(
            pinned <= pin_sum,
            "audit: prefix cache pins {pinned} tokens but actives account for only {pin_sum}"
        );

        // ---- (4) KV budget ----
        assert!(
            st.result.peak_kv_used >= self.prev_peak_kv - REL_EPS,
            "audit: peak_kv_used regressed {} -> {}",
            self.prev_peak_kv,
            st.result.peak_kv_used
        );
        let committed = st.private_tokens + pinned as f64;
        if committed > eng.kv_capacity * (1.0 + REL_EPS) {
            assert!(
                st.active.len() <= 1 || st.result.retractions > self.prev_retractions,
                "audit: KV budget exceeded ({committed} > {}) with {} actives and no \
                 retraction progress this step",
                eng.kv_capacity,
                st.active.len()
            );
        }

        // ---- (5) host ledger ----
        let led = &st.kv.ledger;
        assert!(
            led.host_used_bytes() <= eng.kv_params.host_capacity_bytes * (1.0 + REL_EPS),
            "audit: host memory over budget ({} > {})",
            led.host_used_bytes(),
            eng.kv_params.host_capacity_bytes
        );
        assert_eq!(
            led.offloaded_tokens,
            led.fetched_tokens + led.dropped_tokens + led.resident_tokens(),
            "audit: ledger conservation broken (offloaded != fetched + dropped + resident)"
        );
        assert_eq!(
            st.kv.swapped_out_tokens, led.offloaded_tokens,
            "audit: swapped_out_tokens diverged from the ledger"
        );
        assert_eq!(
            st.kv.swapped_in_tokens, led.fetched_tokens,
            "audit: swapped_in_tokens diverged from the ledger"
        );
        if !eng.kv_params.enabled {
            assert_eq!(
                st.kv.swapped_out_tokens, 0,
                "audit: swap activity with tiering disabled"
            );
        }

        // ---- (6) link FIFO causality ----
        let link = &st.kv.link;
        assert!(
            link.busy_until() >= self.prev_link_busy_until - REL_EPS,
            "audit: link busy_until moved backwards"
        );
        assert!(
            link.busy_time() >= self.prev_link_busy_time - REL_EPS,
            "audit: link busy_time shrank"
        );
        assert!(
            link.busy_until() >= link.busy_time() - REL_EPS,
            "audit: link busy_until {} below busy_time {} (retroactive transfer)",
            link.busy_until(),
            link.busy_time()
        );

        // ---- (7) monotone counters + recompute accounting ----
        assert!(st.clock >= self.prev_clock - REL_EPS, "audit: clock went backwards");
        assert!(st.result.retractions >= self.prev_retractions);
        assert!(st.kv.recomputed_tokens >= self.prev_recomputed);
        assert!(st.kv.swapped_out_tokens >= self.prev_swapped_out);
        assert!(st.kv.swapped_in_tokens >= self.prev_swapped_in);
        if st.kv.recomputed_tokens > self.prev_recomputed {
            assert!(
                st.result.retractions > self.prev_retractions
                    || st.kv.swapped_in_tokens > self.prev_swapped_in,
                "audit: recomputed_tokens grew without a retraction or swap restore"
            );
        }
        if st.kv.swapped_out_tokens > self.prev_swapped_out {
            assert!(
                st.result.retractions > self.prev_retractions,
                "audit: tokens swapped out without a retraction"
            );
        }

        // ---- (9) token conservation at completion ----
        if st.finished >= eng.requests.len() {
            let mut total = 0u64;
            let mut n_finished = 0usize;
            for (i, t) in st.timings.iter().enumerate() {
                if t.finish.is_finite() {
                    let r = &eng.requests[i];
                    total += r.input_len() as u64 + r.true_output as u64;
                    n_finished += 1;
                }
            }
            assert_eq!(
                n_finished, st.finished,
                "audit: finished count {} vs {} finite finish timings",
                st.finished, n_finished
            );
            assert_eq!(
                total, st.result.total_tokens,
                "audit: total_tokens {} but finished requests sum to {total}",
                st.result.total_tokens
            );
        }

        self.prev_clock = st.clock;
        self.prev_peak_kv = st.result.peak_kv_used;
        self.prev_retractions = st.result.retractions;
        self.prev_recomputed = st.kv.recomputed_tokens;
        self.prev_swapped_out = st.kv.swapped_out_tokens;
        self.prev_swapped_in = st.kv.swapped_in_tokens;
        self.prev_link_busy_until = st.kv.link.busy_until();
        self.prev_link_busy_time = st.kv.link.busy_time();
        self.checks += 1;
    }

    /// Invariant 10: audit the finished [`SimResult`] — every derived
    /// metric must match its definition recomputed from the raw counters
    /// it summarizes.  Rule r5 of the static linter keeps this function
    /// total over the struct: adding a `SimResult` field without
    /// referencing it here fails `blendserve lint`.
    pub fn check_final(&self, res: &SimResult) {
        // ---- throughputs ----
        assert!(res.total_time >= 0.0, "audit: negative total_time {}", res.total_time);
        if res.total_time > 0.0 {
            close("throughput", res.throughput, res.total_tokens as f64 / res.total_time);
            close(
                "offline_throughput",
                res.offline_throughput,
                res.offline_tokens as f64 / res.total_time,
            );
        }
        assert!(
            res.offline_tokens <= res.total_tokens,
            "audit: offline goodput {} exceeds total tokens {}",
            res.offline_tokens,
            res.total_tokens
        );
        assert!(
            res.steps > 0 || res.total_tokens == 0,
            "audit: {} tokens produced in zero steps",
            res.total_tokens
        );

        // ---- prefix sharing ----
        assert!(
            res.hit_tokens <= res.prompt_tokens,
            "audit: cache hits {} exceed prompt tokens {}",
            res.hit_tokens,
            res.prompt_tokens
        );
        if res.prompt_tokens > 0 {
            close(
                "sharing_achieved",
                res.sharing_achieved,
                res.hit_tokens as f64 / res.prompt_tokens as f64,
            );
        }

        // ---- online SLO attainment ----
        assert!(
            res.slo_attained <= res.n_online,
            "audit: {} SLO-attained of {} online requests",
            res.slo_attained,
            res.n_online
        );
        assert!(
            res.n_online <= res.timings.len(),
            "audit: {} online requests but only {} timing records",
            res.n_online,
            res.timings.len()
        );
        if res.n_online > 0 {
            close(
                "slo_attainment",
                res.slo_attainment,
                res.slo_attained as f64 / res.n_online as f64,
            );
        }
        assert!(
            res.mean_ttft >= 0.0 && res.p99_ttft >= 0.0 && res.mean_queue_delay >= 0.0,
            "audit: negative latency summary (mean_ttft {}, p99_ttft {}, mean_queue_delay {})",
            res.mean_ttft,
            res.p99_ttft,
            res.mean_queue_delay
        );

        // ---- tiered-KV accounting ----
        assert!(
            res.swapped_in_tokens <= res.swapped_out_tokens,
            "audit: {} tokens swapped in but only {} ever swapped out",
            res.swapped_in_tokens,
            res.swapped_out_tokens
        );
        // Adoption (`adopt_retracted`) grows the heir's swap counters
        // without a local retraction, so the implication only runs in the
        // other direction: recompute needs a discard (retraction) or a
        // dropped/restored offloaded extent to have existed.
        assert!(
            res.retractions == 0 || res.steps > 0,
            "audit: {} retractions in a run that never stepped",
            res.retractions
        );
        assert!(
            res.recomputed_tokens == 0 || res.retractions > 0 || res.swapped_out_tokens > 0,
            "audit: {} tokens recomputed without a retraction or an offloaded extent",
            res.recomputed_tokens
        );
        assert!(
            res.recompute_saved_tokens == 0 || res.swapped_in_tokens > 0,
            "audit: {} tokens saved from recompute without a single restore",
            res.recompute_saved_tokens
        );
        assert!(res.peak_kv_used >= 0.0, "audit: negative peak_kv_used {}", res.peak_kv_used);

        // ---- link occupancy ----
        // No upper bound: `LinkModel::transfer` accrues busy time at
        // issue, so a swap-out that is never waited on (its extent was
        // dropped by a host shrink) can leave `busy_until` past the final
        // clock and push the fraction marginally above 1.
        assert!(
            res.link_busy_frac >= 0.0 && res.link_busy_frac.is_finite(),
            "audit: link_busy_frac {} is negative or non-finite",
            res.link_busy_frac
        );
        let stall_tol = REL_EPS * res.total_time.max(1.0);
        assert!(
            res.link_stall_time >= 0.0 && res.link_stall_time <= res.total_time + stall_tol,
            "audit: link stall {} outside the run's {}s",
            res.link_stall_time,
            res.total_time
        );

        // ---- encoder accounting ----
        assert!(res.encode_time >= 0.0, "audit: negative encode_time {}", res.encode_time);
        // Same slack form as the per-step invariant (absolute REL_EPS on
        // the overlapped seconds, not on the fraction): reconstruct
        // `overlapped` and bound it by the executed encoder seconds.
        assert!(
            res.encode_overlap_frac >= 0.0
                && res.encode_overlap_frac * res.encode_time <= res.encode_time + REL_EPS,
            "audit: encode_overlap_frac {} of {}s exceeds the executed encoder seconds",
            res.encode_overlap_frac,
            res.encode_time
        );
        assert!(
            res.embed_cache_hit_tokens == 0 || res.steps > 0,
            "audit: embedding-cache hits in a run that never stepped"
        );

        // ---- streaming-window accounting ----
        assert!(
            res.cross_window_hit_tokens <= res.hit_tokens,
            "audit: {} cross-window hit tokens exceed total cache hits {}",
            res.cross_window_hit_tokens,
            res.hit_tokens
        );
        // A hit can only cross a window boundary if more than one window
        // was ever fed (the cache epoch never advances otherwise).
        assert!(
            res.windows > 1 || res.cross_window_hit_tokens == 0,
            "audit: {} cross-window hit tokens with only {} windows",
            res.cross_window_hit_tokens,
            res.windows
        );
        assert!(
            res.peak_resident_requests <= res.timings.len(),
            "audit: peak residency {} exceeds the {} requests ever fed",
            res.peak_resident_requests,
            res.timings.len()
        );
        assert!(
            res.peak_resident_requests > 0 || res.steps == 0 || res.timings.is_empty(),
            "audit: a stepped run with requests never observed a resident one"
        );

        // ---- step series vs aggregate busy time ----
        assert!(
            res.total_comp >= 0.0 && res.total_mem >= 0.0,
            "audit: negative busy time (comp {}, mem {})",
            res.total_comp,
            res.total_mem
        );
        assert!(
            res.series.len() as u64 <= res.steps,
            "audit: {} series samples from {} steps",
            res.series.len(),
            res.steps
        );
        // The cap is never silent: the flag and the drop counter are set
        // together, and captured + dropped never exceed the step count
        // (idle-skip steps legitimately carry no sample either way).
        assert_eq!(
            res.series_truncated,
            res.series_dropped > 0,
            "audit: series_truncated {} inconsistent with {} dropped samples",
            res.series_truncated,
            res.series_dropped
        );
        assert!(
            res.series.len() as u64 + res.series_dropped <= res.steps,
            "audit: {} captured + {} dropped series samples from {} steps",
            res.series.len(),
            res.series_dropped,
            res.steps
        );
        if res.series_truncated {
            // A capped series cannot reproduce the aggregates — say so
            // explicitly instead of silently skipping the reconstruction.
            eprintln!(
                "audit: step series hit its cap ({} steps uncaptured) — \
                 skipping series-sum reconstruction",
                res.series_dropped
            );
        } else if res.series.len() as u64 == res.steps {
            // An uncapped, unthinned series covers every step, so its
            // sums must reproduce the aggregates (same addends, same
            // order).
            let mut comp = 0.0;
            let mut mem = 0.0;
            let mut wall = 0.0;
            for s in &res.series {
                comp += s.t_comp;
                mem += s.t_mem;
                wall += s.step_time;
            }
            close("total_comp", res.total_comp, comp);
            close("total_mem", res.total_mem, mem);
            assert!(
                wall <= res.total_time + stall_tol,
                "audit: series step times sum to {} beyond total_time {}",
                wall,
                res.total_time
            );
        }

        // ---- (11) trace reconciliation (DESIGN.md §15) ----
        // A recorded stream must agree exactly with the result it
        // narrates.  Incomplete streams (cap hit, records dropped) are
        // skipped with a log line — reconciling a partial stream would
        // be guesswork.
        if let Some(tr) = res.trace.as_ref() {
            if !tr.complete() {
                eprintln!(
                    "audit: trace stream dropped {} records — \
                     skipping event-stream reconciliation",
                    tr.dropped
                );
                return;
            }
            let mut finishes: BTreeSet<u32> = BTreeSet::new();
            let mut swap_out = 0u64;
            let mut swap_in = 0u64;
            let mut retracts = 0u64;
            let mut windows = 0u64;
            let mut admit_hit = 0u64;
            let mut admit_prompt = 0u64;
            for r in &tr.events {
                match r.ev {
                    TraceEvent::Finish { req } => {
                        assert!(
                            finishes.insert(req),
                            "audit: request {req} finished twice in the trace"
                        );
                    }
                    TraceEvent::SwapOut { tokens, .. } => swap_out += tokens,
                    TraceEvent::SwapIn { tokens, .. } => swap_in += tokens,
                    TraceEvent::Retract { .. } => retracts += 1,
                    TraceEvent::WindowFeed { .. } => windows += 1,
                    TraceEvent::Admit { hit_tokens, new_tokens, .. } => {
                        admit_hit += hit_tokens;
                        admit_prompt += hit_tokens + new_tokens;
                    }
                    _ => {}
                }
            }
            let finished = res.timings.iter().filter(|t| t.finish.is_finite()).count();
            assert_eq!(
                finishes.len(),
                finished,
                "audit: {} distinct Finish events vs {finished} finished timings",
                finishes.len()
            );
            assert_eq!(
                swap_out, res.swapped_out_tokens,
                "audit: Σ SwapOut tokens {swap_out} vs counter {}",
                res.swapped_out_tokens
            );
            assert_eq!(
                swap_in, res.swapped_in_tokens,
                "audit: Σ SwapIn tokens {swap_in} vs counter {}",
                res.swapped_in_tokens
            );
            assert_eq!(
                retracts, res.retractions,
                "audit: {retracts} Retract events vs {} retractions",
                res.retractions
            );
            assert_eq!(
                windows, res.windows,
                "audit: {windows} WindowFeed events vs {} windows",
                res.windows
            );
            assert_eq!(
                admit_hit, res.hit_tokens,
                "audit: Σ Admit hit tokens {admit_hit} vs counter {}",
                res.hit_tokens
            );
            assert_eq!(
                admit_prompt, res.prompt_tokens,
                "audit: Σ Admit prompt tokens {admit_prompt} vs counter {}",
                res.prompt_tokens
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::super::{SimEngine, SimRequest, StaticOrder, StepOutcome};
    use crate::config::{EngineConfig, SchedulerConfig};
    use crate::config::presets;
    use crate::perfmodel::PerfModel;
    use std::sync::Arc;

    fn pm() -> PerfModel {
        PerfModel::new(presets::llama3_8b(), presets::a100_80gb(), 1)
    }

    fn reqs(n: usize) -> Vec<SimRequest> {
        (0..n)
            .map(|i| {
                let prompt: Vec<u32> = (0..96).map(|k| (i * 96 + k) as u32).collect();
                SimRequest::offline(i as u32, Arc::new(prompt), 48, 40)
            })
            .collect()
    }

    #[test]
    fn auditor_runs_and_passes_on_a_plain_batch() {
        let n = 24;
        // Explicit opt-in so the test also exercises the release profile.
        let cfg = EngineConfig { audit: true, ..EngineConfig::default() };
        let mut eng = SimEngine::new(pm(), cfg, SchedulerConfig::default(), reqs(n));
        let mut st = eng.begin();
        let mut adm = StaticOrder::new((0..n as u32).collect());
        let mut steps = 0u64;
        while eng.step_once(&mut st, &mut adm) == StepOutcome::Progress {
            steps += 1;
        }
        let audited = st.audit.as_ref().expect("audit=true carries an auditor").checks();
        assert!(audited > 0 && audited <= steps + 1, "audited {audited} of {steps} steps");
        let r = eng.finalize(st);
        assert_eq!(r.total_tokens, (n * (96 + 48)) as u64);
    }

    #[test]
    fn auditor_absent_when_disabled_in_release() {
        let cfg = EngineConfig::default();
        let eng = SimEngine::new(pm(), cfg.clone(), SchedulerConfig::default(), reqs(2));
        let st = eng.begin();
        assert_eq!(st.audit.is_some(), cfg.audit_enabled());
    }
}
