//! Runtime radix prefix cache (§2.2 "prefix sharing", §A.2 "runtime prefix
//! tree"): a **path-compressed, segment-granular** trie over *computed*
//! prompt prefixes, with reference counting for active requests and
//! leaf-first LRU eviction (DESIGN.md §Runtime-Prefix-Cache).
//!
//! Semantics follow SGLang's RadixAttention: all prompt KV lives in the
//! trie (a shared prefix is stored once); each resident trie token charges
//! one KV slot; eviction removes unreferenced leaf tokens in LRU order.
//! Decode-phase tokens are *not* cached here — they are private to the
//! request and accounted by the engine.
//!
//! Unlike a token-granular trie (one arena node + one hash probe per
//! token), nodes here own `(Arc<Vec<u32>>, start, len)` slices into the
//! immutable prompts — the same zero-copy representation as
//! [`crate::tree`] — and children are keyed by first token only.  Matching
//! walks whole segments with a slice compare, so a lookup costs
//! O(#shared segments) hash probes instead of O(tokens).  Three operations
//! keep token-exact semantics at segment granularity:
//!
//! - **split on partial match**: an op that touches only the head of a
//!   segment splits it, so LRU clocks and pin refcounts stay per-token
//!   exact (the untouched tail keeps its older clock / refcount);
//! - **segment-tail eviction**: the LRU leaf sheds exactly as many tail
//!   tokens as needed, splitting the segment rather than overshooting;
//! - **[`PinHandle`]**: `insert_pinned` returns the deepest pinned node,
//!   so `release` walks O(path nodes) parent links instead of re-matching
//!   the prompt token by token.
//!
//! All externally observable accounting (`size`, `pinned`, `hits_tokens`,
//! `lookup_tokens`, `evicted_tokens`, LRU eviction order) is equivalent
//! bit-for-bit to the token-granular implementation; the randomized oracle
//! test `rust/tests/prefix_cache_oracle.rs` pins that equivalence against
//! the retained reference implementation.

use std::cmp::Reverse;
use std::collections::{BinaryHeap, HashMap};
use std::sync::Arc;

type Id = u32;
const NIL: Id = u32::MAX;

/// Opaque receipt for a pinned prompt prefix, returned by
/// [`RadixCache::insert_pinned`] / [`RadixCache::lookup_insert_pinned`]
/// and consumed by [`RadixCache::release`].
///
/// Internally it names the deepest pinned node plus the pinned token
/// count, so release is an O(path nodes) parent walk.  The handle stays
/// valid across later node splits (a split keeps the original id on the
/// deep half) and its path can never be evicted while the pin is live.
#[must_use = "dropping a PinHandle without `release` leaks pinned KV tokens"]
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct PinHandle {
    node: Id,
    len: u32,
}

impl PinHandle {
    /// The no-op handle: releasing it does nothing.  Returned when
    /// nothing could be pinned (zero-capacity cache, empty prompt).
    pub const EMPTY: PinHandle = PinHandle { node: NIL, len: 0 };

    /// Pinned prefix length in tokens.
    pub fn len(&self) -> usize {
        self.len as usize
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }
}

impl Default for PinHandle {
    fn default() -> Self {
        PinHandle::EMPTY
    }
}

#[derive(Clone, Debug)]
struct CNode {
    parent: Id,
    /// Zero-copy token segment: `tokens[start .. start + len]`.
    tokens: Arc<Vec<u32>>,
    start: u32,
    len: u32,
    n_children: u32,
    /// Active pins whose path passes through this node.  Every token of
    /// the segment carries exactly this refcount (splits keep it exact).
    refs: u32,
    last_use: u64,
    /// Cache epoch at materialization time (splits inherit the original
    /// segment's stamp).  The streaming driver bumps the cache epoch at
    /// window boundaries; a match on a node stamped in an earlier epoch
    /// is a cross-window hit.  Monolithic runs never bump, so every node
    /// matches the live epoch and the cross-epoch stat stays zero.
    epoch: u64,
    /// Slot is recycled (on the free list).
    free: bool,
}

/// Path-compressed segment radix cache with token-exact LRU eviction.
#[derive(Debug)]
pub struct RadixCache {
    nodes: Vec<CNode>,
    /// Child index keyed by `(parent, first token of child segment)`;
    /// one probe per *segment*, not per token.
    children: HashMap<(Id, u32), Id>,
    free_list: Vec<Id>,
    /// Lazy min-heap of eviction candidates `(last_use, id)`.  Entries are
    /// validated on pop (a node may have been touched, re-pinned, split or
    /// grown children since being pushed); a full-scan fallback guards
    /// against leaked candidates.
    evict_heap: BinaryHeap<Reverse<(u64, Id)>>,
    /// Shared empty buffer installed into freed slots so their `Arc`
    /// references to prompt storage drop promptly.
    empty: Arc<Vec<u32>>,
    /// Resident tokens (Σ len over live nodes).
    size: u64,
    /// Tokens currently pinned (refs > 0); maintained incrementally.
    pinned: u64,
    /// Capacity in tokens; inserts beyond it force eviction, and when
    /// nothing is evictable the insert is truncated.
    capacity: u64,
    clock: u64,
    /// Current ingest epoch; new segments are stamped with it.  Advanced
    /// by [`bump_epoch`](Self::bump_epoch) at streaming window
    /// boundaries, never by the cache itself.
    epoch: u64,
    // ---- statistics ----
    pub hits_tokens: u64,
    pub lookup_tokens: u64,
    pub evicted_tokens: u64,
    /// Hit tokens matched on segments stamped in an *earlier* epoch —
    /// i.e. prefix sharing that survived a streaming window boundary.
    /// Always `<= hits_tokens`; stays 0 unless `bump_epoch` was called.
    pub prev_epoch_hit_tokens: u64,
}

/// Length of the common prefix of two equal-length slices; a single
/// `memcmp`-style compare in the (common) full-match case.
fn common_prefix(a: &[u32], b: &[u32]) -> usize {
    debug_assert_eq!(a.len(), b.len());
    if a == b {
        return a.len();
    }
    a.iter().zip(b.iter()).take_while(|(x, y)| x == y).count()
}

/// One segment-match step shared by the lookup and insert walks: the
/// child of `cur` starting with `prompt[depth]`, how many of its tokens
/// match (capped at `bound - depth`), and whether the whole segment
/// matched.
struct SegMatch {
    child: Id,
    matched: usize,
    full: bool,
}

impl RadixCache {
    pub fn new(capacity: u64) -> Self {
        RadixCache {
            nodes: Vec::new(),
            children: HashMap::new(),
            free_list: Vec::new(),
            evict_heap: BinaryHeap::new(),
            empty: Arc::new(Vec::new()),
            size: 0,
            pinned: 0,
            capacity,
            clock: 0,
            epoch: 0,
            hits_tokens: 0,
            lookup_tokens: 0,
            evicted_tokens: 0,
            prev_epoch_hit_tokens: 0,
        }
    }

    /// Advance the ingest epoch: content resident *now* becomes
    /// "previous-epoch" content, so later hits on it accrue to
    /// [`prev_epoch_hit_tokens`].  Called by the streaming driver when a
    /// new window is fed; a run that never calls this observes identical
    /// behavior and statistics to one predating the epoch machinery.
    pub fn bump_epoch(&mut self) {
        self.epoch += 1;
    }

    pub fn size_tokens(&self) -> u64 {
        self.size
    }

    pub fn capacity_tokens(&self) -> u64 {
        self.capacity
    }

    fn match_child(&self, cur: Id, prompt: &[u32], depth: usize, bound: usize) -> Option<SegMatch> {
        let child = self.children.get(&(cur, prompt[depth])).copied()?;
        let n = &self.nodes[child as usize];
        let max_m = (n.len as usize).min(bound - depth);
        let s = n.start as usize;
        let matched = common_prefix(&n.tokens[s..s + max_m], &prompt[depth..depth + max_m]);
        Some(SegMatch { child, matched, full: matched == n.len as usize })
    }

    /// Longest cached prefix of `prompt`, in tokens; bumps LRU clocks
    /// along the path and counts hit statistics.  A partial segment match
    /// splits the node so only the touched head gets the fresh clock.
    pub fn lookup(&mut self, prompt: &[u32]) -> usize {
        self.clock += 1;
        let mut cur = NIL;
        let mut depth = 0usize;
        let mut prev_epoch = 0u64;
        while depth < prompt.len() {
            let sm = match self.match_child(cur, prompt, depth, prompt.len()) {
                Some(sm) => sm,
                None => break,
            };
            if sm.full {
                let n = &mut self.nodes[sm.child as usize];
                n.last_use = self.clock;
                if n.epoch < self.epoch {
                    prev_epoch += sm.matched as u64;
                }
                cur = sm.child;
                depth += sm.matched;
            } else {
                // Partial: split so the untouched tail keeps its old clock.
                let p = self.split(sm.child, sm.matched);
                let n = &mut self.nodes[p as usize];
                n.last_use = self.clock;
                if n.epoch < self.epoch {
                    prev_epoch += sm.matched as u64;
                }
                cur = p;
                depth += sm.matched;
                break;
            }
        }
        if cur != NIL {
            self.push_candidate(cur);
        }
        self.hits_tokens += depth as u64;
        self.lookup_tokens += prompt.len() as u64;
        self.prev_epoch_hit_tokens += prev_epoch;
        depth
    }

    /// Insert (pin) the first `len` tokens of `prompt`, reference-counting
    /// the path for an active request.  Returns `(new_tokens, handle)`:
    /// the number of tokens newly materialized and a [`PinHandle`] whose
    /// `len()` is the prefix length now resident + pinned.  May evict
    /// unreferenced tokens; if capacity is exhausted by pinned tokens the
    /// insert truncates (`handle.len() < len`) — the caller must
    /// [`release`](Self::release) the handle when done either way.
    pub fn insert_pinned(&mut self, prompt: &Arc<Vec<u32>>, len: usize) -> (usize, PinHandle) {
        let (_, new_tokens, handle) = self.walk_insert(prompt, len, false);
        (new_tokens, handle)
    }

    /// The per-admission hot path: one combined walk doing what
    /// `lookup(prompt)` followed by `insert_pinned(prompt, prompt.len())`
    /// did in two.  Returns `(hit_tokens, new_tokens, handle)`; hit and
    /// lookup statistics are counted exactly as a plain `lookup` would.
    pub fn lookup_insert_pinned(&mut self, prompt: &Arc<Vec<u32>>) -> (usize, usize, PinHandle) {
        self.walk_insert(prompt, prompt.len(), true)
    }

    fn walk_insert(
        &mut self,
        prompt: &Arc<Vec<u32>>,
        len: usize,
        count_lookup: bool,
    ) -> (usize, usize, PinHandle) {
        self.clock += 1;
        let len = len.min(prompt.len());
        let mut cur = NIL;
        let mut depth = 0usize;
        let mut prev_epoch = 0u64;
        // ---- match phase: walk/split/pin existing segments ----
        while depth < len {
            let sm = match self.match_child(cur, prompt, depth, len) {
                Some(sm) => sm,
                None => break,
            };
            // A divergence or the `len` bound mid-segment splits the node
            // so the pin covers whole segments only.
            let node = if sm.full {
                sm.child
            } else {
                self.split(sm.child, sm.matched)
            };
            if self.nodes[node as usize].epoch < self.epoch {
                prev_epoch += sm.matched as u64;
            }
            self.pin_node(node);
            cur = node;
            depth += sm.matched;
            if !sm.full {
                break;
            }
        }
        let hit = depth;
        // ---- alloc phase: materialize the missing tail as one segment ----
        let mut new_tokens = 0usize;
        if depth < len {
            let want = (len - depth) as u64;
            // Make room.  Pinned paths (including the one just walked) are
            // never candidates, so this cannot evict the matched prefix;
            // when nothing more is evictable the insert truncates below.
            self.evict_to(self.capacity.saturating_sub(want));
            let take = want.min(self.capacity.saturating_sub(self.size)) as usize;
            if take > 0 {
                let id = self.alloc(CNode {
                    parent: cur,
                    tokens: prompt.clone(),
                    start: depth as u32,
                    len: take as u32,
                    n_children: 0,
                    refs: 1,
                    last_use: self.clock,
                    epoch: self.epoch,
                    free: false,
                });
                if cur != NIL {
                    self.nodes[cur as usize].n_children += 1;
                }
                self.children.insert((cur, prompt[depth]), id);
                self.size += take as u64;
                self.pinned += take as u64;
                new_tokens = take;
                depth += take;
                cur = id;
            }
        }
        if count_lookup {
            self.hits_tokens += hit as u64;
            self.lookup_tokens += prompt.len() as u64;
            self.prev_epoch_hit_tokens += prev_epoch;
        }
        let handle = if depth == 0 {
            PinHandle::EMPTY
        } else {
            PinHandle { node: cur, len: depth as u32 }
        };
        (hit, new_tokens, handle)
    }

    /// Drop one reference along the pinned path (request finished or
    /// retracted).  O(path nodes): walks parent links from the handle's
    /// deepest node.  The tokens stay cached until evicted.
    pub fn release(&mut self, handle: PinHandle) {
        let mut cur = handle.node;
        let mut walked = 0u64;
        while cur != NIL {
            let (len, parent, now_unpinned) = {
                let n = &mut self.nodes[cur as usize];
                debug_assert!(n.refs > 0, "release below zero");
                n.refs = n.refs.saturating_sub(1);
                (n.len as u64, n.parent, n.refs == 0)
            };
            if now_unpinned {
                self.pinned = self.pinned.saturating_sub(len);
            }
            walked += len;
            self.push_candidate(cur);
            cur = parent;
        }
        debug_assert_eq!(walked, handle.len as u64, "pin path length drifted");
    }

    /// Pin one node, maintaining the pinned-token count.
    fn pin_node(&mut self, id: Id) {
        let len = self.nodes[id as usize].len as u64;
        let n = &mut self.nodes[id as usize];
        if n.refs == 0 {
            self.pinned += len;
        }
        n.refs += 1;
        n.last_use = self.clock;
    }

    /// Split node `id` at `m` tokens (0 < m < len): a new *prefix* node
    /// splices in above it; `id` keeps the tail so outstanding
    /// [`PinHandle`]s (which always name the deep end of their path)
    /// remain valid.  Refcounts are inherited by both halves — a pin
    /// through the whole segment covers both — so per-token refs and the
    /// pinned total are unchanged.
    fn split(&mut self, id: Id, m: usize) -> Id {
        let (parent, tokens, start, len, refs, last_use, epoch) = {
            let n = &self.nodes[id as usize];
            (n.parent, n.tokens.clone(), n.start, n.len, n.refs, n.last_use, n.epoch)
        };
        debug_assert!(0 < m && m < len as usize, "split out of range");
        let m = m as u32;
        let p = self.alloc(CNode {
            parent,
            tokens: tokens.clone(),
            start,
            len: m,
            n_children: 1,
            refs,
            last_use,
            // Both halves were materialized together: the head keeps the
            // original ingest epoch so cross-epoch attribution is exact.
            epoch,
            free: false,
        });
        self.children.insert((parent, tokens[start as usize]), p);
        {
            let n = &mut self.nodes[id as usize];
            n.parent = p;
            n.start = start + m;
            n.len = len - m;
        }
        self.children.insert((p, tokens[(start + m) as usize]), id);
        p
    }

    /// Push `id` into the eviction heap if it currently looks evictable.
    fn push_candidate(&mut self, id: Id) {
        let n = &self.nodes[id as usize];
        if !n.free && n.refs == 0 && n.n_children == 0 {
            self.evict_heap.push(Reverse((n.last_use, id)));
        }
    }

    /// Evict up to `max` tokens from the LRU unreferenced leaf segment:
    /// the whole segment when it fits, otherwise exactly `max` tail
    /// tokens (segment-tail split eviction) so callers stay token-exact.
    /// Returns tokens evicted (0 = nothing evictable).  Amortized
    /// O(log n): pops lazily-invalidated heap entries; a one-shot full
    /// scan rebuilds the heap if it runs dry while evictable nodes exist.
    fn evict_lru(&mut self, max: u64) -> u64 {
        if max == 0 {
            return 0;
        }
        for _attempt in 0..2 {
            while let Some(Reverse((lu, id))) = self.evict_heap.pop() {
                let valid = {
                    let n = &self.nodes[id as usize];
                    !n.free && n.refs == 0 && n.n_children == 0 && n.last_use == lu
                };
                if !valid {
                    continue; // stale entry (touched / re-pinned / grew children)
                }
                let nlen = self.nodes[id as usize].len as u64;
                if nlen <= max {
                    self.remove_leaf(id);
                    return nlen;
                }
                // Tail split: shed only the newest `max` tokens of the
                // segment; the surviving head keeps its clock and stays
                // an eviction candidate.
                self.nodes[id as usize].len -= max as u32;
                self.size -= max;
                self.evicted_tokens += max;
                self.evict_heap.push(Reverse((lu, id)));
                return max;
            }
            // Heap dry: rebuild from a full scan once.
            let mut found = false;
            for i in 0..self.nodes.len() {
                let n = &self.nodes[i];
                if !n.free && n.refs == 0 && n.n_children == 0 {
                    self.evict_heap.push(Reverse((n.last_use, i as Id)));
                    found = true;
                }
            }
            if !found {
                return 0;
            }
        }
        0
    }

    /// Evict until at most `target` tokens remain (or nothing evictable).
    /// Token-exact: a final partial segment is tail-split rather than
    /// overshooting.  Returns tokens evicted.
    pub fn evict_to(&mut self, target: u64) -> u64 {
        let mut freed = 0;
        while self.size > target {
            let f = self.evict_lru(self.size - target);
            if f == 0 {
                break;
            }
            freed += f;
        }
        freed
    }

    fn remove_leaf(&mut self, id: Id) {
        let (parent, tok0, nlen) = {
            let n = &self.nodes[id as usize];
            debug_assert!(n.refs == 0 && n.n_children == 0 && !n.free);
            (n.parent, n.tokens[n.start as usize], n.len as u64)
        };
        self.children.remove(&(parent, tok0));
        {
            let n = &mut self.nodes[id as usize];
            n.free = true;
            n.tokens = self.empty.clone();
        }
        self.free_list.push(id);
        if parent != NIL {
            self.nodes[parent as usize].n_children -= 1;
            self.push_candidate(parent);
        }
        self.size -= nlen;
        self.evicted_tokens += nlen;
    }

    fn alloc(&mut self, node: CNode) -> Id {
        match self.free_list.pop() {
            Some(id) => {
                self.nodes[id as usize] = node;
                id
            }
            None => {
                self.nodes.push(node);
                (self.nodes.len() - 1) as Id
            }
        }
    }

    /// Overall hit ratio observed so far (hit tokens / looked-up tokens).
    pub fn hit_ratio(&self) -> f64 {
        if self.lookup_tokens == 0 {
            0.0
        } else {
            self.hits_tokens as f64 / self.lookup_tokens as f64
        }
    }

    /// Tokens currently pinned by active requests (refs > 0).  O(1):
    /// maintained incrementally (the memory-pressure path calls this every
    /// step; see EXPERIMENTS.md §Perf).
    pub fn pinned_tokens(&self) -> u64 {
        self.pinned
    }

    /// Live trie nodes (diagnostic: segment granularity means this is
    /// O(#branch points), not O(tokens)).
    pub fn node_count(&self) -> usize {
        self.nodes.len() - self.free_list.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn p(tokens: &[u32]) -> Arc<Vec<u32>> {
        Arc::new(tokens.to_vec())
    }

    #[test]
    fn lookup_miss_then_hit() {
        let mut c = RadixCache::new(100);
        assert_eq!(c.lookup(&[1, 2, 3]), 0);
        let (new, h) = c.insert_pinned(&p(&[1, 2, 3]), 3);
        assert_eq!((new, h.len()), (3, 3));
        assert_eq!(c.lookup(&[1, 2, 3]), 3);
        assert_eq!(c.lookup(&[1, 2, 9]), 2);
        assert_eq!(c.size_tokens(), 3);
    }

    #[test]
    fn shared_prefix_stored_once() {
        let mut c = RadixCache::new(100);
        let _pin = c.insert_pinned(&p(&[1, 2, 3]), 3);
        let (new, h) = c.insert_pinned(&p(&[1, 2, 4]), 3);
        assert_eq!((new, h.len()), (1, 3));
        assert_eq!(c.size_tokens(), 4);
    }

    #[test]
    fn pinned_tokens_not_evicted() {
        let mut c = RadixCache::new(3);
        let _pin = c.insert_pinned(&p(&[1, 2, 3]), 3);
        // Full of pinned tokens: new insert cannot make room.
        let (new, h) = c.insert_pinned(&p(&[9, 8, 7]), 3);
        assert_eq!((new, h.len()), (0, 0));
        assert_eq!(h, PinHandle::EMPTY);
        assert_eq!(c.size_tokens(), 3);
        assert_eq!(c.lookup(&[1, 2, 3]), 3);
    }

    #[test]
    fn release_allows_eviction() {
        let mut c = RadixCache::new(3);
        let (_, h) = c.insert_pinned(&p(&[1, 2, 3]), 3);
        c.release(h);
        let (new, _) = c.insert_pinned(&p(&[9, 8, 7]), 3);
        assert_eq!(new, 3);
        assert_eq!(c.size_tokens(), 3);
        assert_eq!(c.lookup(&[1, 2, 3]), 0); // evicted
    }

    #[test]
    fn lru_evicts_oldest_first() {
        let mut c = RadixCache::new(4);
        let (_, h) = c.insert_pinned(&p(&[1, 1]), 2);
        c.release(h);
        let (_, h) = c.insert_pinned(&p(&[2, 2]), 2);
        c.release(h);
        // Touch [1,1] so [2,2] is LRU.
        c.lookup(&[1, 1]);
        let _pin = c.insert_pinned(&p(&[3, 3]), 2);
        assert_eq!(c.lookup(&[1, 1]), 2);
        assert_eq!(c.lookup(&[2, 2]), 0);
    }

    #[test]
    fn leaf_first_eviction_keeps_prefix_valid() {
        let mut c = RadixCache::new(4);
        let (_, h) = c.insert_pinned(&p(&[1, 2, 3, 4]), 4);
        c.release(h);
        // Evict 2 tokens: must be the segment tail (tokens 4 then 3).
        c.evict_to(2);
        assert_eq!(c.lookup(&[1, 2, 3, 4]), 2);
        assert_eq!(c.size_tokens(), 2);
    }

    #[test]
    fn refcounts_stack() {
        let mut c = RadixCache::new(10);
        let (_, h1) = c.insert_pinned(&p(&[1, 2]), 2);
        let (_, h2) = c.insert_pinned(&p(&[1, 2]), 2); // second request, same prompt
        c.release(h1);
        // Still pinned by the second request.
        assert_eq!(c.evict_to(0), 0);
        c.release(h2);
        assert_eq!(c.evict_to(0), 2);
    }

    #[test]
    fn hit_ratio_accumulates() {
        let mut c = RadixCache::new(100);
        let _pin = c.insert_pinned(&p(&[1, 2, 3, 4]), 4);
        c.lookup(&[1, 2, 3, 4]); // 4 hits / 4 looked up
        c.lookup(&[5, 6, 7, 8]); // 0 hits / 4 looked up
        assert!((c.hit_ratio() - 0.5).abs() < 1e-9);
    }

    #[test]
    fn truncated_insert_reports_partial() {
        let mut c = RadixCache::new(2);
        let (new, h) = c.insert_pinned(&p(&[1, 2, 3, 4]), 4);
        assert_eq!((new, h.len()), (2, 2));
        assert_eq!(c.size_tokens(), 2);
        // The partial path is pinned until released.
        assert_eq!(c.evict_to(0), 0);
        c.release(h);
        assert_eq!(c.evict_to(0), 2);
    }

    #[test]
    fn combined_pass_matches_separate_lookup_insert() {
        let base = p(&(0..100u32).collect::<Vec<_>>());
        let fork = p(&(0..60u32).chain(900..940).collect::<Vec<_>>());
        let mut a = RadixCache::new(1000);
        let mut b = RadixCache::new(1000);
        for q in [&base, &fork, &base] {
            let hit_a = a.lookup(q);
            let (new_a, ha) = a.insert_pinned(q, q.len());
            let (hit_b, new_b, hb) = b.lookup_insert_pinned(q);
            assert_eq!((hit_a, new_a, ha.len()), (hit_b, new_b, hb.len()));
            a.release(ha);
            b.release(hb);
        }
        assert_eq!(a.hits_tokens, b.hits_tokens);
        assert_eq!(a.lookup_tokens, b.lookup_tokens);
        assert_eq!(a.size_tokens(), b.size_tokens());
    }

    #[test]
    fn split_on_partial_match_keeps_tail_lru() {
        // One 6-token segment; a partial lookup must freshen only the
        // touched head, leaving the tail the LRU eviction victim.
        let mut c = RadixCache::new(100);
        let (_, h) = c.insert_pinned(&p(&[1, 2, 3, 4, 5, 6]), 6);
        c.release(h);
        let (_, h) = c.insert_pinned(&p(&[7, 8]), 2);
        c.release(h); // newer than the [1..6] segment as a whole
        assert_eq!(c.lookup(&[1, 2, 3, 9]), 3); // splits [1,2,3|4,5,6], bumps head
        // Evict 3: the stale tail [4,5,6] must go before the newer [7,8].
        assert_eq!(c.evict_to(c.size_tokens() - 3), 3);
        assert_eq!(c.lookup(&[1, 2, 3, 4]), 3);
        assert_eq!(c.lookup(&[7, 8]), 2);
    }

    #[test]
    fn split_on_partial_evict_rematerializes_tail_only() {
        let mut c = RadixCache::new(100);
        let q = p(&[1, 2, 3, 4, 5, 6, 7, 8]);
        let (_, h) = c.insert_pinned(&q, 8);
        c.release(h);
        assert_eq!(c.evict_to(5), 3); // token-exact tail split
        assert_eq!(c.evicted_tokens, 3);
        assert_eq!(c.lookup(&[1, 2, 3, 4, 5, 6, 7, 8]), 5);
        // Re-insert: only the evicted tail is materialized again.
        let (new, h) = c.insert_pinned(&q, 8);
        assert_eq!((new, h.len()), (3, 8));
        assert_eq!(c.size_tokens(), 8);
        c.release(h);
    }

    #[test]
    fn pin_ending_mid_segment_splits_at_the_boundary() {
        let mut c = RadixCache::new(100);
        let q = p(&[1, 2, 3, 4]);
        let (_, h_all) = c.insert_pinned(&q, 4);
        c.release(h_all);
        let (new, h_head) = c.insert_pinned(&q, 2); // pin only [1,2]
        assert_eq!((new, h_head.len()), (0, 2));
        assert_eq!(c.pinned_tokens(), 2);
        // Only the unpinned tail [3,4] is evictable.
        assert_eq!(c.evict_to(0), 2);
        assert_eq!(c.lookup(&[1, 2, 3, 4]), 2);
        c.release(h_head);
        assert_eq!(c.evict_to(0), 2);
        assert_eq!(c.size_tokens(), 0);
    }

    #[test]
    fn handle_survives_later_splits_of_its_path() {
        let mut c = RadixCache::new(100);
        let (_, h_a) = c.insert_pinned(&p(&[1, 2, 3, 4]), 4);
        // Diverging insert splits A's segment at depth 2 while A is pinned.
        let (_, h_b) = c.insert_pinned(&p(&[1, 2, 9]), 3);
        assert_eq!(c.pinned_tokens(), 5);
        c.release(h_a);
        assert_eq!(c.pinned_tokens(), 3); // [1,2] + [9] still pinned by B
        c.release(h_b);
        assert_eq!(c.pinned_tokens(), 0);
        assert_eq!(c.evict_to(0), 5);
    }

    #[test]
    fn path_compression_uses_few_nodes() {
        let mut c = RadixCache::new(1_000_000);
        // 16 prompts sharing a 4000-token stem: 1 stem node + 16 tails.
        let stem: Vec<u32> = (0..4000).collect();
        for i in 0..16u32 {
            let mut q = stem.clone();
            q.extend((0..8).map(|k| 100_000 + i * 10 + k));
            let (_, h) = c.insert_pinned(&Arc::new(q), 4008);
            c.release(h);
        }
        assert!(c.node_count() <= 2 * 16 + 2, "nodes {}", c.node_count());
        assert_eq!(c.size_tokens(), 4000 + 16 * 8);
    }

    #[test]
    fn dfs_order_needs_less_capacity_than_random() {
        // The Fig. 9 mechanism in miniature: 20 groups x 6 requests with a
        // 30-token shared stem; cache fits ~3 groups.  DFS order re-uses
        // each stem while resident; interleaved order thrashes.
        let groups = 20usize;
        let per = 6usize;
        let stem = 30usize;
        let prompt = |g: usize, i: usize| -> Arc<Vec<u32>> {
            let mut q: Vec<u32> = (0..stem).map(|k| (g * 1000 + k) as u32).collect();
            q.push((900_000 + g * 100 + i) as u32);
            Arc::new(q)
        };
        let run = |order: Vec<(usize, usize)>| -> f64 {
            let mut c = RadixCache::new(3 * (stem as u64 + per as u64));
            for (g, i) in order {
                let q = prompt(g, i);
                let (_, _, h) = c.lookup_insert_pinned(&q);
                c.release(h);
            }
            c.hit_ratio()
        };
        let dfs: Vec<(usize, usize)> =
            (0..groups).flat_map(|g| (0..per).map(move |i| (g, i))).collect();
        let interleaved: Vec<(usize, usize)> =
            (0..per).flat_map(|i| (0..groups).map(move |g| (g, i))).collect();
        let r_dfs = run(dfs);
        let r_int = run(interleaved);
        assert!(r_dfs > 0.5, "dfs hit ratio {r_dfs}");
        assert!(r_dfs > r_int * 2.0, "dfs={r_dfs} interleaved={r_int}");
    }

    #[test]
    fn epoch_attribution_counts_only_cross_epoch_hits() {
        let mut c = RadixCache::new(100);
        let (_, h) = c.insert_pinned(&p(&[1, 2, 3, 4]), 4);
        c.release(h);
        // Same-epoch hit: no cross-epoch attribution.
        assert_eq!(c.lookup(&[1, 2, 3, 4]), 4);
        assert_eq!(c.prev_epoch_hit_tokens, 0);
        c.bump_epoch();
        // Cross-epoch hit: all 4 matched tokens predate the boundary.
        assert_eq!(c.lookup(&[1, 2, 3, 4]), 4);
        assert_eq!(c.prev_epoch_hit_tokens, 4);
        // Content inserted after the bump is same-epoch again.
        let (_, h) = c.insert_pinned(&p(&[9, 9, 9]), 3);
        c.release(h);
        assert_eq!(c.lookup(&[9, 9, 9]), 3);
        assert_eq!(c.prev_epoch_hit_tokens, 4);
        // Un-counted walks (insert_pinned) leave the stat untouched.
        let (_, h) = c.insert_pinned(&p(&[1, 2, 3, 4]), 4);
        c.release(h);
        assert_eq!(c.prev_epoch_hit_tokens, 4);
    }

    #[test]
    fn epoch_split_head_keeps_original_stamp() {
        let mut c = RadixCache::new(100);
        let (_, h) = c.insert_pinned(&p(&[1, 2, 3, 4, 5, 6]), 6);
        c.release(h);
        c.bump_epoch();
        // Diverging walk splits the old segment at depth 3; the matched
        // head was materialized pre-boundary, so 3 tokens accrue.
        let (hit, _, h) = c.lookup_insert_pinned(&p(&[1, 2, 3, 9]));
        assert_eq!(hit, 3);
        assert_eq!(c.prev_epoch_hit_tokens, 3);
        c.release(h);
        // Walking old head + new tail again counts only the old head.
        let (hit, _, h) = c.lookup_insert_pinned(&p(&[1, 2, 3, 9]));
        assert_eq!(hit, 4);
        assert_eq!(c.prev_epoch_hit_tokens, 6);
        c.release(h);
    }
}
