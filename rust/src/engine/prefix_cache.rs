//! Runtime radix prefix cache (§2.2 "prefix sharing", §A.2 "runtime prefix
//! tree"): a token-granular trie over *computed* prompt prefixes, with
//! reference counting for active requests and leaf-first LRU eviction.
//!
//! Semantics follow SGLang's RadixAttention: all prompt KV lives in the
//! trie (a shared prefix is stored once); each resident trie token charges
//! one KV slot; eviction removes unreferenced leaf tokens in LRU order.
//! Decode-phase tokens are *not* cached here — they are private to the
//! request and accounted by the engine.

use std::cmp::Reverse;
use std::collections::{BinaryHeap, HashMap};

type Id = u32;
const NIL: Id = u32::MAX;

#[derive(Clone, Debug)]
struct CNode {
    parent: Id,
    token: u32,
    n_children: u32,
    refs: u32,
    last_use: u64,
    /// Free-list linkage when the slot is recycled.
    free: bool,
}

/// Token-granular radix cache with LRU leaf eviction.
#[derive(Debug)]
pub struct RadixCache {
    nodes: Vec<CNode>,
    children: HashMap<(Id, u32), Id>,
    free_list: Vec<Id>,
    /// Lazy min-heap of eviction candidates `(last_use, id)`.  Entries are
    /// validated on pop (a node may have been touched, re-pinned or grown
    /// children since being pushed); a full-scan fallback guards against
    /// leaked candidates.
    evict_heap: BinaryHeap<Reverse<(u64, Id)>>,
    /// Resident tokens (= live nodes).
    size: u64,
    /// Tokens currently pinned (refs > 0); maintained incrementally.
    pinned: u64,
    /// Capacity in tokens; inserts beyond it force eviction, and when
    /// nothing is evictable the insert is truncated.
    capacity: u64,
    clock: u64,
    // ---- statistics ----
    pub hits_tokens: u64,
    pub lookup_tokens: u64,
    pub evicted_tokens: u64,
}

impl RadixCache {
    pub fn new(capacity: u64) -> Self {
        RadixCache {
            nodes: Vec::new(),
            children: HashMap::new(),
            free_list: Vec::new(),
            evict_heap: BinaryHeap::new(),
            size: 0,
            pinned: 0,
            capacity,
            clock: 0,
            hits_tokens: 0,
            lookup_tokens: 0,
            evicted_tokens: 0,
        }
    }

    pub fn size_tokens(&self) -> u64 {
        self.size
    }

    pub fn capacity_tokens(&self) -> u64 {
        self.capacity
    }

    /// Longest cached prefix of `prompt`, in tokens; bumps LRU clocks along
    /// the path and counts hit statistics.
    pub fn lookup(&mut self, prompt: &[u32]) -> usize {
        self.clock += 1;
        let mut cur = NIL;
        let mut depth = 0usize;
        for &t in prompt {
            match self.children.get(&(cur, t)).copied() {
                Some(next) => {
                    self.nodes[next as usize].last_use = self.clock;
                    cur = next;
                    depth += 1;
                }
                None => break,
            }
        }
        if cur != NIL {
            self.push_candidate(cur);
        }
        self.hits_tokens += depth as u64;
        self.lookup_tokens += prompt.len() as u64;
        depth
    }

    /// Insert (pin) the first `len` tokens of `prompt`, reference-counting
    /// the path for an active request.  Returns `(new_tokens, pinned_len)`:
    /// the number of tokens newly materialized and the prefix length that
    /// is now resident + pinned.  May evict unreferenced tokens; if
    /// capacity is exhausted by pinned tokens the insert truncates and only
    /// the reached prefix is pinned (`pinned_len < len`) — the caller must
    /// `release(prompt, pinned_len)` with the same length when done.
    pub fn insert_pinned(&mut self, prompt: &[u32], len: usize) -> (usize, usize) {
        self.clock += 1;
        let len = len.min(prompt.len());
        let mut cur = NIL;
        let mut new_tokens = 0usize;
        let mut depth = 0usize;
        for &t in prompt.iter().take(len) {
            let next = match self.children.get(&(cur, t)).copied() {
                Some(n) => n,
                None => {
                    if self.size >= self.capacity && !self.evict_one() {
                        break; // truncate: pin what we reached
                    }
                    let id = self.alloc(cur, t);
                    self.children.insert((cur, t), id);
                    self.size += 1;
                    new_tokens += 1;
                    id
                }
            };
            // Pin incrementally so the in-progress path can never be
            // chosen as an eviction victim by the `evict_one` above.
            if self.nodes[next as usize].refs == 0 {
                self.pinned += 1;
            }
            self.nodes[next as usize].refs += 1;
            self.nodes[next as usize].last_use = self.clock;
            cur = next;
            depth += 1;
        }
        (new_tokens, depth)
    }

    /// Drop one reference along the first `len` tokens of `prompt`
    /// (request finished or retracted).  The tokens stay cached until
    /// evicted.
    pub fn release(&mut self, prompt: &[u32], len: usize) {
        let mut cur = NIL;
        for &t in prompt.iter().take(len) {
            match self.children.get(&(cur, t)).copied() {
                Some(next) => cur = next,
                None => break,
            }
        }
        self.unref_path(cur);
    }

    fn unref_path(&mut self, mut cur: Id) {
        while cur != NIL {
            let n = &mut self.nodes[cur as usize];
            debug_assert!(n.refs > 0, "unref below zero");
            n.refs = n.refs.saturating_sub(1);
            if n.refs == 0 {
                self.pinned = self.pinned.saturating_sub(1);
            }
            let n = &self.nodes[cur as usize];
            let parent = n.parent;
            self.push_candidate(cur);
            cur = parent;
        }
    }

    /// Push `id` into the eviction heap if it currently looks evictable.
    fn push_candidate(&mut self, id: Id) {
        let n = &self.nodes[id as usize];
        if !n.free && n.refs == 0 && n.n_children == 0 {
            self.evict_heap.push(Reverse((n.last_use, id)));
        }
    }

    /// Evict the LRU unreferenced leaf token.  Returns false if nothing is
    /// evictable.  Amortized O(log n): pops lazily-invalidated heap entries;
    /// a one-shot full scan rebuilds the heap if it runs dry while
    /// evictable nodes still exist.
    fn evict_one(&mut self) -> bool {
        for _attempt in 0..2 {
            while let Some(Reverse((lu, id))) = self.evict_heap.pop() {
                let n = &self.nodes[id as usize];
                if !n.free && n.refs == 0 && n.n_children == 0 && n.last_use == lu {
                    self.remove_leaf(id);
                    return true;
                }
                // Stale entry (touched / re-pinned / grew children): skip.
            }
            // Heap dry: rebuild from a full scan once.
            let mut found = false;
            for i in 0..self.nodes.len() {
                let n = &self.nodes[i];
                if !n.free && n.refs == 0 && n.n_children == 0 {
                    self.evict_heap.push(Reverse((n.last_use, i as Id)));
                    found = true;
                }
            }
            if !found {
                return false;
            }
        }
        false
    }

    /// Evict until at most `target` tokens remain (or nothing evictable).
    /// Returns tokens evicted.
    pub fn evict_to(&mut self, target: u64) -> u64 {
        let mut freed = 0;
        while self.size > target {
            if !self.evict_one() {
                break;
            }
            freed += 1;
        }
        freed
    }

    fn remove_leaf(&mut self, id: Id) {
        let (parent, token) = {
            let n = &self.nodes[id as usize];
            debug_assert!(n.refs == 0 && n.n_children == 0 && !n.free);
            (n.parent, n.token)
        };
        self.children.remove(&(parent, token));
        self.nodes[id as usize].free = true;
        self.free_list.push(id);
        if parent != NIL {
            self.nodes[parent as usize].n_children -= 1;
            self.push_candidate(parent);
        }
        self.size -= 1;
        self.evicted_tokens += 1;
    }

    fn alloc(&mut self, parent: Id, token: u32) -> Id {
        if parent != NIL {
            self.nodes[parent as usize].n_children += 1;
        }
        let node = CNode {
            parent,
            token,
            n_children: 0,
            refs: 0,
            last_use: self.clock,
            free: false,
        };
        match self.free_list.pop() {
            Some(id) => {
                self.nodes[id as usize] = node;
                id
            }
            None => {
                self.nodes.push(node);
                (self.nodes.len() - 1) as Id
            }
        }
    }

    /// Overall hit ratio observed so far (hit tokens / looked-up tokens).
    pub fn hit_ratio(&self) -> f64 {
        if self.lookup_tokens == 0 {
            0.0
        } else {
            self.hits_tokens as f64 / self.lookup_tokens as f64
        }
    }

    /// Tokens currently pinned by active requests (refs > 0).  O(1):
    /// maintained incrementally (the memory-pressure path calls this every
    /// step; see EXPERIMENTS.md §Perf).
    pub fn pinned_tokens(&self) -> u64 {
        self.pinned
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lookup_miss_then_hit() {
        let mut c = RadixCache::new(100);
        assert_eq!(c.lookup(&[1, 2, 3]), 0);
        assert_eq!(c.insert_pinned(&[1, 2, 3], 3), (3, 3));
        assert_eq!(c.lookup(&[1, 2, 3]), 3);
        assert_eq!(c.lookup(&[1, 2, 9]), 2);
        assert_eq!(c.size_tokens(), 3);
    }

    #[test]
    fn shared_prefix_stored_once() {
        let mut c = RadixCache::new(100);
        c.insert_pinned(&[1, 2, 3], 3);
        let (new, pinned) = c.insert_pinned(&[1, 2, 4], 3);
        assert_eq!((new, pinned), (1, 3));
        assert_eq!(c.size_tokens(), 4);
    }

    #[test]
    fn pinned_tokens_not_evicted() {
        let mut c = RadixCache::new(3);
        c.insert_pinned(&[1, 2, 3], 3);
        // Full of pinned tokens: new insert cannot make room.
        let (new, pinned) = c.insert_pinned(&[9, 8, 7], 3);
        assert_eq!((new, pinned), (0, 0));
        assert_eq!(c.size_tokens(), 3);
        assert_eq!(c.lookup(&[1, 2, 3]), 3);
    }

    #[test]
    fn release_allows_eviction() {
        let mut c = RadixCache::new(3);
        c.insert_pinned(&[1, 2, 3], 3);
        c.release(&[1, 2, 3], 3);
        let (new, _) = c.insert_pinned(&[9, 8, 7], 3);
        assert_eq!(new, 3);
        assert_eq!(c.size_tokens(), 3);
        assert_eq!(c.lookup(&[1, 2, 3]), 0); // evicted
    }

    #[test]
    fn lru_evicts_oldest_first() {
        let mut c = RadixCache::new(4);
        c.insert_pinned(&[1, 1], 2);
        c.release(&[1, 1], 2);
        c.insert_pinned(&[2, 2], 2);
        c.release(&[2, 2], 2);
        // Touch [1,1] so [2,2] is LRU.
        c.lookup(&[1, 1]);
        c.insert_pinned(&[3, 3], 2);
        assert_eq!(c.lookup(&[1, 1]), 2);
        assert_eq!(c.lookup(&[2, 2]), 0);
    }

    #[test]
    fn leaf_first_eviction_keeps_prefix_valid() {
        let mut c = RadixCache::new(4);
        c.insert_pinned(&[1, 2, 3, 4], 4);
        c.release(&[1, 2, 3, 4], 4);
        // Evict 2 tokens: must be [4] then [3] (leaves first).
        c.evict_to(2);
        assert_eq!(c.lookup(&[1, 2, 3, 4]), 2);
        assert_eq!(c.size_tokens(), 2);
    }

    #[test]
    fn refcounts_stack() {
        let mut c = RadixCache::new(10);
        c.insert_pinned(&[1, 2], 2);
        c.insert_pinned(&[1, 2], 2); // second request, same prompt
        c.release(&[1, 2], 2);
        // Still pinned by the second request.
        assert_eq!(c.evict_to(0), 0);
        c.release(&[1, 2], 2);
        assert_eq!(c.evict_to(0), 2);
    }

    #[test]
    fn hit_ratio_accumulates() {
        let mut c = RadixCache::new(100);
        c.insert_pinned(&[1, 2, 3, 4], 4);
        c.lookup(&[1, 2, 3, 4]); // 4 hits / 4 looked up
        c.lookup(&[5, 6, 7, 8]); // 0 hits / 4 looked up
        assert!((c.hit_ratio() - 0.5).abs() < 1e-9);
    }

    #[test]
    fn truncated_insert_reports_partial() {
        let mut c = RadixCache::new(2);
        let (new, pinned) = c.insert_pinned(&[1, 2, 3, 4], 4);
        assert_eq!((new, pinned), (2, 2));
        assert_eq!(c.size_tokens(), 2);
        // The partial path is pinned until released.
        assert_eq!(c.evict_to(0), 0);
        c.release(&[1, 2, 3, 4], pinned);
        assert_eq!(c.evict_to(0), 2);
    }

    #[test]
    fn dfs_order_needs_less_capacity_than_random() {
        // The Fig. 9 mechanism in miniature: 20 groups x 6 requests with a
        // 30-token shared stem; cache fits ~3 groups.  DFS order re-uses
        // each stem while resident; interleaved order thrashes.
        let groups = 20usize;
        let per = 6usize;
        let stem = 30usize;
        let prompt = |g: usize, i: usize| -> Vec<u32> {
            let mut p: Vec<u32> = (0..stem).map(|k| (g * 1000 + k) as u32).collect();
            p.push((900_000 + g * 100 + i) as u32);
            p
        };
        let run = |order: Vec<(usize, usize)>| -> f64 {
            let mut c = RadixCache::new(3 * (stem as u64 + per as u64));
            for (g, i) in order {
                let p = prompt(g, i);
                let hit = c.lookup(&p);
                c.insert_pinned(&p, p.len());
                let _ = hit;
                c.release(&p, p.len());
            }
            c.hit_ratio()
        };
        let dfs: Vec<(usize, usize)> =
            (0..groups).flat_map(|g| (0..per).map(move |i| (g, i))).collect();
        let interleaved: Vec<(usize, usize)> =
            (0..per).flat_map(|i| (0..groups).map(move |g| (g, i))).collect();
        let r_dfs = run(dfs);
        let r_int = run(interleaved);
        assert!(r_dfs > 0.5, "dfs hit ratio {r_dfs}");
        assert!(r_dfs > r_int * 2.0, "dfs={r_dfs} interleaved={r_int}");
    }
}
